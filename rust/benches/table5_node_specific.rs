//! Regenerates paper Table V: node-specific component variants
//! (PosFullEmb vs PosHashEmb Inter/Intra × h∈{1,2}).

use poshashemb::bench_harness::{print_table, rows_from_outcomes, Harness};

fn main() -> anyhow::Result<()> {
    let harness = Harness::from_env()?;
    let ds = std::env::var("POSHASH_DATASET").ok();
    let exps = harness.group("t5", ds.as_deref());
    if exps.is_empty() {
        eprintln!("no t5 artifacts found — run `make artifacts` (GRID=full)");
        return Ok(());
    }
    let outcomes = harness.run_all(&exps)?;
    let rows = rows_from_outcomes(&exps, &outcomes, |e| e.method.name());
    print_table(
        "Table V — node-specific component variants (accuracy / ROC-AUC, mean ± std)",
        &rows,
    );
    println!("\npaper shape: hashed node-specific variants ≈ PosFullEmb at 88–97% savings — \
              the full node-specific capacity is unnecessary.");
    Ok(())
}
