//! Embedding-compose microbench: the scalar reference oracle vs the
//! blocked rayon `ComposeEngine` (full-matrix and minibatch paths),
//! across every `EmbeddingMethod` variant.
//!
//! Default scale is the acceptance configuration n = 100k, d = 64; set
//! `BENCH_QUICK=1` (CI smoke) for a reduced n with minimal iterations.
//! The summary line reports the parallel-vs-reference speedup — expected
//! ≥ 4x on a multi-core host for the table-based methods.

use poshashemb::bench_harness::{bench_compose, ComposeBenchRecord};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::util::bench::{quick, section};

fn main() {
    let n: usize = if quick() { 20_000 } else { 100_000 };
    let d = 64;
    let batch = 4096;
    let k = (n as f64).powf(0.25).ceil() as usize; // paper Eq. 8, alpha = 1/4
    let c = ((n as f64 / k as f64).sqrt()).ceil() as usize;
    let b = c * k;

    eprintln!("building graph + 3-level hierarchy (n={n}, k={k})...");
    let (g, _) = planted_partition(&PlantedPartitionConfig {
        n,
        communities: 64,
        intra_degree: 10.0,
        inter_degree: 2.0,
        seed: 5,
        ..Default::default()
    });
    let hier = Hierarchy::build(&g, &HierarchyConfig::new(k, 3));

    let methods: Vec<(&str, EmbeddingMethod)> = vec![
        ("full", EmbeddingMethod::Full),
        ("hashtrick", EmbeddingMethod::HashTrick { buckets: b }),
        ("bloom", EmbeddingMethod::Bloom { buckets: b, h: 2 }),
        ("hashemb", EmbeddingMethod::HashEmb { buckets: b, h: 2 }),
        ("dhe", EmbeddingMethod::Dhe { encoding_dim: 32, hidden: 32, layers: 1 }),
        ("posemb1", EmbeddingMethod::PosEmb { levels: 1 }),
        ("posemb3", EmbeddingMethod::PosEmb { levels: 3 }),
        ("randompart", EmbeddingMethod::RandomPart { parts: k }),
        ("posfullemb3", EmbeddingMethod::PosFullEmb { levels: 3 }),
        ("inter_h2", EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: b, h: 2 }),
        ("intra_h2", EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 }),
    ];

    let mut all: Vec<ComposeBenchRecord> = Vec::new();
    for (tag, method) in &methods {
        section(&format!("compose {tag} (n={n}, d={d})"));
        let hr = method.needs_hierarchy().then_some(&hier);
        let plan = EmbeddingPlan::build(n, d, method, hr, 0);
        let records = bench_compose(&plan, batch);
        for r in &records {
            println!("{}", r.row());
        }
        all.extend(records);
    }

    section("summary: parallel compose_all speedup vs reference");
    for r in all.iter().filter(|r| r.path == "parallel") {
        let s = r.speedup_vs_reference.unwrap_or(0.0);
        let verdict = if s >= 4.0 { "PASS (>= 4x)" } else { "below 4x" };
        println!("{:<26} {s:>6.2}x  {verdict}", r.method);
    }
    let threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    println!("(host parallelism: {threads} threads; the 4x target assumes a multi-core host)");
}
