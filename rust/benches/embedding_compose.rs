//! L3 perf microbench: embedding-plan construction and the pure-Rust
//! reference composition (host-side baseline the HLO path is compared
//! against in EXPERIMENTS.md §Perf).

use poshashemb::embedding::{compose_embeddings, init_params, EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::util::bench::{bench, black_box, section};

fn main() {
    let n = 50_000;
    let d = 64;
    let (g, _) = planted_partition(&PlantedPartitionConfig {
        n,
        communities: 32,
        intra_degree: 12.0,
        inter_degree: 2.0,
        seed: 5,
            ..Default::default()
    });
    let hier = Hierarchy::build(&g, &HierarchyConfig::new(15, 3));

    section("plan construction (n=50k, d=64)");
    for (name, method) in [
        ("full", EmbeddingMethod::Full),
        ("hashemb", EmbeddingMethod::HashEmb { buckets: 2048, h: 2 }),
        ("intra_h2", EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 58, h: 2 }),
    ] {
        let r = bench(&format!("plan {name}"), || {
            black_box(EmbeddingPlan::build(n, d, &method, Some(&hier), 0))
        });
        println!("{}", r.report(Some((n as u64, "nodes"))));
    }

    section("reference composition (n=50k, d=64)");
    for (name, method) in [
        ("full", EmbeddingMethod::Full),
        ("posemb3", EmbeddingMethod::PosEmb { levels: 3 }),
        ("intra_h2", EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 58, h: 2 }),
    ] {
        let plan = EmbeddingPlan::build(n, d, &method, Some(&hier), 0);
        let params = init_params(&plan, 1);
        let r = bench(&format!("compose {name}"), || {
            black_box(compose_embeddings(&plan, &params))
        });
        println!("{}", r.report(Some(((n * d) as u64, "elements"))));
    }
}
