//! L3 perf microbench: host-side neighbor-sampled minibatch training on
//! `ComposeEngine::compose_batch` — the large-graph training loop that
//! never materializes `n × d`. Reports seed nodes/s and batches/s per
//! configuration (fanout sweep, a 2-layer deep-SAGE config, and the
//! full-batch-equivalence oracle), sharing
//! `bench_harness::bench_minibatch` with the `poshashemb
//! train-minibatch` CLI subcommand.

use poshashemb::bench_harness::bench_minibatch;
use poshashemb::config::default_k;
use poshashemb::coordinator::MinibatchOptions;
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanout, Fanouts, SamplerConfig};
use poshashemb::util::bench::{quick, section};

fn main() {
    let sp = spec("synth-arxiv").expect("registered dataset");
    let ds = Dataset::generate(&sp);
    let k = default_k(sp.n);
    let method = EmbeddingMethod::PosHashEmbIntra {
        levels: 3,
        compression: ((sp.n as f64 / k as f64).sqrt()).ceil() as usize,
        h: 2,
    };
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, 3));
    let plan = EmbeddingPlan::build(sp.n, sp.d, &method, Some(&hier), 0);
    let epochs = if quick() { 2 } else { 8 };
    let opts = MinibatchOptions { epochs, ..Default::default() };

    section(&format!(
        "minibatch training on synth-arxiv n={} d={} ({}, {} epochs)",
        sp.n,
        sp.d,
        method.name(),
        epochs
    ));
    let configs = [
        SamplerConfig { batch_size: 256, fanouts: Fanout::Max(5).into(), shuffle: true },
        SamplerConfig { batch_size: 512, fanouts: Fanout::Max(10).into(), shuffle: true },
        SamplerConfig {
            batch_size: 512,
            fanouts: Fanouts::parse("10,5").expect("static fanouts"),
            shuffle: true,
        },
        SamplerConfig { batch_size: 1024, fanouts: Fanout::All.into(), shuffle: true },
        SamplerConfig::oracle(ds.splits.train.len(), 1),
    ];
    for cfg in &configs {
        let rec = bench_minibatch("synth-arxiv", &ds, &plan, cfg, &opts).expect("bench run");
        println!("{}", rec.row());
        assert!(
            rec.peak_compose_rows <= sp.n,
            "compose block exceeded the node count: {}",
            rec.peak_compose_rows
        );
    }

    // serial oracle vs pipelined engine at the default config: the
    // acceptance comparison (same losses bit for bit, different wall
    // clock). The serial record is what pre-pipeline builds reported.
    // The 2-layer head gets the same A/B to keep the deep path honest.
    section("pipelined engine vs serial oracle (bit-identical losses)");
    let shallow = SamplerConfig { batch_size: 512, fanouts: Fanout::Max(10).into(), shuffle: true };
    let deep = SamplerConfig {
        batch_size: 512,
        fanouts: Fanouts::parse("10,5").expect("static fanouts"),
        shuffle: true,
    };
    for cfg in [&shallow, &deep] {
        let serial_opts =
            MinibatchOptions { epochs, parallel: false, prefetch: 0, ..Default::default() };
        let serial =
            bench_minibatch("synth-arxiv", &ds, &plan, cfg, &serial_opts).expect("serial run");
        let pipelined = bench_minibatch("synth-arxiv", &ds, &plan, cfg, &opts).expect("piped run");
        assert_eq!(
            (serial.first_loss.to_bits(), serial.final_loss.to_bits()),
            (pipelined.first_loss.to_bits(), pipelined.final_loss.to_bits()),
            "pipelined engine drifted from the serial oracle (L={})",
            cfg.fanouts.layers()
        );
        println!("{}", serial.row());
        println!("{}", pipelined.row());
        println!(
            "pipelined speedup (L={}): {:.2}x nodes/s over serial ({} threads)",
            cfg.fanouts.layers(),
            pipelined.nodes_per_sec / serial.nodes_per_sec.max(1e-9),
            pipelined.threads
        );
    }
}
