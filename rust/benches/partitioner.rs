//! L3 perf microbench: the multilevel partitioner (coarsening dominates)
//! on SBM and R-MAT graphs. Throughput target (EXPERIMENTS.md §Perf):
//! ≥ 1M edges/s end-to-end for k-way partitioning.

use poshashemb::graph::{planted_partition, rmat, PlantedPartitionConfig, RmatConfig};
use poshashemb::partition::{
    heavy_edge_matching, partition, Hierarchy, HierarchyConfig, PartitionConfig,
};
use poshashemb::util::bench::{bench, black_box, section};
use poshashemb::util::rng::Rng;

fn main() {
    let (sbm, _) = planted_partition(&PlantedPartitionConfig {
        n: 50_000,
        communities: 32,
        intra_degree: 12.0,
        inter_degree: 2.0,
        seed: 3,
        ..Default::default()
    });
    let edges = sbm.num_edges() as u64;
    section(&format!("partitioner on SBM n=50k m={edges}"));

    let r = bench("heavy_edge_matching", || {
        let mut rng = Rng::seed_from_u64(1);
        black_box(heavy_edge_matching(&sbm, &mut rng))
    });
    println!("{}", r.report(Some((2 * edges, "edge-visits"))));

    for k in [8usize, 32] {
        let r = bench(&format!("partition k={k}"), || {
            black_box(partition(&sbm, &PartitionConfig::with_k(k)))
        });
        println!("{}", r.report(Some((edges, "edges"))));
    }

    let r = bench("hierarchy L=3 k=16", || {
        black_box(Hierarchy::build(&sbm, &HierarchyConfig::new(16, 3)))
    });
    println!("{}", r.report(Some((edges, "edges"))));

    let rg = rmat(&RmatConfig { scale: 15, edge_factor: 8, ..Default::default() });
    let redges = rg.num_edges() as u64;
    section(&format!("partitioner on R-MAT n=32k m={redges} (heavy tail)"));
    let r = bench("partition k=16", || {
        black_box(partition(&rg, &PartitionConfig::with_k(16)))
    });
    println!("{}", r.report(Some((redges, "edges"))));
}
