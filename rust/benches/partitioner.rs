//! L3 perf microbench: the multilevel partitioner on SBM and R-MAT
//! graphs — scalar oracle vs the parallel matching / CSR-native
//! contraction / sibling-parallel hierarchy pipeline. Throughput target
//! (EXPERIMENTS.md §Perf, ROADMAP "Partitioner perf"): ≥ 1M edges/s
//! end-to-end for k = 32 partitioning of the SBM n = 50k graph.

use poshashemb::bench_harness::bench_partition;
use poshashemb::graph::{planted_partition, rmat, PlantedPartitionConfig, RmatConfig};
use poshashemb::util::bench::section;

fn main() {
    let (sbm, _) = planted_partition(&PlantedPartitionConfig {
        n: 50_000,
        communities: 32,
        intra_degree: 12.0,
        inter_degree: 2.0,
        seed: 3,
        ..Default::default()
    });
    section(&format!("partitioner on SBM n=50k m={} (k=32, L=3)", sbm.num_edges()));
    for r in bench_partition(&sbm, 32, 3, 1) {
        println!("{}", r.row());
    }

    let rg = rmat(&RmatConfig { scale: 15, edge_factor: 8, ..Default::default() });
    section(&format!("partitioner on R-MAT n=32k m={} (heavy tail, k=16)", rg.num_edges()));
    for r in bench_partition(&rg, 16, 2, 1) {
        println!("{}", r.row());
    }
}
