//! Regenerates paper Figure 4: quality as a function of the embedding
//! memory budget (1/34…1/2 of full size) for PosHashEmb vs the hashing
//! baselines (HashTrick, Bloom, HashEmb, DHE) and the FullEmb reference.

use poshashemb::bench_harness::Harness;
use poshashemb::metrics::mean_std;
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let harness = Harness::from_env()?;
    let ds = std::env::var("POSHASH_DATASET").ok();
    let exps = harness.group("f4", ds.as_deref());
    if exps.is_empty() {
        eprintln!("no f4 artifacts found — run `make artifacts` (GRID=full)");
        return Ok(());
    }
    let outcomes = harness.run_all(&exps)?;
    // (dataset/model) -> method -> [(budget denom, params, mean, std)]
    let mut plots: BTreeMap<String, BTreeMap<String, Vec<(u32, usize, f64, f64)>>> =
        BTreeMap::new();
    for e in &exps {
        // name: <ds>_<model>_f4_b<den>_<method>
        let tail = e.name.split("_f4_b").nth(1).unwrap_or("");
        let mut it = tail.splitn(2, '_');
        let den: u32 = it.next().unwrap_or("0").parse().unwrap_or(0);
        let method = it.next().unwrap_or("?").to_string();
        if let Some(outs) = outcomes.get(&e.name) {
            let vals: Vec<f64> = outs.iter().map(|o| o.test_metric).collect();
            let (mean, std) = mean_std(&vals);
            let params = outs.first().map_or(0, |o| o.memory.params);
            plots
                .entry(format!("{} / {}", e.dataset, e.model.as_str()))
                .or_default()
                .entry(method)
                .or_default()
                .push((den, params, mean, std));
        }
    }
    println!("\n### Figure 4 — quality vs embedding memory budget\n");
    for (pane, methods) in plots {
        println!("--- {pane} ---");
        println!("{:<12} {:>8} {:>12} {:>16}", "method", "budget", "params", "metric");
        for (method, mut pts) in methods {
            pts.sort_by(|a, b| b.0.cmp(&a.0)); // smallest budget first
            for (den, params, mean, std) in pts {
                println!("{method:<12} 1/{den:<6} {params:>12} {mean:>10.3} ± {std:.3}");
            }
        }
        println!();
    }
    println!("paper shape: PosHashEmb dominates the baselines at every budget and stays \
              flat as memory shrinks; hashing baselines degrade with smaller B.");
    Ok(())
}
