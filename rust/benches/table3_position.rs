//! Regenerates paper Table III: FullEmb vs PosEmb 1-level vs RandomPart
//! vs PosFullEmb 1-level across all (dataset, model) pairs.
//!
//! Env: POSHASH_SEEDS (default 2), POSHASH_EPOCHS, POSHASH_DATASET.

use poshashemb::bench_harness::{print_table, rows_from_outcomes, Harness};

fn main() -> anyhow::Result<()> {
    let harness = Harness::from_env()?;
    let ds = std::env::var("POSHASH_DATASET").ok();
    let exps = harness.group("t3", ds.as_deref());
    if exps.is_empty() {
        eprintln!("no t3 artifacts found — run `make artifacts` (GRID=full)");
        return Ok(());
    }
    let outcomes = harness.run_all(&exps)?;
    let rows = rows_from_outcomes(&exps, &outcomes, |e| e.method.name());
    print_table(
        "Table III — position-specific component (accuracy / ROC-AUC, mean ± std)",
        &rows,
    );
    println!("\npaper shape: PosEmb 1-level ≥ FullEmb nearly everywhere; RandomPart < PosEmb \
              (position signal, not parameter count, drives quality); PosFullEmb ≥ FullEmb.");
    Ok(())
}
