//! Regenerates paper Table IV: hierarchy depth (PosEmb 1/2/3-level vs
//! FullEmb).

use poshashemb::bench_harness::{print_table, rows_from_outcomes, Harness};

fn main() -> anyhow::Result<()> {
    let harness = Harness::from_env()?;
    let ds = std::env::var("POSHASH_DATASET").ok();
    // Table IV = FullEmb + PosEmb{1,2,3}: full/posemb1 live in group t3.
    let mut exps = harness.group("t3", ds.as_deref());
    exps.retain(|e| e.name.ends_with("_full") || e.name.ends_with("_posemb1"));
    exps.extend(harness.group("t4", ds.as_deref()));
    if exps.is_empty() {
        eprintln!("no t4 artifacts found — run `make artifacts` (GRID=full)");
        return Ok(());
    }
    let outcomes = harness.run_all(&exps)?;
    let rows = rows_from_outcomes(&exps, &outcomes, |e| e.method.name());
    print_table("Table IV — hierarchy depth (accuracy / ROC-AUC, mean ± std)", &rows);
    println!("\npaper shape: deeper hierarchies match or improve 1-level at 90–99% savings.");
    Ok(())
}
