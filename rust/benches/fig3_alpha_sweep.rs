//! Regenerates paper Figure 3: PosEmb 1-level quality as a function of
//! alpha (number of partitions k = n^alpha), per (dataset, model).

use poshashemb::bench_harness::Harness;
use poshashemb::metrics::mean_std;
use std::collections::BTreeMap;

fn main() -> anyhow::Result<()> {
    let harness = Harness::from_env()?;
    let ds = std::env::var("POSHASH_DATASET").ok();
    let exps = harness.group("f3", ds.as_deref());
    if exps.is_empty() {
        eprintln!("no f3 artifacts found — run `make artifacts` (GRID=full)");
        return Ok(());
    }
    let outcomes = harness.run_all(&exps)?;
    // series per (dataset, model): alpha tag -> (k, metric)
    let mut series: BTreeMap<String, Vec<(String, usize, f64, f64)>> = BTreeMap::new();
    for e in &exps {
        let alpha_tag = e.name.rsplit("_a").next().unwrap_or("?").to_string();
        if let Some(outs) = outcomes.get(&e.name) {
            let vals: Vec<f64> = outs.iter().map(|o| o.test_metric).collect();
            let (mean, std) = mean_std(&vals);
            series
                .entry(format!("{} / {}", e.dataset, e.model.as_str()))
                .or_default()
                .push((alpha_tag, e.k, mean, std));
        }
    }
    println!("\n### Figure 3 — PosEmb 1-level vs alpha (k = n^alpha)\n");
    for (key, mut points) in series {
        points.sort_by_key(|(_, k, _, _)| *k);
        println!("{key}:");
        for (tag, k, mean, std) in points {
            let bars = "#".repeat((mean * 60.0) as usize);
            println!("  alpha={}/8  k={k:<6} {mean:.3} ± {std:.3}  {bars}", &tag[..1]);
        }
    }
    println!("\npaper shape: quality needs k large enough to capture position, then \
              flattens (or dips where too-fine partitions fragment the signal).");
    Ok(())
}
