//! Power-law (R-MAT) generator properties at larger n: degree-
//! distribution sanity over random configs (in-tree proptest driver)
//! and the thread-count-independence pin for the streamed builder that
//! `train-sharded` feeds on.

use poshashemb::graph::{rmat_streamed, CsrGraph, RmatConfig};
use poshashemb::util::proptest::run_cases;

fn degrees(g: &CsrGraph) -> Vec<usize> {
    (0..g.num_nodes() as u32).map(|u| g.degree(u)).collect()
}

#[test]
fn prop_streamed_rmat_degree_distribution_is_sane() {
    run_cases(8, 0x9A, |rng| {
        let cfg = RmatConfig {
            scale: (10 + rng.gen_range(3)) as u32, // 1k–4k nodes
            edge_factor: 8 + rng.gen_range(9),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let g = rmat_streamed(&cfg);
        g.validate().expect("invalid CSR");
        let n = g.num_nodes();
        assert_eq!(n, 1usize << cfg.scale);
        // symmetrization doubles entries, dedup/self-loop-drop only
        // removes: mean degree lands below 2·edge_factor but a healthy
        // share of the sampled mass must survive
        let entries = g.num_adjacency_entries();
        assert!(entries <= 2 * n * cfg.edge_factor, "entries above symmetrized bound");
        assert!(
            entries * 2 >= n * cfg.edge_factor,
            "lost too much mass: {entries} entries for {} sampled edges",
            n * cfg.edge_factor
        );
        // heavy tail: the max degree dwarfs the mean, and the top
        // decile of nodes carries far more than its 10% share
        let mut degs = degrees(&g);
        degs.sort_unstable_by(|a, b| b.cmp(a));
        let mean = entries as f64 / n as f64;
        assert!(
            degs[0] as f64 > 4.0 * mean,
            "no heavy tail: max {} vs mean {mean:.1}",
            degs[0]
        );
        let top: usize = degs[..n / 10].iter().sum();
        let share = top as f64 / entries as f64;
        assert!(share > 0.25, "top decile holds only {share:.3} of adjacency");
    });
}

#[test]
fn streamed_rmat_is_identical_across_thread_counts() {
    // big enough for several RMAT_CHUNK-sized stream chunks, so the
    // parallel count/fill passes genuinely interleave
    let cfg = RmatConfig { scale: 13, edge_factor: 256, seed: 42, ..Default::default() };
    let run = |threads: usize| {
        rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap()
            .install(|| rmat_streamed(&cfg))
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one.indptr(), four.indptr());
    assert_eq!(one.indices(), four.indices());
    for u in 0..one.num_nodes() as u32 {
        assert_eq!(one.edge_weights(u), four.edge_weights(u), "weights differ at node {u}");
    }
    // and stable on whatever pool the test harness provides
    let ambient = rmat_streamed(&cfg);
    assert_eq!(ambient.indices(), one.indices());
}
