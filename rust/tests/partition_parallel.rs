//! Parallel-partitioner validation: property-based checks that the
//! rayon-parallel coarsening pipeline is (a) structurally valid, (b)
//! bit-identical across thread counts for a fixed seed, and (c) within
//! tolerance of the scalar oracle's partition quality — plus the
//! acceptance pin that `Hierarchy::build` produces identical `z`/`m` at
//! 1 and 4 threads.
//!
//! Thread counts are varied with dedicated `rayon::ThreadPool`s rather
//! than `RAYON_NUM_THREADS` (the global pool is process-wide and the
//! test runner is itself parallel).

use poshashemb::graph::{planted_partition, CsrGraph, PlantedPartitionConfig};
use poshashemb::partition::{
    coarsen, coarsen_reference, edge_cut, heavy_edge_matching, parallel_heavy_edge_matching,
    partition, Hierarchy, HierarchyConfig, PartitionConfig,
};
use poshashemb::util::rng::Rng;
use proptest::prelude::*;

fn sbm(n: usize, communities: usize, intra: f64, inter: f64, seed: u64) -> CsrGraph {
    planted_partition(&PlantedPartitionConfig {
        n,
        communities,
        intra_degree: intra,
        inter_degree: inter,
        seed,
        ..Default::default()
    })
    .0
}

fn in_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("thread pool")
        .install(f)
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    #[test]
    fn parallel_matching_is_valid_involution(
        n in 50usize..800,
        communities in 2usize..8,
        intra in 4.0f64..12.0,
        seed in any::<u64>(),
    ) {
        let g = sbm(n, communities, intra, 1.5, seed);
        let m = parallel_heavy_edge_matching(&g, seed ^ 0x5EED);
        prop_assert_eq!(m.len(), g.num_nodes());
        for u in 0..g.num_nodes() {
            let v = m[u] as usize;
            prop_assert!(v < g.num_nodes(), "out of range at {u}");
            prop_assert_eq!(m[v] as usize, u, "not involutive at {u}");
            if v != u {
                prop_assert!(
                    g.neighbors(u as u32).contains(&(v as u32)),
                    "{u}-{v} matched but not an edge"
                );
            }
        }
    }

    #[test]
    fn parallel_matching_bit_identical_across_thread_counts(
        n in 100usize..1000,
        communities in 2usize..6,
        seed in any::<u64>(),
    ) {
        let g = sbm(n, communities, 8.0, 2.0, seed);
        let m1 = in_pool(1, || parallel_heavy_edge_matching(&g, seed));
        let m4 = in_pool(4, || parallel_heavy_edge_matching(&g, seed));
        prop_assert_eq!(m1, m4);
    }

    #[test]
    fn csr_contraction_matches_reference(
        n in 60usize..700,
        communities in 2usize..7,
        seed in any::<u64>(),
        use_parallel_matching in any::<bool>(),
    ) {
        let g = sbm(n, communities, 7.0, 2.0, seed);
        let m = if use_parallel_matching {
            parallel_heavy_edge_matching(&g, seed)
        } else {
            heavy_edge_matching(&g, &mut Rng::seed_from_u64(seed))
        };
        let (a, amap) = coarsen_reference(&g, &m);
        let (b, bmap) = coarsen(&g, &m);
        prop_assert_eq!(amap, bmap);
        prop_assert_eq!(a.indptr(), b.indptr());
        prop_assert_eq!(a.indices(), b.indices());
        for u in 0..a.num_nodes() as u32 {
            prop_assert_eq!(a.vertex_weight(u), b.vertex_weight(u));
            for (x, y) in a.edge_weights(u).iter().zip(b.edge_weights(u)) {
                prop_assert!((x - y).abs() < 1e-4, "row {u} weight {x} vs {y}");
            }
        }
        let valid = b.validate();
        prop_assert!(valid.is_ok(), "invalid coarse CSR: {:?}", valid);
    }

    #[test]
    fn parallel_partition_quality_within_tolerance(
        n in 600usize..1000,
        seed in any::<u64>(),
    ) {
        // Strong-homophily SBM: the parallel coarsening path must land
        // within 5% of the scalar oracle's edge cut (small absolute slack
        // absorbs integer-sized noise on these tiny cuts). A cut at or
        // below the planted partition's own cut also passes — that is
        // ground-truth quality even when the scalar run got lucky.
        let k = 4;
        let (g, membership) = planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 12.0,
            inter_degree: 1.0,
            seed,
            ..Default::default()
        });
        let planted_cut = edge_cut(&g, &membership);
        let mut cfg = PartitionConfig { k, seed, parallel: false, ..Default::default() };
        let scalar = partition(&g, &cfg);
        cfg.parallel = true;
        let par = partition(&g, &cfg);
        prop_assert!(
            par.edge_cut <= scalar.edge_cut * 1.05 + 2.0 || par.edge_cut <= planted_cut,
            "parallel cut {} vs scalar {} (planted {})",
            par.edge_cut, scalar.edge_cut, planted_cut
        );
    }
}

#[test]
fn hierarchy_identical_at_1_and_4_threads() {
    let g = sbm(2000, 8, 8.0, 1.5, 42);
    let cfg = HierarchyConfig::new(4, 3);
    let h1 = in_pool(1, || Hierarchy::build(&g, &cfg));
    let h4 = in_pool(4, || Hierarchy::build(&g, &cfg));
    assert_eq!(h1.m, h4.m);
    assert_eq!(h1.z, h4.z);
    h1.validate().unwrap();
}

#[test]
fn partition_identical_at_1_and_4_threads() {
    let g = sbm(1500, 6, 9.0, 2.0, 7);
    let cfg = PartitionConfig { k: 6, seed: 11, ..Default::default() };
    let p1 = in_pool(1, || partition(&g, &cfg));
    let p4 = in_pool(4, || partition(&g, &cfg));
    assert_eq!(p1.part, p4.part);
    assert_eq!(p1.edge_cut, p4.edge_cut);
}
