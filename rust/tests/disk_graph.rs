//! Out-of-core acceptance: the disk-backed [`DiskCsr`] store must be
//! indistinguishable — bit for bit — from the in-memory CSR everywhere
//! a graph is consumed. Random R-MAT roundtrips pin the raw arrays and
//! the positioned-read row accessors; node-classification,
//! link-prediction and partition-sharded training pin the derived loss
//! trajectories, metrics and halo traffic across backends (serial and
//! pipelined, k ∈ {1, 4}); and corrupted directories — truncated
//! section, flipped byte, stale manifest — must fail [`DiskCsr::open`]
//! naming the offending section. Mid-write crash atomicity lives in
//! `tests/disk_graph_atomicity.rs` (armed fault points are
//! process-global, so it gets its own binary).

use poshashemb::coordinator::{
    EdgeDecoder, MinibatchOptions, MinibatchOutcome, MinibatchTrainer, Objective, OptimizerKind,
    ShardedTrainer,
};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{rmat_streamed, write_graph_dir, DiskCsr, GraphStore, RmatConfig};
use poshashemb::partition::{GraphShards, Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanout, SamplerConfig};
use poshashemb::util::proptest::run_cases;
use poshashemb::util::tempdir::TempDir;
use std::path::Path;

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

/// The same dataset with its graph swapped for a freshly written and
/// reopened on-disk copy — labels, splits and spec are shared, so any
/// divergence in a training run is the backend's fault.
fn disk_twin(ds: &Dataset, dir: &Path) -> Dataset {
    write_graph_dir(dir, ds.graph.mem()).unwrap();
    let mut twin = ds.clone();
    twin.graph = DiskCsr::open(dir).unwrap().into();
    twin
}

fn assert_outcome_bits(a: &MinibatchOutcome, b: &MinibatchOutcome, what: &str) {
    assert_eq!(a.losses.len(), b.losses.len(), "{what}: epoch counts differ");
    for (e, (x, y)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: epoch {e} loss diverged ({x} vs {y})");
    }
    assert_eq!(a.val_metric.to_bits(), b.val_metric.to_bits(), "{what}: val metric");
    assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits(), "{what}: test metric");
    assert_eq!(a.val_hits.map(f64::to_bits), b.val_hits.map(f64::to_bits), "{what}: val hits");
    assert_eq!(a.test_hits.map(f64::to_bits), b.test_hits.map(f64::to_bits), "{what}: test hits");
    assert_eq!(a.peak_compose_rows, b.peak_compose_rows, "{what}: peak compose rows");
    assert_eq!(a.seeds_per_epoch, b.seeds_per_epoch, "{what}: seeds per epoch");
    assert_eq!(a.batches_per_epoch, b.batches_per_epoch, "{what}: batches per epoch");
}

#[test]
fn prop_random_rmat_roundtrips_bit_identical_through_disk() {
    run_cases(8, 0xD15C, |rng| {
        let g = rmat_streamed(&RmatConfig {
            scale: 5 + rng.gen_range(3) as u32,
            edge_factor: 2 + rng.gen_range(6),
            seed: rng.next_u64(),
            ..Default::default()
        });
        let t = TempDir::new("diskgraph-prop").unwrap();
        let dir = t.path().join("g");
        write_graph_dir(&dir, &g).unwrap();
        let d = DiskCsr::open(&dir).unwrap();
        assert_eq!(GraphStore::num_nodes(&d), g.num_nodes());
        assert_eq!(GraphStore::num_edges(&d), g.num_edges());
        let back = d.to_mem().unwrap();
        assert_eq!(back.indptr(), g.indptr());
        assert_eq!(back.indices(), g.indices());
        for u in 0..g.num_nodes() as u32 {
            assert_eq!(back.edge_weights(u), g.edge_weights(u), "row {u} weights");
        }
        // positioned-read row accessors agree with the resident slices
        let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
        for _ in 0..32 {
            let u = rng.gen_range(g.num_nodes()) as u32;
            d.edges_into(u, &mut nbrs, &mut wts);
            assert_eq!(nbrs, g.neighbors(u), "row {u} neighbors");
            assert_eq!(wts, g.edge_weights(u), "row {u} weights");
            let v = rng.gen_range(g.num_nodes()) as u32;
            assert_eq!(d.has_edge(u, v), g.neighbors(u).binary_search(&v).is_ok(), "({u},{v})");
        }
    });
}

#[test]
fn corrupted_directories_fail_open_naming_the_section() {
    let t = TempDir::new("diskgraph-corrupt").unwrap();
    let g = rmat_streamed(&RmatConfig { scale: 6, edge_factor: 4, seed: 5, ..Default::default() });

    // a truncated section is caught by the byte-length check
    let dir = t.path().join("trunc");
    write_graph_dir(&dir, &g).unwrap();
    let path = dir.join("indices.bin");
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len - 4).unwrap();
    drop(f);
    let err = format!("{:#}", DiskCsr::open(&dir).unwrap_err());
    assert!(err.contains("section 'indices'"), "truncation must name the section: {err}");
    assert!(err.contains("bytes on disk"), "{err}");

    // a single flipped byte is caught by the section checksum
    let dir = t.path().join("flip");
    write_graph_dir(&dir, &g).unwrap();
    let path = dir.join("weights.bin");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, bytes).unwrap();
    let err = format!("{:#}", DiskCsr::open(&dir).unwrap_err());
    assert!(err.contains("checksum mismatch in section 'weights'"), "{err}");

    // graph A's sections under graph B's manifest: stale-manifest guard
    let dir = t.path().join("stale");
    write_graph_dir(&dir, &g).unwrap();
    let other = t.path().join("other");
    let g2 = rmat_streamed(&RmatConfig { scale: 5, edge_factor: 4, seed: 9, ..Default::default() });
    write_graph_dir(&other, &g2).unwrap();
    std::fs::copy(other.join("manifest.json"), dir.join("manifest.json")).unwrap();
    let err = format!("{:#}", DiskCsr::open(&dir).unwrap_err());
    assert!(err.contains("section '"), "a stale manifest must name a section: {err}");
}

#[test]
fn node_classification_training_is_bit_identical_across_backends() {
    let mem = small_dataset(450, 16);
    let t = TempDir::new("diskgraph-nc").unwrap();
    let disk = disk_twin(&mem, &t.path().join("g"));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 2, compression: 5, h: 2 };
    for parallel in [false, true] {
        let run = |ds: &Dataset| {
            // the hierarchy is built over the handle too, so the
            // partition pipeline itself is part of the pinned surface
            let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 2));
            let plan = EmbeddingPlan::build(450, 16, &method, Some(&hier), 7);
            let cfg =
                SamplerConfig { batch_size: 64, fanouts: Fanout::Max(5).into(), shuffle: true };
            let opts = MinibatchOptions {
                epochs: 2,
                seed: 7,
                parallel,
                prefetch: if parallel { 2 } else { 0 },
                ..Default::default()
            };
            MinibatchTrainer::new(ds, &plan, cfg, opts).unwrap().train().unwrap()
        };
        let what = if parallel { "nodeclass pipelined" } else { "nodeclass serial" };
        assert_outcome_bits(&run(&mem), &run(&disk), what);
    }
}

#[test]
fn link_prediction_training_is_bit_identical_across_backends() {
    // link prediction leans hardest on the disk backend: negative
    // sampling rejects candidates through `has_edge` (per-probe
    // positioned reads), and the edge split walks every row
    let mem = small_dataset(400, 16);
    let t = TempDir::new("diskgraph-lp").unwrap();
    let disk = disk_twin(&mem, &t.path().join("g"));
    let plan =
        EmbeddingPlan::build(400, 16, &EmbeddingMethod::HashEmb { buckets: 48, h: 2 }, None, 3);
    for parallel in [false, true] {
        let run = |ds: &Dataset| {
            let cfg =
                SamplerConfig { batch_size: 64, fanouts: Fanout::Max(5).into(), shuffle: true };
            let opts = MinibatchOptions {
                epochs: 2,
                lr: 0.03,
                optimizer: OptimizerKind::Adam,
                seed: 7,
                parallel,
                prefetch: if parallel { 2 } else { 0 },
                hidden: 16,
                objective: Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 2 },
                ..Default::default()
            };
            MinibatchTrainer::new(ds, &plan, cfg, opts).unwrap().train().unwrap()
        };
        let what = if parallel { "linkpred pipelined" } else { "linkpred serial" };
        assert_outcome_bits(&run(&mem), &run(&disk), what);
    }
}

#[test]
fn graph_shards_are_identical_across_backends() {
    let mem = small_dataset(600, 8);
    let t = TempDir::new("diskgraph-shards").unwrap();
    let disk = disk_twin(&mem, &t.path().join("g"));
    for k in [1usize, 4] {
        let a = GraphShards::build(&mem.graph, k, 0x5EED);
        let b = GraphShards::build(&disk.graph, k, 0x5EED);
        assert_eq!(a.assignment, b.assignment, "k={k}: assignment");
        assert_eq!(a.edge_cut.to_bits(), b.edge_cut.to_bits(), "k={k}: edge cut");
        for (sa, sb) in a.shards.iter().zip(&b.shards) {
            assert_eq!(sa.owned, sb.owned, "k={k} shard {}: owned", sa.id);
            assert_eq!(sa.halo, sb.halo, "k={k} shard {}: halo", sa.id);
            assert_eq!(sa.locals, sb.locals, "k={k} shard {}: locals", sa.id);
        }
    }
}

#[test]
fn sharded_training_is_bit_identical_across_backends() {
    let mem = small_dataset(600, 16);
    let t = TempDir::new("diskgraph-sharded").unwrap();
    let disk = disk_twin(&mem, &t.path().join("g"));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 2, compression: 5, h: 2 };
    for k in [1usize, 4] {
        for parallel in [false, true] {
            let run = |ds: &Dataset| {
                let cfg = SamplerConfig { batch_size: 64, ..Default::default() };
                let opts = MinibatchOptions {
                    epochs: 2,
                    seed: 7,
                    parallel,
                    prefetch: if parallel { 2 } else { 0 },
                    ..Default::default()
                };
                ShardedTrainer::new(ds, &method, 4, k, 1, cfg, opts).unwrap().train().unwrap()
            };
            let (a, b) = (run(&mem), run(&disk));
            let what = format!("sharded k={k} {}", if parallel { "pipelined" } else { "serial" });
            assert_eq!(a.edge_cut.to_bits(), b.edge_cut.to_bits(), "{what}: edge cut");
            assert_eq!(a.halo_bytes_total, b.halo_bytes_total, "{what}: halo bytes");
            assert_eq!(a.exchanges, b.exchanges, "{what}: exchanges");
            assert_eq!(a.val_metric.to_bits(), b.val_metric.to_bits(), "{what}: val metric");
            assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits(), "{what}: test metric");
            assert_eq!(a.losses.len(), b.losses.len(), "{what}: epoch counts differ");
            for (e, (x, y)) in a.losses.iter().zip(&b.losses).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: epoch {e} aggregate loss");
            }
            for (sa, sb) in a.shards.iter().zip(&b.shards) {
                assert_eq!(
                    sa.halo_bytes_per_exchange,
                    sb.halo_bytes_per_exchange,
                    "{what}: shard {} halo bytes per exchange",
                    sa.shard
                );
                assert_eq!(sa.owned_nodes, sb.owned_nodes, "{what}: shard {}", sa.shard);
                assert_eq!(sa.halo_nodes, sb.halo_nodes, "{what}: shard {}", sa.shard);
                for (e, (x, y)) in sa.losses.iter().zip(&sb.losses).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{what}: shard {} epoch {e} loss",
                        sa.shard
                    );
                }
            }
        }
    }
}
