//! Atomic graph-directory publication: a fault injected anywhere in
//! `write_graph_dir`'s write path (section write, manifest write, final
//! rename) must leave either no graph directory at all or the previous
//! fully-intact directory — never a torn one. Lives in its own
//! integration binary because armed fault points are process-global and
//! `tests/disk_graph.rs` calls `write_graph_dir` concurrently.

use poshashemb::graph::{rmat_streamed, write_graph_dir, DiskCsr, GraphStore, RmatConfig};
use poshashemb::util::fault;
use poshashemb::util::tempdir::TempDir;

#[test]
fn failed_graph_publish_leaves_no_trace_and_keeps_the_old_directory() {
    let _g = fault::test_guard();
    fault::reset();
    let t = TempDir::new("diskgraph-atomic").unwrap();
    let dir = t.path().join("graph");
    let g1 = rmat_streamed(&RmatConfig { scale: 6, edge_factor: 4, seed: 1, ..Default::default() });

    // a fault at any stage before publication leaves nothing behind —
    // no graph directory and no orphaned temp sibling
    for site in [
        "diskgraph.section=1",
        "diskgraph.section=3",
        "diskgraph.manifest=1",
        "diskgraph.rename=1",
    ] {
        fault::arm(site).unwrap();
        let err = write_graph_dir(&dir, &g1).unwrap_err();
        fault::reset();
        assert!(format!("{err:#}").contains("injected fault"), "{site}: {err:#}");
        assert!(!dir.exists(), "{site}: failed publish must not leave a directory");
        let leftovers = std::fs::read_dir(t.path()).unwrap().count();
        assert_eq!(leftovers, 0, "{site}: failed publish must clean up its temp dir");
    }

    // publish a good directory, then fail a re-publish over it: the old
    // graph must remain fully intact, verified and bit-identical
    write_graph_dir(&dir, &g1).unwrap();
    let g2 = rmat_streamed(&RmatConfig { scale: 5, edge_factor: 4, seed: 2, ..Default::default() });
    fault::arm("diskgraph.manifest=1").unwrap();
    write_graph_dir(&dir, &g2).unwrap_err();
    fault::reset();
    let d = DiskCsr::open(&dir).unwrap();
    assert_eq!(GraphStore::num_nodes(&d), g1.num_nodes());
    let back = d.to_mem().unwrap();
    assert_eq!(back.indptr(), g1.indptr());
    assert_eq!(back.indices(), g1.indices());
}
