//! Integration tests over the full Rust stack (require `make artifacts`
//! for at least the smoke grid; skip gracefully otherwise).

use poshashemb::bench_harness::Harness;
use poshashemb::config::{full_grid, materialize};
use poshashemb::coordinator::{run_experiment, TrainOptions};
use poshashemb::runtime::{Manifest, RuntimeClient};
use std::path::Path;

fn manifest_or_skip() -> Option<(RuntimeClient, Manifest)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let client = match RuntimeClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: {e}");
            return None;
        }
    };
    let manifest = Manifest::load(dir).unwrap();
    Some((client, manifest))
}

fn find_ready<'a>(
    manifest: &Manifest,
    grid: &'a [poshashemb::config::Experiment],
    name: &str,
) -> Option<&'a poshashemb::config::Experiment> {
    let e = grid.iter().find(|e| e.name == name)?;
    manifest.contains(&format!("{name}.train")).then_some(e)
}

#[test]
fn training_reduces_loss_and_beats_chance() {
    let Some((client, manifest)) = manifest_or_skip() else { return };
    let grid = full_grid();
    let Some(e) = find_ready(&manifest, &grid, "arxiv_gcn_posemb3") else { return };
    let opts = TrainOptions { epochs: Some(25), eval_every: 5, patience: 0, ..Default::default() };
    let out = run_experiment(&client, &manifest, e, 0, &opts).unwrap();
    // losses are probed every epoch for small states, at eval cadence
    // (every 5) for large ones; either way the curve must drop.
    assert!(out.losses.len() == 25 || out.losses.len() == 5, "{}", out.losses.len());
    let (first, last) = (out.losses[0], *out.losses.last().unwrap());
    assert!(last < first * 0.8, "loss did not drop: {:?}", out.losses);
    // 40-class problem: chance = 0.025
    assert!(out.test_metric > 0.2, "test acc {}", out.test_metric);
    assert!(out.val_metric >= out.test_metric - 0.1);
}

#[test]
fn hlo_loss_matches_rust_cross_entropy_of_eval_logits() {
    // Cross-layer parity: the loss reported by the train HLO at step 1
    // must equal the masked CE computed in Rust from the eval HLO's
    // logits at the same parameters.
    let Some((client, manifest)) = manifest_or_skip() else { return };
    let grid = full_grid();
    let Some(e) = find_ready(&manifest, &grid, "arxiv_gcn_full") else { return };
    let (ds, _, _) = materialize(e, 3);

    // run 1 training epoch to get loss(params_0)
    let opts = TrainOptions { epochs: Some(1), eval_every: 1, patience: 0, ..Default::default() };
    let out = run_experiment(&client, &manifest, e, 3, &opts).unwrap();
    let hlo_loss = out.losses[0] as f64;

    // recompute in Rust: run eval at the SAME initial params. We can't
    // read the pre-step logits from the outcome, so rebuild the identical
    // run but with 0 training epochs is impossible (loop runs >=1).
    // Instead recompute CE from the val-logits path: run_experiment with
    // 1 epoch evaluates AFTER the step, so instead verify the value is
    // consistent with chance-level CE at init: ln(40) ± 15%.
    let expect = (ds.spec.classes as f64).ln();
    assert!(
        (hlo_loss - expect).abs() / expect < 0.15,
        "initial CE {hlo_loss} vs ln(C) {expect}"
    );
}

#[test]
fn deterministic_given_seed() {
    let Some((client, manifest)) = manifest_or_skip() else { return };
    let grid = full_grid();
    let Some(e) = find_ready(&manifest, &grid, "arxiv_gcn_posemb1") else { return };
    let opts = TrainOptions { epochs: Some(5), eval_every: 5, patience: 0, ..Default::default() };
    let a = run_experiment(&client, &manifest, e, 7, &opts).unwrap();
    let b = run_experiment(&client, &manifest, e, 7, &opts).unwrap();
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.test_metric, b.test_metric);
}

#[test]
fn seeds_change_hash_draws_but_not_shapes() {
    let Some((client, manifest)) = manifest_or_skip() else { return };
    let grid = full_grid();
    let Some(e) = find_ready(&manifest, &grid, "arxiv_gcn_intra_h2") else { return };
    let opts = TrainOptions { epochs: Some(3), eval_every: 3, patience: 0, ..Default::default() };
    let a = run_experiment(&client, &manifest, e, 0, &opts).unwrap();
    let b = run_experiment(&client, &manifest, e, 1, &opts).unwrap();
    assert_eq!(a.memory.params, b.memory.params);
    assert_ne!(a.losses, b.losses, "different seeds gave identical runs");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some((client, manifest)) = manifest_or_skip() else { return };
    let mut e = full_grid().remove(0);
    e.name = "nonexistent_config".into();
    let err = run_experiment(&client, &manifest, &e, 0, &TrainOptions::default()).unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "err: {err}");
}

#[test]
fn harness_groups_filter_by_manifest() {
    let Some((_client, _manifest)) = manifest_or_skip() else { return };
    std::env::set_var("POSHASH_SEEDS", "1");
    let h = Harness::from_env().unwrap();
    let t3 = h.group("t3", None);
    // every returned experiment has both artifacts
    for e in &t3 {
        assert!(h.manifest.contains(&format!("{}.train", e.name)));
        assert!(h.manifest.contains(&format!("{}.eval", e.name)));
    }
    assert!(h.group("nope", None).is_empty());
}
