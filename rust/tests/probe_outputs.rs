//! Diagnostic: inspect PJRT output structure for a lowered artifact.
//! Needs the `pjrt` feature and `make artifacts` for the smoke grid.
#![cfg(feature = "pjrt")]

use poshashemb::runtime::{DeviceBuffer, Dtype, HostTensor, Manifest, RuntimeClient};

#[test]
fn probe_eval_outputs() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return Ok(());
    }
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(dir)?;
    for name in ["arxiv_gcn_posemb3.eval", "arxiv_gcn_posemb3.train"] {
        if !manifest.contains(name) {
            continue;
        }
        let spec = manifest.get(name)?;
        let exe = client.compile_hlo_file(&manifest.hlo_path(spec))?;
        let mut bufs = Vec::new();
        for i in &spec.inputs {
            let n: usize = i.shape.iter().product::<usize>().max(1);
            let t = match i.dtype {
                Dtype::F32 => HostTensor::F32(vec![0.01; n], i.shape.clone()),
                Dtype::I32 => HostTensor::I32(vec![0; n], i.shape.clone()),
            };
            bufs.push(client.upload(&t)?);
        }
        let args: Vec<&DeviceBuffer> = bufs.iter().collect();
        let outs = client.execute(&exe, &args)?;
        println!("{name}: {} output buffers (expect {})", outs.len(), spec.num_outputs);
        // packed ABI: both train and eval roots are single f32 arrays —
        // downloadable directly (tuple buffers would abort in 0.5.1).
        let v = client.download_f32(&outs[0])?;
        assert!(!v.is_empty());
        assert_eq!(outs.len(), spec.num_outputs);
    }
    Ok(())
}
