// Diagnostic: inspect PJRT output structure for a lowered artifact.
// (Requires `make artifacts` for the smoke grid.)
use poshashemb::runtime::{Dtype, HostTensor, Manifest, RuntimeClient};

#[test]
fn probe_eval_outputs() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return Ok(());
    }
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(dir)?;
    for name in ["arxiv_gcn_posemb3.eval", "arxiv_gcn_posemb3.train"] {
        if !manifest.contains(name) { continue; }
        let spec = manifest.get(name)?;
        let exe = client.compile_hlo_file(&manifest.hlo_path(spec))?;
        let mut bufs = Vec::new();
        for i in &spec.inputs {
            let n: usize = i.shape.iter().product::<usize>().max(1);
            let t = match i.dtype {
                Dtype::F32 => HostTensor::F32(vec![0.01; n], i.shape.clone()),
                Dtype::I32 => HostTensor::I32(vec![0; n], i.shape.clone()),
            };
            bufs.push(client.upload(&t)?);
        }
        let outs = exe.execute_b::<&xla::PjRtBuffer>(&bufs.iter().collect::<Vec<_>>())?;
        println!("{name}: outer len {}", outs.len());
        for (i, replica) in outs.iter().enumerate() {
            println!("  [{i}] inner len {} (expect {} outputs)", replica.len(), spec.num_outputs);
            for (j, b) in replica.iter().enumerate().take(3) {
                println!("    [{i}][{j}] shape {:?}", b.on_device_shape());
            }
        }
        // packed ABI: both train and eval roots are single f32 arrays —
        // downloadable directly (tuple buffers would abort in 0.5.1).
        let lit = outs[0][0].to_literal_sync()?;
        println!("  literal size_bytes {}", lit.size_bytes());
        let v = lit.to_vec::<f32>()?;
        assert!(!v.is_empty());
        assert_eq!(outs[0].len(), spec.num_outputs);
    }
    Ok(())
}
