//! Atomic model-artifact publication: a fault injected anywhere in
//! `save_artifact`'s write path (section write, manifest write, final
//! rename) must leave either no artifact directory at all or the
//! previous fully-intact artifact — never a torn one. Lives in its own
//! integration binary because armed fault points are process-global and
//! the serve suite's other tests call `save_artifact` concurrently.

use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{init_params, EmbeddingPlan, MethodSpec};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::serve::{save_artifact, ServeEngine};
use poshashemb::util::fault;
use poshashemb::util::tempdir::TempDir;

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn build(n: usize, d: usize, tag: &str) -> (Dataset, EmbeddingPlan) {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    let ds = Dataset::generate(&s);
    let r = MethodSpec::parse(tag).unwrap().resolve(n).unwrap();
    let hier = r.method.needs_hierarchy().then(|| {
        Hierarchy::build(&ds.graph, &HierarchyConfig::new(r.k, r.method.levels().max(1)))
    });
    let plan = EmbeddingPlan::build(n, d, &r.method, hier.as_ref(), 7);
    (ds, plan)
}

#[test]
fn failed_artifact_publish_leaves_no_trace_and_keeps_the_old_artifact() {
    let _g = fault::test_guard();
    fault::reset();
    let t = TempDir::new("artifact-atomic").unwrap();
    let dir = t.path().join("model");
    let (ds, plan) = build(200, 8, "inter(k=4)");
    let params = init_params(&plan, 3);

    // a fault at any stage before publication leaves nothing behind —
    // no artifact directory and no orphaned temp sibling
    for site in ["artifact.section=1", "artifact.manifest=1", "artifact.rename=1"] {
        fault::arm(site).unwrap();
        let err = save_artifact(&dir, &ds, &plan, &params, 1, 16).unwrap_err();
        fault::reset();
        assert!(format!("{err:#}").contains("injected fault"), "{site}: {err:#}");
        assert!(!dir.exists(), "{site}: failed publish must not leave a directory");
        let leftovers = std::fs::read_dir(t.path()).unwrap().count();
        assert_eq!(leftovers, 0, "{site}: failed publish must clean up its temp dir");
    }

    // publish a good artifact, then fail a re-publish over it: the old
    // artifact must remain fully intact and openable
    save_artifact(&dir, &ds, &plan, &params, 1, 16).unwrap();
    fault::arm("artifact.manifest=1").unwrap();
    save_artifact(&dir, &ds, &plan, &params, 1, 16).unwrap_err();
    fault::reset();
    let mut engine = ServeEngine::open(&dir, 0).unwrap();
    assert!(engine.embed(&[0, 1, 2]).is_ok(), "old artifact survives a failed re-publish");
}
