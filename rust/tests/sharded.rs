//! Partition-sharded training acceptance: the headline pins from the
//! determinism ledger. k=1 must reproduce the plain minibatch trainer
//! **bit for bit** (serial and pipelined engines); k>1 must be
//! deterministic for a fixed (seed, k); and every shard's resident
//! table must fit in `full/k` plus its halo replica rows.

use poshashemb::coordinator::{MinibatchOptions, MinibatchTrainer, ShardedTrainer};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::SamplerConfig;

const HIER_K: usize = 4;

/// A shrunk synth-arxiv: small enough that four trainers finish in
/// test time, large enough that k=4 sharding leaves no shard empty.
fn small_dataset(d: usize) -> Dataset {
    let mut sp = spec("synth-arxiv").unwrap();
    sp.n = 600;
    sp.communities = 30;
    sp.supers = 6;
    sp.d = d;
    Dataset::generate(&sp)
}

fn small_cfg() -> SamplerConfig {
    SamplerConfig { batch_size: 64, ..Default::default() }
}

fn small_opts(parallel: bool) -> MinibatchOptions {
    MinibatchOptions {
        epochs: 2,
        seed: 7,
        parallel,
        prefetch: if parallel { 2 } else { 0 },
        ..Default::default()
    }
}

/// Loss trajectory of the plain (unsharded) trainer on `ds`.
fn reference_losses(
    ds: &Dataset,
    method: &EmbeddingMethod,
    cfg: &SamplerConfig,
    opts: &MinibatchOptions,
) -> Vec<f64> {
    let hier = if method.needs_hierarchy() {
        let levels = method.levels().max(1);
        Some(Hierarchy::build(&ds.graph, &HierarchyConfig::new(HIER_K, levels)))
    } else {
        None
    };
    let plan = EmbeddingPlan::build(ds.spec.n, ds.spec.d, method, hier.as_ref(), opts.seed);
    let mut t = MinibatchTrainer::new(ds, &plan, cfg.clone(), opts.clone()).unwrap();
    t.train().unwrap().losses
}

fn assert_bitwise_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: trajectory lengths differ");
    for (e, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: epoch {e} diverged ({x:.17e} vs {y:.17e})"
        );
    }
}

#[test]
fn k1_reproduces_minibatch_trainer_bit_for_bit() {
    let ds = small_dataset(16);
    let cfg = small_cfg();
    let methods = [
        EmbeddingMethod::Full,
        EmbeddingMethod::PosHashEmbIntra { levels: 2, compression: 5, h: 2 },
    ];
    for method in &methods {
        for parallel in [false, true] {
            let opts = small_opts(parallel);
            let want = reference_losses(&ds, method, &cfg, &opts);
            let out = ShardedTrainer::new(&ds, method, HIER_K, 1, 1, cfg.clone(), opts)
                .unwrap()
                .train()
                .unwrap();
            assert_eq!(out.k, 1);
            let what = format!(
                "{} ({})",
                method.name(),
                if parallel { "pipelined" } else { "serial" }
            );
            assert_bitwise_eq(&want, &out.losses, &what);
            // k=1 has no remote rows, so nothing crosses shards
            assert_eq!(out.halo_bytes_total, 0, "{what}: k=1 exchanged halo bytes");
            assert_eq!(out.shards[0].halo_nodes, 0);
        }
    }
}

#[test]
fn fixed_seed_and_k_runs_are_deterministic() {
    let ds = small_dataset(16);
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 2, compression: 5, h: 2 };
    let run = || {
        ShardedTrainer::new(&ds, &method, HIER_K, 4, 1, small_cfg(), small_opts(true))
            .unwrap()
            .train()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.k, 4);
    assert_eq!(a.edge_cut.to_bits(), b.edge_cut.to_bits());
    assert_bitwise_eq(&a.losses, &b.losses, "aggregate losses");
    assert_eq!(a.val_metric.to_bits(), b.val_metric.to_bits());
    assert_eq!(a.test_metric.to_bits(), b.test_metric.to_bits());
    assert_eq!(a.halo_bytes_total, b.halo_bytes_total);
    assert_eq!(a.exchanges, b.exchanges);
    for (sa, sb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(sa.owned_nodes, sb.owned_nodes);
        assert_eq!(sa.halo_nodes, sb.halo_nodes);
        assert_eq!(sa.halo_bytes_per_exchange, sb.halo_bytes_per_exchange);
        assert_bitwise_eq(&sa.losses, &sb.losses, "per-shard losses");
    }
}

#[test]
fn per_shard_resident_tables_fit_in_full_over_k_plus_halo() {
    let d = 16;
    let ds = small_dataset(d);
    let k = 4;
    let out = ShardedTrainer::new(&ds, &EmbeddingMethod::Full, HIER_K, k, 1, small_cfg(), {
        let mut o = small_opts(true);
        o.epochs = 1;
        o
    })
    .unwrap()
    .train()
    .unwrap();
    assert_eq!(out.full_table_bytes, (ds.spec.n * d * 4) as u64);
    // 1.15 absorbs the partitioner's epsilon = 0.10 imbalance slack
    let per_shard_budget = 1.15 * out.full_table_bytes as f64 / k as f64;
    let mut peak = 0u64;
    for s in &out.shards {
        let halo_bytes = (s.halo_nodes * d * 4) as u64;
        assert!(
            (s.resident_table_bytes as f64) <= per_shard_budget + halo_bytes as f64,
            "shard {} resident {}B exceeds full/k ({:.0}B) + halo ({halo_bytes}B)",
            s.shard,
            s.resident_table_bytes,
            per_shard_budget
        );
        peak = peak.max(s.resident_table_bytes);
    }
    assert_eq!(out.peak_resident_table_bytes, peak);
    // every node is owned by exactly one shard
    assert_eq!(out.shards.iter().map(|s| s.owned_nodes).sum::<usize>(), ds.spec.n);
}
