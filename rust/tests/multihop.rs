//! Multi-hop sampling + deep-SAGE-head validation: (a) one-hop
//! multi-hop blocks are bit-identical to the classic single-hop
//! sampler, and the L = 1 trainer reproduces the legacy one-layer
//! trainer's loss trajectory **bit for bit** (pinned against a
//! test-local replica of that loop); (b) per-layer fanout bounds and
//! the hop-chaining prefix invariant hold; (c) at L = 2 the pipelined
//! engine reproduces the serial oracle exactly at 1 and 4 rayon
//! threads for SGD and Adam, across prefetch depths; (d) the all-∞
//! L-layer oracle configuration matches the L-layer full-batch trainer
//! within 1e-5 per epoch.
//!
//! Thread counts are varied with dedicated `rayon::ThreadPool`s rather
//! than `RAYON_NUM_THREADS` (the global pool is process-wide and the
//! test runner is itself parallel).

use poshashemb::coordinator::{
    train_full_batch, MinibatchOptions, MinibatchTrainer, OptimizerKind,
};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{ComposeEngine, EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, CsrGraph, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{
    mix_seed, Fanout, Fanouts, NeighborSampler, SamplerConfig, SeedBatcher,
};
use poshashemb::util::rng::Rng;
use proptest::prelude::*;

fn sbm(n: usize, communities: usize, intra: f64, inter: f64, seed: u64) -> CsrGraph {
    planted_partition(&PlantedPartitionConfig {
        n,
        communities,
        intra_degree: intra,
        inter_degree: inter,
        seed,
        ..Default::default()
    })
    .0
}

fn in_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("thread pool")
        .install(f)
}

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

fn distinct_seeds(n: usize, count: usize, seed: u64) -> Vec<u32> {
    let mut ids: Vec<u32> = (0..n as u32).collect();
    Rng::seed_from_u64(seed).shuffle(&mut ids);
    ids.truncate(count.clamp(1, n));
    ids
}

/// Loss trajectory of one minibatch run under the given knobs.
fn run_losses(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    cfg: &SamplerConfig,
    optimizer: OptimizerKind,
    parallel: bool,
    prefetch: usize,
) -> Vec<f64> {
    let opts = MinibatchOptions {
        epochs: 4,
        lr: 0.03,
        optimizer,
        seed: 7,
        parallel,
        prefetch,
        hidden: 16,
        ..Default::default()
    };
    let mut tr = MinibatchTrainer::new(ds, plan, cfg.clone(), opts).unwrap();
    tr.train().unwrap().losses
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    #[test]
    fn one_hop_multi_blocks_match_single_hop_sampler_bits(
        n in 80usize..500,
        fanout in 1usize..7,
        seed in any::<u64>(),
    ) {
        // the L=1 data path must be the pre-multi-hop data path, bit
        // for bit: hop 0 keeps the caller's RNG stream verbatim
        let g = sbm(n, 4, 7.0, 1.5, seed);
        let seeds = distinct_seeds(n, n / 5, seed ^ 0xAB);
        let fans = Fanouts::single(Fanout::Max(fanout));
        let single =
            NeighborSampler::new(&g, Fanout::Max(fanout), seed).sample_block(&seeds, 3, 2);
        let multi = NeighborSampler::multi_hop(&g, &fans, seed).sample_multi(&seeds, 3, 2);
        prop_assert_eq!(multi.num_hops(), 1);
        prop_assert_eq!(&multi.hops[0], &single);
    }

    #[test]
    fn per_layer_fanout_bounds_and_chaining_hold(
        n in 100usize..500,
        f0 in 1usize..6,
        f1 in 1usize..6,
        seed in any::<u64>(),
    ) {
        let g = sbm(n, 5, 8.0, 2.0, seed);
        let seeds = distinct_seeds(n, 30, seed ^ 0xC0);
        let fanouts = Fanouts::new(vec![Fanout::Max(f0), Fanout::Max(f1)]);
        let mut sampler = NeighborSampler::multi_hop(&g, &fanouts, seed);
        let mhb = sampler.sample_multi(&seeds, 1, 4);
        prop_assert_eq!(mhb.num_hops(), 2);
        // hop 0: seed prefix + per-seed fanout bound
        let h0 = mhb.hop(0);
        prop_assert_eq!(&h0.nodes[..seeds.len()], &seeds[..]);
        for (si, &s) in seeds.iter().enumerate() {
            prop_assert_eq!(h0.neighbors_of(si).len(), g.degree(s).min(f0), "hop 0 seed {}", s);
        }
        // hop 1: seeded by hop 0's full node list, same order
        let h1 = mhb.hop(1);
        prop_assert_eq!(h1.num_seeds, h0.num_rows());
        prop_assert_eq!(&h1.nodes[..h0.nodes.len()], &h0.nodes[..]);
        for (si, &s) in h0.nodes.iter().enumerate() {
            prop_assert_eq!(h1.neighbors_of(si).len(), g.degree(s).min(f1), "hop 1 seed {}", s);
        }
        // every hop's rows are unique global ids
        for hop in &mhb.hops {
            let mut ids: Vec<u32> = hop.nodes.clone();
            ids.sort_unstable();
            ids.dedup();
            prop_assert_eq!(ids.len(), hop.nodes.len(), "duplicate rows in a hop");
        }
        // deterministic per coordinates at any thread count
        let again = in_pool(4, move || {
            NeighborSampler::multi_hop(&g, &fanouts, seed).sample_multi(&seeds, 1, 4)
        });
        prop_assert_eq!(mhb, again);
    }
}

/// A test-local replica of the legacy (pre-multi-hop) one-layer
/// minibatch trainer: same seed streams, same init draws, same
/// per-element accumulation orders, dense SGD apply (bit-identical to
/// the sparse apply because untouched gradients are exactly 0.0).
/// Bloom keeps it honest and small: one node table, no learned y, no
/// position levels.
#[allow(clippy::too_many_arguments)]
fn legacy_single_hop_losses(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    batch: usize,
    fanout: usize,
    epochs: usize,
    lr: f32,
    seed: u64,
) -> Vec<f64> {
    let d = plan.d;
    let classes = ds.spec.classes;
    let node = plan.node.as_ref().expect("node plan");
    let h = node.h;
    let buckets = node.table.rows;
    assert!(!node.learned_weights && plan.position.is_none(), "use Bloom for this oracle");

    // ---- parameter init: embedding tables + the legacy head draws ----
    let mut store = poshashemb::embedding::init_params(plan, seed);
    let mut rng = Rng::seed_from_u64(mix_seed(&[seed, 0x6EAD]));
    let a = 1.0 / (d as f32).sqrt();
    let mut w_self: Vec<f32> = (0..d * classes).map(|_| rng.gen_f32_range(-a, a)).collect();
    let mut w_neigh: Vec<f32> = (0..d * classes).map(|_| rng.gen_f32_range(-a, a)).collect();
    let mut bias = vec![0f32; classes];

    // ---- the legacy seed streams ----
    let batcher = SeedBatcher::new(&ds.splits.train, batch, true, mix_seed(&[seed, 0x5EED5]));
    let mut sampler =
        NeighborSampler::new(&ds.graph, Fanout::Max(fanout), mix_seed(&[seed, 0x54AFF]));
    let engine = ComposeEngine::new(plan);

    // dense gradient accumulators (zero entries update by -lr·0 = no-op)
    let mut gw_self = vec![0f32; d * classes];
    let mut gw_neigh = vec![0f32; d * classes];
    let mut gbias = vec![0f32; classes];
    let mut gx = vec![0f32; buckets * d];
    let mut losses = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let mut loss_sum = 0f64;
        let mut seen = 0usize;
        for (bi, seeds) in batcher.epoch_batches(epoch).iter().enumerate() {
            let block = sampler.sample_block(seeds, epoch, bi);
            let s = block.num_seeds;
            let rows = block.num_rows();
            let x = engine.compose_batch(&store, &block.nodes);
            // forward: neighbor means + logits, legacy accumulation order
            let mut nbar = vec![0f32; s * d];
            for si in 0..s {
                let dst = &mut nbar[si * d..(si + 1) * d];
                let nbs = block.neighbors_of(si);
                for &r in nbs {
                    for (o, v) in dst.iter_mut().zip(&x[r as usize * d..(r as usize + 1) * d]) {
                        *o += v;
                    }
                }
                if !nbs.is_empty() {
                    let inv = 1.0 / nbs.len() as f32;
                    for o in dst.iter_mut() {
                        *o *= inv;
                    }
                }
            }
            let mut logits = vec![0f32; s * classes];
            for si in 0..s {
                let out = &mut logits[si * classes..(si + 1) * classes];
                out.copy_from_slice(&bias);
                let xs = &x[si * d..(si + 1) * d];
                let nb = &nbar[si * d..(si + 1) * d];
                for (aa, (&xa, &na)) in xs.iter().zip(nb).enumerate() {
                    let ws = &w_self[aa * classes..(aa + 1) * classes];
                    let wn = &w_neigh[aa * classes..(aa + 1) * classes];
                    for ((o, wsj), wnj) in out.iter_mut().zip(ws).zip(wn) {
                        *o += xa * wsj + na * wnj;
                    }
                }
            }
            // loss + dL/dlogits (softmax CE, mean over the batch seeds);
            // per-seed losses sum into a per-batch accumulator first,
            // exactly like the trainer's step → epoch association
            let gscale = 1.0 / s as f32;
            let mut glog = vec![0f32; s * classes];
            let mut batch_loss = 0f64;
            for si in 0..s {
                let node_id = block.nodes[si] as usize;
                let label = ds.labels[node_id] as usize;
                let lrow = &logits[si * classes..(si + 1) * classes];
                let grow = &mut glog[si * classes..(si + 1) * classes];
                let max = lrow.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
                let mut sum = 0f32;
                for (g, &v) in grow.iter_mut().zip(lrow) {
                    let e = (v - max).exp();
                    *g = e;
                    sum += e;
                }
                let inv = gscale / sum;
                for g in grow.iter_mut() {
                    *g *= inv;
                }
                grow[label] -= gscale;
                batch_loss += ((max + sum.ln()) - lrow[label]) as f64;
            }
            loss_sum += batch_loss;
            // head gradients in the legacy order: W_self, W_neigh, bias
            for si in 0..s {
                let g = &glog[si * classes..(si + 1) * classes];
                let xs = &x[si * d..(si + 1) * d];
                for (aa, &xa) in xs.iter().enumerate() {
                    for (o, &gj) in gw_self[aa * classes..(aa + 1) * classes].iter_mut().zip(g) {
                        *o += xa * gj;
                    }
                }
            }
            for si in 0..s {
                let g = &glog[si * classes..(si + 1) * classes];
                let nb = &nbar[si * d..(si + 1) * d];
                for (aa, &na) in nb.iter().enumerate() {
                    for (o, &gj) in gw_neigh[aa * classes..(aa + 1) * classes].iter_mut().zip(g) {
                        *o += na * gj;
                    }
                }
            }
            for si in 0..s {
                let g = &glog[si * classes..(si + 1) * classes];
                for (o, &gj) in gbias.iter_mut().zip(g) {
                    *o += gj;
                }
            }
            // dL/dv per block row (self add at the seed's position, then
            // the per-seed neighbor scatter)
            let mut dx = vec![0f32; rows * d];
            let mut dn = vec![0f32; d];
            for si in 0..s {
                let g = &glog[si * classes..(si + 1) * classes];
                for aa in 0..d {
                    let ws = &w_self[aa * classes..(aa + 1) * classes];
                    let wn = &w_neigh[aa * classes..(aa + 1) * classes];
                    let mut acc_s = 0f32;
                    let mut acc_n = 0f32;
                    for ((&gj, wsj), wnj) in g.iter().zip(ws).zip(wn) {
                        acc_s += gj * wsj;
                        acc_n += gj * wnj;
                    }
                    dx[si * d + aa] += acc_s;
                    dn[aa] = acc_n;
                }
                let nbs = block.neighbors_of(si);
                if !nbs.is_empty() {
                    let inv = 1.0 / nbs.len() as f32;
                    for &r in nbs {
                        let dst = &mut dx[r as usize * d..(r as usize + 1) * d];
                        for (o, v) in dst.iter_mut().zip(&dn) {
                            *o += inv * v;
                        }
                    }
                }
            }
            // embedding scatter (block-row order; Bloom: weight 1 per hash)
            for (r, &nid) in block.nodes.iter().enumerate() {
                let gv = &dx[r * d..(r + 1) * d];
                for t in 0..h {
                    let row = node.node_major[nid as usize * h + t] as usize;
                    for (o, &v) in gx[row * d..(row + 1) * d].iter_mut().zip(gv) {
                        *o += v;
                    }
                }
            }
            // dense SGD apply + gradient reset
            let xt = store.get_mut(&node.table.name);
            for (w, g) in xt.iter_mut().zip(&gx) {
                *w -= lr * g;
            }
            for (w, g) in w_self.iter_mut().zip(&gw_self) {
                *w -= lr * g;
            }
            for (w, g) in w_neigh.iter_mut().zip(&gw_neigh) {
                *w -= lr * g;
            }
            for (w, g) in bias.iter_mut().zip(&gbias) {
                *w -= lr * g;
            }
            gx.fill(0.0);
            gw_self.fill(0.0);
            gw_neigh.fill(0.0);
            gbias.fill(0.0);
            seen += s;
        }
        losses.push(loss_sum / seen as f64);
    }
    losses
}

#[test]
fn l1_trainer_reproduces_the_legacy_single_hop_trainer_bit_for_bit() {
    let ds = small_dataset(360, 16);
    let method = EmbeddingMethod::Bloom { buckets: 48, h: 2 };
    let plan = EmbeddingPlan::build(360, 16, &method, None, 5);
    let (batch, fanout, epochs, lr, seed) = (48usize, 4usize, 3usize, 0.05f32, 11u64);
    let legacy = legacy_single_hop_losses(&ds, &plan, batch, fanout, epochs, lr, seed);
    let cfg =
        SamplerConfig { batch_size: batch, fanouts: Fanout::Max(fanout).into(), shuffle: true };
    let opts = MinibatchOptions {
        epochs,
        lr,
        optimizer: OptimizerKind::Sgd,
        seed,
        parallel: false,
        prefetch: 0,
        ..Default::default()
    };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg.clone(), opts.clone()).unwrap();
    let serial = tr.train().unwrap().losses;
    assert_eq!(serial, legacy, "generalized L=1 serial trainer drifted from the legacy loop");
    // and the pipelined engine reproduces the same bits
    let piped_opts = MinibatchOptions { parallel: true, prefetch: 2, ..opts };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, piped_opts).unwrap();
    let piped = tr.train().unwrap().losses;
    assert_eq!(piped, legacy, "pipelined L=1 trainer drifted from the legacy loop");
}

#[test]
fn two_layer_pipelined_is_bit_identical_to_its_serial_oracle_at_1_and_4_threads() {
    // the acceptance pin: L=2 pipelined (prefetch + parallel step) must
    // reproduce the L=2 serial oracle EXACTLY, for SGD and Adam.
    let ds = small_dataset(500, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 5, h: 2 };
    let plan = EmbeddingPlan::build(500, 16, &method, Some(&hier), 3);
    let cfg =
        SamplerConfig { batch_size: 72, fanouts: Fanouts::parse("5,3").unwrap(), shuffle: true };
    for optimizer in [OptimizerKind::Sgd, OptimizerKind::Adam] {
        let serial = run_losses(&ds, &plan, &cfg, optimizer, false, 0);
        let piped1 = in_pool(1, || run_losses(&ds, &plan, &cfg, optimizer, true, 2));
        let piped4 = in_pool(4, || run_losses(&ds, &plan, &cfg, optimizer, true, 2));
        assert_eq!(piped1, serial, "{:?}: 1-thread pipelined vs serial", optimizer);
        assert_eq!(piped4, serial, "{:?}: 4-thread pipelined vs serial", optimizer);
    }
}

#[test]
fn two_layer_prefetch_depth_does_not_change_the_trajectory() {
    let ds = small_dataset(400, 16);
    let plan =
        EmbeddingPlan::build(400, 16, &EmbeddingMethod::HashEmb { buckets: 48, h: 2 }, None, 1);
    let cfg =
        SamplerConfig { batch_size: 56, fanouts: Fanouts::parse("4,2").unwrap(), shuffle: true };
    let baseline = run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 0);
    for depth in [1usize, 2, 8] {
        let got = run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, depth);
        assert_eq!(got, baseline, "prefetch depth {depth}");
    }
}

#[test]
fn all_fanout_two_layer_minibatch_matches_full_batch_trajectory() {
    // acceptance: all fanouts = ∞, L layers reproduces an L-layer
    // full-batch trajectory within 1e-5 per epoch, for SGD and Adam.
    let ds = small_dataset(450, 16);
    let plan = EmbeddingPlan::build(
        450,
        16,
        &EmbeddingMethod::HashEmb { buckets: 56, h: 2 },
        None,
        9,
    );
    for optimizer in [OptimizerKind::Sgd, OptimizerKind::Adam] {
        let opts = MinibatchOptions {
            epochs: 4,
            lr: 0.02,
            optimizer,
            seed: 9,
            hidden: 16,
            ..Default::default()
        };
        let full = train_full_batch(&ds, &plan, &opts, 2).unwrap();
        let cfg = SamplerConfig::oracle(ds.splits.train.len(), 2);
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        let mini = tr.train().unwrap();
        assert_eq!(mini.losses.len(), full.losses.len());
        for (e, (m, f)) in mini.losses.iter().zip(&full.losses).enumerate() {
            assert!(
                (m - f).abs() <= 1e-5,
                "{optimizer:?} epoch {e}: minibatch {m} vs full-batch {f}"
            );
        }
        // the same data path also yields (near-)identical final metrics
        assert!((mini.val_metric - full.val_metric).abs() <= 0.02, "{optimizer:?}");
        assert!((mini.test_metric - full.test_metric).abs() <= 0.02, "{optimizer:?}");
    }
}

#[test]
fn all_fanout_three_layer_minibatch_matches_full_batch_trajectory() {
    let ds = small_dataset(300, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(3, 2));
    let method = EmbeddingMethod::PosHashEmbInter { levels: 2, buckets: 30, h: 2 };
    let plan = EmbeddingPlan::build(300, 16, &method, Some(&hier), 2);
    let opts = MinibatchOptions {
        epochs: 3,
        lr: 0.05,
        optimizer: OptimizerKind::Sgd,
        seed: 4,
        hidden: 8,
        ..Default::default()
    };
    let full = train_full_batch(&ds, &plan, &opts, 3).unwrap();
    let cfg = SamplerConfig::oracle(ds.splits.train.len(), 3);
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let mini = tr.train().unwrap();
    for (e, (m, f)) in mini.losses.iter().zip(&full.losses).enumerate() {
        assert!((m - f).abs() <= 1e-5, "epoch {e}: minibatch {m} vs full-batch {f}");
    }
}

#[test]
fn two_layer_training_is_bit_identical_across_thread_counts() {
    let ds = small_dataset(420, 16);
    let plan = EmbeddingPlan::build(
        420,
        16,
        &EmbeddingMethod::HashEmb { buckets: 48, h: 2 },
        None,
        6,
    );
    let cfg =
        SamplerConfig { batch_size: 64, fanouts: Fanouts::parse("6,3").unwrap(), shuffle: true };
    let l1 = in_pool(1, || run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 2));
    let l4 = in_pool(4, || run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 2));
    assert_eq!(l1, l4, "L=2 losses diverge across thread counts");
}

#[test]
fn deep_head_peak_rows_respect_the_multi_hop_bound() {
    // peak compose rows for L hops is bounded by batch·Π(fanout_l + 1)
    // and must stay below n for small batches
    let n = 1500;
    let ds = small_dataset(n, 16);
    let plan =
        EmbeddingPlan::build(n, 16, &EmbeddingMethod::HashEmb { buckets: 96, h: 2 }, None, 5);
    let (batch, f0, f1) = (32usize, 4usize, 3usize);
    let cfg = SamplerConfig {
        batch_size: batch,
        fanouts: Fanouts::new(vec![Fanout::Max(f0), Fanout::Max(f1)]),
        shuffle: true,
    };
    let opts = MinibatchOptions { epochs: 2, seed: 5, hidden: 16, ..Default::default() };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let out = tr.train().unwrap();
    assert!(out.peak_compose_rows >= batch);
    assert!(
        out.peak_compose_rows <= batch * (f0 + 1) * (f1 + 1),
        "peak {} exceeds batch·Π(fanout+1) = {}",
        out.peak_compose_rows,
        batch * (f0 + 1) * (f1 + 1)
    );
    assert!(out.peak_compose_rows < n, "deep head composed the full matrix");
    assert!(out.losses.iter().all(|l| l.is_finite()));
}
