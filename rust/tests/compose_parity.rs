//! Property-based parity: `ComposeEngine::compose_all` and
//! `compose_batch` must match the scalar oracle
//! `reference::compose_embeddings` within 1e-5 for EVERY
//! `EmbeddingMethod` variant, over random graphs, hierarchies, embedding
//! dimensions, block sizes and hash seeds (proptest shrinks failures to
//! a minimal case).

use poshashemb::embedding::{
    compose_embeddings, init_params, ComposeEngine, ComposeOptions, EmbeddingMethod, EmbeddingPlan,
};
use poshashemb::graph::{planted_partition, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use proptest::prelude::*;

const TOL: f32 = 1e-5;

/// Build the method for a variant index so every enum variant is covered
/// uniformly; parameters derive from (n, salt) to stay in-range.
fn method_for(variant: usize, n: usize, salt: usize) -> EmbeddingMethod {
    let buckets = 2 + (salt % (n / 2).max(1));
    let h = 1 + salt % 3;
    let levels = 1 + salt % 3;
    match variant {
        0 => EmbeddingMethod::Full,
        1 => EmbeddingMethod::HashTrick { buckets },
        2 => EmbeddingMethod::Bloom { buckets, h },
        3 => EmbeddingMethod::HashEmb { buckets, h },
        4 => EmbeddingMethod::Dhe {
            encoding_dim: 4 + salt % 8,
            hidden: 8 + salt % 8,
            layers: 1 + salt % 2,
        },
        5 => EmbeddingMethod::PosEmb { levels },
        6 => EmbeddingMethod::RandomPart { parts: 2 + salt % 6 },
        7 => EmbeddingMethod::PosFullEmb { levels },
        8 => EmbeddingMethod::PosHashEmbInter { levels, buckets, h },
        _ => EmbeddingMethod::PosHashEmbIntra { levels, compression: 1 + salt % 9, h },
    }
}

fn random_hierarchy(n: usize, k: usize, seed: u64) -> Hierarchy {
    let (g, _) = planted_partition(&PlantedPartitionConfig {
        n,
        communities: k,
        intra_degree: 6.0,
        inter_degree: 1.5,
        seed,
        ..Default::default()
    });
    let mut cfg = HierarchyConfig::new(k, 3);
    cfg.base.seed = seed ^ 0x51;
    Hierarchy::build(&g, &cfg)
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!((x - y).abs() <= TOL, "{what}: element {i} diverges: {x} vs {y}");
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    #[test]
    fn engine_matches_reference_for_all_methods(
        variant in 0usize..10,
        n in 40usize..260,
        d_sel in 0usize..3,
        k in 2usize..5,
        salt in 0usize..1000,
        seed in any::<u64>(),
        block in 1usize..96,
    ) {
        let d = [8usize, 16, 32][d_sel];
        let method = method_for(variant, n, salt);
        let hier = method
            .needs_hierarchy()
            .then(|| random_hierarchy(n, k, seed ^ 0xF00D));
        let plan = EmbeddingPlan::build(n, d, &method, hier.as_ref(), seed);
        let params = init_params(&plan, seed ^ 0x9E37);

        let oracle = compose_embeddings(&plan, &params);
        let opts = ComposeOptions { block_nodes: block, parallel: true };
        let engine = ComposeEngine::with_options(&plan, opts);

        // full-matrix path
        let fast = engine.compose_all(&params);
        assert_close(&fast, &oracle, &format!("compose_all[{}]", method.name()));

        // minibatch path: strided, unordered, with a repeat
        let mut nodes: Vec<u32> = (0..n as u32).step_by(1 + salt % 5).collect();
        nodes.reverse();
        nodes.push(nodes[0]);
        let batch = engine.compose_batch(&params, &nodes);
        for (row, &i) in nodes.iter().enumerate() {
            let got = &batch[row * d..(row + 1) * d];
            let want = &oracle[i as usize * d..(i as usize + 1) * d];
            assert_close(got, want, &format!("compose_batch[{}] node {i}", method.name()));
        }
    }

    #[test]
    fn engine_deterministic_across_block_sizes(
        n in 50usize..200,
        seed in any::<u64>(),
        block_a in 1usize..64,
        block_b in 64usize..512,
    ) {
        let (method, _) = EmbeddingMethod::paper_default_intra(n);
        let hier = random_hierarchy(n, 3, seed);
        let plan = EmbeddingPlan::build(n, 16, &method, Some(&hier), seed);
        let params = init_params(&plan, seed);
        let a_opts = ComposeOptions { block_nodes: block_a, parallel: true };
        let b_opts = ComposeOptions { block_nodes: block_b, parallel: false };
        let a = ComposeEngine::with_options(&plan, a_opts).compose_all(&params);
        let b = ComposeEngine::with_options(&plan, b_opts).compose_all(&params);
        // identical accumulation order => identical bits, not just 1e-5
        prop_assert_eq!(a, b);
    }
}
