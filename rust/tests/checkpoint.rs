//! Crash-safe checkpoint/resume acceptance. The headline pin: a
//! training run killed at an arbitrary batch boundary and resumed from
//! disk reproduces the uninterrupted run's loss trajectory and final
//! parameter tables **bit for bit** — serial or pipelined, SGD or
//! Adam. Also pins torn-checkpoint fallback, keep-last-K retention,
//! run-key refusal and the fresh-start (empty dir) resume path.
//!
//! Every test that trains takes [`fault::test_guard`] for its whole
//! body: the fault registry and its hit counters are process-global,
//! so an armed `trainer.step` fault in one test must never leak hits
//! into a concurrently running control trainer of another.

use poshashemb::coordinator::{
    CheckpointConfig, EdgeDecoder, MinibatchOptions, MinibatchTrainer, Objective, OptimizerKind,
};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan, ParamStore};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanout, SamplerConfig};
use poshashemb::util::fault;
use poshashemb::util::tempdir::TempDir;
use std::path::{Path, PathBuf};

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

/// A paper-method configuration with every trainable table family
/// (position levels + intra pools + learned y + SAGE head).
fn build(n: usize) -> (Dataset, EmbeddingPlan) {
    let ds = small_dataset(n, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 5, h: 2 };
    let plan = EmbeddingPlan::build(n, 16, &method, Some(&hier), 3);
    (ds, plan)
}

fn cfg() -> SamplerConfig {
    SamplerConfig { batch_size: 64, fanouts: Fanout::Max(5).into(), shuffle: true }
}

fn opts(
    optimizer: OptimizerKind,
    parallel: bool,
    checkpoint: Option<CheckpointConfig>,
    resume: bool,
) -> MinibatchOptions {
    MinibatchOptions {
        epochs: 4,
        lr: 0.03,
        optimizer,
        seed: 7,
        parallel,
        prefetch: if parallel { 2 } else { 0 },
        hidden: 16,
        checkpoint,
        resume,
        ..Default::default()
    }
}

/// Every tensor's exact bits, in canonical order.
fn param_bits(p: &ParamStore) -> Vec<(String, Vec<u32>)> {
    p.names()
        .iter()
        .map(|n| (n.clone(), p.get(n).iter().map(|x| x.to_bits()).collect()))
        .collect()
}

fn ckpt_names(root: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(root)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("ckpt-"))
        .collect();
    names.sort();
    names
}

fn newest_ckpt(root: &Path) -> PathBuf {
    root.join(ckpt_names(root).last().expect("at least one checkpoint"))
}

#[test]
fn killed_and_resumed_training_is_bit_identical_to_uninterrupted() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let runs =
        [(OptimizerKind::Adam, false), (OptimizerKind::Adam, true), (OptimizerKind::Sgd, false)];
    for (optimizer, parallel) in runs {
        let label = format!("{optimizer:?} parallel={parallel}");

        // uninterrupted control
        let o = opts(optimizer, parallel, None, false);
        let mut control = MinibatchTrainer::new(&ds, &plan, cfg(), o).unwrap();
        let control_out = control.train().unwrap();

        // victim: checkpoints every 3 steps, killed before its 8th step
        // (mid-epoch: 420 nodes / batch 64 is > 1 batch per epoch)
        let t = TempDir::new("ckpt-parity").unwrap();
        let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 3, keep: 0 };
        let o = opts(optimizer, parallel, Some(ck.clone()), false);
        let mut victim = MinibatchTrainer::new(&ds, &plan, cfg(), o).unwrap();
        fault::arm("trainer.step=8").unwrap();
        let err = victim.train().unwrap_err();
        fault::reset();
        assert!(format!("{err:#}").contains("injected fault"), "{label}: {err:#}");
        assert!(!ckpt_names(t.path()).is_empty(), "{label}: victim left no checkpoint");

        // resume from disk and train to completion
        let o = opts(optimizer, parallel, Some(ck), true);
        let mut resumed = MinibatchTrainer::new(&ds, &plan, cfg(), o).unwrap();
        let resumed_out = resumed.train().unwrap();

        assert_eq!(resumed_out.losses, control_out.losses, "{label}: loss trajectory");
        assert_eq!(resumed_out.val_metric, control_out.val_metric, "{label}: val metric");
        assert_eq!(resumed_out.test_metric, control_out.test_metric, "{label}: test metric");
        assert_eq!(param_bits(resumed.params()), param_bits(control.params()), "{label}: tables");
    }
}

#[test]
fn killed_and_resumed_link_prediction_is_bit_identical_to_uninterrupted() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    // LP shares the checkpoint machinery wholesale (RunKey carries the
    // objective; edge_w/edge_b live in the ParamStore), so kill/resume
    // parity must hold under it too — pipelined and serial.
    let runs = [(OptimizerKind::Adam, true), (OptimizerKind::Sgd, false)];
    for (optimizer, parallel) in runs {
        let label = format!("lp {optimizer:?} parallel={parallel}");
        let lp_opts = |checkpoint: Option<CheckpointConfig>, resume: bool| {
            let mut o = opts(optimizer, parallel, checkpoint, resume);
            o.objective =
                Objective::LinkPrediction { decoder: EdgeDecoder::Hadamard, neg_per_pos: 2 };
            o
        };

        // uninterrupted control
        let mut control = MinibatchTrainer::new(&ds, &plan, cfg(), lp_opts(None, false)).unwrap();
        let control_out = control.train().unwrap();

        // victim: checkpoints every 3 steps, killed before its 8th step
        let t = TempDir::new("ckpt-lp-parity").unwrap();
        let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 3, keep: 0 };
        let mut victim =
            MinibatchTrainer::new(&ds, &plan, cfg(), lp_opts(Some(ck.clone()), false)).unwrap();
        fault::arm("trainer.step=8").unwrap();
        let err = victim.train().unwrap_err();
        fault::reset();
        assert!(format!("{err:#}").contains("injected fault"), "{label}: {err:#}");
        assert!(!ckpt_names(t.path()).is_empty(), "{label}: victim left no checkpoint");

        // resume from disk and train to completion
        let mut resumed =
            MinibatchTrainer::new(&ds, &plan, cfg(), lp_opts(Some(ck), true)).unwrap();
        let resumed_out = resumed.train().unwrap();

        assert_eq!(resumed_out.losses, control_out.losses, "{label}: loss trajectory");
        assert_eq!(resumed_out.val_metric, control_out.val_metric, "{label}: val AUC");
        assert_eq!(resumed_out.test_metric, control_out.test_metric, "{label}: test AUC");
        assert_eq!(resumed_out.val_hits, control_out.val_hits, "{label}: val hits@k");
        assert_eq!(resumed_out.test_hits, control_out.test_hits, "{label}: test hits@k");
        assert_eq!(param_bits(resumed.params()), param_bits(control.params()), "{label}: tables");
    }
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_objective() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let t = TempDir::new("ckpt-objkey").unwrap();
    let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 2, keep: 0 };
    // node-classification victim leaves checkpoints behind...
    let mut victim =
        MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck.clone()), false)).unwrap();
    fault::arm("trainer.step=5").unwrap();
    victim.train().unwrap_err();
    fault::reset();

    // ...which a link-prediction run must refuse to resume from
    let mut other = opts3(Some(ck), true);
    other.objective = Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 2 };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg(), other).unwrap();
    let err = tr.train().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different run"), "refusal names the cause: {msg}");
    assert!(msg.contains("objective"), "refusal names the differing field: {msg}");
}

#[test]
fn resume_falls_back_when_the_newest_checkpoint_is_torn() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let mut control = MinibatchTrainer::new(&ds, &plan, cfg(), opts3(None, false)).unwrap();
    let control_out = control.train().unwrap();

    let t = TempDir::new("ckpt-torn").unwrap();
    let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 2, keep: 0 };
    let mut victim =
        MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck.clone()), false)).unwrap();
    fault::arm("trainer.step=7").unwrap();
    victim.train().unwrap_err();
    fault::reset();
    assert!(ckpt_names(t.path()).len() >= 2, "need an older checkpoint to fall back to");

    // tear the newest checkpoint the way an unluckily-timed crash
    // would: its manifest (always written last) goes missing
    std::fs::remove_file(newest_ckpt(t.path()).join("manifest.json")).unwrap();

    let mut resumed = MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck), true)).unwrap();
    let out = resumed.train().unwrap();
    assert_eq!(out.losses, control_out.losses, "fallback resume still matches the control");
    assert_eq!(param_bits(resumed.params()), param_bits(control.params()));
}

/// [`opts`] pinned to the serial-Adam configuration the single-path
/// tests use.
fn opts3(checkpoint: Option<CheckpointConfig>, resume: bool) -> MinibatchOptions {
    opts(OptimizerKind::Adam, false, checkpoint, resume)
}

#[test]
fn retention_keeps_only_the_newest_k_checkpoints() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let t = TempDir::new("ckpt-keep").unwrap();
    let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 1, keep: 2 };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck), false)).unwrap();
    let out = tr.train().unwrap();
    assert_eq!(out.losses.len(), 4, "full run completed");
    let names = ckpt_names(t.path());
    assert_eq!(names.len(), 2, "keep=2 retains exactly two: {names:?}");
    let latest = std::fs::read_to_string(t.path().join("LATEST")).unwrap();
    assert_eq!(latest.trim(), names.last().unwrap().as_str(), "LATEST names the newest");
}

#[test]
fn resume_refuses_a_checkpoint_from_a_different_run() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let t = TempDir::new("ckpt-runkey").unwrap();
    let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 2, keep: 0 };
    let mut victim =
        MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck.clone()), false)).unwrap();
    fault::arm("trainer.step=5").unwrap();
    victim.train().unwrap_err();
    fault::reset();

    let mut other = opts3(Some(ck), true);
    other.lr = 0.05;
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg(), other).unwrap();
    let err = tr.train().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different run"), "refusal names the cause: {msg}");
    assert!(msg.contains("lr"), "refusal names the differing field: {msg}");
}

#[test]
fn resume_on_an_empty_directory_trains_from_scratch() {
    let _g = fault::test_guard();
    fault::reset();
    let (ds, plan) = build(420);
    let mut control = MinibatchTrainer::new(&ds, &plan, cfg(), opts3(None, false)).unwrap();
    let control_out = control.train().unwrap();

    let t = TempDir::new("ckpt-empty").unwrap();
    let ck = CheckpointConfig { dir: t.path().to_path_buf(), every: 0, keep: 0 };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg(), opts3(Some(ck), true)).unwrap();
    let out = tr.train().unwrap();
    assert_eq!(out.losses, control_out.losses, "fresh-start resume is a plain run");
    assert!(ckpt_names(t.path()).is_empty(), "every=0 writes no periodic checkpoints");
}
