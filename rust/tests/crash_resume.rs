//! Subprocess crash-resume smoke: drives the real binary's
//! `crash-test` subcommand — an uninterrupted control, a checkpointing
//! victim killed mid-epoch by an injected abort (`POSHASH_FAULT`,
//! no unwinding, no destructors, no flushes), and a `--resume` that
//! must land on the control's loss trajectory bit for bit. The
//! in-process twin of these scenarios lives in `tests/checkpoint.rs`;
//! this file is the one that proves recovery across a genuine process
//! death.

use std::process::Command;

fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_poshashemb"));
    // never inherit a fault spec from the test runner's environment
    c.env_remove("POSHASH_FAULT");
    c
}

#[test]
fn crash_test_harness_passes_on_the_pipelined_path() {
    let out = bin().arg("crash-test").output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "crash-test failed:\nstdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("crash-test PASS"), "stdout: {stdout}");
}

#[test]
fn crash_test_harness_passes_on_the_serial_oracle_path() {
    let out = bin().args(["crash-test", "--serial"]).output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "crash-test failed:\nstdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("crash-test PASS"), "stdout: {stdout}");
}

#[test]
fn resume_without_a_checkpoint_dir_is_refused() {
    let out = bin()
        .args(["train-minibatch", "--nodes", "300", "--dim", "8", "--epochs", "1", "--resume"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--resume without --checkpoint-dir must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--checkpoint-dir"), "stderr: {stderr}");
}
