//! Pipelined-engine validation: property-based checks that (a) sharded
//! `GradBuffer` accumulation is bit-identical to serial accumulation,
//! (b) the pipelined trainer (prefetched sampling + parallel backward +
//! parallel optimizer apply) reproduces the serial oracle trainer's
//! loss trajectory **exactly** — at 1 and 4 rayon threads, for SGD and
//! Adam, across prefetch depths — and (c) the parallel optimizer apply
//! path matches the serial one bit for bit on large touched sets.
//!
//! Thread counts are varied with dedicated `rayon::ThreadPool`s rather
//! than `RAYON_NUM_THREADS` (the global pool is process-wide and the
//! test runner is itself parallel).

use poshashemb::coordinator::{GradBuffer, MinibatchOptions, MinibatchTrainer, OptimizerKind};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanout, SamplerConfig};
use proptest::prelude::*;

fn in_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("thread pool")
        .install(f)
}

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

/// Loss trajectory of one training run under the given execution knobs.
fn run_losses(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    cfg: &SamplerConfig,
    optimizer: OptimizerKind,
    parallel: bool,
    prefetch: usize,
) -> Vec<f64> {
    let opts = MinibatchOptions {
        epochs: 4,
        lr: 0.03,
        optimizer,
        seed: 7,
        parallel,
        prefetch,
        hidden: 16,
        ..Default::default()
    };
    let mut tr = MinibatchTrainer::new(ds, plan, cfg.clone(), opts).unwrap();
    tr.train().unwrap().losses
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    #[test]
    fn sharded_accumulation_is_bit_identical_to_serial(
        rows in 1usize..120,
        cols in 1usize..12,
        shards in 1usize..40,
        ops in prop::collection::vec((0usize..1000, -4.0f32..4.0, -4.0f32..4.0), 1..250),
    ) {
        // the same scatter workload applied serially and via row-range
        // shards must produce identical bits and the same touched set
        let ops: Vec<(usize, f32, Vec<f32>)> = ops
            .into_iter()
            .map(|(row, scale, v)| (row % rows, scale, vec![v; cols]))
            .collect();
        let mut serial = GradBuffer::new(rows, cols);
        for (row, scale, src) in &ops {
            serial.add_row(*row, *scale, src);
        }
        let mut sharded = GradBuffer::new(rows, cols);
        sharded.sharded_accumulate(shards, |sh| {
            for (row, scale, src) in &ops {
                if sh.contains(*row) {
                    sh.add_row(*row, *scale, src);
                }
            }
        });
        for row in 0..rows {
            prop_assert_eq!(serial.row(row), sharded.row(row), "row {}", row);
        }
        let mut a: Vec<u32> = serial.touched_rows().to_vec();
        let mut b: Vec<u32> = sharded.touched_rows().to_vec();
        a.sort_unstable();
        b.sort_unstable();
        prop_assert_eq!(a, b);
    }

    #[test]
    fn pipelined_training_reproduces_serial_oracle_exactly(
        n in 300usize..700,
        batch in 48usize..160,
        fanout in 2usize..8,
        adam in any::<bool>(),
    ) {
        // the acceptance pin: prefetched + parallel-backward training
        // must reproduce the serial trainer's loss trajectory EXACTLY
        // (bit-for-bit f64 equality), at 1 and at 4 rayon threads.
        let ds = small_dataset(n, 16);
        let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 3));
        let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 5, h: 2 };
        let plan = EmbeddingPlan::build(n, 16, &method, Some(&hier), 3);
        let cfg =
            SamplerConfig { batch_size: batch, fanouts: Fanout::Max(fanout).into(), shuffle: true };
        let optimizer = if adam { OptimizerKind::Adam } else { OptimizerKind::Sgd };
        let serial = run_losses(&ds, &plan, &cfg, optimizer, false, 0);
        let piped1 = in_pool(1, || run_losses(&ds, &plan, &cfg, optimizer, true, 2));
        let piped4 = in_pool(4, || run_losses(&ds, &plan, &cfg, optimizer, true, 2));
        prop_assert_eq!(&piped1, &serial, "1-thread pipelined vs serial");
        prop_assert_eq!(&piped4, &serial, "4-thread pipelined vs serial");
    }
}

#[test]
fn prefetch_depth_does_not_change_the_trajectory() {
    let ds = small_dataset(500, 16);
    let method = EmbeddingMethod::HashEmb { buckets: 64, h: 2 };
    let plan = EmbeddingPlan::build(500, 16, &method, None, 1);
    let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(4).into(), shuffle: true };
    let baseline = run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 0);
    for depth in [1usize, 2, 8] {
        let got = run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, depth);
        assert_eq!(got, baseline, "prefetch depth {depth}");
    }
}

#[test]
fn parallel_trainer_is_bit_identical_across_thread_counts_with_head_tables() {
    // complements tests/minibatch.rs: the full method family (position
    // levels + intra pools + learned y) through the pipelined path.
    let ds = small_dataset(650, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 2));
    let method = EmbeddingMethod::PosHashEmbInter { levels: 2, buckets: 48, h: 2 };
    let plan = EmbeddingPlan::build(650, 16, &method, Some(&hier), 5);
    let cfg = SamplerConfig { batch_size: 96, fanouts: Fanout::Max(6).into(), shuffle: true };
    let l1 = in_pool(1, || run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 2));
    let l4 = in_pool(4, || run_losses(&ds, &plan, &cfg, OptimizerKind::Adam, true, 2));
    assert_eq!(l1, l4);
}

#[test]
fn full_embedding_method_trains_identically_serial_and_pipelined() {
    // FullEmb exercises the identity node plan (h = 1, no learned y):
    // the node-major gather layout must not disturb it either.
    let ds = small_dataset(400, 16);
    let plan = EmbeddingPlan::build(400, 16, &EmbeddingMethod::Full, None, 2);
    let cfg = SamplerConfig { batch_size: 80, fanouts: Fanout::Max(5).into(), shuffle: true };
    let serial = run_losses(&ds, &plan, &cfg, OptimizerKind::Sgd, false, 0);
    let piped = in_pool(4, || run_losses(&ds, &plan, &cfg, OptimizerKind::Sgd, true, 2));
    assert_eq!(piped, serial);
}
