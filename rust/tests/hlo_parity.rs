//! Cross-layer parity: the AOT HLO (with the Pallas kernel inside) vs
//! the pure-Rust reference implementation of the embedding layer.
//!
//! Strategy: for a PosEmb-only experiment (no GNN nonlinearity on the
//! embedding itself), the eval logits are GCN(V). We can't invert the
//! GNN, but linearity in V lets us verify the *composition* through a
//! sharper check: two parameter states that the Rust reference says
//! produce identical V must produce identical logits through the HLO,
//! and states differing only in one partition's row must change only
//! that partition's nodes' logits.

use poshashemb::config::{full_grid, materialize};
use poshashemb::coordinator::{build_statics, init_full_params};
use poshashemb::embedding::compose_embeddings;
use poshashemb::runtime::{DeviceBuffer, HostTensor, Manifest, RuntimeClient};
use std::path::Path;

fn setup() -> Option<(RuntimeClient, Manifest)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let client = match RuntimeClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping: {e}");
            return None;
        }
    };
    Some((client, Manifest::load(dir).unwrap()))
}

/// Run the eval HLO at given packed params, return logits.
fn eval_logits(
    client: &RuntimeClient,
    manifest: &Manifest,
    name: &str,
    state_host: &[f32],
    statics: &[(String, HostTensor)],
) -> Vec<f32> {
    let spec = manifest.get(&format!("{name}.eval")).unwrap();
    let exe = client.load(manifest, spec).unwrap();
    let state = client
        .upload(&HostTensor::F32(state_host.to_vec(), vec![state_host.len()]))
        .unwrap();
    let mut bufs = vec![state];
    for (_, t) in statics {
        bufs.push(client.upload(t).unwrap());
    }
    let args: Vec<&DeviceBuffer> = bufs.iter().collect();
    let outs = client.execute(&exe, &args).unwrap();
    client.download_f32(&outs[0]).unwrap()
}

#[test]
fn perturbing_one_partition_row_only_moves_that_partitions_nodes() {
    let Some((client, manifest)) = setup() else { return };
    let name = "arxiv_gcn_posemb1";
    if !manifest.contains(&format!("{name}.eval")) {
        eprintln!("skipping: {name} not lowered");
        return;
    }
    let grid = full_grid();
    let e = grid.iter().find(|e| e.name == name).unwrap();
    let (ds, hier, plan) = materialize(e, 0);
    let statics = build_statics(&ds, e.model, &plan);

    let store = init_full_params(&plan, e.model, ds.spec.classes, 0);
    let psize: usize = store.names().iter().map(|n| store.get(n).len()).sum();
    let total = 3 * psize + 2;
    let mut state = vec![0f32; total];
    let mut off = 0;
    for n in store.names() {
        let d = store.get(n);
        state[off..off + d.len()].copy_from_slice(d);
        off += d.len();
    }
    state[3 * psize] = 1.0;

    let base = eval_logits(&client, &manifest, name, &state, &statics);

    // bump partition 0's position row (pos_0 is the first table)
    let d = plan.d;
    let mut state2 = state.clone();
    for c in 0..d {
        state2[c] += 0.5; // row 0 of pos_0
    }
    let moved = eval_logits(&client, &manifest, name, &state2, &statics);

    // 2-layer GCN: nodes within 2 hops of partition 0 may move; nodes in
    // partition 0 MUST move. Check the must-move side exactly.
    let z0 = &hier.as_ref().unwrap().z[0];
    let classes = ds.spec.classes;
    let mut moved_in_p0 = 0usize;
    let mut total_p0 = 0usize;
    for i in 0..ds.graph.num_nodes() {
        let changed = (0..classes)
            .any(|c| (base[i * classes + c] - moved[i * classes + c]).abs() > 1e-6);
        if z0[i] == 0 {
            total_p0 += 1;
            moved_in_p0 += usize::from(changed);
        }
    }
    assert!(total_p0 > 0);
    assert!(
        moved_in_p0 as f64 / total_p0 as f64 > 0.99,
        "{moved_in_p0}/{total_p0} partition-0 nodes moved"
    );
}

#[test]
fn rust_reference_composition_agrees_with_itself_across_layout() {
    // Pure-Rust sanity anchoring the parity story: composing with the
    // plan's param order must equal a manual per-node walk.
    let grid = full_grid();
    let e = grid.iter().find(|e| e.name == "arxiv_gcn_intra_h2").unwrap();
    let (_ds, _h, plan) = materialize(e, 1);
    let store = poshashemb::embedding::init_params(&plan, 9);
    let v = compose_embeddings(&plan, &store);
    let d = plan.d;
    let pos = plan.position.as_ref().unwrap();
    let node = plan.node.as_ref().unwrap();
    let y = store.get("node_y");
    let h = node.h;
    for i in [0usize, 17, 1234, plan.n - 1] {
        for c in 0..d {
            let mut expect = 0f32;
            for (j, t) in pos.tables.iter().enumerate() {
                if c < t.cols {
                    expect += store.get(&t.name)[pos.z[j][i] as usize * t.cols + c];
                }
            }
            for t in 0..h {
                let row = node.node_major[i * h + t] as usize;
                expect += y[i * h + t] * store.get("node_x")[row * d + c];
            }
            assert!((v[i * d + c] - expect).abs() < 1e-5, "node {i} dim {c}");
        }
    }
}
