//! Minibatch-training validation: property-based checks that (a) the
//! neighbor sampler respects fanout bounds and block invariants, (b)
//! sampling and whole training runs are bit-identical across rayon
//! thread counts for a fixed seed, (c) the fanout = ∞ oracle
//! configuration reproduces the full-batch trainer's loss trajectory
//! within 1e-5 per epoch, and (d) the trainer never composes an `n × d`
//! block (peak compose allocation is bounded by `batch × (fanout + 1)`).
//!
//! Thread counts are varied with dedicated `rayon::ThreadPool`s rather
//! than `RAYON_NUM_THREADS` (the global pool is process-wide and the
//! test runner is itself parallel).

use poshashemb::coordinator::{
    train_full_batch, MinibatchOptions, MinibatchTrainer, OptimizerKind,
};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, CsrGraph, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanout, NeighborSampler, SamplerConfig, SeedBatcher};
use poshashemb::util::rng::Rng;
use proptest::prelude::*;
use std::collections::HashSet;

fn sbm(n: usize, communities: usize, intra: f64, inter: f64, seed: u64) -> CsrGraph {
    planted_partition(&PlantedPartitionConfig {
        n,
        communities,
        intra_degree: intra,
        inter_degree: inter,
        seed,
        ..Default::default()
    })
    .0
}

fn in_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("thread pool")
        .install(f)
}

/// Shrunk synth-arxiv analog: small enough for per-epoch full-batch
/// composes in debug-mode tests, same generator and split machinery.
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

fn distinct_seeds(n: usize, count: usize, seed: u64) -> Vec<u32> {
    let mut ids: Vec<u32> = (0..n as u32).collect();
    Rng::seed_from_u64(seed).shuffle(&mut ids);
    ids.truncate(count.clamp(1, n));
    ids
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    #[test]
    fn sampled_blocks_respect_fanout_bounds(
        n in 80usize..600,
        communities in 2usize..7,
        fanout in 1usize..8,
        seed in any::<u64>(),
    ) {
        let g = sbm(n, communities, 7.0, 2.0, seed);
        let seeds = distinct_seeds(n, n / 4, seed ^ 0xF00);
        let mut sampler = NeighborSampler::new(&g, Fanout::Max(fanout), seed);
        let block = sampler.sample_block(&seeds, 3, 1);
        // seeds form the block prefix, all rows are unique node ids
        prop_assert_eq!(&block.nodes[..seeds.len()], &seeds[..]);
        let unique: HashSet<u32> = block.nodes.iter().copied().collect();
        prop_assert_eq!(unique.len(), block.nodes.len(), "duplicate block rows");
        for (si, &s) in seeds.iter().enumerate() {
            let sampled = block.neighbors_of(si);
            let deg = g.degree(s);
            prop_assert_eq!(sampled.len(), deg.min(fanout), "seed {} fanout", s);
            let mut globals = HashSet::new();
            for &r in sampled {
                let v = block.nodes[r as usize];
                prop_assert!(g.neighbors(s).contains(&v), "{v} is not a neighbor of {s}");
                prop_assert!(globals.insert(v), "neighbor {v} sampled twice for {s}");
            }
        }
        // resampling the same coordinates reproduces the block exactly
        prop_assert_eq!(block, sampler.sample_block(&seeds, 3, 1));
    }

    #[test]
    fn sampling_is_thread_count_invariant(
        n in 100usize..500,
        fanout in 1usize..6,
        seed in any::<u64>(),
    ) {
        let g = sbm(n, 4, 8.0, 1.5, seed);
        let seeds = distinct_seeds(n, 40, seed ^ 0xB00);
        let block1 = in_pool(1, || {
            NeighborSampler::new(&g, Fanout::Max(fanout), seed).sample_block(&seeds, 2, 5)
        });
        let block4 = in_pool(4, || {
            NeighborSampler::new(&g, Fanout::Max(fanout), seed).sample_block(&seeds, 2, 5)
        });
        prop_assert_eq!(block1, block4);
        let batcher = SeedBatcher::new(&seeds, 7, true, seed);
        let b1 = in_pool(1, || batcher.epoch_batches(9));
        let b4 = in_pool(4, || batcher.epoch_batches(9));
        prop_assert_eq!(b1, b4);
    }

    #[test]
    fn oracle_minibatch_matches_full_batch_trainer(
        n in 400usize..800,
        seed in any::<u64>(),
    ) {
        // fanout = ∞, one batch = the whole train split, no shuffle:
        // the minibatch path must reproduce the full-batch trainer's
        // loss trajectory (acceptance bound: 1e-5 per epoch).
        let ds = small_dataset(n, 16);
        let plan = EmbeddingPlan::build(
            n,
            16,
            &EmbeddingMethod::HashEmb { buckets: (n / 8).max(8), h: 2 },
            None,
            seed,
        );
        let opts = MinibatchOptions {
            epochs: 6,
            lr: 0.05,
            optimizer: OptimizerKind::Sgd,
            seed,
            ..Default::default()
        };
        let full = train_full_batch(&ds, &plan, &opts, 1).unwrap();
        let cfg = SamplerConfig::oracle(ds.splits.train.len(), 1);
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        let mini = tr.train().unwrap();
        prop_assert_eq!(mini.losses.len(), full.losses.len());
        for (e, (a, b)) in mini.losses.iter().zip(&full.losses).enumerate() {
            prop_assert!(
                (a - b).abs() <= 1e-5,
                "epoch {}: minibatch loss {} vs full-batch {}",
                e, a, b
            );
        }
    }
}

#[test]
fn oracle_parity_holds_with_adam_and_position_tables() {
    // the paper-default method family (position + intra hash pools) with
    // Adam: same oracle-parity contract as the SGD proptest.
    let ds = small_dataset(600, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 6, h: 2 };
    let plan = EmbeddingPlan::build(600, 16, &method, Some(&hier), 11);
    let opts = MinibatchOptions {
        epochs: 5,
        lr: 0.01,
        optimizer: OptimizerKind::Adam,
        seed: 11,
        ..Default::default()
    };
    let full = train_full_batch(&ds, &plan, &opts, 1).unwrap();
    let cfg = SamplerConfig::oracle(ds.splits.train.len(), 1);
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let mini = tr.train().unwrap();
    for (e, (a, b)) in mini.losses.iter().zip(&full.losses).enumerate() {
        assert!((a - b).abs() <= 1e-5, "epoch {e}: {a} vs {b}");
    }
    // the same data path also yields (near-)identical final metrics;
    // slack allows a borderline argmax flip from float associativity
    assert!((mini.val_metric - full.val_metric).abs() <= 0.02);
    assert!((mini.test_metric - full.test_metric).abs() <= 0.02);
}

#[test]
fn trainer_never_composes_a_full_matrix() {
    // acceptance: peak compose allocation is batch_rows × d, bounded by
    // batch × (fanout + 1) — never the n × d the paper tells us to avoid.
    let n = 2000;
    let ds = small_dataset(n, 16);
    let plan = EmbeddingPlan::build(
        n,
        16,
        &EmbeddingMethod::HashEmb { buckets: 128, h: 2 },
        None,
        5,
    );
    let (batch, fanout) = (64, 4);
    let cfg =
        SamplerConfig { batch_size: batch, fanouts: Fanout::Max(fanout).into(), shuffle: true };
    let opts = MinibatchOptions { epochs: 3, seed: 5, ..Default::default() };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let out = tr.train().unwrap();
    assert!(out.peak_compose_rows >= batch, "peak {} below batch", out.peak_compose_rows);
    assert!(
        out.peak_compose_rows <= batch * (fanout + 1),
        "peak {} exceeds batch × (fanout + 1) = {}",
        out.peak_compose_rows,
        batch * (fanout + 1)
    );
    assert!(out.peak_compose_rows < n, "minibatch trainer composed the full matrix");
    assert!(out.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn training_is_bit_identical_across_thread_counts() {
    let ds = small_dataset(700, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 2));
    let method = EmbeddingMethod::PosHashEmbInter { levels: 2, buckets: 60, h: 2 };
    let plan = EmbeddingPlan::build(700, 16, &method, Some(&hier), 3);
    let cfg = SamplerConfig { batch_size: 96, fanouts: Fanout::Max(5).into(), shuffle: true };
    let run = || {
        let opts = MinibatchOptions { epochs: 4, seed: 9, ..Default::default() };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg.clone(), opts).unwrap();
        tr.train().unwrap().losses
    };
    let l1 = in_pool(1, run);
    let l4 = in_pool(4, run);
    assert_eq!(l1, l4, "losses diverge across thread counts");
}

#[test]
fn minibatch_training_reduces_loss_and_scores_sanely() {
    let ds = small_dataset(1200, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(5, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 8, h: 2 };
    let plan = EmbeddingPlan::build(1200, 16, &method, Some(&hier), 1);
    let cfg = SamplerConfig { batch_size: 128, fanouts: Fanout::Max(8).into(), shuffle: true };
    let opts = MinibatchOptions { epochs: 15, lr: 0.02, seed: 1, ..Default::default() };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let out = tr.train().unwrap();
    let first = out.losses.first().copied().unwrap();
    let last = out.losses.last().copied().unwrap();
    assert!(last < first * 0.95, "loss did not decrease: {first} -> {last}");
    assert!((0.0..=1.0).contains(&out.val_metric));
    assert!((0.0..=1.0).contains(&out.test_metric));
}

#[test]
fn multilabel_task_trains_with_finite_decreasing_loss() {
    let mut s = spec("synth-proteins").unwrap();
    s.n = 600;
    s.communities = 12;
    s.d = 16;
    let ds = Dataset::generate(&s);
    let plan = EmbeddingPlan::build(
        600,
        16,
        &EmbeddingMethod::HashEmb { buckets: 64, h: 2 },
        None,
        2,
    );
    let cfg = SamplerConfig { batch_size: 96, fanouts: Fanout::Max(6).into(), shuffle: true };
    let opts = MinibatchOptions { epochs: 10, lr: 0.02, seed: 2, ..Default::default() };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    let out = tr.train().unwrap();
    assert!(out.losses.iter().all(|l| l.is_finite()));
    let first = out.losses.first().copied().unwrap();
    let last = out.losses.last().copied().unwrap();
    assert!(last < first, "multilabel loss did not decrease: {first} -> {last}");
    // ROC-AUC lives in [0, 1]
    assert!((0.0..=1.0).contains(&out.val_metric));
    assert!((0.0..=1.0).contains(&out.test_metric));
}
