//! Serving-path validation: versioned model artifacts + the query
//! engine.
//!
//! * save → load round trip is **bit-exact**: `ServeEngine::embed`
//!   returns the same bits as `ComposeEngine::compose_batch` on the
//!   original in-memory (plan, params).
//! * corruption is diagnosable: a flipped byte fails naming the
//!   section, a future `format_version` fails mentioning the gate.
//! * the hot-node LRU cache is invisible to results: cached and
//!   uncached engines agree bit for bit at every capacity, including
//!   caches smaller than the working set (eviction churn).
//! * train → save → serve end to end: `MinibatchOptions::save_model`
//!   writes an artifact whose `classify`/`topk_neighbors` answers are
//!   well-formed and deterministic.
//! * the acceptance memory band: an `inter(k=9)` artifact at n = 6000,
//!   d = 64 keeps resident table bytes ≤ 15% of the Full-table
//!   baseline.

use poshashemb::coordinator::{MinibatchOptions, MinibatchTrainer};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{init_params, ComposeEngine, EmbeddingPlan, MethodSpec, ParamStore};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanouts, SamplerConfig};
use poshashemb::serve::{save_artifact, ServeEngine, FORMAT_VERSION};
use poshashemb::util::tempdir::TempDir;
use std::path::Path;

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

/// Dataset + plan for a method tag, building the hierarchy if needed.
fn build(n: usize, d: usize, tag: &str, seed: u64) -> (Dataset, EmbeddingPlan) {
    let ds = small_dataset(n, d);
    let r = MethodSpec::parse(tag).unwrap().resolve(n).unwrap();
    let hier = r.method.needs_hierarchy().then(|| {
        Hierarchy::build(&ds.graph, &HierarchyConfig::new(r.k, r.method.levels().max(1)))
    });
    let plan = EmbeddingPlan::build(n, d, &r.method, hier.as_ref(), seed);
    (ds, plan)
}

/// Save an untrained (tables-only) artifact for `tag` into `dir`.
fn save_untrained(
    dir: &Path,
    n: usize,
    d: usize,
    tag: &str,
) -> (Dataset, EmbeddingPlan, ParamStore) {
    let (ds, plan) = build(n, d, tag, 7);
    let params = init_params(&plan, 3);
    save_artifact(dir, &ds, &plan, &params, 1, 16).unwrap();
    (ds, plan, params)
}

#[test]
fn save_load_round_trip_is_bit_exact() {
    let t = TempDir::new("serve-roundtrip").unwrap();
    let (_ds, plan, params) = save_untrained(t.path(), 400, 8, "inter(k=4)");

    let manifest = {
        let engine = ServeEngine::open(t.path(), 0).unwrap();
        engine.manifest().clone()
    };
    assert_eq!(manifest.format_version, FORMAT_VERSION);
    assert_eq!(manifest.n, 400);
    assert_eq!(manifest.d, 8);
    assert_eq!(manifest.dataset, "synth-arxiv");
    // the manifest's method tag round-trips through the shared parser
    let reparsed = MethodSpec::parse(&manifest.method).unwrap().resolve(400).unwrap();
    assert_eq!(reparsed.method, plan.method);
    let table_bytes: usize = plan.param_shapes().iter().map(|s| s.size() * 4).sum();
    assert_eq!(manifest.resident_table_bytes, table_bytes);
    assert_eq!(manifest.full_table_bytes, 400 * 8 * 4);

    // embed must reproduce compose_batch on the original params bitwise
    let mut engine = ServeEngine::open(t.path(), 0).unwrap();
    let ids: Vec<u32> = (0..400).step_by(3).map(|i| i as u32).collect();
    let served = engine.embed(&ids).unwrap().to_vec();
    let oracle = ComposeEngine::new(&plan).compose_batch(&params, &ids);
    assert_eq!(served.len(), oracle.len());
    for (i, (a, b)) in served.iter().zip(&oracle).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "row element {i}: served {a} != composed {b}");
    }
}

#[test]
fn flipped_byte_fails_naming_the_section() {
    let t = TempDir::new("serve-corrupt").unwrap();
    save_untrained(t.path(), 200, 8, "inter(k=4)");
    let victim = t.path().join("pos_0.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();

    let err = ServeEngine::open(t.path(), 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum mismatch"), "unexpected error: {msg}");
    assert!(msg.contains("pos_0"), "error must name the section: {msg}");
}

#[test]
fn future_format_version_fails_cleanly() {
    let t = TempDir::new("serve-version").unwrap();
    save_untrained(t.path(), 200, 8, "hashemb");
    let mpath = t.path().join("manifest.json");
    let text = std::fs::read_to_string(&mpath).unwrap();
    let needle = format!("\"format_version\": {FORMAT_VERSION}");
    assert!(text.contains(&needle), "manifest layout changed under the test");
    std::fs::write(&mpath, text.replace(&needle, "\"format_version\": 99")).unwrap();

    let err = ServeEngine::open(t.path(), 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("format_version"), "unexpected error: {msg}");
    assert!(msg.contains("99"), "error must show the found version: {msg}");
}

#[test]
fn cached_engine_matches_uncached_bit_for_bit() {
    let t = TempDir::new("serve-cache").unwrap();
    save_untrained(t.path(), 300, 8, "intra");

    let mut oracle = ServeEngine::open(t.path(), 0).unwrap();
    // capacities below, at and above the working-set size — the small
    // ones churn through evictions constantly
    for cap in [1usize, 7, 64, 1024] {
        let mut cached = ServeEngine::open(t.path(), cap).unwrap();
        for round in 0..6u32 {
            // overlapping batches with repeats, so rounds re-hit ids
            let ids: Vec<u32> = (0..50).map(|i| (i * (round + 1) + round) % 300).collect();
            let want = oracle.embed(&ids).unwrap().to_vec();
            let got = cached.embed(&ids).unwrap();
            assert_eq!(got, &want[..], "cap {cap} round {round} diverged");
        }
        let (hits, misses) = cached.cache_stats();
        assert_eq!(hits + misses, 6 * 50, "every lookup is a hit or a miss");
        if cap >= 1024 {
            assert!(hits > 0, "warm cache must serve some hits");
        }
    }
}

#[test]
fn train_save_serve_end_to_end() {
    let t = TempDir::new("serve-e2e").unwrap();
    let (ds, plan) = build(300, 8, "inter(k=4)", 11);
    let cfg = SamplerConfig { fanouts: Fanouts::parse("4,3").unwrap(), ..Default::default() };
    let opts = MinibatchOptions {
        epochs: 1,
        hidden: 16,
        seed: 5,
        save_model: Some(t.path().to_path_buf()),
        ..Default::default()
    };
    let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
    tr.train().unwrap();

    let mut engine = ServeEngine::open(t.path(), 32).unwrap();
    let m = engine.manifest();
    assert_eq!(m.layers, 2);
    let classes = m.classes;

    // classify: one logit row per id, finite, deterministic
    let ids = [0u32, 17, 123, 299];
    let logits = engine.classify(&ids).unwrap();
    assert_eq!(logits.len(), ids.len() * classes);
    assert!(logits.iter().all(|v| v.is_finite()));
    assert_eq!(logits, engine.classify(&ids).unwrap());
    let dup_err = engine.classify(&[3, 3]).unwrap_err();
    assert!(format!("{dup_err:#}").contains("duplicate"));

    // topk: neighbors only, descending similarity, deterministic
    let k = 3;
    let top = engine.topk_neighbors(17, k).unwrap();
    assert!(top.len() <= k);
    let nbrs = ds.graph.mem().neighbors(17);
    for (v, sim) in &top {
        assert!(nbrs.contains(v), "{v} is not a neighbor of 17");
        assert!(sim.is_finite() && *sim <= 1.0 + 1e-5);
    }
    for w in top.windows(2) {
        assert!(w[0].1 >= w[1].1, "similarities must be sorted descending");
    }
    assert_eq!(top, engine.topk_neighbors(17, k).unwrap());
}

#[test]
fn inter_artifact_stays_within_the_memory_band() {
    let t = TempDir::new("serve-band").unwrap();
    let (ds, plan) = build(6000, 64, "inter(k=9)", 1);
    let params = init_params(&plan, 1);
    let manifest = save_artifact(t.path(), &ds, &plan, &params, 1, 16).unwrap();
    let ratio = manifest.resident_table_bytes as f64 / manifest.full_table_bytes as f64;
    assert!(
        ratio <= 0.15,
        "inter(k=9) resident tables are {:.1}% of Full — acceptance band is ≤ 15%",
        ratio * 100.0
    );
    assert!(ratio >= 0.005, "suspiciously small footprint ({ratio}) — check the accounting");
}
