//! Property-based tests over the substrates (in-tree proptest driver:
//! seeded random cases, failing seed printed for reproduction).

use poshashemb::embedding::{compose_embeddings, init_params, EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};
use poshashemb::hashing::HashedIndices;
use poshashemb::partition::{
    edge_cut, partition, random_partition, Hierarchy, HierarchyConfig, PartitionConfig,
};
use poshashemb::util::json::Json;
use poshashemb::util::proptest::run_cases;
use poshashemb::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> poshashemb::graph::CsrGraph {
    let n = 20 + rng.gen_range(400);
    let m = n + rng.gen_range(4 * n);
    let mut b = GraphBuilder::new(n);
    for _ in 0..m {
        let u = rng.gen_range(n) as u32;
        let v = rng.gen_range(n) as u32;
        b.add_edge(u, v, 1.0 + rng.gen_f64() as f32);
    }
    b.build()
}

#[test]
fn prop_builder_output_is_always_valid_csr() {
    run_cases(40, 0xA, |rng| {
        let g = random_graph(rng);
        g.validate().expect("invalid CSR");
    });
}

#[test]
fn prop_partition_covers_and_respects_k() {
    run_cases(25, 0xB, |rng| {
        let g = random_graph(rng);
        let k = 2 + rng.gen_range(7);
        let p = partition(&g, &PartitionConfig { k, seed: rng.next_u64(), ..Default::default() });
        assert_eq!(p.part.len(), g.num_nodes());
        assert!(p.part.iter().all(|&x| (x as usize) < k));
        // recomputed cut matches the reported cut
        assert!((edge_cut(&g, &p.part) - p.edge_cut).abs() < 1e-3);
    });
}

#[test]
fn prop_partition_beats_random_on_homophilous_graphs() {
    run_cases(10, 0xC, |rng| {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 600 + rng.gen_range(600),
            communities: 4 + rng.gen_range(4),
            intra_degree: 10.0,
            inter_degree: 1.5,
            seed: rng.next_u64(),
            ..Default::default()
        });
        let k = 4;
        let p = partition(&g, &PartitionConfig::with_k(k));
        let rcut = edge_cut(&g, &random_partition(g.num_nodes(), k, rng.next_u64()));
        assert!(p.edge_cut < rcut, "multilevel {} !< random {rcut}", p.edge_cut);
    });
}

#[test]
fn prop_hierarchy_parent_child_consistent() {
    run_cases(12, 0xD, |rng| {
        let g = random_graph(rng);
        let k = 2 + rng.gen_range(3);
        let levels = 1 + rng.gen_range(3);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(k, levels));
        h.validate().expect("inconsistent hierarchy");
        assert_eq!(h.total_partitions(), (1..=levels).map(|j| k.pow(j as u32)).sum::<usize>());
    });
}

#[test]
fn prop_hash_indices_in_range_all_shapes() {
    run_cases(50, 0xE, |rng| {
        let n = 1 + rng.gen_range(3000);
        let h = 1 + rng.gen_range(4);
        let b = 1 + rng.gen_range(512) as u32;
        let hi = HashedIndices::build(n, h, b, rng.next_u64());
        for row in &hi.indices {
            assert_eq!(row.len(), n);
            assert!(row.iter().all(|&x| x < b));
        }
    });
}

#[test]
fn prop_plan_savings_matches_param_count() {
    run_cases(30, 0xF, |rng| {
        let n = 100 + rng.gen_range(2000);
        let d = [8usize, 16, 32][rng.gen_range(3)];
        let b = 1 + rng.gen_range(n / 2);
        let method = match rng.gen_range(4) {
            0 => EmbeddingMethod::Full,
            1 => EmbeddingMethod::HashTrick { buckets: b },
            2 => EmbeddingMethod::Bloom { buckets: b, h: 2 },
            _ => EmbeddingMethod::HashEmb { buckets: b, h: 2 },
        };
        let plan = EmbeddingPlan::build(n, d, &method, None, rng.next_u64());
        let expect = match &method {
            EmbeddingMethod::Full => n * d,
            EmbeddingMethod::HashTrick { buckets } | EmbeddingMethod::Bloom { buckets, .. } => {
                buckets * d
            }
            EmbeddingMethod::HashEmb { buckets, h } => buckets * d + n * h,
            _ => unreachable!(),
        };
        assert_eq!(plan.num_params(), expect);
        let s = plan.savings();
        assert!((s - (1.0 - expect as f64 / (n * d) as f64)).abs() < 1e-9);
    });
}

#[test]
fn prop_composition_is_linear_in_tables() {
    // v(2 * params) == 2 * v(params) for weight-linear methods
    run_cases(15, 0x10, |rng| {
        let n = 50 + rng.gen_range(200);
        let plan = EmbeddingPlan::build(
            n,
            16,
            &EmbeddingMethod::Bloom { buckets: 1 + rng.gen_range(40), h: 2 },
            None,
            rng.next_u64(),
        );
        let params = init_params(&plan, rng.next_u64());
        let v1 = compose_embeddings(&plan, &params);
        let mut doubled = params.clone();
        for name in doubled.names().to_vec() {
            for x in doubled.get_mut(&name) {
                *x *= 2.0;
            }
        }
        let v2 = compose_embeddings(&plan, &doubled);
        for (a, b) in v1.iter().zip(v2.iter()) {
            assert!((2.0 * a - b).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    run_cases(60, 0x11, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Json {
            let kinds = if depth > 2 { 4 } else { 6 };
            match rng.gen_range(kinds) {
                0 => Json::Null,
                1 => Json::Bool(rng.gen_bool(0.5)),
                2 => Json::Num((rng.gen_f64() * 2e6).round() / 2.0 - 5e5),
                3 => Json::Str(format!("s{}-\"x\"\n", rng.gen_range(1000))),
                4 => Json::Arr((0..rng.gen_range(5)).map(|_| gen(rng, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..rng.gen_range(5))
                        .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 0);
        let s = v.to_string();
        let back = Json::parse(&s).expect("reparse");
        assert_eq!(v, back, "roundtrip mismatch for {s}");
    });
}
