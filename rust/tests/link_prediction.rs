//! Link-prediction acceptance: property-based checks that (a) the
//! seeded negative sampler never emits a true edge and is a pure
//! function of `(seed, epoch, batch)` — rebuilding the batcher
//! reproduces every batch bit for bit — and (b) the pipelined trainer
//! under the link-prediction objective reproduces the serial oracle's
//! loss trajectory **exactly** at 1 and 4 rayon threads, for SGD and
//! Adam, for both edge decoders. Deterministic tests pin the evaluation
//! metrics (AUC, hits@k) to be identical across execution modes too.
//!
//! Thread counts are varied with dedicated `rayon::ThreadPool`s rather
//! than `RAYON_NUM_THREADS` (the global pool is process-wide and the
//! test runner is itself parallel), mirroring `tests/parallel_train.rs`.

use poshashemb::coordinator::{
    EdgeDecoder, MinibatchOptions, MinibatchOutcome, MinibatchTrainer, Objective, OptimizerKind,
};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{EdgeBatcher, EdgeSplit, Fanout, SamplerConfig};
use proptest::prelude::*;

fn in_pool<T: Send>(threads: usize, f: impl FnOnce() -> T + Send) -> T {
    rayon::ThreadPoolBuilder::new()
        .num_threads(threads)
        .build()
        .expect("thread pool")
        .install(f)
}

/// Shrunk synth-arxiv analog (same generator/splits as the seed tests).
fn small_dataset(n: usize, d: usize) -> Dataset {
    let mut s = spec("synth-arxiv").unwrap();
    s.n = n;
    s.communities = (n / 30).max(4);
    s.d = d;
    Dataset::generate(&s)
}

/// One link-prediction training run under the given execution knobs.
fn run_lp(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    cfg: &SamplerConfig,
    decoder: EdgeDecoder,
    optimizer: OptimizerKind,
    parallel: bool,
    prefetch: usize,
) -> MinibatchOutcome {
    let opts = MinibatchOptions {
        epochs: 3,
        lr: 0.03,
        optimizer,
        seed: 7,
        parallel,
        prefetch,
        hidden: 16,
        objective: Objective::LinkPrediction { decoder, neg_per_pos: 2 },
        ..Default::default()
    };
    let mut tr = MinibatchTrainer::new(ds, plan, cfg.clone(), opts).unwrap();
    tr.train().unwrap()
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(12))]

    #[test]
    fn sampled_negatives_are_never_true_edges_and_are_deterministic(
        n in 200usize..500,
        batch in 16usize..64,
        neg in 1usize..4,
        epoch in 0usize..3,
        seed in 0u64..1000,
    ) {
        let ds = small_dataset(n, 16);
        let split = EdgeSplit::build(&ds.graph, 0.05, 0.10, seed);
        prop_assume!(!split.train.is_empty());
        let batcher = EdgeBatcher::new(&split.train, batch, true, neg, seed);
        // an independently rebuilt batcher must agree bit for bit —
        // batches are pure functions of (seed, epoch, batch index)
        let rebuilt = EdgeBatcher::new(&split.train, batch, true, neg, seed);
        for bi in 0..batcher.num_batches().min(3) {
            let eb = batcher.batch(&ds.graph, epoch, bi);
            prop_assert_eq!(eb.neg.len(), eb.pos.len() * neg);
            for &(u, v) in &eb.neg {
                prop_assert!(u < v, "negatives are normalized (min, max): ({u}, {v})");
                prop_assert!(
                    ds.graph.mem().neighbors(u).binary_search(&v).is_err(),
                    "sampled negative ({u}, {v}) is a true edge"
                );
            }
            let eb2 = rebuilt.batch(&ds.graph, epoch, bi);
            prop_assert_eq!(&eb.pos, &eb2.pos, "positives (epoch {}, batch {})", epoch, bi);
            prop_assert_eq!(&eb.neg, &eb2.neg, "negatives (epoch {}, batch {})", epoch, bi);
            prop_assert_eq!(&eb.seeds, &eb2.seeds, "seed sets (epoch {}, batch {})", epoch, bi);
            // the deduped seed set covers exactly the scored endpoints
            for &(a, b) in eb.pos_local.iter().chain(&eb.neg_local) {
                prop_assert!((a as usize) < eb.seeds.len() && (b as usize) < eb.seeds.len());
            }
        }
    }

    #[test]
    fn lp_pipelined_training_reproduces_serial_oracle_exactly(
        n in 300usize..600,
        batch in 32usize..96,
        fanout in 2usize..6,
        adam in any::<bool>(),
        hadamard in any::<bool>(),
    ) {
        // the LP acceptance pin: prefetched + parallel-backward training
        // under the link-prediction objective must reproduce the serial
        // trainer's loss trajectory EXACTLY (bit-for-bit f64 equality),
        // at 1 and at 4 rayon threads, for both decoders.
        let ds = small_dataset(n, 16);
        let plan =
            EmbeddingPlan::build(n, 16, &EmbeddingMethod::HashEmb { buckets: 48, h: 2 }, None, 3);
        let cfg =
            SamplerConfig { batch_size: batch, fanouts: Fanout::Max(fanout).into(), shuffle: true };
        let decoder = if hadamard { EdgeDecoder::Hadamard } else { EdgeDecoder::Dot };
        let optimizer = if adam { OptimizerKind::Adam } else { OptimizerKind::Sgd };
        let serial = run_lp(&ds, &plan, &cfg, decoder, optimizer, false, 0).losses;
        let piped1 = in_pool(1, || run_lp(&ds, &plan, &cfg, decoder, optimizer, true, 2).losses);
        let piped4 = in_pool(4, || run_lp(&ds, &plan, &cfg, decoder, optimizer, true, 2).losses);
        prop_assert_eq!(&piped1, &serial, "1-thread pipelined vs serial");
        prop_assert_eq!(&piped4, &serial, "4-thread pipelined vs serial");
    }
}

#[test]
fn lp_metrics_match_between_serial_and_pipelined_with_position_method() {
    // the paper method (position levels + intra pools + learned y)
    // through the LP path: the whole outcome — losses, AUC and hits@k
    // on both held-out folds — must be identical across execution modes.
    let ds = small_dataset(450, 16);
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(4, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 5, h: 2 };
    let plan = EmbeddingPlan::build(450, 16, &method, Some(&hier), 3);
    let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(5).into(), shuffle: true };
    let serial = run_lp(&ds, &plan, &cfg, EdgeDecoder::Dot, OptimizerKind::Adam, false, 0);
    let piped = in_pool(4, || run_lp(&ds, &plan, &cfg, EdgeDecoder::Dot, OptimizerKind::Adam, true, 2));
    assert_eq!(piped.losses, serial.losses, "loss trajectory");
    assert_eq!(piped.val_metric, serial.val_metric, "val AUC");
    assert_eq!(piped.test_metric, serial.test_metric, "test AUC");
    assert_eq!(piped.val_hits, serial.val_hits, "val hits@k");
    assert_eq!(piped.test_hits, serial.test_hits, "test hits@k");
    // sanity on ranges: AUC and hits@k are probabilities
    assert!((0.0..=1.0).contains(&serial.test_metric), "AUC {}", serial.test_metric);
    let hits = serial.test_hits.expect("LP reports hits@k");
    assert!((0.0..=1.0).contains(&hits), "hits {hits}");
    assert!(serial.val_hits.is_some());
}

#[test]
fn lp_trains_the_loss_down_and_beats_chance_auc() {
    // end-to-end signal check: a few epochs on the community graph must
    // pull BCE below its ~0.693 chance level and push AUC above 0.5
    // (communities make linked pairs genuinely more similar).
    let ds = small_dataset(600, 16);
    let plan =
        EmbeddingPlan::build(600, 16, &EmbeddingMethod::Full, None, 0);
    let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(5).into(), shuffle: true };
    let out = run_lp(&ds, &plan, &cfg, EdgeDecoder::Dot, OptimizerKind::Adam, true, 2);
    let first = out.losses.first().copied().unwrap();
    let last = out.losses.last().copied().unwrap();
    assert!(last < first, "loss should fall: {first} -> {last}");
    assert!(out.test_metric > 0.5, "AUC should beat chance: {}", out.test_metric);
}

#[test]
fn edge_split_is_disjoint_and_seed_stable() {
    let ds = small_dataset(400, 16);
    let a = EdgeSplit::build(&ds.graph, 0.05, 0.10, 11);
    let b = EdgeSplit::build(&ds.graph, 0.05, 0.10, 11);
    assert_eq!(a.train, b.train, "same seed, same split");
    assert_eq!(a.val, b.val);
    assert_eq!(a.test, b.test);
    let total = a.train.len() + a.val.len() + a.test.len();
    assert_eq!(total, ds.graph.num_edges(), "every undirected edge lands in exactly one fold");
    let c = EdgeSplit::build(&ds.graph, 0.05, 0.10, 12);
    assert_ne!(a.train, c.train, "different seed shuffles the folds");
}
