//! Multilevel k-way graph partitioner — the METIS substitute.
//!
//! The paper (Algorithm 1, line 2) calls `metis(G, k, L)` to obtain the
//! membership matrix **Z**. METIS itself is not available here, so this
//! module implements the same multilevel paradigm from scratch:
//!
//! 1. **Coarsening** (`matching` + `coarsen`): heavy-edge matching
//!    collapses matched pairs into super-nodes until the graph is small.
//!    Both phases are parallel by default (deterministic lock-free
//!    matching rounds + a CSR-native contraction kernel); the scalar
//!    implementations stay in-tree as validation oracles and are
//!    selected with `PartitionConfig { parallel: false, .. }`.
//! 2. **Initial partitioning** (`initial`): greedy graph growing produces
//!    a balanced k-way partition of the coarsest graph.
//! 3. **Uncoarsening + refinement** (`refine`): the partition is projected
//!    back level by level; boundary nodes are moved by positive-gain
//!    greedy passes (a k-way FM variant) under a balance constraint.
//!
//! `hierarchy` applies the partitioner recursively to build the L-level
//! hierarchy of Algorithm 1 and the per-node membership vectors `z_i`.
//! `random` provides the RandomPart baseline of Table III.

mod coarsen;
mod hierarchy;
mod initial;
mod matching;
mod random;
mod refine;
mod shard;

pub use coarsen::{coarsen, coarsen_reference};
pub use hierarchy::{induced_subgraph, induced_subgraph_with_scratch, Hierarchy, HierarchyConfig};
pub use matching::{heavy_edge_matching, parallel_heavy_edge_matching};
pub use random::random_partition;
pub use shard::{GraphShards, Shard};

use crate::graph::{CsrGraph, GraphStore};
use crate::util::rng::Rng;

/// Partitioner configuration.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    /// Number of parts.
    pub k: usize,
    /// Allowed imbalance: max part weight ≤ (1 + epsilon) * ceil(W / k).
    pub epsilon: f64,
    /// Stop coarsening when the graph has at most `coarsen_until * k`
    /// nodes (or coarsening stalls).
    pub coarsen_until: usize,
    /// Refinement passes per uncoarsening level.
    pub refine_passes: usize,
    /// RNG seed (tie-breaking in matching/growing).
    pub seed: u64,
    /// Coarsen with the deterministic rayon-parallel kernels
    /// ([`parallel_heavy_edge_matching`] + CSR-native [`coarsen`]);
    /// `false` selects the full scalar oracle pipeline
    /// ([`heavy_edge_matching`] + [`coarsen_reference`]). Both are
    /// deterministic for a fixed seed; the parallel path is additionally
    /// independent of thread count.
    pub parallel: bool,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig {
            k: 2,
            epsilon: 0.10,
            coarsen_until: 30,
            refine_passes: 4,
            seed: 1,
            parallel: true,
        }
    }
}

impl PartitionConfig {
    /// Config for `k` parts with library defaults.
    pub fn with_k(k: usize) -> Self {
        PartitionConfig { k, ..Default::default() }
    }
}

/// Result of a k-way partitioning.
#[derive(Debug, Clone)]
pub struct Partitioning {
    /// `part[i]` ∈ [0, k) for every node.
    pub part: Vec<u32>,
    /// Number of parts requested.
    pub k: usize,
    /// Total weight of cut edges.
    pub edge_cut: f64,
    /// max part weight / ideal part weight.
    pub imbalance: f64,
}

impl Partitioning {
    /// Nodes per part.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.part {
            sizes[p as usize] += 1;
        }
        sizes
    }
}

/// Compute the weighted edge cut of an assignment.
pub fn edge_cut<G: GraphStore + ?Sized>(g: &G, part: &[u32]) -> f64 {
    let mut cut = 0f64;
    let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
    for u in 0..g.num_nodes() as u32 {
        g.edges_into(u, &mut nbrs, &mut wts);
        for (&v, &w) in nbrs.iter().zip(&wts) {
            if part[u as usize] != part[v as usize] {
                cut += w as f64;
            }
        }
    }
    cut / 2.0
}

/// Compute imbalance: `max_part_weight / (W / k)`.
pub fn imbalance<G: GraphStore + ?Sized>(g: &G, part: &[u32], k: usize) -> f64 {
    let mut wts = vec![0u64; k];
    for u in 0..g.num_nodes() {
        wts[part[u] as usize] += g.vertex_weight(u as u32) as u64;
    }
    let ideal = g.total_vertex_weight() as f64 / k as f64;
    wts.iter().copied().max().unwrap_or(0) as f64 / ideal.max(1.0)
}

/// Multilevel k-way partitioning — the main entry point.
///
/// Generic over the storage backend: the first-level pass (matching,
/// contraction, the final refinement sweep and the cut/imbalance
/// metrics) reads `g` through [`GraphStore`], so a disk-backed graph is
/// partitioned without ever materializing it — only the (much smaller)
/// coarse graphs are built in memory. Every RNG draw happens in the
/// same order regardless of backend, so the partition is bit-identical.
pub fn partition<G: GraphStore + ?Sized>(g: &G, cfg: &PartitionConfig) -> Partitioning {
    assert!(cfg.k >= 1, "k must be >= 1");
    let n = g.num_nodes();
    if cfg.k == 1 || n <= cfg.k {
        // trivial cases: single part, or fewer nodes than parts (spread
        // round-robin so every part is non-empty where possible).
        let part: Vec<u32> = (0..n).map(|i| (i % cfg.k) as u32).collect();
        let cut = edge_cut(g, &part);
        let imb = imbalance(g, &part, cfg.k);
        return Partitioning { part, k: cfg.k, edge_cut: cut, imbalance: imb };
    }
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let target = (cfg.coarsen_until * cfg.k).max(2 * cfg.k);

    // ---- coarsening phase ----
    // `coarse[i]` is the level-(i+1) graph; `maps[i]` maps the previous
    // level (the store itself for i == 0, else `coarse[i-1]`) onto it.
    // `parallel: false` is the full scalar pipeline (oracle matching
    // AND oracle contraction), so benches comparing the two paths
    // measure the pre-parallelization baseline, not a hybrid.
    let mut coarse_graphs: Vec<CsrGraph> = Vec::new();
    let mut maps: Vec<Vec<u32>> = Vec::new();
    // level 0 contracts straight off the store — the one level whose
    // graph may not fit in memory
    if n > target {
        let (coarse, map) = if cfg.parallel {
            let matching = parallel_heavy_edge_matching(g, rng.next_u64());
            coarsen(g, &matching)
        } else {
            let matching = heavy_edge_matching(g, &mut rng);
            coarsen_reference(g, &matching)
        };
        // stall guard: coarsening must shrink by ≥5% or we stop
        if (coarse.num_nodes() as f64) <= n as f64 * 0.95 {
            maps.push(map);
            coarse_graphs.push(coarse);
        }
    }
    // deeper levels are all in-memory
    while let Some(cur) = coarse_graphs.last() {
        if cur.num_nodes() <= target {
            break;
        }
        let (coarse, map) = if cfg.parallel {
            let matching = parallel_heavy_edge_matching(cur, rng.next_u64());
            coarsen(cur, &matching)
        } else {
            let matching = heavy_edge_matching(cur, &mut rng);
            coarsen_reference(cur, &matching)
        };
        if coarse.num_nodes() as f64 > cur.num_nodes() as f64 * 0.95 {
            break;
        }
        maps.push(map);
        coarse_graphs.push(coarse);
    }

    // ---- initial partitioning on the coarsest graph ----
    let mut part = match coarse_graphs.last() {
        Some(coarsest) => {
            let mut p = initial::greedy_growing(coarsest, cfg.k, cfg.epsilon, &mut rng);
            refine::refine(coarsest, &mut p, cfg.k, cfg.epsilon, cfg.refine_passes);
            p
        }
        // no coarsening happened (small graph or immediate stall):
        // partition the store directly
        None => {
            let mut p = initial::greedy_growing(g, cfg.k, cfg.epsilon, &mut rng);
            refine::refine(g, &mut p, cfg.k, cfg.epsilon, cfg.refine_passes);
            p
        }
    };

    // ---- uncoarsening + refinement ----
    for lvl in (0..maps.len()).rev() {
        let map = &maps[lvl];
        let mut fine_part = vec![0u32; map.len()];
        for (u, &cu) in map.iter().enumerate() {
            fine_part[u] = part[cu as usize];
        }
        if lvl == 0 {
            refine::refine(g, &mut fine_part, cfg.k, cfg.epsilon, cfg.refine_passes);
        } else {
            refine::refine(
                &coarse_graphs[lvl - 1],
                &mut fine_part,
                cfg.k,
                cfg.epsilon,
                cfg.refine_passes,
            );
        }
        part = fine_part;
    }

    let cut = edge_cut(g, &part);
    let imb = imbalance(g, &part, cfg.k);
    Partitioning { part, k: cfg.k, edge_cut: cut, imbalance: imb }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};

    fn sbm(n: usize, k: usize, seed: u64) -> (CsrGraph, Vec<u32>) {
        planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 10.0,
            inter_degree: 1.0,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn partition_covers_all_parts_and_is_balanced() {
        let (g, _) = sbm(1200, 4, 11);
        let p = partition(&g, &PartitionConfig::with_k(4));
        assert_eq!(p.part.len(), g.num_nodes());
        let sizes = p.part_sizes();
        assert!(sizes.iter().all(|&s| s > 0), "empty part: {sizes:?}");
        assert!(p.imbalance < 1.2, "imbalance {}", p.imbalance);
    }

    #[test]
    fn partition_recovers_planted_communities() {
        // With strong homophily the min-cut partition should align with the
        // planted blocks far better than chance.
        let (g, membership) = sbm(1000, 4, 3);
        let p = partition(&g, &PartitionConfig::with_k(4));
        // purity: for each found part, the max planted-block share
        let mut counts = vec![[0usize; 4]; 4];
        for (i, &fp) in p.part.iter().enumerate() {
            counts[fp as usize][membership[i] as usize] += 1;
        }
        let mut pure = 0usize;
        for row in &counts {
            pure += row.iter().max().unwrap();
        }
        let purity = pure as f64 / g.num_nodes() as f64;
        assert!(purity > 0.75, "purity {purity}");
    }

    #[test]
    fn partition_cut_beats_random() {
        let (g, _) = sbm(800, 4, 17);
        let p = partition(&g, &PartitionConfig::with_k(4));
        let rand_part = random_partition(g.num_nodes(), 4, 99);
        let rand_cut = edge_cut(&g, &rand_part);
        assert!(
            p.edge_cut < rand_cut * 0.5,
            "multilevel cut {} vs random {}",
            p.edge_cut,
            rand_cut
        );
    }

    #[test]
    fn k_equals_one() {
        let (g, _) = sbm(100, 2, 5);
        let p = partition(&g, &PartitionConfig::with_k(1));
        assert!(p.part.iter().all(|&x| x == 0));
        assert_eq!(p.edge_cut, 0.0);
    }

    #[test]
    fn more_parts_than_nodes() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        let g = b.build();
        let p = partition(&g, &PartitionConfig::with_k(8));
        assert_eq!(p.part.len(), 3);
        assert!(p.part.iter().all(|&x| (x as usize) < 8));
    }

    #[test]
    fn parallel_coarsening_matches_scalar_quality() {
        // within 5% of the scalar oracle's cut, or at ground-truth
        // (planted-partition) quality outright
        let (g, membership) = sbm(1000, 4, 7);
        let planted_cut = edge_cut(&g, &membership);
        let mut cfg = PartitionConfig { k: 4, parallel: false, ..Default::default() };
        let scalar = partition(&g, &cfg);
        cfg.parallel = true;
        let par = partition(&g, &cfg);
        assert!(
            par.edge_cut <= scalar.edge_cut * 1.05 + 2.0 || par.edge_cut <= planted_cut,
            "parallel cut {} vs scalar {} (planted {planted_cut})",
            par.edge_cut,
            scalar.edge_cut
        );
        assert!(par.imbalance < 1.2, "imbalance {}", par.imbalance);
    }

    #[test]
    fn deterministic_given_seed() {
        let (g, _) = sbm(600, 3, 2);
        let cfg = PartitionConfig { k: 3, seed: 123, ..Default::default() };
        let p1 = partition(&g, &cfg);
        let p2 = partition(&g, &cfg);
        assert_eq!(p1.part, p2.part);
    }

    #[test]
    fn disconnected_graph_handled() {
        // two disjoint triangles + isolated nodes
        let mut b = GraphBuilder::new(8);
        for (u, v) in [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)] {
            b.add_edge(u, v, 1.0);
        }
        let g = b.build();
        let p = partition(&g, &PartitionConfig::with_k(2));
        assert_eq!(p.part.len(), 8);
        assert!(p.imbalance <= 1.6);
    }
}
