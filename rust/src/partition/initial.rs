//! Initial k-way partitioning of the coarsest graph by greedy graph
//! growing (GGGP): grow each part from a random seed along a BFS-like
//! frontier ordered by connectivity gain, stopping at the balance target.
//! Leftover nodes (disconnected pockets) are assigned to the lightest
//! part.

use crate::graph::GraphStore;
use crate::util::rng::Rng;
use std::collections::BinaryHeap;

/// Grow a balanced k-way partition on (small) graph `g`.
pub fn greedy_growing<G: GraphStore + ?Sized>(
    g: &G,
    k: usize,
    epsilon: f64,
    rng: &mut Rng,
) -> Vec<u32> {
    let n = g.num_nodes();
    const FREE: u32 = u32::MAX;
    let mut part = vec![FREE; n];
    let total_w = g.total_vertex_weight() as f64;
    let max_part_w = ((total_w / k as f64) * (1.0 + epsilon)).ceil() as u64;
    let target_w = (total_w / k as f64).ceil() as u64;

    let mut part_w = vec![0u64; k];
    let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
    for p in 0..k {
        // pick an unassigned seed (random probes, then linear scan)
        let mut seed = None;
        for _ in 0..16 {
            let cand = rng.gen_range(n);
            if part[cand] == FREE {
                seed = Some(cand);
                break;
            }
        }
        let seed = match seed.or_else(|| (0..n).find(|&u| part[u] == FREE)) {
            Some(s) => s,
            None => break, // everything assigned
        };
        // frontier heap keyed by gain = weight-to-part (max-heap on f32 bits)
        let mut heap: BinaryHeap<(ordered::F64, u32)> = BinaryHeap::new();
        heap.push((ordered::F64(0.0), seed as u32));
        while let Some((_, u)) = heap.pop() {
            let ui = u as usize;
            if part[ui] != FREE {
                continue;
            }
            let vw = g.vertex_weight(u) as u64;
            if part_w[p] + vw > max_part_w {
                continue;
            }
            part[ui] = p as u32;
            part_w[p] += vw;
            if part_w[p] >= target_w {
                break;
            }
            g.edges_into(u, &mut nbrs, &mut wts);
            for (&v, &w) in nbrs.iter().zip(&wts) {
                if part[v as usize] == FREE {
                    heap.push((ordered::F64(w as f64), v));
                }
            }
        }
    }
    // leftovers → lightest part
    for u in 0..n {
        if part[u] == FREE {
            let (p, _) = part_w.iter().enumerate().min_by_key(|(_, &w)| w).unwrap();
            part[u] = p as u32;
            part_w[p] += g.vertex_weight(u as u32) as u64;
        }
    }
    part
}

/// Total-order f64 wrapper for the frontier heap.
mod ordered {
    #[derive(PartialEq)]
    pub struct F64(pub f64);
    impl Eq for F64 {}
    impl PartialOrd for F64 {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for F64 {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.total_cmp(&other.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};

    #[test]
    fn all_nodes_assigned_in_range() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 300,
            communities: 3,
            intra_degree: 8.0,
            inter_degree: 1.0,
            seed: 21,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(5);
        let part = greedy_growing(&g, 3, 0.05, &mut rng);
        assert!(part.iter().all(|&p| p < 3));
        let mut sizes = [0usize; 3];
        for &p in &part {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s > 0));
    }

    #[test]
    fn growing_respects_rough_balance() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 1000,
            communities: 10,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 22,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(6);
        let part = greedy_growing(&g, 5, 0.10, &mut rng);
        let imb = crate::partition::imbalance(&g, &part, 5);
        // growing alone can exceed (1+eps) via the leftover sweep; refine
        // tightens it later. Assert a loose sanity bound here.
        assert!(imb < 1.6, "imbalance {imb}");
    }

    #[test]
    fn handles_edgeless_graph() {
        let g = GraphBuilder::new(10).build();
        let mut rng = Rng::seed_from_u64(7);
        let part = greedy_growing(&g, 2, 0.1, &mut rng);
        let ones = part.iter().filter(|&&p| p == 1).count();
        assert!(ones >= 3 && ones <= 7, "split {ones}/10");
    }
}
