//! K-way boundary refinement (greedy FM variant).
//!
//! Each pass scans boundary nodes and greedily moves a node to the
//! neighboring part with the highest positive cut gain, subject to the
//! balance constraint. Passes repeat until no improving move or the pass
//! budget is exhausted. This is the "greedy refinement" variant METIS
//! uses for k-way partitions (full FM with hill-climbing buys a few
//! percent at much higher complexity; see EXPERIMENTS.md ablation).

use crate::graph::GraphStore;

/// Refine `part` in place.
pub fn refine<G: GraphStore + ?Sized>(
    g: &G,
    part: &mut [u32],
    k: usize,
    epsilon: f64,
    passes: usize,
) {
    if k <= 1 {
        return;
    }
    let n = g.num_nodes();
    let total_w = g.total_vertex_weight() as f64;
    let max_part_w = ((total_w / k as f64) * (1.0 + epsilon)).ceil() as u64;
    let min_part_w = ((total_w / k as f64) * (1.0 - epsilon)).floor() as u64;

    let mut part_w = vec![0u64; k];
    for u in 0..n {
        part_w[part[u] as usize] += g.vertex_weight(u as u32) as u64;
    }

    // connectivity[p] reused per node: weight of u's edges into part p
    let mut conn = vec![0f32; k];
    let mut touched: Vec<u32> = Vec::with_capacity(16);
    let (mut nbrs, mut wts) = (Vec::new(), Vec::new());

    for _pass in 0..passes {
        let mut moved = 0usize;
        for u in 0..n as u32 {
            let ui = u as usize;
            let home = part[ui] as usize;
            // compute connectivity to adjacent parts
            touched.clear();
            let mut is_boundary = false;
            g.edges_into(u, &mut nbrs, &mut wts);
            for (&v, &w) in nbrs.iter().zip(&wts) {
                let pv = part[v as usize] as usize;
                if conn[pv] == 0.0 {
                    touched.push(pv as u32);
                }
                conn[pv] += w;
                if pv != home {
                    is_boundary = true;
                }
            }
            if is_boundary {
                let internal = conn[home];
                let vw = g.vertex_weight(u) as u64;
                let mut best: Option<(usize, f32)> = None;
                for &pt in &touched {
                    let p = pt as usize;
                    if p == home {
                        continue;
                    }
                    let gain = conn[p] - internal;
                    let balance_ok = part_w[p] + vw <= max_part_w
                        && part_w[home].saturating_sub(vw) >= min_part_w.min(part_w[home]);
                    // also allow balance-improving moves with zero gain when
                    // home part is overweight
                    let rescue = part_w[home] > max_part_w && part_w[p] + vw <= max_part_w;
                    if (gain > 0.0 && balance_ok) || (gain >= 0.0 && rescue) {
                        match best {
                            None => best = Some((p, gain)),
                            Some((_, bg)) if gain > bg => best = Some((p, gain)),
                            _ => {}
                        }
                    }
                }
                if let Some((p, _)) = best {
                    part[ui] = p as u32;
                    part_w[home] -= vw;
                    part_w[p] += vw;
                    moved += 1;
                }
            }
            // reset connectivity scratch
            for &pt in &touched {
                conn[pt as usize] = 0.0;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};
    use crate::partition::{edge_cut, random_partition};

    #[test]
    fn refinement_never_increases_cut() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 400,
            communities: 4,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 31,
            ..Default::default()
        });
        let mut part = random_partition(g.num_nodes(), 4, 1);
        let before = edge_cut(&g, &part);
        refine(&g, &mut part, 4, 0.1, 6);
        let after = edge_cut(&g, &part);
        assert!(after <= before, "cut went up: {before} -> {after}");
    }

    #[test]
    fn refinement_substantially_improves_random_start() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 600,
            communities: 2,
            intra_degree: 10.0,
            inter_degree: 1.0,
            seed: 32,
            ..Default::default()
        });
        let mut part = random_partition(g.num_nodes(), 2, 2);
        let before = edge_cut(&g, &part);
        refine(&g, &mut part, 2, 0.1, 10);
        let after = edge_cut(&g, &part);
        assert!(after < 0.8 * before, "insufficient improvement {before} -> {after}");
    }

    #[test]
    fn respects_balance_constraint() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 500,
            communities: 5,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 33,
            ..Default::default()
        });
        let mut part = random_partition(g.num_nodes(), 5, 3);
        refine(&g, &mut part, 5, 0.1, 6);
        let imb = crate::partition::imbalance(&g, &part, 5);
        // refinement starts balanced (random ≈ balanced) and must not blow up
        assert!(imb <= 1.25, "imbalance {imb}");
    }

    #[test]
    fn perfect_partition_is_stable() {
        // two cliques connected by one edge, already optimally split
        let mut b = GraphBuilder::new(8);
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                b.add_edge(u, v, 1.0);
            }
        }
        for u in 4..8u32 {
            for v in (u + 1)..8 {
                b.add_edge(u, v, 1.0);
            }
        }
        b.add_edge(0, 4, 1.0);
        let g = b.build();
        let mut part = vec![0, 0, 0, 0, 1, 1, 1, 1];
        refine(&g, &mut part, 2, 0.1, 4);
        assert_eq!(part, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn k1_noop() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        let g = b.build();
        let mut part = vec![0, 0, 0];
        refine(&g, &mut part, 1, 0.1, 3);
        assert_eq!(part, vec![0, 0, 0]);
    }
}
