//! Graph contraction given a matching.
//!
//! Matched pairs become single super-nodes; vertex weights add; parallel
//! edges between super-nodes merge by summing weights; edges internal to
//! a collapsed pair disappear.
//!
//! Two implementations of the same contract:
//!
//! * [`coarsen`] — CSR-native two-pass kernel: a parallel counting pass
//!   derives per-coarse-node degree offsets (upper bounds, pre-merge),
//!   then a parallel scatter fills each coarse row from its two fine
//!   rows, sorts it, and merges duplicate coarse edges in place. O(m)
//!   with no per-edge hashing or global edge-list sort; rows are
//!   disjoint slices, so the pass runs on the rayon pool and the result
//!   is identical at any thread count.
//! * [`coarsen_reference`] — the original `GraphBuilder` path, kept as
//!   the oracle the kernel is validated against (identical structure;
//!   weights agree up to float summation order).

use crate::graph::{CsrGraph, GraphBuilder, GraphStore};
use rayon::prelude::*;

/// Split `buf` into consecutive variable-length rows per `offsets`
/// (`offsets.len() - 1` rows; row i spans `offsets[i]..offsets[i+1]`).
/// The returned mutable slices are disjoint, so they can be filled in
/// parallel.
fn split_rows<'a, T>(mut buf: &'a mut [T], offsets: &[u64]) -> Vec<&'a mut [T]> {
    let mut rows = Vec::with_capacity(offsets.len().saturating_sub(1));
    for w in offsets.windows(2) {
        let (head, tail) = std::mem::take(&mut buf).split_at_mut((w[1] - w[0]) as usize);
        rows.push(head);
        buf = tail;
    }
    rows
}

/// Contract `g` along `matching` (an involution, `matching[u] ∈ {u, v}`).
/// Returns the coarse graph and the fine→coarse node map. Reads `g`
/// through [`GraphStore`], so the fine graph may be disk-backed; the
/// coarse output is always in-memory.
pub fn coarsen<G: GraphStore + ?Sized>(g: &G, matching: &[u32]) -> (CsrGraph, Vec<u32>) {
    let n = g.num_nodes();
    assert_eq!(matching.len(), n);
    // Coarse numbering in first-seen fine order — identical to the
    // reference path, so uncoarsening projections are unchanged.
    let mut map = vec![u32::MAX; n];
    let mut rep: Vec<u32> = Vec::with_capacity(n / 2 + 1);
    for u in 0..n {
        if map[u] != u32::MAX {
            continue;
        }
        let c = rep.len() as u32;
        map[u] = c;
        let v = matching[u] as usize;
        if v != u {
            map[v] = c;
        }
        rep.push(u as u32);
    }
    let cn = rep.len();

    let vwgts: Vec<u32> = rep
        .par_iter()
        .map(|&u| {
            let v = matching[u as usize];
            g.vertex_weight(u) + if v != u { g.vertex_weight(v) } else { 0 }
        })
        .collect();

    // Pass 1 (counting): per-coarse-node slot upper bounds (both fine
    // adjacency lists, before dedup/self-edge elision) → row offsets.
    let ub: Vec<u64> = rep
        .par_iter()
        .map(|&u| {
            let v = matching[u as usize];
            (g.degree(u) + if v != u { g.degree(v) } else { 0 }) as u64
        })
        .collect();
    let mut offsets = vec![0u64; cn + 1];
    for c in 0..cn {
        offsets[c + 1] = offsets[c] + ub[c];
    }

    // Pass 2 (scatter): gather each coarse row from its fine rows, sort
    // by coarse neighbor, merge duplicates in ascending-neighbor order
    // (deterministic summation independent of thread count).
    let mut entries: Vec<(u32, f32)> = vec![(0, 0.0); offsets[cn] as usize];
    let lens: Vec<usize> = split_rows(&mut entries, &offsets)
        .into_par_iter()
        .enumerate()
        .map_init(
            || (Vec::new(), Vec::new()),
            |(nbrs, wts), (c, row)| {
                let u = rep[c];
                let v = matching[u as usize];
                let mut len = 0usize;
                for m in [u, v] {
                    g.edges_into(m, nbrs, wts);
                    for (&nb, &w) in nbrs.iter().zip(wts.iter()) {
                        let cnb = map[nb as usize];
                        if cnb != c as u32 {
                            row[len] = (cnb, w);
                            len += 1;
                        }
                    }
                    if v == u {
                        break;
                    }
                }
                let filled = &mut row[..len];
                filled.sort_unstable_by_key(|e| e.0);
                let mut out = 0usize;
                let mut i = 0usize;
                while i < len {
                    let (c0, mut wsum) = filled[i];
                    i += 1;
                    while i < len && filled[i].0 == c0 {
                        wsum += filled[i].1;
                        i += 1;
                    }
                    filled[out] = (c0, wsum);
                    out += 1;
                }
                out
            },
        )
        .collect();

    // Compact the merged row prefixes into the final CSR arrays.
    let mut indptr = vec![0u64; cn + 1];
    for c in 0..cn {
        indptr[c + 1] = indptr[c] + lens[c] as u64;
    }
    let mut indices = vec![0u32; indptr[cn] as usize];
    let mut weights = vec![0f32; indptr[cn] as usize];
    split_rows(&mut indices, &indptr)
        .into_par_iter()
        .zip(split_rows(&mut weights, &indptr))
        .enumerate()
        .for_each(|(c, (irow, wrow))| {
            let s = offsets[c] as usize;
            for (j, &(nb, w)) in entries[s..s + lens[c]].iter().enumerate() {
                irow[j] = nb;
                wrow[j] = w;
            }
        });
    (CsrGraph::from_parts(indptr, indices, weights, vwgts), map)
}

/// Scalar `GraphBuilder` contraction — the oracle for [`coarsen`].
pub fn coarsen_reference<G: GraphStore + ?Sized>(g: &G, matching: &[u32]) -> (CsrGraph, Vec<u32>) {
    let n = g.num_nodes();
    assert_eq!(matching.len(), n);
    let mut map = vec![u32::MAX; n];
    let mut coarse_n = 0u32;
    for u in 0..n {
        let v = matching[u] as usize;
        if map[u] != u32::MAX {
            continue;
        }
        map[u] = coarse_n;
        if v != u {
            map[v] = coarse_n;
        }
        coarse_n += 1;
    }
    let mut vwgts = vec![0u32; coarse_n as usize];
    for u in 0..n {
        vwgts[map[u] as usize] += g.vertex_weight(u as u32);
    }
    let mut b = GraphBuilder::new(coarse_n as usize).with_vertex_weights(vwgts);
    let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
    for u in 0..n as u32 {
        g.edges_into(u, &mut nbrs, &mut wts);
        for (&v, &w) in nbrs.iter().zip(&wts) {
            if u < v {
                let (cu, cv) = (map[u as usize], map[v as usize]);
                if cu != cv {
                    b.add_edge(cu, cv, w);
                }
            }
        }
    }
    (b.build(), map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// square 0-1-2-3-0 with matching (0,1) and (2,3) →
    /// coarse: two super-nodes joined by a weight-2 edge.
    #[test]
    fn square_contracts_to_heavy_edge() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.add_edge(3, 0, 1.0);
        let g = b.build();
        let matching = vec![1, 0, 3, 2];
        let (cg, map) = coarsen(&g, &matching);
        assert_eq!(cg.num_nodes(), 2);
        assert_eq!(cg.num_edges(), 1);
        assert_eq!(cg.edge_weights(0), &[2.0]);
        assert_eq!(cg.vertex_weight(0), 2);
        assert_eq!(cg.vertex_weight(1), 2);
        assert_eq!(map[0], map[1]);
        assert_eq!(map[2], map[3]);
        assert_ne!(map[0], map[2]);
    }

    #[test]
    fn self_matched_nodes_survive() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        let g = b.build();
        let matching = vec![1, 0, 2]; // 2 self-matched
        let (cg, map) = coarsen(&g, &matching);
        assert_eq!(cg.num_nodes(), 2);
        assert_eq!(cg.vertex_weight(map[2]), 1);
        assert_eq!(cg.num_edges(), 1);
        cg.validate().unwrap();
    }

    #[test]
    fn total_vertex_weight_preserved() {
        let mut b = GraphBuilder::new(6);
        for (u, v) in [(0, 1), (1, 2), (3, 4), (4, 5), (2, 3)] {
            b.add_edge(u, v, 1.0);
        }
        let g = b.build();
        let matching = vec![1, 0, 3, 2, 5, 4];
        let (cg, _) = coarsen(&g, &matching);
        assert_eq!(cg.total_vertex_weight(), g.total_vertex_weight());
    }

    #[test]
    fn edge_cut_weight_preserved_across_contraction() {
        // cut edges between super-nodes keep their total weight
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 2, 1.5);
        b.add_edge(0, 3, 0.5);
        b.add_edge(1, 2, 1.0);
        b.add_edge(0, 1, 9.0); // internal to supernode A
        b.add_edge(2, 3, 9.0); // internal to supernode B
        let g = b.build();
        let matching = vec![1, 0, 3, 2];
        let (cg, _) = coarsen(&g, &matching);
        assert_eq!(cg.num_edges(), 1);
        assert_eq!(cg.edge_weights(0), &[3.0]); // 1.5 + 0.5 + 1.0
    }

    #[test]
    fn csr_kernel_matches_reference_on_random_graph() {
        use crate::graph::{planted_partition, PlantedPartitionConfig};
        use crate::util::rng::Rng;
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 900,
            communities: 6,
            intra_degree: 9.0,
            inter_degree: 2.0,
            seed: 19,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(5);
        let matching = super::super::heavy_edge_matching(&g, &mut rng);
        let (a, amap) = coarsen_reference(&g, &matching);
        let (b, bmap) = coarsen(&g, &matching);
        assert_eq!(amap, bmap);
        assert_eq!(a.indptr(), b.indptr());
        assert_eq!(a.indices(), b.indices());
        for u in 0..a.num_nodes() as u32 {
            for (x, y) in a.edge_weights(u).iter().zip(b.edge_weights(u)) {
                assert!((x - y).abs() < 1e-4, "weight mismatch at row {u}: {x} vs {y}");
            }
            assert_eq!(a.vertex_weight(u), b.vertex_weight(u));
        }
        b.validate().unwrap();
    }

    #[test]
    fn empty_and_edgeless_graphs() {
        let g0 = GraphBuilder::new(0).build();
        let (cg0, map0) = coarsen(&g0, &[]);
        assert_eq!(cg0.num_nodes(), 0);
        assert!(map0.is_empty());
        let g3 = GraphBuilder::new(3).build();
        let (cg3, _) = coarsen(&g3, &[0, 1, 2]);
        assert_eq!(cg3.num_nodes(), 3);
        assert_eq!(cg3.num_edges(), 0);
        cg3.validate().unwrap();
    }
}
