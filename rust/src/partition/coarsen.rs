//! Graph contraction given a matching.
//!
//! Matched pairs become single super-nodes; vertex weights add; parallel
//! edges between super-nodes merge by summing weights (handled by
//! `GraphBuilder`); edges internal to a collapsed pair disappear.

use crate::graph::{CsrGraph, GraphBuilder};

/// Contract `g` along `matching` (an involution, `matching[u] ∈ {u, v}`).
/// Returns the coarse graph and the fine→coarse node map.
pub fn coarsen(g: &CsrGraph, matching: &[u32]) -> (CsrGraph, Vec<u32>) {
    let n = g.num_nodes();
    assert_eq!(matching.len(), n);
    let mut map = vec![u32::MAX; n];
    let mut coarse_n = 0u32;
    for u in 0..n {
        let v = matching[u] as usize;
        if map[u] != u32::MAX {
            continue;
        }
        map[u] = coarse_n;
        if v != u {
            map[v] = coarse_n;
        }
        coarse_n += 1;
    }
    let mut vwgts = vec![0u32; coarse_n as usize];
    for u in 0..n {
        vwgts[map[u] as usize] += g.vertex_weight(u as u32);
    }
    let mut b = GraphBuilder::new(coarse_n as usize).with_vertex_weights(vwgts);
    for u in 0..n as u32 {
        for (v, w) in g.edges(u) {
            if u < v {
                let (cu, cv) = (map[u as usize], map[v as usize]);
                if cu != cv {
                    b.add_edge(cu, cv, w);
                }
            }
        }
    }
    (b.build(), map)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    /// square 0-1-2-3-0 with matching (0,1) and (2,3) →
    /// coarse: two super-nodes joined by a weight-2 edge.
    #[test]
    fn square_contracts_to_heavy_edge() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        b.add_edge(3, 0, 1.0);
        let g = b.build();
        let matching = vec![1, 0, 3, 2];
        let (cg, map) = coarsen(&g, &matching);
        assert_eq!(cg.num_nodes(), 2);
        assert_eq!(cg.num_edges(), 1);
        assert_eq!(cg.edge_weights(0), &[2.0]);
        assert_eq!(cg.vertex_weight(0), 2);
        assert_eq!(cg.vertex_weight(1), 2);
        assert_eq!(map[0], map[1]);
        assert_eq!(map[2], map[3]);
        assert_ne!(map[0], map[2]);
    }

    #[test]
    fn self_matched_nodes_survive() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        let g = b.build();
        let matching = vec![1, 0, 2]; // 2 self-matched
        let (cg, map) = coarsen(&g, &matching);
        assert_eq!(cg.num_nodes(), 2);
        assert_eq!(cg.vertex_weight(map[2]), 1);
        assert_eq!(cg.num_edges(), 1);
        cg.validate().unwrap();
    }

    #[test]
    fn total_vertex_weight_preserved() {
        let mut b = GraphBuilder::new(6);
        for (u, v) in [(0, 1), (1, 2), (3, 4), (4, 5), (2, 3)] {
            b.add_edge(u, v, 1.0);
        }
        let g = b.build();
        let matching = vec![1, 0, 3, 2, 5, 4];
        let (cg, _) = coarsen(&g, &matching);
        assert_eq!(cg.total_vertex_weight(), g.total_vertex_weight());
    }

    #[test]
    fn edge_cut_weight_preserved_across_contraction() {
        // cut edges between super-nodes keep their total weight
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 2, 1.5);
        b.add_edge(0, 3, 0.5);
        b.add_edge(1, 2, 1.0);
        b.add_edge(0, 1, 9.0); // internal to supernode A
        b.add_edge(2, 3, 9.0); // internal to supernode B
        let g = b.build();
        let matching = vec![1, 0, 3, 2];
        let (cg, _) = coarsen(&g, &matching);
        assert_eq!(cg.num_edges(), 1);
        assert_eq!(cg.edge_weights(0), &[3.0]); // 1.5 + 0.5 + 1.0
    }
}
