//! Recursive hierarchical k-way partitioning (paper Algorithm 1, line 2:
//! `Z, l ← metis(G, k, L)`).
//!
//! Level 0 is a k-way partition of the whole graph; level j+1 splits each
//! level-j partition into k parts by partitioning its induced subgraph,
//! so level j has `m_j = k^(j+1)` partition ids. Partition ids are
//! globally dense per level with `id_{j+1} = id_j * k + local`, so a
//! node's path through the hierarchy is recoverable from any level's id.

use super::{partition, PartitionConfig};
use crate::graph::{CsrGraph, GraphBuilder};

/// Configuration for hierarchy construction.
#[derive(Debug, Clone)]
pub struct HierarchyConfig {
    /// Branching factor k (paper: `k = n^alpha`).
    pub k: usize,
    /// Number of levels L (paper default 3).
    pub levels: usize,
    /// Base partitioner configuration (k is overridden per split).
    pub base: PartitionConfig,
}

impl HierarchyConfig {
    /// Hierarchy with k parts per level, L levels, default partitioner.
    pub fn new(k: usize, levels: usize) -> Self {
        HierarchyConfig { k, levels, base: PartitionConfig::default() }
    }

    /// Paper's `k = ceil(n^alpha)` rule (Eq. 8).
    pub fn from_alpha(n: usize, alpha: f64, levels: usize) -> Self {
        let k = (n as f64).powf(alpha).round().max(2.0) as usize;
        Self::new(k, levels)
    }
}

/// The L-level membership structure (paper's **Z** matrix).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    /// `z[j][i]` = partition id of node `i` at level `j` (level 0 coarsest).
    pub z: Vec<Vec<u32>>,
    /// Number of partitions per level: `m[j] = k^(j+1)` (paper's vector l).
    /// Note these are *nominal* counts; empty partitions can occur when a
    /// subgraph has fewer nodes than k.
    pub m: Vec<usize>,
    /// Branching factor.
    pub k: usize,
}

impl Hierarchy {
    /// Build an L-level hierarchy over `g`.
    pub fn build(g: &CsrGraph, cfg: &HierarchyConfig) -> Self {
        assert!(cfg.levels >= 1, "need at least one level");
        assert!(cfg.k >= 2, "k must be >= 2");
        let n = g.num_nodes();
        let mut z: Vec<Vec<u32>> = Vec::with_capacity(cfg.levels);
        let mut m: Vec<usize> = Vec::with_capacity(cfg.levels);

        // level 0: partition the whole graph
        let p0 = partition(g, &PartitionConfig { k: cfg.k, ..cfg.base.clone() });
        z.push(p0.part.clone());
        m.push(cfg.k);

        // subsequent levels: split each current partition into k
        for lvl in 1..cfg.levels {
            let prev = &z[lvl - 1];
            let prev_m = m[lvl - 1];
            let mut cur = vec![0u32; n];
            // group node ids by previous-level partition
            let mut groups: Vec<Vec<u32>> = vec![Vec::new(); prev_m];
            for (i, &p) in prev.iter().enumerate() {
                groups[p as usize].push(i as u32);
            }
            for (pid, nodes) in groups.iter().enumerate() {
                if nodes.is_empty() {
                    continue;
                }
                let (sub, _back) = induced_subgraph(g, nodes);
                let seed = cfg.base.seed ^ ((lvl as u64) << 32) ^ pid as u64;
                let sp = partition(&sub, &PartitionConfig { k: cfg.k, seed, ..cfg.base.clone() });
                for (local, &orig) in nodes.iter().enumerate() {
                    cur[orig as usize] = (pid * cfg.k) as u32 + sp.part[local];
                }
            }
            z.push(cur);
            m.push(prev_m * cfg.k);
        }
        Hierarchy { z, m, k: cfg.k }
    }

    /// Number of levels.
    pub fn levels(&self) -> usize {
        self.z.len()
    }

    /// Membership path of node `i`: `[z_0(i), .., z_{L-1}(i)]`.
    pub fn path(&self, i: usize) -> Vec<u32> {
        self.z.iter().map(|lvl| lvl[i]).collect()
    }

    /// Total number of partitions across all levels (paper Eq. 10).
    pub fn total_partitions(&self) -> usize {
        self.m.iter().sum()
    }

    /// Check the parent-child consistency invariant
    /// `z_{j+1}(i) / k == z_j(i)` for all nodes and levels.
    pub fn validate(&self) -> Result<(), String> {
        for j in 1..self.levels() {
            for i in 0..self.z[0].len() {
                if self.z[j][i] as usize / self.k != self.z[j - 1][i] as usize {
                    return Err(format!(
                        "node {i}: level {j} id {} inconsistent with parent {}",
                        self.z[j][i],
                        self.z[j - 1][i]
                    ));
                }
            }
        }
        for (j, lvl) in self.z.iter().enumerate() {
            for (i, &p) in lvl.iter().enumerate() {
                if p as usize >= self.m[j] {
                    return Err(format!("node {i}: level {j} id {p} out of range {}", self.m[j]));
                }
            }
        }
        Ok(())
    }
}

/// Extract the induced subgraph on `nodes`; returns the subgraph (local
/// ids = index into `nodes`) and the local→global map (`nodes` itself).
pub fn induced_subgraph(g: &CsrGraph, nodes: &[u32]) -> (CsrGraph, Vec<u32>) {
    let mut global_to_local = std::collections::HashMap::with_capacity(nodes.len());
    for (local, &orig) in nodes.iter().enumerate() {
        global_to_local.insert(orig, local as u32);
    }
    let vwgts = nodes.iter().map(|&u| g.vertex_weight(u)).collect();
    let mut b = GraphBuilder::new(nodes.len()).with_vertex_weights(vwgts);
    for (local, &orig) in nodes.iter().enumerate() {
        for (v, w) in g.edges(orig) {
            if let Some(&lv) = global_to_local.get(&v) {
                if (local as u32) < lv {
                    b.add_edge(local as u32, lv, w);
                }
            }
        }
    }
    (b.build(), nodes.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};

    fn sbm(n: usize) -> CsrGraph {
        planted_partition(&PlantedPartitionConfig {
            n,
            communities: 8,
            intra_degree: 8.0,
            inter_degree: 1.5,
            seed: 41,
            ..Default::default()
        })
        .0
    }

    #[test]
    fn three_level_hierarchy_shapes() {
        let g = sbm(1000);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(3, 3));
        assert_eq!(h.levels(), 3);
        assert_eq!(h.m, vec![3, 9, 27]);
        assert_eq!(h.total_partitions(), 39); // 3 + 9 + 27 (Eq. 10)
        h.validate().unwrap();
    }

    #[test]
    fn paths_are_consistent() {
        let g = sbm(500);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(2, 3));
        for i in 0..g.num_nodes() {
            let p = h.path(i);
            assert_eq!(p.len(), 3);
            assert_eq!(p[1] as usize / 2, p[0] as usize);
            assert_eq!(p[2] as usize / 2, p[1] as usize);
        }
    }

    #[test]
    fn alpha_rule_matches_paper() {
        // paper §IV-E: ogbn-arxiv n=169,343, alpha=3/8 -> k=125? They list
        // alpha 1/8..6/8 -> k {5,25,125,441,9261}. Check a couple.
        let cfg = HierarchyConfig::from_alpha(169_343, 0.25, 3);
        assert_eq!(cfg.k, 20); // n^(1/4) ≈ 20.3
        let cfg = HierarchyConfig::from_alpha(169_343, 0.5, 3);
        assert_eq!(cfg.k, 412); // n^(1/2) ≈ 411.5 (paper rounds to 441=21^2 via different rule)
    }

    #[test]
    fn single_level_is_plain_partition() {
        let g = sbm(300);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(4, 1));
        assert_eq!(h.levels(), 1);
        assert_eq!(h.m, vec![4]);
        let distinct: std::collections::HashSet<u32> = h.z[0].iter().copied().collect();
        assert!(distinct.len() <= 4 && distinct.len() >= 2);
    }

    #[test]
    fn induced_subgraph_structure() {
        let g = sbm(200);
        let nodes: Vec<u32> = (0..50).collect();
        let (sub, back) = induced_subgraph(&g, &nodes);
        assert_eq!(sub.num_nodes(), 50);
        assert_eq!(back, nodes);
        sub.validate().unwrap();
        // every subgraph edge is an original edge
        for u in 0..50u32 {
            for &v in sub.neighbors(u) {
                assert!(g.neighbors(back[u as usize]).contains(&back[v as usize]));
            }
        }
    }

    #[test]
    fn tiny_partitions_dont_crash() {
        // n smaller than k^L: deep levels get degenerate splits
        let g = sbm(40);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(4, 3));
        h.validate().unwrap();
        assert_eq!(h.m, vec![4, 16, 64]);
    }
}
