//! Recursive hierarchical k-way partitioning (paper Algorithm 1, line 2:
//! `Z, l ← metis(G, k, L)`).
//!
//! Level 0 is a k-way partition of the whole graph; level j+1 splits each
//! level-j partition into k parts by partitioning its induced subgraph,
//! so level j has `m_j = k^(j+1)` partition ids. Partition ids are
//! globally dense per level with `id_{j+1} = id_j * k + local`, so a
//! node's path through the hierarchy is recoverable from any level's id.

use super::{partition, PartitionConfig};
use crate::graph::{CsrGraph, GraphStore};
use rayon::prelude::*;

/// Configuration for hierarchy construction.
#[derive(Debug, Clone)]
pub struct HierarchyConfig {
    /// Branching factor k (paper: `k = n^alpha`).
    pub k: usize,
    /// Number of levels L (paper default 3).
    pub levels: usize,
    /// Base partitioner configuration (k is overridden per split).
    pub base: PartitionConfig,
}

impl HierarchyConfig {
    /// Hierarchy with k parts per level, L levels, default partitioner.
    pub fn new(k: usize, levels: usize) -> Self {
        HierarchyConfig { k, levels, base: PartitionConfig::default() }
    }

    /// Paper's `k = ceil(n^alpha)` rule (Eq. 8).
    pub fn from_alpha(n: usize, alpha: f64, levels: usize) -> Self {
        let k = (n as f64).powf(alpha).round().max(2.0) as usize;
        Self::new(k, levels)
    }
}

/// The L-level membership structure (paper's **Z** matrix).
#[derive(Debug, Clone)]
pub struct Hierarchy {
    /// `z[j][i]` = partition id of node `i` at level `j` (level 0 coarsest).
    pub z: Vec<Vec<u32>>,
    /// Number of partitions per level: `m[j] = k^(j+1)` (paper's vector l).
    /// Note these are *nominal* counts; empty partitions can occur when a
    /// subgraph has fewer nodes than k.
    pub m: Vec<usize>,
    /// Branching factor.
    pub k: usize,
}

impl Hierarchy {
    /// Build an L-level hierarchy over `g` — generic over the storage
    /// backend. Only level 0 and the level-1 subgraph extraction read
    /// `g`; every deeper level partitions in-memory induced subgraphs.
    pub fn build<G: GraphStore + ?Sized>(g: &G, cfg: &HierarchyConfig) -> Self {
        assert!(cfg.levels >= 1, "need at least one level");
        assert!(cfg.k >= 2, "k must be >= 2");
        let n = g.num_nodes();
        let mut z: Vec<Vec<u32>> = Vec::with_capacity(cfg.levels);
        let mut m: Vec<usize> = Vec::with_capacity(cfg.levels);

        // level 0: partition the whole graph
        let p0 = partition(g, &PartitionConfig { k: cfg.k, ..cfg.base.clone() });
        z.push(p0.part.clone());
        m.push(cfg.k);

        // Subsequent levels: split each current partition into k. Sibling
        // subgraphs are independent, so they are extracted and partitioned
        // on the rayon pool; each worker split reuses one
        // `global_to_local` scratch buffer across the groups it owns.
        // Results are collected in pid order and every split seeds from
        // (lvl, pid), so `z` is identical at any thread count.
        for lvl in 1..cfg.levels {
            let prev = &z[lvl - 1];
            let prev_m = m[lvl - 1];
            // group node ids by previous-level partition
            let mut groups: Vec<Vec<u32>> = vec![Vec::new(); prev_m];
            for (i, &p) in prev.iter().enumerate() {
                groups[p as usize].push(i as u32);
            }
            let parts: Vec<Option<Vec<u32>>> = groups
                .par_iter()
                .enumerate()
                .map_init(
                    || vec![u32::MAX; n],
                    |scratch, (pid, nodes)| {
                        if nodes.is_empty() {
                            return None;
                        }
                        let sub = induced_subgraph_with_scratch(g, nodes, scratch);
                        let seed = cfg.base.seed ^ ((lvl as u64) << 32) ^ pid as u64;
                        let pc = PartitionConfig { k: cfg.k, seed, ..cfg.base.clone() };
                        Some(partition(&sub, &pc).part)
                    },
                )
                .collect();
            let mut cur = vec![0u32; n];
            for (pid, (nodes, part)) in groups.iter().zip(&parts).enumerate() {
                if let Some(part) = part {
                    for (local, &orig) in nodes.iter().enumerate() {
                        cur[orig as usize] = (pid * cfg.k) as u32 + part[local];
                    }
                }
            }
            z.push(cur);
            m.push(prev_m * cfg.k);
        }
        Hierarchy { z, m, k: cfg.k }
    }

    /// Number of levels.
    pub fn levels(&self) -> usize {
        self.z.len()
    }

    /// Membership path of node `i`: `[z_0(i), .., z_{L-1}(i)]`.
    pub fn path(&self, i: usize) -> Vec<u32> {
        self.z.iter().map(|lvl| lvl[i]).collect()
    }

    /// Total number of partitions across all levels (paper Eq. 10).
    pub fn total_partitions(&self) -> usize {
        self.m.iter().sum()
    }

    /// The per-node partition-id slice at one `level` — the bulk
    /// counterpart of [`path`](Hierarchy::path) for callers that walk
    /// every node at a fixed level (the sharded trainer's setup path
    /// reads level 0 for all `n` nodes: one slice borrow here instead
    /// of `n` `path()` allocations).
    pub fn shard_assignments(&self, level: usize) -> &[u32] {
        &self.z[level]
    }

    /// Check the parent-child consistency invariant
    /// `z_{j+1}(i) / k == z_j(i)` for all nodes and levels.
    pub fn validate(&self) -> Result<(), String> {
        for j in 1..self.levels() {
            for i in 0..self.z[0].len() {
                if self.z[j][i] as usize / self.k != self.z[j - 1][i] as usize {
                    return Err(format!(
                        "node {i}: level {j} id {} inconsistent with parent {}",
                        self.z[j][i],
                        self.z[j - 1][i]
                    ));
                }
            }
        }
        for (j, lvl) in self.z.iter().enumerate() {
            for (i, &p) in lvl.iter().enumerate() {
                if p as usize >= self.m[j] {
                    return Err(format!("node {i}: level {j} id {p} out of range {}", self.m[j]));
                }
            }
        }
        Ok(())
    }
}

/// Extract the induced subgraph on `nodes`; returns the subgraph (local
/// ids = index into `nodes`) and the local→global map (`nodes` itself).
///
/// Both directions of every adjacency entry whose endpoints are in
/// `nodes` are copied, so the subgraph of an undirected-symmetric graph
/// is undirected-symmetric (pinned by
/// `induced_subgraph_is_undirected_symmetric`) — `validate()` holds on
/// the result whenever it holds on `g`.
pub fn induced_subgraph<G: GraphStore + ?Sized>(g: &G, nodes: &[u32]) -> (CsrGraph, Vec<u32>) {
    let mut scratch = vec![u32::MAX; g.num_nodes()];
    (induced_subgraph_with_scratch(g, nodes, &mut scratch), nodes.to_vec())
}

/// CSR-native induced-subgraph extraction with a caller-owned
/// `global_to_local` scratch buffer (`g.num_nodes()` entries, all
/// `u32::MAX` on entry; restored on exit). One buffer serves many
/// sibling extractions without O(n) re-clearing or per-call hashing —
/// the hot path of [`Hierarchy::build`].
pub fn induced_subgraph_with_scratch<G: GraphStore + ?Sized>(
    g: &G,
    nodes: &[u32],
    global_to_local: &mut [u32],
) -> CsrGraph {
    let ln = nodes.len();
    let (mut row_nbrs, mut row_wts) = (Vec::new(), Vec::new());
    for (local, &u) in nodes.iter().enumerate() {
        // unconditional: a dirty scratch or duplicate node would yield a
        // silently corrupt subgraph, and the check is O(1) per node
        assert_eq!(global_to_local[u as usize], u32::MAX, "dirty scratch or duplicate node {u}");
        global_to_local[u as usize] = local as u32;
    }
    // counting pass: in-subgraph degree per local node → row offsets
    let mut indptr = vec![0u64; ln + 1];
    for (local, &u) in nodes.iter().enumerate() {
        let mut deg = 0u64;
        g.neighbors_into(u, &mut row_nbrs);
        for &v in &row_nbrs {
            if global_to_local[v as usize] != u32::MAX {
                deg += 1;
            }
        }
        indptr[local + 1] = deg;
    }
    for i in 0..ln {
        indptr[i + 1] += indptr[i];
    }
    // fill pass: rows are consecutive, so one cursor walks the arrays
    let mut indices = vec![0u32; indptr[ln] as usize];
    let mut weights = vec![0f32; indptr[ln] as usize];
    let mut cursor = 0usize;
    for &u in nodes {
        g.edges_into(u, &mut row_nbrs, &mut row_wts);
        for (&v, &w) in row_nbrs.iter().zip(&row_wts) {
            let lv = global_to_local[v as usize];
            if lv != u32::MAX {
                indices[cursor] = lv;
                weights[cursor] = w;
                cursor += 1;
            }
        }
    }
    // ascending `nodes` keep rows sorted for free (global CSR rows are
    // sorted and the mapping is monotone); arbitrary orders need a
    // per-row sort to restore the builder's canonical layout.
    if !nodes.windows(2).all(|w| w[0] <= w[1]) {
        for local in 0..ln {
            let (s, e) = (indptr[local] as usize, indptr[local + 1] as usize);
            let mut row: Vec<(u32, f32)> =
                indices[s..e].iter().copied().zip(weights[s..e].iter().copied()).collect();
            row.sort_unstable_by_key(|&(v, _)| v);
            for (j, (v, w)) in row.into_iter().enumerate() {
                indices[s + j] = v;
                weights[s + j] = w;
            }
        }
    }
    for &u in nodes {
        global_to_local[u as usize] = u32::MAX;
    }
    let vwgts = nodes.iter().map(|&u| g.vertex_weight(u)).collect();
    CsrGraph::from_parts(indptr, indices, weights, vwgts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};

    fn sbm(n: usize) -> CsrGraph {
        planted_partition(&PlantedPartitionConfig {
            n,
            communities: 8,
            intra_degree: 8.0,
            inter_degree: 1.5,
            seed: 41,
            ..Default::default()
        })
        .0
    }

    #[test]
    fn three_level_hierarchy_shapes() {
        let g = sbm(1000);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(3, 3));
        assert_eq!(h.levels(), 3);
        assert_eq!(h.m, vec![3, 9, 27]);
        assert_eq!(h.total_partitions(), 39); // 3 + 9 + 27 (Eq. 10)
        h.validate().unwrap();
    }

    #[test]
    fn paths_are_consistent() {
        let g = sbm(500);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(2, 3));
        for i in 0..g.num_nodes() {
            let p = h.path(i);
            assert_eq!(p.len(), 3);
            assert_eq!(p[1] as usize / 2, p[0] as usize);
            assert_eq!(p[2] as usize / 2, p[1] as usize);
        }
    }

    #[test]
    fn alpha_rule_matches_paper() {
        // paper §IV-E: ogbn-arxiv n=169,343, alpha=3/8 -> k=125? They list
        // alpha 1/8..6/8 -> k {5,25,125,441,9261}. Check a couple.
        let cfg = HierarchyConfig::from_alpha(169_343, 0.25, 3);
        assert_eq!(cfg.k, 20); // n^(1/4) ≈ 20.3
        let cfg = HierarchyConfig::from_alpha(169_343, 0.5, 3);
        assert_eq!(cfg.k, 412); // n^(1/2) ≈ 411.5 (paper rounds to 441=21^2 via different rule)
    }

    #[test]
    fn single_level_is_plain_partition() {
        let g = sbm(300);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(4, 1));
        assert_eq!(h.levels(), 1);
        assert_eq!(h.m, vec![4]);
        let distinct: std::collections::HashSet<u32> = h.z[0].iter().copied().collect();
        assert!(distinct.len() <= 4 && distinct.len() >= 2);
    }

    #[test]
    fn induced_subgraph_structure() {
        let g = sbm(200);
        let nodes: Vec<u32> = (0..50).collect();
        let (sub, back) = induced_subgraph(&g, &nodes);
        assert_eq!(sub.num_nodes(), 50);
        assert_eq!(back, nodes);
        sub.validate().unwrap();
        // every subgraph edge is an original edge
        for u in 0..50u32 {
            for &v in sub.neighbors(u) {
                assert!(g.neighbors(back[u as usize]).contains(&back[v as usize]));
            }
        }
    }

    #[test]
    fn induced_subgraph_is_undirected_symmetric() {
        // Non-contiguous, UNSORTED node set: both directions of every
        // in-set edge must survive extraction. `validate()` pins the
        // symmetry invariant (v ∈ adj(u) ⇔ u ∈ adj(v), equal weights).
        let g = sbm(300);
        let mut nodes: Vec<u32> = (0..300u32).step_by(3).collect();
        nodes.reverse();
        let (sub, back) = induced_subgraph(&g, &nodes);
        assert_eq!(back, nodes);
        sub.validate().unwrap();
        // edge count matches a direct double scan of g over the set
        let in_set: std::collections::HashSet<u32> = nodes.iter().copied().collect();
        let mut expect = 0usize;
        for &u in &nodes {
            expect += g.neighbors(u).iter().filter(|v| in_set.contains(v)).count();
        }
        assert_eq!(sub.num_adjacency_entries(), expect);
    }

    #[test]
    fn scratch_reuse_matches_fresh_extraction() {
        let g = sbm(120);
        let mut scratch = vec![u32::MAX; g.num_nodes()];
        let sets: [Vec<u32>; 3] =
            [(0..40u32).collect(), (30..90u32).collect(), (0..120u32).step_by(2).collect()];
        for nodes in &sets {
            let reused = induced_subgraph_with_scratch(&g, nodes, &mut scratch);
            let (fresh, _) = induced_subgraph(&g, nodes);
            assert_eq!(reused.indptr(), fresh.indptr());
            assert_eq!(reused.indices(), fresh.indices());
            reused.validate().unwrap();
            assert!(scratch.iter().all(|&x| x == u32::MAX), "scratch not restored");
        }
    }

    #[test]
    fn tiny_partitions_dont_crash() {
        // n smaller than k^L: deep levels get degenerate splits
        let g = sbm(40);
        let h = Hierarchy::build(&g, &HierarchyConfig::new(4, 3));
        h.validate().unwrap();
        assert_eq!(h.m, vec![4, 16, 64]);
    }
}
