//! Random partitioning — the RandomPart baseline of Table III.
//!
//! The paper notes RandomPart "is a hashing trick with the number of hash
//! buckets B equal to the number of partitions k": nodes are assigned to
//! parts uniformly at random, destroying the positional signal while
//! keeping the parameter count identical to PosEmb 1-level.

use crate::util::rng::Rng;

/// Uniform random assignment of `n` nodes to `k` parts.
pub fn random_partition(n: usize, k: usize, seed: u64) -> Vec<u32> {
    assert!(k >= 1);
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| rng.gen_range(k) as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_parts_roughly_uniformly() {
        let part = random_partition(10_000, 8, 1);
        let mut sizes = vec![0usize; 8];
        for &p in &part {
            sizes[p as usize] += 1;
        }
        for &s in &sizes {
            assert!(s > 1000 && s < 1500, "size {s}");
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(random_partition(100, 4, 7), random_partition(100, 4, 7));
        assert_ne!(random_partition(100, 4, 7), random_partition(100, 4, 8));
    }

    #[test]
    fn k1_all_zero() {
        assert!(random_partition(50, 1, 3).iter().all(|&p| p == 0));
    }
}
