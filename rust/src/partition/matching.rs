//! Heavy-edge matching (HEM) for the coarsening phase.
//!
//! Visits nodes in random order; each unmatched node matches with its
//! unmatched neighbor of maximum edge weight (ties → lower id). Nodes with
//! no unmatched neighbor stay matched to themselves — the classic METIS
//! HEM scheme, which preferentially collapses heavy edges so the coarse
//! graph preserves the cut structure of the fine graph.

use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// `matching[u] == v` means u and v are collapsed together (v may equal u).
/// Always an involution: `matching[matching[u]] == u`.
pub fn heavy_edge_matching(g: &CsrGraph, rng: &mut Rng) -> Vec<u32> {
    let n = g.num_nodes();
    const UNMATCHED: u32 = u32::MAX;
    let mut matching = vec![UNMATCHED; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    for &u in &order {
        if matching[u as usize] != UNMATCHED {
            continue;
        }
        let mut best: Option<(u32, f32)> = None;
        for (v, w) in g.edges(u) {
            if matching[v as usize] != UNMATCHED || v == u {
                continue;
            }
            match best {
                None => best = Some((v, w)),
                Some((bv, bw)) => {
                    if w > bw || (w == bw && v < bv) {
                        best = Some((v, w));
                    }
                }
            }
        }
        match best {
            Some((v, _)) => {
                matching[u as usize] = v;
                matching[v as usize] = u;
            }
            None => matching[u as usize] = u,
        }
    }
    matching
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};

    #[test]
    fn matching_is_involution() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 500,
            communities: 5,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 4,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(0);
        let m = heavy_edge_matching(&g, &mut rng);
        for u in 0..g.num_nodes() {
            let v = m[u] as usize;
            assert_eq!(m[v] as usize, u, "not involutive at {u}");
        }
    }

    #[test]
    fn prefers_heavy_edges() {
        // star with one heavy edge: 0-1 weight 10, 0-2 and 0-3 weight 1.
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 10.0);
        b.add_edge(0, 2, 1.0);
        b.add_edge(0, 3, 1.0);
        let g = b.build();
        // try several seeds: whenever 0 picks first, it must take 1
        for seed in 0..10 {
            let mut rng = Rng::seed_from_u64(seed);
            let m = heavy_edge_matching(&g, &mut rng);
            // 0 and 1 both unmatched at each other's turn unless one of
            // 2/3 grabbed 0 first (they only connect to 0). If 0 is
            // matched to 2 or 3, then 0 was not first. But if 0-1 matched,
            // great. Just assert involution + validity here, plus: if 0
            // went first (m[2]==2 or matched to nothing else)… keep it
            // simple: assert somebody matched 0.
            assert_ne!(m[0], u32::MAX);
            for u in 0..4 {
                let v = m[u] as usize;
                assert_eq!(m[v] as usize, u);
            }
        }
        // deterministic check: force order by matching on a 2-node graph
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 5.0);
        let g2 = b.build();
        let mut rng = Rng::seed_from_u64(1);
        let m = heavy_edge_matching(&g2, &mut rng);
        assert_eq!(m[0], 1);
        assert_eq!(m[1], 0);
    }

    #[test]
    fn isolated_nodes_self_match() {
        let b = GraphBuilder::new(3);
        let g = b.build();
        let mut rng = Rng::seed_from_u64(2);
        let m = heavy_edge_matching(&g, &mut rng);
        assert_eq!(m, vec![0, 1, 2]);
    }

    #[test]
    fn matching_shrinks_graph_substantially() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 1000,
            communities: 4,
            intra_degree: 10.0,
            inter_degree: 1.0,
            seed: 8,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(3);
        let m = heavy_edge_matching(&g, &mut rng);
        let pairs = (0..g.num_nodes()).filter(|&u| m[u] as usize != u).count() / 2;
        // dense-enough graph: expect most nodes matched
        assert!(pairs as f64 > 0.3 * g.num_nodes() as f64, "pairs {pairs}");
    }
}
