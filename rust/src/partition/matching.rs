//! Heavy-edge matching (HEM) for the coarsening phase.
//!
//! Two implementations of the same contract (`matching[matching[u]] ==
//! u`, matched pairs are edges, leftovers self-match):
//!
//! * [`heavy_edge_matching`] — the scalar oracle. Visits nodes in random
//!   order; each unmatched node matches with its unmatched neighbor of
//!   maximum edge weight (ties → lower id) — the classic METIS HEM
//!   scheme, which preferentially collapses heavy edges so the coarse
//!   graph preserves the cut structure of the fine graph.
//! * [`parallel_heavy_edge_matching`] — rayon-parallel local-max
//!   matching. Each round, every unmatched node proposes to its best
//!   unmatched neighbor; mutual proposals are claimed lock-free with
//!   `AtomicU32` compare-exchange over chunked node ranges, and the
//!   losers retry against the updated matched set in the next round.
//!   The proposal function is pure (reads only round-start state) and
//!   claimed pairs are vertex-disjoint, so the result is deterministic
//!   for a fixed seed at any thread count — only the seeded tie-break
//!   priorities distinguish two runs, never the schedule.

use crate::graph::GraphStore;
use crate::util::rng::Rng;
use rayon::prelude::*;
use std::sync::atomic::{AtomicU32, Ordering};

const UNMATCHED: u32 = u32::MAX;

/// Nodes per parallel work unit in the matching rounds: small enough to
/// load-balance heavy-tailed degree distributions, large enough to
/// amortize rayon task overhead.
const MATCH_CHUNK: usize = 4096;

/// `matching[u] == v` means u and v are collapsed together (v may equal u).
/// Always an involution: `matching[matching[u]] == u`.
pub fn heavy_edge_matching<G: GraphStore + ?Sized>(g: &G, rng: &mut Rng) -> Vec<u32> {
    let n = g.num_nodes();
    let mut matching = vec![UNMATCHED; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
    for &u in &order {
        if matching[u as usize] != UNMATCHED {
            continue;
        }
        let mut best: Option<(u32, f32)> = None;
        g.edges_into(u, &mut nbrs, &mut wts);
        for (&v, &w) in nbrs.iter().zip(&wts) {
            if matching[v as usize] != UNMATCHED || v == u {
                continue;
            }
            match best {
                None => best = Some((v, w)),
                Some((bv, bw)) => {
                    if w > bw || (w == bw && v < bv) {
                        best = Some((v, w));
                    }
                }
            }
        }
        match best {
            Some((v, _)) => {
                matching[u as usize] = v;
                matching[v as usize] = u;
            }
            None => matching[u as usize] = u,
        }
    }
    matching
}

/// SplitMix64 finalizer — per-node tie-break priorities from a seed.
#[inline]
fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic rayon-parallel heavy-edge matching.
///
/// Same contract as [`heavy_edge_matching`] (valid involution, matched
/// pairs are edges of `g`), built from conflict-resolution rounds:
///
/// 1. **Propose** — every still-unmatched node picks its best unmatched
///    neighbor: maximum edge weight, ties broken by seeded per-node
///    priority then id. Proposals only read round-start matched state, so
///    the phase is embarrassingly parallel over chunked node ranges.
/// 2. **Claim** — a pair (u, v) with mutual proposals is claimed by its
///    lower endpoint via `AtomicU32` compare-exchange on both slots.
///    Mutual-best pairs are vertex-disjoint, so claims never conflict;
///    the CAS guards the invariant rather than arbitrating races. Nodes
///    whose proposal was one-sided stay unmatched and retry next round;
///    nodes with no unmatched neighbor left self-match immediately.
///
/// A mutual pair always exists while any unmatched node still has an
/// unmatched neighbor (follow best-proposal pointers: weights are
/// non-decreasing along the chain and the priority tie-break rules out
/// longer cycles, so the chain ends in a 2-cycle), so every round makes
/// progress and the loop terminates.
pub fn parallel_heavy_edge_matching<G: GraphStore + ?Sized>(g: &G, seed: u64) -> Vec<u32> {
    let n = g.num_nodes();
    if n == 0 {
        return Vec::new();
    }
    let pri: Vec<u64> = (0..n as u64).into_par_iter().map(|u| mix64(seed ^ mix64(u))).collect();
    let matching: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNMATCHED)).collect();
    let candidate: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(UNMATCHED)).collect();
    let mut active: Vec<u32> = (0..n as u32).collect();
    while !active.is_empty() {
        // Phase 1: propose. Writes land in disjoint slots (one per active
        // node); reads see only round-start matched state. Each chunk
        // carries its own adjacency copy-out scratch.
        active.par_chunks(MATCH_CHUNK).for_each(|chunk| {
            let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
            for &u in chunk {
                let mut best: Option<(f32, u64, u32)> = None;
                g.edges_into(u, &mut nbrs, &mut wts);
                for (&v, &w) in nbrs.iter().zip(&wts) {
                    if v == u || matching[v as usize].load(Ordering::Relaxed) != UNMATCHED {
                        continue;
                    }
                    let pv = pri[v as usize];
                    let better = match best {
                        None => true,
                        Some((bw, bp, bv)) => w > bw || (w == bw && (pv, v) < (bp, bv)),
                    };
                    if better {
                        best = Some((w, pv, v));
                    }
                }
                let c = best.map_or(UNMATCHED, |(_, _, v)| v);
                candidate[u as usize].store(c, Ordering::Relaxed);
            }
        });
        // Phase 2: claim mutual pairs; retire dead-end nodes.
        active.par_chunks(MATCH_CHUNK).for_each(|chunk| {
            for &u in chunk {
                let v = candidate[u as usize].load(Ordering::Relaxed);
                if v == UNMATCHED {
                    // every neighbor is already matched: u can never pair
                    matching[u as usize].store(u, Ordering::Relaxed);
                    continue;
                }
                if u < v && candidate[v as usize].load(Ordering::Relaxed) == u {
                    let claim_u = matching[u as usize].compare_exchange(
                        UNMATCHED,
                        v,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    );
                    if claim_u.is_ok() {
                        let claim_v = matching[v as usize].compare_exchange(
                            UNMATCHED,
                            u,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        );
                        debug_assert!(claim_v.is_ok(), "mutual pairs must be vertex-disjoint");
                    }
                }
            }
        });
        let before = active.len();
        active.retain(|&u| matching[u as usize].load(Ordering::Relaxed) == UNMATCHED);
        if active.len() == before {
            // Unreachable by the progress argument above; self-match the
            // remainder rather than livelock if the invariant ever breaks.
            if cfg!(debug_assertions) {
                panic!("matching round made no progress ({} nodes active)", active.len());
            }
            for &u in &active {
                matching[u as usize].store(u, Ordering::Relaxed);
            }
            break;
        }
    }
    matching.into_iter().map(AtomicU32::into_inner).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, CsrGraph, GraphBuilder, PlantedPartitionConfig};

    #[test]
    fn matching_is_involution() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 500,
            communities: 5,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 4,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(0);
        let m = heavy_edge_matching(&g, &mut rng);
        for u in 0..g.num_nodes() {
            let v = m[u] as usize;
            assert_eq!(m[v] as usize, u, "not involutive at {u}");
        }
    }

    #[test]
    fn prefers_heavy_edges() {
        // star with one heavy edge: 0-1 weight 10, 0-2 and 0-3 weight 1.
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 10.0);
        b.add_edge(0, 2, 1.0);
        b.add_edge(0, 3, 1.0);
        let g = b.build();
        // try several seeds: whenever 0 picks first, it must take 1
        for seed in 0..10 {
            let mut rng = Rng::seed_from_u64(seed);
            let m = heavy_edge_matching(&g, &mut rng);
            // 0 and 1 both unmatched at each other's turn unless one of
            // 2/3 grabbed 0 first (they only connect to 0). If 0 is
            // matched to 2 or 3, then 0 was not first. But if 0-1 matched,
            // great. Just assert involution + validity here, plus: if 0
            // went first (m[2]==2 or matched to nothing else)… keep it
            // simple: assert somebody matched 0.
            assert_ne!(m[0], u32::MAX);
            for u in 0..4 {
                let v = m[u] as usize;
                assert_eq!(m[v] as usize, u);
            }
        }
        // deterministic check: force order by matching on a 2-node graph
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 5.0);
        let g2 = b.build();
        let mut rng = Rng::seed_from_u64(1);
        let m = heavy_edge_matching(&g2, &mut rng);
        assert_eq!(m[0], 1);
        assert_eq!(m[1], 0);
    }

    #[test]
    fn isolated_nodes_self_match() {
        let b = GraphBuilder::new(3);
        let g = b.build();
        let mut rng = Rng::seed_from_u64(2);
        let m = heavy_edge_matching(&g, &mut rng);
        assert_eq!(m, vec![0, 1, 2]);
    }

    #[test]
    fn matching_shrinks_graph_substantially() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 1000,
            communities: 4,
            intra_degree: 10.0,
            inter_degree: 1.0,
            seed: 8,
            ..Default::default()
        });
        let mut rng = Rng::seed_from_u64(3);
        let m = heavy_edge_matching(&g, &mut rng);
        let pairs = (0..g.num_nodes()).filter(|&u| m[u] as usize != u).count() / 2;
        // dense-enough graph: expect most nodes matched
        assert!(pairs as f64 > 0.3 * g.num_nodes() as f64, "pairs {pairs}");
    }

    fn assert_valid_matching(g: &CsrGraph, m: &[u32]) {
        assert_eq!(m.len(), g.num_nodes());
        for u in 0..g.num_nodes() {
            let v = m[u] as usize;
            assert!(v < g.num_nodes(), "out of range at {u}");
            assert_eq!(m[v] as usize, u, "not involutive at {u}");
            if v != u {
                assert!(g.neighbors(u as u32).contains(&(v as u32)), "{u}-{v} not an edge");
            }
        }
    }

    #[test]
    fn parallel_matching_is_valid_and_deterministic() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 1500,
            communities: 6,
            intra_degree: 9.0,
            inter_degree: 2.0,
            seed: 12,
            ..Default::default()
        });
        let a = parallel_heavy_edge_matching(&g, 7);
        let b = parallel_heavy_edge_matching(&g, 7);
        let c = parallel_heavy_edge_matching(&g, 8);
        assert_valid_matching(&g, &a);
        assert_valid_matching(&g, &c);
        assert_eq!(a, b, "same seed must give identical matchings");
        assert_ne!(a, c, "different seeds should explore different matchings");
    }

    #[test]
    fn parallel_matching_prefers_heavy_edges() {
        // path a-b-c with b-c twice as heavy: b must pair with c
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 2.0);
        let g = b.build();
        for seed in 0..8 {
            let m = parallel_heavy_edge_matching(&g, seed);
            assert_eq!(m, vec![0, 2, 1], "seed {seed}");
        }
    }

    #[test]
    fn parallel_matching_handles_degenerate_graphs() {
        let empty = GraphBuilder::new(0).build();
        assert!(parallel_heavy_edge_matching(&empty, 1).is_empty());
        let isolated = GraphBuilder::new(4).build();
        assert_eq!(parallel_heavy_edge_matching(&isolated, 1), vec![0, 1, 2, 3]);
    }

    #[test]
    fn parallel_matching_shrinks_graph_substantially() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 1000,
            communities: 4,
            intra_degree: 10.0,
            inter_degree: 1.0,
            seed: 8,
            ..Default::default()
        });
        let m = parallel_heavy_edge_matching(&g, 3);
        let pairs = (0..g.num_nodes()).filter(|&u| m[u] as usize != u).count() / 2;
        assert!(pairs as f64 > 0.3 * g.num_nodes() as f64, "pairs {pairs}");
    }
}
