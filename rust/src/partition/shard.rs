//! Graph sharding for partition-sharded training.
//!
//! [`GraphShards::build`] cuts a graph into `k` shards with the
//! multilevel partitioner, then extracts per-shard induced subgraphs
//! over each shard's **owned** nodes plus a one-hop **halo** of
//! cross-partition neighbors. The halo is what lets a shard run
//! neighbor-sampled minibatch epochs locally: every owned seed's 1-hop
//! neighborhood is fully resident (deeper hops are truncated at the
//! halo boundary — the standard distributed-GNN approximation), while
//! halo parameter rows are refreshed from their owning shard by the
//! sharded trainer's per-epoch halo exchange.
//!
//! Local node ids are positions in the **ascending** merged
//! `owned ∪ halo` list, so [`induced_subgraph_with_scratch`] takes its
//! no-sort fast path and — crucially — at `k = 1` the single shard's
//! local graph is the input graph **bit for bit** (identity node list),
//! which is what the sharded trainer's k = 1 parity pin stands on.

use super::{edge_cut, induced_subgraph_with_scratch, Hierarchy, HierarchyConfig, PartitionConfig};
use crate::graph::{CsrGraph, GraphStore};

/// One shard: an owned node set, its one-hop halo, and the induced
/// local subgraph over both.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Shard id in `[0, k)`.
    pub id: usize,
    /// Global ids of nodes this shard owns (ascending).
    pub owned: Vec<u32>,
    /// Global ids of one-hop cross-partition neighbors (ascending,
    /// disjoint from `owned`).
    pub halo: Vec<u32>,
    /// `owned ∪ halo`, ascending — local id `l` is global `locals[l]`.
    pub locals: Vec<u32>,
    /// Induced subgraph over `locals` (local ids).
    pub graph: CsrGraph,
}

impl Shard {
    /// Local id of a global node, if resident on this shard.
    pub fn local_of(&self, global: u32) -> Option<u32> {
        self.locals.binary_search(&global).ok().map(|l| l as u32)
    }

    /// Is local id `l` an owned (vs halo) node?
    pub fn is_owned_local(&self, l: u32, assignment: &[u32]) -> bool {
        assignment[self.locals[l as usize] as usize] == self.id as u32
    }
}

/// A `k`-way sharding of one graph: the assignment vector, the cut it
/// pays, and the per-shard induced subgraphs with halos.
#[derive(Debug, Clone)]
pub struct GraphShards {
    /// `assignment[i]` ∈ `[0, k)`: the shard owning global node `i`.
    pub assignment: Vec<u32>,
    /// Number of shards.
    pub k: usize,
    /// Weighted edge cut of the assignment (each cut edge once).
    pub edge_cut: f64,
    /// The shards, indexed by id.
    pub shards: Vec<Shard>,
}

impl GraphShards {
    /// Partition `g` into `k` shards (multilevel partitioner, seeded by
    /// `seed`) and extract each shard's owned + halo induced subgraph.
    ///
    /// `k = 1` skips the partitioner entirely: one shard owning every
    /// node in ascending order, no halo, and a local graph bit-identical
    /// to `g`.
    ///
    /// Generic over [`GraphStore`]: a disk-backed graph is read row by
    /// row here and never materialized globally — only the (smaller)
    /// per-shard induced subgraphs live in memory afterwards.
    pub fn build<G: GraphStore + ?Sized>(g: &G, k: usize, seed: u64) -> Self {
        assert!(k >= 1, "need at least one shard");
        let n = g.num_nodes();
        let assignment: Vec<u32> = if k == 1 {
            vec![0; n]
        } else {
            // a 1-level hierarchy is exactly one multilevel k-way cut;
            // shard_assignments(0) hands back the whole level-0 slice
            let cfg = HierarchyConfig {
                k,
                levels: 1,
                base: PartitionConfig { seed, ..PartitionConfig::default() },
            };
            Hierarchy::build(g, &cfg).shard_assignments(0).to_vec()
        };
        let cut = edge_cut(g, &assignment);

        let mut owned: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &p) in assignment.iter().enumerate() {
            owned[p as usize].push(i as u32);
        }
        let mut scratch = vec![u32::MAX; n];
        let mut row = Vec::new();
        let shards: Vec<Shard> = owned
            .into_iter()
            .enumerate()
            .map(|(id, owned)| {
                let mut halo: Vec<u32> = Vec::new();
                for &u in &owned {
                    g.neighbors_into(u, &mut row);
                    halo.extend(row.iter().filter(|&&v| assignment[v as usize] != id as u32));
                }
                halo.sort_unstable();
                halo.dedup();
                // ascending merge of two disjoint sorted lists
                let mut locals = Vec::with_capacity(owned.len() + halo.len());
                let (mut a, mut b) = (0usize, 0usize);
                while a < owned.len() || b < halo.len() {
                    match (owned.get(a), halo.get(b)) {
                        (Some(&u), Some(&v)) if u < v => {
                            locals.push(u);
                            a += 1;
                        }
                        (Some(_), Some(&v)) => {
                            locals.push(v);
                            b += 1;
                        }
                        (Some(&u), None) => {
                            locals.push(u);
                            a += 1;
                        }
                        (None, Some(&v)) => {
                            locals.push(v);
                            b += 1;
                        }
                        (None, None) => unreachable!(),
                    }
                }
                let graph = induced_subgraph_with_scratch(g, &locals, &mut scratch);
                Shard { id, owned, halo, locals, graph }
            })
            .collect();
        GraphShards { assignment, k, edge_cut: cut, shards }
    }

    /// Total halo replicas across all shards (each cross-partition
    /// neighbor counted once per shard replicating it).
    pub fn total_halo_nodes(&self) -> usize {
        self.shards.iter().map(|s| s.halo.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};

    fn sbm(n: usize, k: usize, seed: u64) -> CsrGraph {
        planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 8.0,
            inter_degree: 1.0,
            seed,
            ..Default::default()
        })
        .0
    }

    #[test]
    fn k1_shard_is_the_whole_graph_bit_for_bit() {
        let g = sbm(500, 4, 3);
        let s = GraphShards::build(&g, 1, 7);
        assert_eq!(s.k, 1);
        assert_eq!(s.edge_cut, 0.0);
        let sh = &s.shards[0];
        assert_eq!(sh.owned, (0..500u32).collect::<Vec<_>>());
        assert!(sh.halo.is_empty());
        assert_eq!(sh.graph.indptr(), g.indptr());
        assert_eq!(sh.graph.indices(), g.indices());
    }

    #[test]
    fn shards_cover_all_nodes_exactly_once() {
        let g = sbm(800, 4, 5);
        let s = GraphShards::build(&g, 4, 11);
        let total: usize = s.shards.iter().map(|sh| sh.owned.len()).sum();
        assert_eq!(total, g.num_nodes());
        for sh in &s.shards {
            for &u in &sh.owned {
                assert_eq!(s.assignment[u as usize], sh.id as u32);
            }
            for &v in &sh.halo {
                assert_ne!(s.assignment[v as usize], sh.id as u32);
                assert!(sh.owned.binary_search(&v).is_err());
            }
            assert!(sh.locals.windows(2).all(|w| w[0] < w[1]), "locals not ascending");
            assert_eq!(sh.locals.len(), sh.owned.len() + sh.halo.len());
            assert_eq!(sh.graph.num_nodes(), sh.locals.len());
        }
    }

    #[test]
    fn halo_closes_every_owned_nodes_one_hop_neighborhood() {
        let g = sbm(600, 3, 9);
        let s = GraphShards::build(&g, 3, 2);
        for sh in &s.shards {
            for &u in &sh.owned {
                for &v in g.neighbors(u) {
                    assert!(
                        sh.local_of(v).is_some(),
                        "shard {} misses neighbor {v} of owned {u}",
                        sh.id
                    );
                }
                // and the local row matches the global row, remapped
                let lu = sh.local_of(u).unwrap();
                let local_row: Vec<u32> =
                    sh.graph.neighbors(lu).iter().map(|&l| sh.locals[l as usize]).collect();
                assert_eq!(local_row, g.neighbors(u), "row mismatch for owned {u}");
            }
        }
    }

    #[test]
    fn deterministic_for_fixed_seed_and_k() {
        let g = sbm(700, 4, 1);
        let a = GraphShards::build(&g, 4, 42);
        let b = GraphShards::build(&g, 4, 42);
        assert_eq!(a.assignment, b.assignment);
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.owned, y.owned);
            assert_eq!(x.halo, y.halo);
            assert_eq!(x.graph.indices(), y.graph.indices());
        }
    }
}
