//! Versioned on-disk model artifacts.
//!
//! An artifact is a directory: one `manifest.json` plus one raw
//! little-endian binary file per *section*. Sections cover everything a
//! serving process needs and nothing it does not:
//!
//! * every trained tensor of the [`ParamStore`] (embedding tables,
//!   importance weights, SAGE head) as `f32` sections,
//! * the plan's static index arrays (`z_0..z_{L-1}` level assignments,
//!   the node-major hash index matrix) as `u32` sections,
//! * the CSR graph (`indptr`/`indices`/`weights`/`vwgts`) so `classify`
//!   and `topk_neighbors` can aggregate neighborhoods without the
//!   training dataset.
//!
//! The manifest records, per section, the dtype, shape, byte length and
//! an FNV-1a/64 checksum (see [`crate::util::checksum`]); the loader
//! verifies all three and names the offending section on mismatch. A
//! `format_version` gate makes future layout changes fail cleanly
//! instead of mis-reading bytes, and the `method` field stores the
//! round-trippable [`EmbeddingMethod`] display tag so the loader can
//! rebuild the plan without knowing how the artifact was trained.
//!
//! DHE models are rejected at save time: DHE has no embedding tables
//! (the host trainers refuse it for the same reason), so there is
//! nothing for the serving path to memory-resident.

use crate::bench_harness::bench_git_sha;
use crate::data::{Dataset, TaskKind};
use crate::embedding::{
    EmbeddingMethod, EmbeddingPlan, NodePlan, ParamStore, PositionPlan, TableShape,
};
use crate::graph::CsrGraph;
use crate::util::checksum::checksum_string;
use anyhow::{anyhow, bail, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

/// On-disk layout version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;

/// The manifest `kind` discriminator (the HLO runtime has its own,
/// unrelated artifact manifest — this tag keeps them unmistakable).
pub const MODEL_KIND: &str = "poshashemb-model";

/// Manifest file name inside an artifact directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// One binary section of a model artifact.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct SectionSpec {
    /// Section name (tensor/index/graph-array name).
    pub name: String,
    /// File name inside the artifact directory.
    pub file: String,
    /// Element dtype: `"f32"`, `"u32"` or `"u64"` (little-endian).
    pub dtype: String,
    /// Logical shape; the element count is the product.
    pub shape: Vec<usize>,
    /// Exact file length in bytes.
    pub bytes: usize,
    /// Tagged checksum of the file bytes (`"fnv1a64:<hex>"`).
    pub checksum: String,
}

/// The JSON manifest of a saved model artifact.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct ModelManifest {
    /// Layout version; loaders bail on anything but [`FORMAT_VERSION`].
    pub format_version: u32,
    /// Always [`MODEL_KIND`].
    pub kind: String,
    /// Round-trippable method tag (parses back via
    /// `EmbeddingMethod::from_str`, e.g. `inter(levels=3,b=234,h=1)`).
    pub method: String,
    /// Paper-style method display name (e.g. `PosHashEmb-Inter`).
    pub method_name: String,
    /// Dataset the model was trained on.
    pub dataset: String,
    /// Task kind: `"multiclass"` or `"multilabel"`.
    pub task: String,
    /// Number of nodes.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Output classes (or binary tasks).
    pub classes: usize,
    /// SAGE head depth.
    pub layers: usize,
    /// Hidden width of intermediate head layers.
    pub hidden: usize,
    /// Position-hierarchy levels (0 when the method has no position
    /// component).
    pub levels: usize,
    /// Producing build's git revision (same convention as bench
    /// records).
    pub git_sha: String,
    /// All trained tensor names in canonical store order (embedding
    /// tables first, then the head).
    pub param_names: Vec<String>,
    /// Every binary section, in write order.
    pub sections: Vec<SectionSpec>,
    /// Bytes of learned *embedding-table* sections (position + node
    /// tables + importance weights — the paper's memory metric; head
    /// parameters excluded).
    pub resident_table_bytes: usize,
    /// Bytes of static index sections (`z_*`, `node_major`).
    pub resident_index_bytes: usize,
    /// Full-table baseline at equal dim: `n · d · 4` bytes.
    pub full_table_bytes: usize,
}

/// A fully verified, decoded artifact — what [`super::ServeEngine`]
/// is built from.
pub(crate) struct LoadedModel {
    /// The parsed manifest.
    pub manifest: ModelManifest,
    /// Plan rebuilt from the manifest + index sections.
    pub plan: EmbeddingPlan,
    /// All trained tensors in canonical order.
    pub params: ParamStore,
    /// The serving graph.
    pub graph: CsrGraph,
}

// ---------------------------------------------------------------------
// little-endian byte codecs
// ---------------------------------------------------------------------

fn f32_to_le(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u32_to_le(v: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn u64_to_le(v: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 8);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn le_to_f32(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn le_to_u32(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn le_to_u64(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

/// Decoded section payload.
pub(crate) enum SectionData {
    /// f32 elements.
    F32(Vec<f32>),
    /// u32 elements.
    U32(Vec<u32>),
    /// u64 elements.
    U64(Vec<u64>),
}

// ---------------------------------------------------------------------
// save
// ---------------------------------------------------------------------

/// Serialize a trained model into the artifact directory `dir`
/// (created if missing; existing section files are overwritten).
///
/// `params` must hold the plan's tables plus an `layers`-deep SAGE head
/// as produced by the host trainers. Returns the written manifest.
pub fn save_artifact(
    dir: &Path,
    ds: &Dataset,
    plan: &EmbeddingPlan,
    params: &ParamStore,
    layers: usize,
    hidden: usize,
) -> Result<ModelManifest> {
    if plan.dhe.is_some() {
        bail!("model artifacts do not support DHE (no embedding tables to serve)");
    }
    if plan.n != ds.graph.num_nodes() {
        bail!("plan is for n = {} but dataset has {} nodes", plan.n, ds.graph.num_nodes());
    }
    fs::create_dir_all(dir)
        .with_context(|| format!("creating artifact directory {}", dir.display()))?;

    // (name, bytes, dtype, shape) in write order
    let mut raw: Vec<(String, Vec<u8>, &'static str, Vec<usize>)> = Vec::new();
    for name in params.names() {
        let shape = params.shape(name).to_vec();
        raw.push((name.clone(), f32_to_le(params.get(name)), "f32", shape));
    }
    let mut levels = 0usize;
    if let Some(pos) = &plan.position {
        levels = pos.tables.len();
        for (j, z) in pos.z.iter().enumerate() {
            raw.push((format!("z_{j}"), u32_to_le(z), "u32", vec![z.len()]));
        }
    }
    if let Some(node) = &plan.node {
        raw.push((
            "node_major".to_string(),
            u32_to_le(&node.node_major),
            "u32",
            vec![plan.n, node.h],
        ));
    }
    let g = &ds.graph;
    let vwgts: Vec<u32> = (0..g.num_nodes() as u32).map(|u| g.vertex_weight(u)).collect();
    raw.push(("graph_indptr".into(), u64_to_le(g.indptr()), "u64", vec![g.num_nodes() + 1]));
    raw.push(("graph_indices".into(), u32_to_le(g.indices()), "u32", vec![g.indices().len()]));
    let all_weights: Vec<f32> =
        (0..g.num_nodes() as u32).flat_map(|u| g.edge_weights(u).iter().copied()).collect();
    raw.push(("graph_weights".into(), f32_to_le(&all_weights), "f32", vec![all_weights.len()]));
    raw.push(("graph_vwgts".into(), u32_to_le(&vwgts), "u32", vec![g.num_nodes()]));

    let mut sections = Vec::with_capacity(raw.len());
    for (name, bytes, dtype, shape) in &raw {
        let file = format!("{name}.bin");
        let path = dir.join(&file);
        fs::write(&path, bytes)
            .with_context(|| format!("writing section '{name}' ({})", path.display()))?;
        sections.push(SectionSpec {
            name: name.clone(),
            file,
            dtype: (*dtype).to_string(),
            shape: shape.clone(),
            bytes: bytes.len(),
            checksum: checksum_string(bytes),
        });
    }

    let resident_table_bytes: usize = plan.param_shapes().iter().map(|t| t.size() * 4).sum();
    let resident_index_bytes: usize = sections
        .iter()
        .filter(|s| s.name.starts_with("z_") || s.name == "node_major")
        .map(|s| s.bytes)
        .sum();
    let manifest = ModelManifest {
        format_version: FORMAT_VERSION,
        kind: MODEL_KIND.to_string(),
        method: plan.method.to_string(),
        method_name: plan.method.name().to_string(),
        dataset: ds.spec.name.to_string(),
        task: match ds.spec.task {
            TaskKind::MultiClass => "multiclass".to_string(),
            TaskKind::MultiLabel => "multilabel".to_string(),
        },
        n: plan.n,
        d: plan.d,
        classes: ds.spec.classes,
        layers,
        hidden,
        levels,
        git_sha: bench_git_sha(),
        param_names: params.names().to_vec(),
        sections,
        resident_table_bytes,
        resident_index_bytes,
        full_table_bytes: plan.n * plan.d * 4,
    };
    let json = serde_json::to_string_pretty(&manifest).context("serializing manifest")?;
    let mpath = dir.join(MANIFEST_FILE);
    fs::write(&mpath, json).with_context(|| format!("writing {}", mpath.display()))?;
    Ok(manifest)
}

// ---------------------------------------------------------------------
// load
// ---------------------------------------------------------------------

fn dtype_width(dtype: &str) -> Result<usize> {
    match dtype {
        "f32" | "u32" => Ok(4),
        "u64" => Ok(8),
        other => bail!("unsupported section dtype '{other}'"),
    }
}

/// Read, verify and decode an artifact directory.
///
/// Every section's byte length and checksum are verified against the
/// manifest before decoding; errors name the failing section so torn
/// writes and mixed-up files are diagnosable from the message alone.
pub(crate) fn load_artifact(dir: &Path) -> Result<LoadedModel> {
    let mpath = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath)
        .with_context(|| format!("reading model manifest {}", mpath.display()))?;
    let manifest: ModelManifest =
        serde_json::from_str(&text).with_context(|| format!("parsing {}", mpath.display()))?;
    if manifest.kind != MODEL_KIND {
        bail!("{} is a '{}' artifact, expected '{MODEL_KIND}'", dir.display(), manifest.kind);
    }
    if manifest.format_version != FORMAT_VERSION {
        bail!(
            "model artifact {} has format_version {}, this build reads {FORMAT_VERSION}; \
             re-save the model with a matching build",
            dir.display(),
            manifest.format_version
        );
    }

    let mut data: BTreeMap<String, SectionData> = BTreeMap::new();
    let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for sec in &manifest.sections {
        let path = dir.join(&sec.file);
        let bytes = fs::read(&path)
            .with_context(|| format!("reading section '{}' ({})", sec.name, path.display()))?;
        if bytes.len() != sec.bytes {
            bail!(
                "section '{}' ({}) is {} bytes on disk, manifest says {}",
                sec.name,
                sec.file,
                bytes.len(),
                sec.bytes
            );
        }
        let got = checksum_string(&bytes);
        if got != sec.checksum {
            bail!(
                "checksum mismatch in section '{}' ({}): manifest {}, file {}",
                sec.name,
                sec.file,
                sec.checksum,
                got
            );
        }
        let elems: usize = sec.shape.iter().product();
        if elems * dtype_width(&sec.dtype)? != bytes.len() {
            bail!("section '{}' shape {:?} does not match its byte length", sec.name, sec.shape);
        }
        let decoded = match sec.dtype.as_str() {
            "f32" => SectionData::F32(le_to_f32(&bytes)),
            "u32" => SectionData::U32(le_to_u32(&bytes)),
            _ => SectionData::U64(le_to_u64(&bytes)),
        };
        shapes.insert(sec.name.clone(), sec.shape.clone());
        data.insert(sec.name.clone(), decoded);
    }

    let take_f32 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<f32>> {
        match data.remove(name) {
            Some(SectionData::F32(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected f32)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let take_u32 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<u32>> {
        match data.remove(name) {
            Some(SectionData::U32(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected u32)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let take_u64 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<u64>> {
        match data.remove(name) {
            Some(SectionData::U64(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected u64)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let table_shape = |shapes: &BTreeMap<String, Vec<usize>>, name: &str| -> Result<TableShape> {
        let s = shapes
            .get(name)
            .ok_or_else(|| anyhow!("artifact is missing required section '{name}'"))?;
        if s.len() != 2 {
            bail!("table section '{name}' must be 2-D, got shape {s:?}");
        }
        Ok(TableShape { name: name.to_string(), rows: s[0], cols: s[1] })
    };

    // -- parameters, in the manifest's canonical order --
    let mut params = ParamStore::default();
    for name in &manifest.param_names {
        let shape = shapes
            .get(name)
            .ok_or_else(|| anyhow!("manifest lists parameter '{name}' but no such section"))?
            .clone();
        params.insert(name, shape, take_f32(&mut data, name)?);
    }

    // -- plan, rebuilt from method tag + index sections --
    let method: EmbeddingMethod = manifest
        .method
        .parse()
        .map_err(|e| anyhow!("manifest method tag '{}': {e}", manifest.method))?;
    if matches!(method, EmbeddingMethod::Dhe { .. }) {
        bail!("DHE artifacts are not servable (and cannot be saved)");
    }
    let position = if manifest.levels > 0 {
        let mut tables = Vec::with_capacity(manifest.levels);
        let mut z = Vec::with_capacity(manifest.levels);
        for j in 0..manifest.levels {
            tables.push(table_shape(&shapes, &format!("pos_{j}"))?);
            let zj = take_u32(&mut data, &format!("z_{j}"))?;
            if zj.len() != manifest.n {
                bail!("section 'z_{j}' has {} entries, expected n = {}", zj.len(), manifest.n);
            }
            z.push(zj);
        }
        Some(PositionPlan { tables, z })
    } else {
        None
    };
    let node = if shapes.contains_key("node_major") {
        let table = table_shape(&shapes, "node_x")?;
        let nm_shape = shapes["node_major"].clone();
        if nm_shape.len() != 2 || nm_shape[0] != manifest.n {
            bail!("section 'node_major' must be [n, h], got shape {nm_shape:?}");
        }
        let node_major = take_u32(&mut data, "node_major")?;
        Some(NodePlan {
            table,
            h: nm_shape[1],
            node_major,
            learned_weights: manifest.param_names.iter().any(|p| p == "node_y"),
        })
    } else {
        None
    };
    let plan = EmbeddingPlan {
        method,
        n: manifest.n,
        d: manifest.d,
        position,
        node,
        dhe: None,
    };

    // -- serving graph --
    let indptr = take_u64(&mut data, "graph_indptr")?;
    if indptr.len() != manifest.n + 1 {
        bail!("section 'graph_indptr' has {} entries, expected n + 1", indptr.len());
    }
    let indices = take_u32(&mut data, "graph_indices")?;
    let weights = take_f32(&mut data, "graph_weights")?;
    let vwgts = take_u32(&mut data, "graph_vwgts")?;
    if weights.len() != indices.len() || vwgts.len() != manifest.n {
        bail!("graph sections disagree on edge/node counts");
    }
    let graph = CsrGraph::from_parts(indptr, indices, weights, vwgts);

    Ok(LoadedModel { manifest, plan, params, graph })
}
