//! Versioned on-disk model artifacts.
//!
//! An artifact is a directory: one `manifest.json` plus one raw
//! little-endian binary file per *section*. Sections cover everything a
//! serving process needs and nothing it does not:
//!
//! * every trained tensor of the [`ParamStore`] (embedding tables,
//!   importance weights, SAGE head) as `f32` sections,
//! * the plan's static index arrays (`z_0..z_{L-1}` level assignments,
//!   the node-major hash index matrix) as `u32` sections,
//! * the CSR graph (`indptr`/`indices`/`weights`/`vwgts`) so `classify`
//!   and `topk_neighbors` can aggregate neighborhoods without the
//!   training dataset.
//!
//! The manifest records, per section, the dtype, shape, byte length and
//! an FNV-1a/64 checksum (see [`crate::util::checksum`]); the loader
//! verifies all three and names the offending section on mismatch. A
//! `format_version` gate makes future layout changes fail cleanly
//! instead of mis-reading bytes, and the `method` field stores the
//! round-trippable [`EmbeddingMethod`] display tag so the loader can
//! rebuild the plan without knowing how the artifact was trained.
//!
//! DHE models are rejected at save time: DHE has no embedding tables
//! (the host trainers refuse it for the same reason), so there is
//! nothing for the serving path to memory-resident.

use crate::bench_harness::bench_git_sha;
use crate::data::{Dataset, TaskKind};
use crate::embedding::{
    EmbeddingMethod, EmbeddingPlan, NodePlan, ParamStore, PositionPlan, TableShape,
};
use crate::graph::CsrGraph;
use crate::util::fault;
use crate::util::sections::{publish_dir, read_section, temp_sibling, write_section};
use anyhow::{anyhow, bail, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::Write;
use std::path::Path;

pub use crate::util::sections::{SectionData, SectionSpec};

/// On-disk layout version this build reads and writes.
pub const FORMAT_VERSION: u32 = 1;

/// The manifest `kind` discriminator (the HLO runtime has its own,
/// unrelated artifact manifest — this tag keeps them unmistakable).
pub const MODEL_KIND: &str = "poshashemb-model";

/// Manifest file name inside an artifact directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// The JSON manifest of a saved model artifact.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct ModelManifest {
    /// Layout version; loaders bail on anything but [`FORMAT_VERSION`].
    pub format_version: u32,
    /// Always [`MODEL_KIND`].
    pub kind: String,
    /// Round-trippable method tag (parses back via
    /// `EmbeddingMethod::from_str`, e.g. `inter(levels=3,b=234,h=1)`).
    pub method: String,
    /// Paper-style method display name (e.g. `PosHashEmb-Inter`).
    pub method_name: String,
    /// Dataset the model was trained on.
    pub dataset: String,
    /// Task kind: `"multiclass"` or `"multilabel"`.
    pub task: String,
    /// Number of nodes.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Output classes (or binary tasks).
    pub classes: usize,
    /// SAGE head depth.
    pub layers: usize,
    /// Hidden width of intermediate head layers.
    pub hidden: usize,
    /// Position-hierarchy levels (0 when the method has no position
    /// component).
    pub levels: usize,
    /// Producing build's git revision (same convention as bench
    /// records).
    pub git_sha: String,
    /// All trained tensor names in canonical store order (embedding
    /// tables first, then the head).
    pub param_names: Vec<String>,
    /// Every binary section, in write order.
    pub sections: Vec<SectionSpec>,
    /// Bytes of learned *embedding-table* sections (position + node
    /// tables + importance weights — the paper's memory metric; head
    /// parameters excluded).
    pub resident_table_bytes: usize,
    /// Bytes of static index sections (`z_*`, `node_major`).
    pub resident_index_bytes: usize,
    /// Full-table baseline at equal dim: `n · d · 4` bytes.
    pub full_table_bytes: usize,
}

/// A fully verified, decoded artifact — what [`super::ServeEngine`]
/// is built from.
pub(crate) struct LoadedModel {
    /// The parsed manifest.
    pub manifest: ModelManifest,
    /// Plan rebuilt from the manifest + index sections.
    pub plan: EmbeddingPlan,
    /// All trained tensors in canonical order.
    pub params: ParamStore,
    /// The serving graph.
    pub graph: CsrGraph,
}

// ---------------------------------------------------------------------
// save
// ---------------------------------------------------------------------

/// Serialize a trained model into the artifact directory `dir` and
/// return the written manifest.
///
/// The publish is **atomic** (see [`crate::util::sections`]): every
/// section is written fsynced into a temp sibling, `manifest.json` is
/// written last, and the directory is renamed over `dir` — so
/// [`super::ServeEngine::open`] can never observe a torn or
/// half-updated model directory, no matter when the writer dies
/// (injected-fault sites: `artifact.section` / `artifact.manifest` /
/// `artifact.rename`). `params` must hold the plan's tables plus an
/// `layers`-deep SAGE head as produced by the host trainers.
pub fn save_artifact(
    dir: &Path,
    ds: &Dataset,
    plan: &EmbeddingPlan,
    params: &ParamStore,
    layers: usize,
    hidden: usize,
) -> Result<ModelManifest> {
    if plan.dhe.is_some() {
        bail!("model artifacts do not support DHE (no embedding tables to serve)");
    }
    if plan.n != ds.graph.num_nodes() {
        bail!("plan is for n = {} but dataset has {} nodes", plan.n, ds.graph.num_nodes());
    }
    if let Some(parent) = dir.parent() {
        if parent != Path::new("") {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating artifact parent {}", parent.display()))?;
        }
    }

    // (name, data, shape) in write order
    let mut raw: Vec<(String, SectionData, Vec<usize>)> = Vec::new();
    for name in params.names() {
        let shape = params.shape(name).to_vec();
        raw.push((name.clone(), SectionData::F32(params.get(name).to_vec()), shape));
    }
    let mut levels = 0usize;
    if let Some(pos) = &plan.position {
        levels = pos.tables.len();
        for (j, z) in pos.z.iter().enumerate() {
            raw.push((format!("z_{j}"), SectionData::U32(z.clone()), vec![z.len()]));
        }
    }
    if let Some(node) = &plan.node {
        raw.push((
            "node_major".to_string(),
            SectionData::U32(node.node_major.clone()),
            vec![plan.n, node.h],
        ));
    }
    let g = ds.graph.mem();
    let vwgts: Vec<u32> = (0..g.num_nodes() as u32).map(|u| g.vertex_weight(u)).collect();
    raw.push((
        "graph_indptr".into(),
        SectionData::U64(g.indptr().to_vec()),
        vec![g.num_nodes() + 1],
    ));
    raw.push((
        "graph_indices".into(),
        SectionData::U32(g.indices().to_vec()),
        vec![g.indices().len()],
    ));
    let all_weights: Vec<f32> =
        (0..g.num_nodes() as u32).flat_map(|u| g.edge_weights(u).iter().copied()).collect();
    let weights_len = all_weights.len();
    raw.push(("graph_weights".into(), SectionData::F32(all_weights), vec![weights_len]));
    raw.push(("graph_vwgts".into(), SectionData::U32(vwgts), vec![g.num_nodes()]));

    let param_names = params.names().to_vec();
    let tmp = temp_sibling(dir);
    fs::create_dir_all(&tmp)
        .with_context(|| format!("creating artifact temp dir {}", tmp.display()))?;
    let res = write_artifact_contents(&tmp, ds, plan, &raw, param_names, layers, hidden, levels)
        .and_then(|m| {
            fault::hit("artifact.rename").context("publishing artifact")?;
            Ok(m)
        })
        .and_then(|m| publish_dir(&tmp, dir).map(|()| m));
    match res {
        Ok(manifest) => Ok(manifest),
        Err(e) => {
            let _ = fs::remove_dir_all(&tmp);
            Err(e)
        }
    }
}

/// Write every section (fsynced) and then the manifest into `tmp`.
#[allow(clippy::too_many_arguments)]
fn write_artifact_contents(
    tmp: &Path,
    ds: &Dataset,
    plan: &EmbeddingPlan,
    raw: &[(String, SectionData, Vec<usize>)],
    param_names: Vec<String>,
    layers: usize,
    hidden: usize,
    levels: usize,
) -> Result<ModelManifest> {
    let mut sections = Vec::with_capacity(raw.len());
    for (name, data, shape) in raw {
        sections.push(write_section(tmp, name, shape, data, "artifact.section")?);
    }
    let resident_table_bytes: usize = plan.param_shapes().iter().map(|t| t.size() * 4).sum();
    let resident_index_bytes: usize = sections
        .iter()
        .filter(|s| s.name.starts_with("z_") || s.name == "node_major")
        .map(|s| s.bytes)
        .sum();
    let manifest = ModelManifest {
        format_version: FORMAT_VERSION,
        kind: MODEL_KIND.to_string(),
        method: plan.method.to_string(),
        method_name: plan.method.name().to_string(),
        dataset: ds.spec.name.to_string(),
        task: match ds.spec.task {
            TaskKind::MultiClass => "multiclass".to_string(),
            TaskKind::MultiLabel => "multilabel".to_string(),
        },
        n: plan.n,
        d: plan.d,
        classes: ds.spec.classes,
        layers,
        hidden,
        levels,
        git_sha: bench_git_sha(),
        param_names,
        sections,
        resident_table_bytes,
        resident_index_bytes,
        full_table_bytes: plan.n * plan.d * 4,
    };
    let json = serde_json::to_string_pretty(&manifest).context("serializing manifest")?;
    fault::hit("artifact.manifest").context("writing artifact manifest")?;
    let mpath = tmp.join(MANIFEST_FILE);
    let mut f = File::create(&mpath).with_context(|| format!("creating {}", mpath.display()))?;
    f.write_all(json.as_bytes()).with_context(|| format!("writing {}", mpath.display()))?;
    f.sync_all().with_context(|| format!("fsyncing {}", mpath.display()))?;
    Ok(manifest)
}

// ---------------------------------------------------------------------
// load
// ---------------------------------------------------------------------

/// Read, verify and decode an artifact directory.
///
/// Every section's byte length and checksum are verified against the
/// manifest before decoding; errors name the failing section so torn
/// writes and mixed-up files are diagnosable from the message alone.
pub(crate) fn load_artifact(dir: &Path) -> Result<LoadedModel> {
    let mpath = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath)
        .with_context(|| format!("reading model manifest {}", mpath.display()))?;
    let manifest: ModelManifest =
        serde_json::from_str(&text).with_context(|| format!("parsing {}", mpath.display()))?;
    if manifest.kind != MODEL_KIND {
        bail!("{} is a '{}' artifact, expected '{MODEL_KIND}'", dir.display(), manifest.kind);
    }
    if manifest.format_version != FORMAT_VERSION {
        bail!(
            "model artifact {} has format_version {}, this build reads {FORMAT_VERSION}; \
             re-save the model with a matching build",
            dir.display(),
            manifest.format_version
        );
    }

    let mut data: BTreeMap<String, SectionData> = BTreeMap::new();
    let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for sec in &manifest.sections {
        let decoded = read_section(dir, sec)?;
        shapes.insert(sec.name.clone(), sec.shape.clone());
        data.insert(sec.name.clone(), decoded);
    }

    let take_f32 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<f32>> {
        match data.remove(name) {
            Some(SectionData::F32(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected f32)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let take_u32 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<u32>> {
        match data.remove(name) {
            Some(SectionData::U32(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected u32)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let take_u64 = |data: &mut BTreeMap<String, SectionData>, name: &str| -> Result<Vec<u64>> {
        match data.remove(name) {
            Some(SectionData::U64(v)) => Ok(v),
            Some(_) => bail!("section '{name}' has the wrong dtype (expected u64)"),
            None => bail!("artifact is missing required section '{name}'"),
        }
    };
    let table_shape = |shapes: &BTreeMap<String, Vec<usize>>, name: &str| -> Result<TableShape> {
        let s = shapes
            .get(name)
            .ok_or_else(|| anyhow!("artifact is missing required section '{name}'"))?;
        if s.len() != 2 {
            bail!("table section '{name}' must be 2-D, got shape {s:?}");
        }
        Ok(TableShape { name: name.to_string(), rows: s[0], cols: s[1] })
    };

    // -- parameters, in the manifest's canonical order --
    let mut params = ParamStore::default();
    for name in &manifest.param_names {
        let shape = shapes
            .get(name)
            .ok_or_else(|| anyhow!("manifest lists parameter '{name}' but no such section"))?
            .clone();
        params.insert(name, shape, take_f32(&mut data, name)?);
    }

    // -- plan, rebuilt from method tag + index sections --
    let method: EmbeddingMethod = manifest
        .method
        .parse()
        .map_err(|e| anyhow!("manifest method tag '{}': {e}", manifest.method))?;
    if matches!(method, EmbeddingMethod::Dhe { .. }) {
        bail!("DHE artifacts are not servable (and cannot be saved)");
    }
    let position = if manifest.levels > 0 {
        let mut tables = Vec::with_capacity(manifest.levels);
        let mut z = Vec::with_capacity(manifest.levels);
        for j in 0..manifest.levels {
            tables.push(table_shape(&shapes, &format!("pos_{j}"))?);
            let zj = take_u32(&mut data, &format!("z_{j}"))?;
            if zj.len() != manifest.n {
                bail!("section 'z_{j}' has {} entries, expected n = {}", zj.len(), manifest.n);
            }
            z.push(zj);
        }
        Some(PositionPlan { tables, z })
    } else {
        None
    };
    let node = if shapes.contains_key("node_major") {
        let table = table_shape(&shapes, "node_x")?;
        let nm_shape = shapes["node_major"].clone();
        if nm_shape.len() != 2 || nm_shape[0] != manifest.n {
            bail!("section 'node_major' must be [n, h], got shape {nm_shape:?}");
        }
        let node_major = take_u32(&mut data, "node_major")?;
        Some(NodePlan {
            table,
            h: nm_shape[1],
            node_major,
            learned_weights: manifest.param_names.iter().any(|p| p == "node_y"),
        })
    } else {
        None
    };
    let plan = EmbeddingPlan {
        method,
        n: manifest.n,
        d: manifest.d,
        position,
        node,
        dhe: None,
    };

    // -- serving graph --
    let indptr = take_u64(&mut data, "graph_indptr")?;
    if indptr.len() != manifest.n + 1 {
        bail!("section 'graph_indptr' has {} entries, expected n + 1", indptr.len());
    }
    let indices = take_u32(&mut data, "graph_indices")?;
    let weights = take_f32(&mut data, "graph_weights")?;
    let vwgts = take_u32(&mut data, "graph_vwgts")?;
    if weights.len() != indices.len() || vwgts.len() != manifest.n {
        bail!("graph sections disagree on edge/node counts");
    }
    let graph = CsrGraph::from_parts(indptr, indices, weights, vwgts);

    Ok(LoadedModel { manifest, plan, params, graph })
}
