//! The mmap-style query engine over a loaded model artifact.
//!
//! [`ServeEngine::open`] verifies and decodes an artifact directory
//! once (checksummed section reads, see [`super::artifact`]), then
//! serves three queries from the resident tables without ever
//! materializing the `n × d` matrix:
//!
//! * [`embed`](ServeEngine::embed) — compose embedding rows for a batch
//!   of node ids through the same [`ComposeEngine`] batch path the
//!   trainers use, fronted by a hot-node LRU cache ([`LruRows`]).
//!   Cached and uncached answers are **bit-identical**: a composed row
//!   depends only on its own gathers, so replaying it from the cache
//!   returns the exact bytes compose produced (pinned by
//!   `tests/serve.rs`).
//! * [`classify`](ServeEngine::classify) — full-neighborhood SAGE
//!   forward to logits, sharing `mean_rows`/`sage_affine_row` with the
//!   trainers so serving can never drift from evaluation.
//! * [`topk_neighbors`](ServeEngine::topk_neighbors) — a node's graph
//!   neighbors ranked by cosine similarity in embedding space
//!   (deterministic id tiebreak).
//!
//! The cache is sized in *rows* (`cache_rows × d` floats) so operators
//! reason in the same unit as the tables; `cache_rows = 0` disables
//! caching entirely and is the oracle the cached path is tested
//! against.

use super::artifact::{load_artifact, ModelManifest};
use crate::coordinator::{head_param_names, layer_dims, mean_rows, sage_affine_row};
use crate::embedding::{ComposeEngine, EmbeddingPlan, ParamStore};
use crate::graph::CsrGraph;
use crate::sampler::{Fanouts, MultiHopBlock, NeighborSampler};
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// Sentinel slot id for the LRU's intrusive links.
const NONE: u32 = u32::MAX;

/// Fixed-capacity LRU over embedding rows: a slot arena (`cap × d`
/// floats) threaded by an intrusive doubly-linked recency list, with a
/// `node id → slot` map. All operations are O(1); capacity 0 is a
/// valid "cache off" configuration where `get` always misses and
/// `insert` is a no-op.
struct LruRows {
    d: usize,
    cap: usize,
    map: HashMap<u32, u32>,
    /// Per-slot node id (valid for slots < `len`).
    keys: Vec<u32>,
    prev: Vec<u32>,
    next: Vec<u32>,
    /// Slot arena, row-major `cap × d`.
    data: Vec<f32>,
    head: u32,
    tail: u32,
    len: usize,
}

impl LruRows {
    fn new(cap: usize, d: usize) -> Self {
        LruRows {
            d,
            cap,
            map: HashMap::with_capacity(cap),
            keys: vec![NONE; cap],
            prev: vec![NONE; cap],
            next: vec![NONE; cap],
            data: vec![0f32; cap * d],
            head: NONE,
            tail: NONE,
            len: 0,
        }
    }

    /// Unlink `slot` from the recency list.
    fn unlink(&mut self, slot: u32) {
        let (p, n) = (self.prev[slot as usize], self.next[slot as usize]);
        if p == NONE {
            self.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NONE {
            self.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
    }

    /// Link `slot` at the most-recent end.
    fn push_front(&mut self, slot: u32) {
        self.prev[slot as usize] = NONE;
        self.next[slot as usize] = self.head;
        if self.head != NONE {
            self.prev[self.head as usize] = slot;
        }
        self.head = slot;
        if self.tail == NONE {
            self.tail = slot;
        }
    }

    /// The cached row for `id`, promoting it to most-recent.
    fn get(&mut self, id: u32) -> Option<&[f32]> {
        let slot = *self.map.get(&id)?;
        if self.head != slot {
            self.unlink(slot);
            self.push_front(slot);
        }
        let s = slot as usize;
        Some(&self.data[s * self.d..(s + 1) * self.d])
    }

    /// Insert (or refresh) `id`'s row, evicting the least-recent entry
    /// at capacity.
    fn insert(&mut self, id: u32, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d);
        if self.cap == 0 {
            return;
        }
        let slot = if let Some(&s) = self.map.get(&id) {
            if self.head != s {
                self.unlink(s);
                self.push_front(s);
            }
            s
        } else {
            let s = if self.len < self.cap {
                let s = self.len as u32;
                self.len += 1;
                s
            } else {
                let victim = self.tail;
                self.unlink(victim);
                self.map.remove(&self.keys[victim as usize]);
                victim
            };
            self.keys[s as usize] = id;
            self.map.insert(id, s);
            self.push_front(s);
            s
        };
        let s = slot as usize;
        self.data[s * self.d..(s + 1) * self.d].copy_from_slice(row);
    }
}

/// A loaded model artifact serving embedding/classification queries.
///
/// Construction is the only I/O; every query runs against the resident
/// tables. See the module docs for the query surface and the caching
/// contract, and [`crate::bench_harness::bench_serve`] for the load
/// driver that measures it.
pub struct ServeEngine {
    manifest: ModelManifest,
    plan: EmbeddingPlan,
    params: ParamStore,
    graph: CsrGraph,
    cache: LruRows,
    hits: u64,
    misses: u64,
    /// Batch output scratch (`ids.len() × d`), reused across calls.
    out: Vec<f32>,
    /// Batch positions (into `out`) of cache misses.
    miss_pos: Vec<usize>,
    /// Node ids of cache misses, aligned with `miss_pos`.
    miss_ids: Vec<u32>,
    /// Compose scratch for the miss rows.
    miss_rows: Vec<f32>,
}

impl ServeEngine {
    /// Open an artifact directory, verifying every section checksum,
    /// with a hot-node cache of `cache_rows` embedding rows (0 = no
    /// cache).
    pub fn open(dir: &Path, cache_rows: usize) -> Result<Self> {
        let m = load_artifact(dir)?;
        let d = m.plan.d;
        Ok(ServeEngine {
            manifest: m.manifest,
            plan: m.plan,
            params: m.params,
            graph: m.graph,
            cache: LruRows::new(cache_rows, d),
            hits: 0,
            misses: 0,
            out: Vec::new(),
            miss_pos: Vec::new(),
            miss_ids: Vec::new(),
            miss_rows: Vec::new(),
        })
    }

    /// The artifact's manifest (method, dataset, shapes, footprints).
    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    /// Number of nodes served.
    pub fn n(&self) -> usize {
        self.plan.n
    }

    /// Embedding dimension.
    pub fn d(&self) -> usize {
        self.plan.d
    }

    /// Hot-node cache capacity in rows.
    pub fn cache_rows(&self) -> usize {
        self.cache.cap
    }

    /// `(hits, misses)` since the last
    /// [`reset_cache_stats`](ServeEngine::reset_cache_stats) call.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Zero the hit/miss counters (the cache contents stay warm).
    pub fn reset_cache_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }

    /// Bytes of learned embedding-table sections resident in memory.
    pub fn resident_table_bytes(&self) -> usize {
        self.manifest.resident_table_bytes
    }

    /// Bytes of static index sections resident in memory.
    pub fn resident_index_bytes(&self) -> usize {
        self.manifest.resident_index_bytes
    }

    /// The Full-table baseline at equal dim: `n · d · 4` bytes.
    pub fn full_table_bytes(&self) -> usize {
        self.manifest.full_table_bytes
    }

    fn check_ids(&self, ids: &[u32]) -> Result<()> {
        let n = self.plan.n;
        if let Some(&bad) = ids.iter().find(|&&i| i as usize >= n) {
            bail!("node id {bad} out of range (n = {n})");
        }
        Ok(())
    }

    /// Embedding rows for `ids`, row-major `ids.len() × d`, served from
    /// the LRU cache where possible and composed in one batch
    /// otherwise. The returned slice borrows internal scratch and is
    /// valid until the next query.
    pub fn embed(&mut self, ids: &[u32]) -> Result<&[f32]> {
        self.check_ids(ids)?;
        let d = self.plan.d;
        self.out.resize(ids.len() * d, 0.0);
        self.miss_pos.clear();
        self.miss_ids.clear();
        for (i, &id) in ids.iter().enumerate() {
            if let Some(row) = self.cache.get(id) {
                self.out[i * d..(i + 1) * d].copy_from_slice(row);
                self.hits += 1;
            } else {
                self.miss_pos.push(i);
                self.miss_ids.push(id);
                self.misses += 1;
            }
        }
        if !self.miss_ids.is_empty() {
            self.miss_rows.resize(self.miss_ids.len() * d, 0.0);
            let engine = ComposeEngine::new(&self.plan);
            // ids were range-checked above, so the checked path's
            // bounds pre-scan would be pure overhead
            let prepared = engine.prepare(&self.params);
            prepared.compose_into_unchecked(&self.miss_ids, &mut self.miss_rows);
            for (j, (&i, &id)) in self.miss_pos.iter().zip(&self.miss_ids).enumerate() {
                let row = &self.miss_rows[j * d..(j + 1) * d];
                self.out[i * d..(i + 1) * d].copy_from_slice(row);
                self.cache.insert(id, row);
            }
        }
        Ok(&self.out[..ids.len() * d])
    }

    /// Class logits for `ids`, row-major `ids.len() × classes`: the
    /// trained SAGE head over full neighborhoods — operation for
    /// operation the trainers' evaluation forward
    /// ([`crate::coordinator::MinibatchTrainer::evaluate`]), minus the
    /// metric.
    pub fn classify(&self, ids: &[u32]) -> Result<Vec<f32>> {
        self.check_ids(ids)?;
        let classes = self.manifest.classes;
        if ids.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.plan.d;
        let layers = self.manifest.layers;
        let hidden = self.manifest.hidden;
        let fans = Fanouts::all(layers);
        let mut sampler = NeighborSampler::multi_hop(&self.graph, &fans, 0);
        let mut mhb = MultiHopBlock::default();
        sampler.sample_multi_into(ids, 0, 0, &mut mhb);
        if mhb.num_seeds() != ids.len() {
            bail!("classify batch must not contain duplicate node ids");
        }
        let rows = mhb.num_rows();
        let mut x = vec![0f32; rows * d];
        let engine = ComposeEngine::new(&self.plan);
        engine.prepare(&self.params).compose_into_unchecked(&mhb.outer().nodes, &mut x);
        let heads: Vec<(&[f32], &[f32], &[f32])> = head_param_names(layers)
            .iter()
            .map(|(ws, wn, b)| (self.params.get(ws), self.params.get(wn), self.params.get(b)))
            .collect();
        let mut cur: Vec<f32> = Vec::new();
        let mut nxt: Vec<f32> = Vec::new();
        let mut nb = vec![0f32; if layers > 1 { d.max(hidden) } else { d }];
        for j in 0..layers {
            let blk = mhb.hop(layers - 1 - j);
            let s = blk.num_seeds;
            let (din, dout) = layer_dims(d, classes, hidden, layers, j);
            nxt.resize(s * dout, 0.0);
            let input: &[f32] = if j == 0 { &x } else { &cur };
            let (w_self, w_neigh, bias) = heads[j];
            for si in 0..s {
                mean_rows(&mut nb[..din], input, blk.neighbors_of(si));
                sage_affine_row(
                    &input[si * din..(si + 1) * din],
                    &nb[..din],
                    w_self,
                    w_neigh,
                    bias,
                    &mut nxt[si * dout..(si + 1) * dout],
                );
            }
            if j + 1 < layers {
                for v in nxt[..s * dout].iter_mut() {
                    *v = v.max(0.0);
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        cur.truncate(ids.len() * classes);
        Ok(cur)
    }

    /// `id`'s graph neighbors ranked by cosine similarity to `id` in
    /// embedding space, best first, at most `k` results. Ties break on
    /// ascending node id so rankings are deterministic.
    pub fn topk_neighbors(&mut self, id: u32, k: usize) -> Result<Vec<(u32, f32)>> {
        self.check_ids(&[id])?;
        let nbrs: Vec<u32> = self.graph.neighbors(id).to_vec();
        if nbrs.is_empty() || k == 0 {
            return Ok(Vec::new());
        }
        let d = self.plan.d;
        let mut ids = Vec::with_capacity(nbrs.len() + 1);
        ids.push(id);
        ids.extend_from_slice(&nbrs);
        let emb = self.embed(&ids)?;
        let anchor = &emb[..d];
        let anorm = anchor.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut ranked: Vec<(u32, f32)> = nbrs
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let row = &emb[(i + 1) * d..(i + 2) * d];
                let dot: f32 = anchor.iter().zip(row).map(|(a, b)| a * b).sum();
                let rnorm = row.iter().map(|x| x * x).sum::<f32>().sqrt();
                let denom = anorm * rnorm;
                (v, if denom > 0.0 { dot / denom } else { 0.0 })
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal).then(a.0.cmp(&b.0))
        });
        ranked.truncate(k);
        Ok(ranked)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32, d: usize) -> Vec<f32> {
        vec![v; d]
    }

    #[test]
    fn lru_hits_and_promotes() {
        let mut c = LruRows::new(2, 4);
        c.insert(10, &row(1.0, 4));
        c.insert(20, &row(2.0, 4));
        assert_eq!(c.get(10), Some(&row(1.0, 4)[..]));
        // 20 is now least-recent; inserting 30 evicts it
        c.insert(30, &row(3.0, 4));
        assert!(c.get(20).is_none());
        assert_eq!(c.get(10), Some(&row(1.0, 4)[..]));
        assert_eq!(c.get(30), Some(&row(3.0, 4)[..]));
    }

    #[test]
    fn lru_eviction_is_least_recent() {
        let mut c = LruRows::new(3, 2);
        for id in [1u32, 2, 3] {
            c.insert(id, &row(id as f32, 2));
        }
        c.insert(4, &row(4.0, 2));
        assert!(c.get(1).is_none(), "oldest entry should be evicted");
        for id in [2u32, 3, 4] {
            assert!(c.get(id).is_some(), "id {id} should be resident");
        }
    }

    #[test]
    fn lru_refresh_overwrites_in_place() {
        let mut c = LruRows::new(2, 2);
        c.insert(7, &row(1.0, 2));
        c.insert(7, &row(9.0, 2));
        assert_eq!(c.get(7), Some(&row(9.0, 2)[..]));
        // refreshing did not consume a second slot
        c.insert(8, &row(2.0, 2));
        assert!(c.get(7).is_some() && c.get(8).is_some());
    }

    #[test]
    fn lru_capacity_zero_is_a_no_op() {
        let mut c = LruRows::new(0, 4);
        c.insert(1, &row(1.0, 4));
        assert!(c.get(1).is_none());
    }
}
