//! The serving path: versioned model artifacts + a resident query
//! engine.
//!
//! Training produces parameters; this module makes them *servable*
//! without the training stack:
//!
//! ```text
//! train (minibatch / full-batch)
//!   └─ save_artifact ──► <dir>/manifest.json        versioned, checksummed
//!                        <dir>/pos_0.bin …          f32 tables
//!                        <dir>/z_0.bin, node_major  u32 index arrays
//!                        <dir>/graph_*.bin          CSR for classify/top-k
//!   ServeEngine::open ◄──┘   (verify every section, rebuild the plan
//!                             from the manifest's method tag)
//!   └─ embed / classify / topk_neighbors
//! ```
//!
//! Sections are loaded once into resident buffers and served as
//! zero-copy views from then on; nothing is re-read or re-decoded per
//! query, and the `n × d` matrix is never materialized. (True OS-level
//! `mmap(2)` would need a platform crate the offline dependency set
//! does not carry; the section files are raw little-endian arrays
//! precisely so [`artifact`]'s loader is the single isolated upgrade
//! point if one is added.)
//!
//! The synthetic load driver lives in
//! [`crate::bench_harness::bench_serve`]; the CLI front door is
//! `poshashemb train-minibatch --save-model <dir>` followed by
//! `poshashemb serve-bench --model <dir>`.

pub mod artifact;
mod engine;

pub use artifact::{save_artifact, ModelManifest, SectionSpec, FORMAT_VERSION};
pub use engine::ServeEngine;
