//! Synthetic dataset construction.

use super::splits::{train_val_test_split, Splits};
use crate::graph::{planted_partition, GraphHandle, GraphStats, PlantedPartitionConfig};
use crate::util::rng::Rng;

/// Prediction task kind (paper: multi-class for arxiv/products, multi-
/// label binary for proteins).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Single label in `[0, classes)`; metric = accuracy.
    MultiClass,
    /// `classes` independent binary labels; metric = mean ROC-AUC.
    MultiLabel,
}

/// Specification of a synthetic dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Registered dataset name.
    pub name: &'static str,
    /// Number of nodes.
    pub n: usize,
    /// Classes (MultiClass) or number of binary tasks (MultiLabel).
    pub classes: usize,
    /// Planted fine communities (homophily source).
    pub communities: usize,
    /// Super-communities (coarse homophily scale; see generate.rs).
    pub supers: usize,
    /// Expected intra-community degree per node.
    pub intra_degree: f64,
    /// Same-super cross-community expected degree.
    pub super_degree: f64,
    /// Expected global inter-community degree per node.
    pub inter_degree: f64,
    /// Probability a node's canonical label comes from its SUPER-community
    /// (coarse signal a few position partitions can capture) rather than
    /// its fine community.
    pub super_label_weight: f64,
    /// Training fraction (matches the original OGB split regimes:
    /// arxiv 0.54, products 0.08, proteins 0.65).
    pub train_frac: f64,
    /// Probability a node's label deviates from its community's canonical
    /// label — controls how much signal needs *node-specific* modeling,
    /// which is exactly the PosHashEmb x-component's job.
    pub label_flip: f64,
    /// Prediction task kind (drives loss and metric).
    pub task: TaskKind,
    /// Embedding dimension the paper pairs with this dataset.
    pub d: usize,
    /// Generation seed (graph, labels and splits all derive from it).
    pub seed: u64,
}

/// A realized dataset: graph + labels + splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// The spec this dataset was generated from.
    pub spec: DatasetSpec,
    /// The undirected graph — in-memory or disk-backed (see
    /// [`GraphHandle`]). Paths that need the resident CSR (full-batch
    /// training, statics, artifact export) call `graph.mem()`.
    pub graph: GraphHandle,
    /// Planted community of each node (ground truth, not visible to models).
    pub communities: Vec<u32>,
    /// MultiClass: `labels[i] ∈ [0, classes)`.
    /// MultiLabel: row-major `n × classes` in {0, 1}.
    pub labels: Vec<u32>,
    /// Train/val/test node folds.
    pub splits: Splits,
}

impl Dataset {
    /// Generate the dataset deterministically from its spec.
    pub fn generate(spec: &DatasetSpec) -> Self {
        let (graph, communities) = planted_partition(&PlantedPartitionConfig {
            n: spec.n,
            communities: spec.communities,
            supers: spec.supers,
            intra_degree: spec.intra_degree,
            super_degree: spec.super_degree,
            inter_degree: spec.inter_degree,
            seed: spec.seed,
        });
        let mut rng = Rng::seed_from_u64(spec.seed ^ 0x1ABE1);
        let comms_per_super = spec.communities.div_ceil(spec.supers);
        let labels = match spec.task {
            TaskKind::MultiClass => {
                // two-scale canonical label: coarse (super-community) with
                // prob super_label_weight, else fine (community); uniform
                // flip with prob label_flip. Mirrors real graphs where the
                // label field is smooth at coarse scales with fine detail.
                (0..spec.n)
                    .map(|i| {
                        let classes = spec.classes as u32;
                        let fine = communities[i] % classes;
                        let coarse = (communities[i] as usize / comms_per_super) as u32 % classes;
                        let use_coarse = rng.gen_bool(spec.super_label_weight);
                        let canon = if use_coarse { coarse } else { fine };
                        if rng.gen_bool(spec.label_flip) {
                            rng.gen_range(spec.classes) as u32
                        } else {
                            canon
                        }
                    })
                    .collect()
            }
            TaskKind::MultiLabel => {
                // each task t marks a random subset of SUPER-communities
                // positive (coarse signal) and flips a subset of fine
                // communities (fine detail); node flips with label_flip.
                let mut positive: Vec<Vec<bool>> = Vec::with_capacity(spec.classes);
                for _ in 0..spec.classes {
                    let super_pos: Vec<bool> =
                        (0..spec.supers).map(|_| rng.gen_bool(0.5)).collect();
                    positive.push(
                        (0..spec.communities)
                            .map(|c| {
                                let base = super_pos[(c / comms_per_super).min(spec.supers - 1)];
                                if rng.gen_bool(1.0 - spec.super_label_weight) {
                                    rng.gen_bool(0.5)
                                } else {
                                    base
                                }
                            })
                            .collect(),
                    );
                }
                let mut labels = vec![0u32; spec.n * spec.classes];
                for i in 0..spec.n {
                    for t in 0..spec.classes {
                        let canon = positive[t][communities[i] as usize];
                        let flipped = rng.gen_bool(spec.label_flip);
                        labels[i * spec.classes + t] = u32::from(canon ^ flipped);
                    }
                }
                labels
            }
        };
        let val_frac = ((1.0 - spec.train_frac) / 2.0).min(0.2);
        let splits = train_val_test_split(spec.n, spec.train_frac, val_frac, spec.seed ^ 0x5114);
        Dataset { spec: spec.clone(), graph: graph.into(), communities, labels, splits }
    }

    /// Graph statistics with label-homophily (Table II analog row).
    ///
    /// Needs the resident CSR (panics for disk-backed datasets).
    pub fn stats(&self) -> GraphStats {
        match self.spec.task {
            TaskKind::MultiClass => GraphStats::compute(self.graph.mem(), Some(&self.labels)),
            TaskKind::MultiLabel => GraphStats::compute(self.graph.mem(), Some(&self.communities)),
        }
    }

    /// Labels as i32 (HLO input layout).
    pub fn labels_i32(&self) -> Vec<i32> {
        self.labels.iter().map(|&x| x as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;

    #[test]
    fn multiclass_labels_in_range() {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 2000; // shrink for test speed
        s.communities = 40;
        let ds = Dataset::generate(&s);
        assert_eq!(ds.labels.len(), 2000);
        assert!(ds.labels.iter().all(|&l| l < 40));
    }

    #[test]
    fn multilabel_shape_and_binary() {
        let mut s = spec("synth-proteins").unwrap();
        s.n = 1200;
        s.communities = 12;
        let ds = Dataset::generate(&s);
        assert_eq!(ds.labels.len(), 1200 * 16);
        assert!(ds.labels.iter().all(|&l| l <= 1));
        // both classes present in most tasks
        let mut pos = vec![0usize; 16];
        for i in 0..1200 {
            for t in 0..16 {
                pos[t] += ds.labels[i * 16 + t] as usize;
            }
        }
        let nontrivial = pos.iter().filter(|&&p| p > 120 && p < 1080).count();
        assert!(nontrivial >= 12, "degenerate tasks: {pos:?}");
    }

    #[test]
    fn labels_correlate_with_position() {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 4000;
        let ds = Dataset::generate(&s);
        let cps = s.communities.div_ceil(s.supers);
        let agree = (0..4000)
            .filter(|&i| {
                let fine = ds.communities[i] % s.classes as u32;
                let coarse = (ds.communities[i] as usize / cps) as u32 % s.classes as u32;
                ds.labels[i] == fine || ds.labels[i] == coarse
            })
            .count();
        // canonical (fine or coarse) survives unless flipped: ≈ 1 - flip
        let frac = agree as f64 / 4000.0;
        assert!(frac > 0.6, "label-position agreement {frac}");
    }

    #[test]
    fn generation_is_deterministic() {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 1000;
        let a = Dataset::generate(&s);
        let b = Dataset::generate(&s);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.splits.train, b.splits.train);
    }

    #[test]
    fn graph_has_homophily() {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 3000;
        let ds = Dataset::generate(&s);
        // label homophily well above the 1/classes chance rate
        let st = ds.stats();
        let chance = 1.0 / s.classes as f64;
        assert!(
            st.edge_homophily.unwrap() > 4.0 * chance,
            "homophily {:?} vs chance {chance}",
            st.edge_homophily
        );
        // community homophily is the strong signal
        let cst = crate::graph::GraphStats::compute(ds.graph.mem(), Some(&ds.communities));
        assert!(cst.edge_homophily.unwrap() > 0.3);
    }
}
