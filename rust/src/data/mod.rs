//! Dataset registry: synthetic analogs of the paper's OGB benchmarks.
//!
//! The OGB datasets themselves (ogbn-arxiv/proteins/products) cannot be
//! shipped; per DESIGN.md §3 each is replaced by a planted-partition graph
//! at reduced scale that preserves the property the paper's method
//! exploits — **homophily**: labels correlate with communities, neighbors
//! tend to share labels. Degree regimes follow the originals (arxiv
//! sparse ~7 avg, proteins dense ~300 avg scaled to ~40, products ~25).

mod splits;
mod synth;

pub use splits::{train_val_test_split, Splits};
pub use synth::{Dataset, DatasetSpec, TaskKind};

/// Names of the registered synthetic datasets (paper Table II analogs).
pub const DATASET_NAMES: [&str; 3] = ["synth-arxiv", "synth-products", "synth-proteins"];

/// Look up a registered dataset spec by name.
pub fn spec(name: &str) -> Option<DatasetSpec> {
    match name {
        // ogbn-arxiv: 169,343 nodes, 40 classes, avg deg ~13.7 (dir) — here
        // ~1/28 scale (CPU full-batch budget), sparse citation-like regime.
        "synth-arxiv" => Some(DatasetSpec {
            name: "synth-arxiv",
            n: 6_000,
            classes: 40,
            communities: 120,
            supers: 12,
            intra_degree: 7.0,
            super_degree: 4.0,
            inter_degree: 2.0,
            label_flip: 0.30,
            super_label_weight: 0.6,
            train_frac: 0.54,
            task: TaskKind::MultiClass,
            d: 64,
            seed: 0xA12F,
        }),
        // ogbn-products: 2.449M nodes, 47 classes, dense co-purchase — here
        // heavily scaled down but still the largest of the three.
        "synth-products" => Some(DatasetSpec {
            name: "synth-products",
            n: 12_000,
            classes: 47,
            communities: 240,
            supers: 16,
            intra_degree: 12.0,
            super_degree: 7.0,
            inter_degree: 3.0,
            label_flip: 0.25,
            super_label_weight: 0.6,
            train_frac: 0.08,
            task: TaskKind::MultiClass,
            d: 64,
            seed: 0xB4C5,
        }),
        // ogbn-proteins: 132,534 nodes, 112 binary tasks, very dense — here
        // small scale with 16 binary tasks and a denser regime.
        "synth-proteins" => Some(DatasetSpec {
            name: "synth-proteins",
            n: 4_000,
            classes: 16, // 16 binary tasks
            communities: 80,
            supers: 10,
            intra_degree: 20.0,
            super_degree: 10.0,
            inter_degree: 6.0,
            label_flip: 0.25,
            super_label_weight: 0.7,
            train_frac: 0.65,
            task: TaskKind::MultiLabel,
            d: 48, // paper uses 200; scaled with n for CPU budget
            seed: 0xC0DE,
        }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registered_specs_resolve() {
        for name in DATASET_NAMES {
            let s = spec(name).unwrap();
            assert_eq!(s.name, name);
            assert!(s.n > 1000);
        }
        assert!(spec("nope").is_none());
    }

    #[test]
    fn products_is_largest() {
        let a = spec("synth-arxiv").unwrap().n;
        let p = spec("synth-products").unwrap().n;
        let r = spec("synth-proteins").unwrap().n;
        assert!(p > a && p > r);
    }
}
