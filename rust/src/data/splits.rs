//! Train/val/test splits (the paper uses OGB's provided splits; here
//! deterministic random splits with fixed proportions).

use crate::util::rng::Rng;

/// Node index sets for each fold.
#[derive(Debug, Clone)]
pub struct Splits {
    /// Training node ids.
    pub train: Vec<u32>,
    /// Validation node ids.
    pub val: Vec<u32>,
    /// Test node ids.
    pub test: Vec<u32>,
}

impl Splits {
    /// Boolean mask (1.0/0.0 f32) over nodes for a fold — the HLO masks
    /// the loss with this.
    pub fn mask_f32(fold: &[u32], n: usize) -> Vec<f32> {
        let mut m = vec![0f32; n];
        for &i in fold {
            m[i as usize] = 1.0;
        }
        m
    }
}

/// Split `n` nodes into train/val/test with the given fractions
/// (test gets the remainder).
pub fn train_val_test_split(n: usize, train_frac: f64, val_frac: f64, seed: u64) -> Splits {
    assert!(train_frac > 0.0 && val_frac >= 0.0 && train_frac + val_frac < 1.0);
    let mut ids: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut ids);
    let n_train = (n as f64 * train_frac) as usize;
    let n_val = (n as f64 * val_frac) as usize;
    Splits {
        train: ids[..n_train].to_vec(),
        val: ids[n_train..n_train + n_val].to_vec(),
        test: ids[n_train + n_val..].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn partition_of_all_nodes() {
        let s = train_val_test_split(1000, 0.6, 0.2, 1);
        assert_eq!(s.train.len(), 600);
        assert_eq!(s.val.len(), 200);
        assert_eq!(s.test.len(), 200);
        let all: HashSet<u32> =
            s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        assert_eq!(all.len(), 1000);
    }

    #[test]
    fn masks_are_disjoint_and_cover() {
        let s = train_val_test_split(100, 0.5, 0.25, 2);
        let mt = Splits::mask_f32(&s.train, 100);
        let mv = Splits::mask_f32(&s.val, 100);
        let me = Splits::mask_f32(&s.test, 100);
        for i in 0..100 {
            assert_eq!(mt[i] + mv[i] + me[i], 1.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = train_val_test_split(500, 0.6, 0.2, 7);
        let b = train_val_test_split(500, 0.6, 0.2, 7);
        let c = train_val_test_split(500, 0.6, 0.2, 8);
        assert_eq!(a.train, b.train);
        assert_ne!(a.train, c.train);
    }

    #[test]
    #[should_panic]
    fn invalid_fractions_rejected() {
        train_val_test_split(10, 0.8, 0.3, 1);
    }
}
