//! Fanout-bounded uniform neighbor sampling on CSR graphs: one-hop
//! [`SampledBlock`]s and their multi-hop chaining into
//! [`MultiHopBlock`]s for deep SAGE heads.

use super::{mix_seed, Fanout, Fanouts};
use crate::graph::GraphStore;
use crate::util::rng::Rng;

/// Stream-seed domain tag for hops beyond the first: hop `l > 0` draws
/// from `mix_seed(seed, HOP_STREAM_TAG, l)`, so every layer has an
/// independent per-`(seed, epoch, batch, layer, node)` RNG stream while
/// hop 0 keeps the caller's stream verbatim — which is what makes a
/// one-hop multi-hop block bit-identical to the classic single-hop
/// sampler (`rust/tests/multihop.rs`).
const HOP_STREAM_TAG: u64 = 0x4A7_E5;

/// One sampled computation block: the node rows a minibatch step
/// composes, plus the seed → sampled-neighbor topology over those rows.
///
/// Layout invariants (pinned by `rust/tests/minibatch.rs`):
/// * `nodes` holds **unique** global node ids; the first `num_seeds`
///   entries are the batch's seed nodes in batch order, followed by the
///   sampled frontier in discovery order.
/// * `neighbors_of(s)` returns **local** row indices into `nodes`, so a
///   trainer can compose `nodes` once with `compose_batch` and aggregate
///   entirely in block-row space.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SampledBlock {
    /// Unique global node ids to compose (seeds first, then frontier).
    pub nodes: Vec<u32>,
    /// Number of seed rows (the prefix of `nodes`).
    pub num_seeds: usize,
    /// CSR-style offsets into `neigh_idx`, one row per seed
    /// (`len == num_seeds + 1`).
    pub neigh_ptr: Vec<u32>,
    /// Sampled neighbors as local row indices into `nodes`.
    pub neigh_idx: Vec<u32>,
}

impl SampledBlock {
    /// Total rows to compose (`nodes.len()`): the batch's peak compose
    /// allocation is exactly `num_rows() × d`.
    pub fn num_rows(&self) -> usize {
        self.nodes.len()
    }

    /// Sampled neighbors of seed row `s`, as local row indices.
    pub fn neighbors_of(&self, s: usize) -> &[u32] {
        let (lo, hi) = (self.neigh_ptr[s] as usize, self.neigh_ptr[s + 1] as usize);
        &self.neigh_idx[lo..hi]
    }
}

/// A chain of per-hop [`SampledBlock`]s for an L-layer SAGE head,
/// sampled outer-to-inner.
///
/// Layout invariants (pinned by `rust/tests/multihop.rs`):
/// * `hops[0]` is the **output layer's** topology: its seeds are the
///   batch's seed nodes.
/// * `hops[l + 1]`'s seeds are exactly `hops[l].nodes` — same ids, same
///   order — so `hops[l].nodes` is always a prefix of
///   `hops[l + 1].nodes`, and `hops[l]`'s local row indices are valid
///   row indices into every deeper hop's feature matrix.
/// * The **last** hop's `nodes` is the complete set of rows a step
///   composes ([`num_rows`](MultiHopBlock::num_rows) ×`d` is the peak
///   compose allocation).
///
/// Forward pass mapping for an `L`-layer head: SAGE layer `j`
/// (`j = 0` reads the composed embeddings) aggregates with the topology
/// of `hops[L - 1 - j]`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MultiHopBlock {
    /// Per-hop blocks, outer-to-inner as sampled (see type docs).
    pub hops: Vec<SampledBlock>,
}

impl MultiHopBlock {
    /// Number of sampled hops (= SAGE head depth).
    pub fn num_hops(&self) -> usize {
        self.hops.len()
    }

    /// The batch's seed-node count (loss rows).
    pub fn num_seeds(&self) -> usize {
        self.hops.first().map_or(0, |b| b.num_seeds)
    }

    /// Total rows to compose: the outermost (last) hop's node count.
    pub fn num_rows(&self) -> usize {
        self.hops.last().map_or(0, SampledBlock::num_rows)
    }

    /// The outermost hop — the block whose `nodes` a step composes.
    pub fn outer(&self) -> &SampledBlock {
        self.hops.last().expect("empty MultiHopBlock")
    }

    /// The hop-`l` block (0 = seeds' direct neighborhood).
    pub fn hop(&self, l: usize) -> &SampledBlock {
        &self.hops[l]
    }
}

/// Uniform neighbor sampler over any [`GraphStore`] backend (in-memory
/// CSR or on-disk), bounded per hop by a [`Fanout`].
///
/// Seeds with degree ≤ fanout keep their whole neighborhood (in
/// adjacency order); larger neighborhoods are sampled without
/// replacement by a partial Fisher–Yates draw whose RNG is keyed by
/// `(hop stream seed, epoch, batch, node)` via [`mix_seed`] — hop 0's
/// stream is the constructor's `seed` verbatim, deeper hops re-key
/// with a domain tag — so every block is reproducible at any thread
/// count, and resampling the same batch coordinates always returns the
/// same (multi-hop) block.
///
/// The sampler owns a `global → local` scratch array (`u32::MAX` =
/// absent, restored after every call), shared across hops, plus an
/// adjacency-row scratch the backend copies each seed's neighbor row
/// into, so block construction does no hashing and allocates only the
/// block itself. Because every draw is keyed by coordinates — never by
/// access order — the blocks are bit-identical across backends.
pub struct NeighborSampler<'g> {
    graph: &'g dyn GraphStore,
    /// Per-hop (fanout, stream seed).
    hops: Vec<(Fanout, u64)>,
    node_to_local: Vec<u32>,
    pick: Vec<u32>,
    /// Current seed's neighbor row (backend copy-out scratch).
    adj: Vec<u32>,
}

impl<'g> NeighborSampler<'g> {
    /// Single-hop sampler over `graph`; `seed` keys all draws.
    pub fn new(graph: &'g dyn GraphStore, fanout: Fanout, seed: u64) -> Self {
        Self::multi_hop(graph, &Fanouts::single(fanout), seed)
    }

    /// Multi-hop sampler: one chained hop per [`Fanouts`] entry. Hop 0
    /// draws from `seed`'s stream exactly as a single-hop sampler
    /// would; hop `l > 0` draws from an independent re-keyed stream.
    pub fn multi_hop(graph: &'g dyn GraphStore, fanouts: &Fanouts, seed: u64) -> Self {
        let hops = fanouts
            .as_slice()
            .iter()
            .enumerate()
            .map(|(l, &f)| match l {
                0 => (f, seed),
                _ => (f, mix_seed(&[seed, HOP_STREAM_TAG, l as u64])),
            })
            .collect();
        NeighborSampler {
            graph,
            hops,
            node_to_local: vec![u32::MAX; graph.num_nodes()],
            pick: Vec::new(),
            adj: Vec::new(),
        }
    }

    /// The hop-0 fanout.
    pub fn fanout(&self) -> Fanout {
        self.hops[0].0
    }

    /// Number of sampled hops per multi-hop block.
    pub fn num_hops(&self) -> usize {
        self.hops.len()
    }

    /// Sample the one-hop (hop 0) block for `seeds` (distinct ids) at
    /// batch coordinates `(epoch, batch)`. Deterministic per
    /// `(sampler seed, epoch, batch)`; seed order is preserved.
    pub fn sample_block(&mut self, seeds: &[u32], epoch: usize, batch: usize) -> SampledBlock {
        let mut block = SampledBlock::default();
        self.sample_block_into(seeds, epoch, batch, &mut block);
        block
    }

    /// [`sample_block`](NeighborSampler::sample_block) into a
    /// caller-owned block, reusing its vectors' capacity — the
    /// allocation-free variant the prefetcher's buffer pool drives.
    /// `block`'s previous contents are discarded; the result is
    /// identical to a fresh `sample_block` call at the same coordinates.
    pub fn sample_block_into(
        &mut self,
        seeds: &[u32],
        epoch: usize,
        batch: usize,
        block: &mut SampledBlock,
    ) {
        self.sample_hop_into(0, seeds, epoch, batch, block);
    }

    /// Sample the full hop chain for `seeds` at `(epoch, batch)` —
    /// allocating convenience over
    /// [`sample_multi_into`](NeighborSampler::sample_multi_into).
    pub fn sample_multi(&mut self, seeds: &[u32], epoch: usize, batch: usize) -> MultiHopBlock {
        let mut mhb = MultiHopBlock::default();
        self.sample_multi_into(seeds, epoch, batch, &mut mhb);
        mhb
    }

    /// Sample the full hop chain into a caller-owned [`MultiHopBlock`],
    /// reusing its per-hop vectors' capacity. Hop 0 samples around
    /// `seeds`; hop `l + 1` samples around hop `l`'s complete node list
    /// (so each hop's nodes form a prefix of the next hop's). The
    /// result is a pure function of `(sampler seed, epoch, batch)`.
    pub fn sample_multi_into(
        &mut self,
        seeds: &[u32],
        epoch: usize,
        batch: usize,
        mhb: &mut MultiHopBlock,
    ) {
        let hops = self.hops.len();
        mhb.hops.truncate(hops);
        mhb.hops.resize_with(hops, SampledBlock::default);
        for l in 0..hops {
            // split so hop l - 1's nodes (this hop's seeds) and hop l's
            // output block can be borrowed at once
            let (done, rest) = mhb.hops.split_at_mut(l);
            let block = &mut rest[0];
            match done.last() {
                None => self.sample_hop_into(l, seeds, epoch, batch, block),
                Some(prev) => {
                    let prev_nodes: &[u32] = &prev.nodes;
                    self.sample_hop_into(l, prev_nodes, epoch, batch, block);
                }
            }
        }
    }

    /// One hop's sampling kernel: hop `hop`'s (fanout, stream) applied
    /// to `seeds`, writing `block`.
    fn sample_hop_into(
        &mut self,
        hop: usize,
        seeds: &[u32],
        epoch: usize,
        batch: usize,
        block: &mut SampledBlock,
    ) {
        let (fanout, stream) = self.hops[hop];
        // destructure for disjoint borrows: the backend copy-out fills
        // `adj` while `node_to_local`/`pick` stay mutably borrowed
        let NeighborSampler { graph, node_to_local, pick, adj, .. } = self;
        let n = graph.num_nodes() as u32;
        let nodes = &mut block.nodes;
        nodes.clear();
        nodes.reserve(seeds.len() * 2);
        for (local, &s) in seeds.iter().enumerate() {
            assert!(s < n, "seed {s} out of range (n = {n})");
            assert_eq!(node_to_local[s as usize], u32::MAX, "duplicate seed {s}");
            node_to_local[s as usize] = local as u32;
            nodes.push(s);
        }
        let neigh_ptr = &mut block.neigh_ptr;
        neigh_ptr.clear();
        neigh_ptr.reserve(seeds.len() + 1);
        neigh_ptr.push(0);
        let neigh_idx = &mut block.neigh_idx;
        neigh_idx.clear();
        for &s in seeds {
            graph.neighbors_into(s, adj);
            // `sampled` selects the indirection: the common no-sampling
            // path (degree ≤ fanout, or Fanout::All) walks `adj`
            // directly and never touches the `pick` scratch
            let (take, sampled) = match fanout.limit() {
                Some(f) if adj.len() > f => {
                    // partial Fisher–Yates over adjacency positions; the
                    // per-(seed, epoch, batch, layer, node) stream makes
                    // the draw independent of scheduling, batch layout
                    // and hop structure
                    let mut rng = Rng::seed_from_u64(mix_seed(&[
                        stream,
                        epoch as u64,
                        batch as u64,
                        s as u64,
                    ]));
                    pick.clear();
                    pick.extend(0..adj.len() as u32);
                    for t in 0..f {
                        let j = t + rng.gen_range(adj.len() - t);
                        pick.swap(t, j);
                    }
                    (f, true)
                }
                _ => (adj.len(), false),
            };
            for t in 0..take {
                let v = if sampled { adj[pick[t] as usize] } else { adj[t] };
                let local = node_to_local[v as usize];
                let local = if local == u32::MAX {
                    let l = nodes.len() as u32;
                    node_to_local[v as usize] = l;
                    nodes.push(v);
                    l
                } else {
                    local
                };
                neigh_idx.push(local);
            }
            neigh_ptr.push(neigh_idx.len() as u32);
        }
        for &u in nodes.iter() {
            node_to_local[u as usize] = u32::MAX;
        }
        block.num_seeds = seeds.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CsrGraph, GraphBuilder};

    fn path_graph(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 - 1 {
            b.add_edge(u, u + 1, 1.0);
        }
        b.build()
    }

    #[test]
    fn full_fanout_takes_whole_neighborhood_in_order() {
        let g = path_graph(5);
        let mut s = NeighborSampler::new(&g, Fanout::All, 0);
        let block = s.sample_block(&[2, 0], 0, 0);
        assert_eq!(block.num_seeds, 2);
        assert_eq!(&block.nodes[..2], &[2, 0]);
        // node 2's neighbors are {1, 3}; node 0's neighbor is {1}
        let n2: Vec<u32> = block.neighbors_of(0).iter().map(|&r| block.nodes[r as usize]).collect();
        assert_eq!(n2, vec![1, 3]);
        let n0: Vec<u32> = block.neighbors_of(1).iter().map(|&r| block.nodes[r as usize]).collect();
        assert_eq!(n0, vec![1]);
        // node 1 appears once even though two seeds reach it
        assert_eq!(block.nodes.iter().filter(|&&u| u == 1).count(), 1);
    }

    #[test]
    fn fanout_zero_yields_no_neighbors() {
        let g = path_graph(4);
        let mut s = NeighborSampler::new(&g, Fanout::Max(0), 0);
        let block = s.sample_block(&[1, 2], 0, 0);
        assert_eq!(block.nodes, vec![1, 2]);
        assert!(block.neighbors_of(0).is_empty());
        assert!(block.neighbors_of(1).is_empty());
    }

    #[test]
    fn scratch_is_restored_between_calls() {
        let g = path_graph(6);
        let mut s = NeighborSampler::new(&g, Fanout::Max(1), 9);
        let a = s.sample_block(&[0, 3], 1, 0);
        let b = s.sample_block(&[0, 3], 1, 0);
        assert_eq!(a, b);
        // disjoint second batch works on the same scratch
        let c = s.sample_block(&[5], 1, 1);
        assert_eq!(c.nodes[0], 5);
    }

    #[test]
    fn sample_block_into_reuses_buffers_and_matches_fresh_blocks() {
        let g = path_graph(8);
        let mut s = NeighborSampler::new(&g, Fanout::Max(2), 4);
        let fresh = s.sample_block(&[1, 4, 6], 0, 0);
        // a recycled block with unrelated stale contents samples identically
        let mut reused = s.sample_block(&[0, 7], 3, 9);
        s.sample_block_into(&[1, 4, 6], 0, 0, &mut reused);
        assert_eq!(fresh, reused);
    }

    #[test]
    #[should_panic(expected = "duplicate seed")]
    fn duplicate_seeds_rejected() {
        let g = path_graph(3);
        NeighborSampler::new(&g, Fanout::All, 0).sample_block(&[1, 1], 0, 0);
    }

    #[test]
    fn single_hop_multi_block_matches_sample_block_bits() {
        let g = path_graph(9);
        let seeds = [2u32, 5, 8];
        let mut a = NeighborSampler::new(&g, Fanout::Max(1), 3);
        let mut b = NeighborSampler::multi_hop(&g, &Fanouts::single(Fanout::Max(1)), 3);
        let single = a.sample_block(&seeds, 4, 2);
        let multi = b.sample_multi(&seeds, 4, 2);
        assert_eq!(multi.num_hops(), 1);
        assert_eq!(multi.hops[0], single);
        assert_eq!(multi.num_seeds(), 3);
        assert_eq!(multi.num_rows(), single.num_rows());
    }

    #[test]
    fn multi_hop_chains_each_hop_on_the_previous_nodes() {
        let g = path_graph(12);
        let fanouts = Fanouts::parse("2,2").unwrap();
        let mut s = NeighborSampler::multi_hop(&g, &fanouts, 7);
        let mhb = s.sample_multi(&[0, 6], 1, 0);
        assert_eq!(mhb.num_hops(), 2);
        // hop l's nodes are a prefix of hop l+1's, in the same order
        let h0 = &mhb.hops[0];
        let h1 = &mhb.hops[1];
        assert_eq!(h1.num_seeds, h0.num_rows());
        assert_eq!(&h1.nodes[..h0.nodes.len()], &h0.nodes[..]);
        assert_eq!(mhb.outer().nodes, h1.nodes);
        // resampling the same coordinates reproduces the chain exactly
        assert_eq!(mhb, s.sample_multi(&[0, 6], 1, 0));
        // recycled multi-hop blocks resample identically
        let mut reused = s.sample_multi(&[3], 9, 9);
        s.sample_multi_into(&[0, 6], 1, 0, &mut reused);
        assert_eq!(mhb, reused);
    }

    #[test]
    fn multi_hop_block_shrinks_when_sampler_has_fewer_hops() {
        let g = path_graph(6);
        let mut deep = NeighborSampler::multi_hop(&g, &Fanouts::parse("1,1,1").unwrap(), 0);
        let mut shallow = NeighborSampler::new(&g, Fanout::Max(1), 0);
        let mut mhb = deep.sample_multi(&[2], 0, 0);
        assert_eq!(mhb.num_hops(), 3);
        shallow.sample_multi_into(&[2], 0, 0, &mut mhb);
        assert_eq!(mhb.num_hops(), 1);
    }
}
