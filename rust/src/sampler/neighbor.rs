//! Fanout-bounded uniform neighbor sampling on CSR graphs.

use super::{mix_seed, Fanout};
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

/// One sampled computation block: the node rows a minibatch step
/// composes, plus the seed → sampled-neighbor topology over those rows.
///
/// Layout invariants (pinned by `rust/tests/minibatch.rs`):
/// * `nodes` holds **unique** global node ids; the first `num_seeds`
///   entries are the batch's seed nodes in batch order, followed by the
///   sampled frontier in discovery order.
/// * `neighbors_of(s)` returns **local** row indices into `nodes`, so a
///   trainer can compose `nodes` once with `compose_batch` and aggregate
///   entirely in block-row space.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SampledBlock {
    /// Unique global node ids to compose (seeds first, then frontier).
    pub nodes: Vec<u32>,
    /// Number of seed rows (the prefix of `nodes`).
    pub num_seeds: usize,
    /// CSR-style offsets into `neigh_idx`, one row per seed
    /// (`len == num_seeds + 1`).
    pub neigh_ptr: Vec<u32>,
    /// Sampled neighbors as local row indices into `nodes`.
    pub neigh_idx: Vec<u32>,
}

impl SampledBlock {
    /// Total rows to compose (`nodes.len()`): the batch's peak compose
    /// allocation is exactly `num_rows() × d`.
    pub fn num_rows(&self) -> usize {
        self.nodes.len()
    }

    /// Sampled neighbors of seed row `s`, as local row indices.
    pub fn neighbors_of(&self, s: usize) -> &[u32] {
        let (lo, hi) = (self.neigh_ptr[s] as usize, self.neigh_ptr[s + 1] as usize);
        &self.neigh_idx[lo..hi]
    }
}

/// Uniform neighbor sampler over a [`CsrGraph`], bounded by a [`Fanout`].
///
/// Seeds with degree ≤ fanout keep their whole neighborhood (in
/// adjacency order); larger neighborhoods are sampled without
/// replacement by a partial Fisher–Yates draw whose RNG is keyed by
/// `(stream seed, epoch, batch, node)` via [`mix_seed`] — so every block
/// is reproducible at any thread count, and resampling the same batch
/// coordinates always returns the same block.
///
/// The sampler owns a `global → local` scratch array (`u32::MAX` =
/// absent, restored after every call), so block construction does no
/// hashing and allocates only the block itself.
pub struct NeighborSampler<'g> {
    graph: &'g CsrGraph,
    fanout: Fanout,
    seed: u64,
    node_to_local: Vec<u32>,
    pick: Vec<u32>,
}

impl<'g> NeighborSampler<'g> {
    /// Sampler over `graph` with the given fanout; `seed` keys all draws.
    pub fn new(graph: &'g CsrGraph, fanout: Fanout, seed: u64) -> Self {
        NeighborSampler {
            graph,
            fanout,
            seed,
            node_to_local: vec![u32::MAX; graph.num_nodes()],
            pick: Vec::new(),
        }
    }

    /// The configured fanout.
    pub fn fanout(&self) -> Fanout {
        self.fanout
    }

    /// Sample the one-hop block for `seeds` (distinct ids) at batch
    /// coordinates `(epoch, batch)`. Deterministic per
    /// `(sampler seed, epoch, batch)`; seed order is preserved.
    pub fn sample_block(&mut self, seeds: &[u32], epoch: usize, batch: usize) -> SampledBlock {
        let mut block = SampledBlock::default();
        self.sample_block_into(seeds, epoch, batch, &mut block);
        block
    }

    /// [`sample_block`](NeighborSampler::sample_block) into a
    /// caller-owned block, reusing its vectors' capacity — the
    /// allocation-free variant the prefetcher's buffer pool drives.
    /// `block`'s previous contents are discarded; the result is
    /// identical to a fresh `sample_block` call at the same coordinates.
    pub fn sample_block_into(
        &mut self,
        seeds: &[u32],
        epoch: usize,
        batch: usize,
        block: &mut SampledBlock,
    ) {
        let n = self.graph.num_nodes() as u32;
        let nodes = &mut block.nodes;
        nodes.clear();
        nodes.reserve(seeds.len() * 2);
        for (local, &s) in seeds.iter().enumerate() {
            assert!(s < n, "seed {s} out of range (n = {n})");
            assert_eq!(self.node_to_local[s as usize], u32::MAX, "duplicate seed {s}");
            self.node_to_local[s as usize] = local as u32;
            nodes.push(s);
        }
        let neigh_ptr = &mut block.neigh_ptr;
        neigh_ptr.clear();
        neigh_ptr.reserve(seeds.len() + 1);
        neigh_ptr.push(0);
        let neigh_idx = &mut block.neigh_idx;
        neigh_idx.clear();
        for &s in seeds {
            let adj = self.graph.neighbors(s);
            // `sampled` selects the indirection: the common no-sampling
            // path (degree ≤ fanout, or Fanout::All) walks `adj`
            // directly and never touches the `pick` scratch
            let (take, sampled) = match self.fanout.limit() {
                Some(f) if adj.len() > f => {
                    // partial Fisher–Yates over adjacency positions; the
                    // per-(seed, epoch, batch, node) stream makes the
                    // draw independent of scheduling and batch layout
                    let mut rng = Rng::seed_from_u64(mix_seed(&[
                        self.seed,
                        epoch as u64,
                        batch as u64,
                        s as u64,
                    ]));
                    self.pick.clear();
                    self.pick.extend(0..adj.len() as u32);
                    for t in 0..f {
                        let j = t + rng.gen_range(adj.len() - t);
                        self.pick.swap(t, j);
                    }
                    (f, true)
                }
                _ => (adj.len(), false),
            };
            for t in 0..take {
                let v = if sampled { adj[self.pick[t] as usize] } else { adj[t] };
                let local = self.node_to_local[v as usize];
                let local = if local == u32::MAX {
                    let l = nodes.len() as u32;
                    self.node_to_local[v as usize] = l;
                    nodes.push(v);
                    l
                } else {
                    local
                };
                neigh_idx.push(local);
            }
            neigh_ptr.push(neigh_idx.len() as u32);
        }
        for &u in nodes.iter() {
            self.node_to_local[u as usize] = u32::MAX;
        }
        block.num_seeds = seeds.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path_graph(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 - 1 {
            b.add_edge(u, u + 1, 1.0);
        }
        b.build()
    }

    #[test]
    fn full_fanout_takes_whole_neighborhood_in_order() {
        let g = path_graph(5);
        let mut s = NeighborSampler::new(&g, Fanout::All, 0);
        let block = s.sample_block(&[2, 0], 0, 0);
        assert_eq!(block.num_seeds, 2);
        assert_eq!(&block.nodes[..2], &[2, 0]);
        // node 2's neighbors are {1, 3}; node 0's neighbor is {1}
        let n2: Vec<u32> = block.neighbors_of(0).iter().map(|&r| block.nodes[r as usize]).collect();
        assert_eq!(n2, vec![1, 3]);
        let n0: Vec<u32> = block.neighbors_of(1).iter().map(|&r| block.nodes[r as usize]).collect();
        assert_eq!(n0, vec![1]);
        // node 1 appears once even though two seeds reach it
        assert_eq!(block.nodes.iter().filter(|&&u| u == 1).count(), 1);
    }

    #[test]
    fn fanout_zero_yields_no_neighbors() {
        let g = path_graph(4);
        let mut s = NeighborSampler::new(&g, Fanout::Max(0), 0);
        let block = s.sample_block(&[1, 2], 0, 0);
        assert_eq!(block.nodes, vec![1, 2]);
        assert!(block.neighbors_of(0).is_empty());
        assert!(block.neighbors_of(1).is_empty());
    }

    #[test]
    fn scratch_is_restored_between_calls() {
        let g = path_graph(6);
        let mut s = NeighborSampler::new(&g, Fanout::Max(1), 9);
        let a = s.sample_block(&[0, 3], 1, 0);
        let b = s.sample_block(&[0, 3], 1, 0);
        assert_eq!(a, b);
        // disjoint second batch works on the same scratch
        let c = s.sample_block(&[5], 1, 1);
        assert_eq!(c.nodes[0], 5);
    }

    #[test]
    fn sample_block_into_reuses_buffers_and_matches_fresh_blocks() {
        let g = path_graph(8);
        let mut s = NeighborSampler::new(&g, Fanout::Max(2), 4);
        let fresh = s.sample_block(&[1, 4, 6], 0, 0);
        // a recycled block with unrelated stale contents samples identically
        let mut reused = s.sample_block(&[0, 7], 3, 9);
        s.sample_block_into(&[1, 4, 6], 0, 0, &mut reused);
        assert_eq!(fresh, reused);
    }

    #[test]
    #[should_panic(expected = "duplicate seed")]
    fn duplicate_seeds_rejected() {
        let g = path_graph(3);
        NeighborSampler::new(&g, Fanout::All, 0).sample_block(&[1, 1], 0, 0);
    }
}
