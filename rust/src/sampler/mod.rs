//! GraphSAGE-style minibatch sampling: seed-node batching over train
//! splits plus fanout-bounded uniform neighbor sampling on
//! [`CsrGraph`](crate::graph::CsrGraph).
//!
//! This is the data path that makes minibatch training on
//! [`ComposeEngine::compose_batch`](crate::embedding::ComposeEngine::compose_batch)
//! possible: instead of composing all `n × d` node embeddings per epoch
//! (exactly what the paper says not to do at scale), the trainer asks the
//! sampler for one [`SampledBlock`] at a time — the batch's seed nodes
//! plus a bounded sampled neighborhood — and composes only those rows.
//!
//! **Determinism invariant.** Every random draw is keyed by
//! [`mix_seed`] over `(stream seed, epoch, batch, layer, node)` — hop 0
//! uses the caller's stream seed verbatim, each deeper hop re-keys its
//! own stream — and realized with the crate's own
//! [`Rng`](crate::util::rng::Rng), so a run is reproducible bit-for-bit
//! at any rayon thread count and regardless of scheduling: the same
//! `(seed, epoch, batch)` always yields the same batches and the same
//! sampled blocks, single- or multi-hop. `rust/tests/minibatch.rs` and
//! `rust/tests/multihop.rs` pin this at 1 vs 4 threads.
//!
//! **Multi-hop blocks.** Deeper GNN heads need deeper neighborhoods:
//! [`NeighborSampler::sample_multi_into`] chains one [`SampledBlock`]
//! per hop into a [`MultiHopBlock`], outer-to-inner — hop 0 is the
//! output layer's topology over the batch seeds, and each next hop
//! takes the previous hop's full node list as its seeds, so the last
//! hop's `nodes` is the complete set of rows a step composes.
//!
//! **Oracle configuration.** [`SamplerConfig::oracle`] (every fanout =
//! ∞, one batch = every train node, no shuffle) makes the minibatch
//! data path mathematically identical to full-batch training — the
//! equivalence the minibatch trainer is tested against.

mod batcher;
mod edges;
mod neighbor;
mod prefetch;

pub use batcher::SeedBatcher;
pub use edges::{sample_negative, EdgeBatch, EdgeBatcher, EdgeSplit, SeedSource};
pub use neighbor::{MultiHopBlock, NeighborSampler, SampledBlock};
pub use prefetch::{BlockPrefetcher, PrefetchError};

/// Per-seed neighbor cap for one sampled hop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fanout {
    /// Take every neighbor — the full-batch-equivalence oracle setting.
    All,
    /// Uniformly sample (without replacement) at most this many
    /// neighbors per seed.
    Max(usize),
}

impl Fanout {
    /// The cap as an option (`None` = unbounded).
    pub fn limit(self) -> Option<usize> {
        match self {
            Fanout::All => None,
            Fanout::Max(f) => Some(f),
        }
    }

    /// Parse a CLI-style fanout: an integer, or `all`/`inf` for ∞.
    pub fn parse(s: &str) -> Result<Self, String> {
        if s.eq_ignore_ascii_case("all") || s.eq_ignore_ascii_case("inf") {
            return Ok(Fanout::All);
        }
        s.parse::<usize>()
            .map(Fanout::Max)
            .map_err(|_| format!("bad fanout '{s}' (expected an integer or 'all')"))
    }
}

impl std::fmt::Display for Fanout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fanout::All => write!(f, "all"),
            Fanout::Max(x) => write!(f, "{x}"),
        }
    }
}

/// Per-layer fanouts for multi-hop sampling: entry `l` caps hop `l`
/// (hop 0 is the seeds' direct neighborhood and feeds the head's
/// **last** SAGE layer, so `Fanouts::parse("10,5")` samples 10 direct
/// neighbors per seed and 5 neighbors per frontier node). The number
/// of entries is the number of sampled hops and therefore the SAGE
/// head's depth.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fanouts(Vec<Fanout>);

impl Fanouts {
    /// Fanouts from an explicit per-hop list (must be non-empty).
    pub fn new(fanouts: Vec<Fanout>) -> Self {
        assert!(!fanouts.is_empty(), "at least one fanout layer required");
        Fanouts(fanouts)
    }

    /// Single-hop fanouts (the classic one-layer configuration).
    pub fn single(fanout: Fanout) -> Self {
        Fanouts(vec![fanout])
    }

    /// `layers` unbounded hops — the full-neighborhood configuration
    /// evaluation and the full-batch-equivalence oracle use.
    pub fn all(layers: usize) -> Self {
        Fanouts(vec![Fanout::All; layers.max(1)])
    }

    /// Number of sampled hops (= SAGE head depth).
    pub fn layers(&self) -> usize {
        self.0.len()
    }

    /// Fanout of hop `l`.
    pub fn get(&self, l: usize) -> Fanout {
        self.0[l]
    }

    /// The per-hop fanouts as a slice.
    pub fn as_slice(&self) -> &[Fanout] {
        &self.0
    }

    /// Per-hop caps as options (`None` = unbounded), for bench records.
    pub fn limits(&self) -> Vec<Option<usize>> {
        self.0.iter().map(|f| f.limit()).collect()
    }

    /// Parse a CLI-style comma-separated list, e.g. `10,5` or `all,8`.
    pub fn parse(s: &str) -> Result<Self, String> {
        let v: Result<Vec<Fanout>, String> = s.split(',').map(Fanout::parse).collect();
        let v = v?;
        if v.is_empty() {
            return Err("empty fanout list".to_string());
        }
        Ok(Fanouts(v))
    }
}

impl From<Fanout> for Fanouts {
    fn from(f: Fanout) -> Self {
        Fanouts::single(f)
    }
}

impl std::fmt::Display for Fanouts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (l, fan) in self.0.iter().enumerate() {
            if l > 0 {
                write!(f, ",")?;
            }
            write!(f, "{fan}")?;
        }
        Ok(())
    }
}

/// Sampling knobs for minibatch training (carried on
/// [`Experiment`](crate::config::Experiment); CLI flags override).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SamplerConfig {
    /// Seed nodes per batch.
    pub batch_size: usize,
    /// Per-hop neighbor fanouts; the list length is the number of
    /// sampled hops and the SAGE head's layer count.
    pub fanouts: Fanouts,
    /// Reshuffle the seed order every epoch (disable for oracle-parity
    /// runs, where batch order must match the full-batch split order).
    pub shuffle: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { batch_size: 512, fanouts: Fanouts::single(Fanout::Max(10)), shuffle: true }
    }
}

impl SamplerConfig {
    /// The full-batch-equivalence oracle configuration: one batch holding
    /// all `num_train` seeds, every neighbor taken at every hop, no
    /// epoch shuffle. With these knobs the `layers`-deep minibatch
    /// trainer computes the same epoch update as the `layers`-deep
    /// full-batch trainer (tested to 1e-5 per epoch).
    pub fn oracle(num_train: usize, layers: usize) -> Self {
        SamplerConfig {
            batch_size: num_train.max(1),
            fanouts: Fanouts::all(layers),
            shuffle: false,
        }
    }
}

/// Mix a word sequence into one 64-bit stream seed (SplitMix-style
/// avalanche per word). Used to derive independent, reproducible RNG
/// streams from `(seed, epoch, batch, node)` coordinates, so sampling is
/// deterministic no matter how work is scheduled across threads.
pub fn mix_seed(words: &[u64]) -> u64 {
    let mut h = 0x243F_6A88_85A3_08D3u64; // pi digits: arbitrary non-zero start
    for &w in words {
        h ^= w.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_seed_is_deterministic_and_word_sensitive() {
        assert_eq!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 3]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 4]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[3, 2, 1]));
        assert_ne!(mix_seed(&[0]), mix_seed(&[0, 0]));
    }

    #[test]
    fn fanout_parse_and_limit() {
        assert_eq!(Fanout::parse("all").unwrap(), Fanout::All);
        assert_eq!(Fanout::parse("INF").unwrap(), Fanout::All);
        assert_eq!(Fanout::parse("7").unwrap(), Fanout::Max(7));
        assert!(Fanout::parse("x").is_err());
        assert_eq!(Fanout::All.limit(), None);
        assert_eq!(Fanout::Max(3).limit(), Some(3));
        assert_eq!(Fanout::All.to_string(), "all");
        assert_eq!(Fanout::Max(5).to_string(), "5");
    }

    #[test]
    fn oracle_config_shape() {
        let c = SamplerConfig::oracle(123, 2);
        assert_eq!(c.batch_size, 123);
        assert_eq!(c.fanouts, Fanouts::all(2));
        assert_eq!(c.fanouts.layers(), 2);
        assert!(!c.shuffle);
        // degenerate inputs still yield a usable config
        let degenerate = SamplerConfig::oracle(0, 0);
        assert_eq!(degenerate.batch_size, 1);
        assert_eq!(degenerate.fanouts.layers(), 1);
    }

    #[test]
    fn fanouts_parse_display_roundtrip() {
        let f = Fanouts::parse("10,5").unwrap();
        assert_eq!(f.layers(), 2);
        assert_eq!(f.get(0), Fanout::Max(10));
        assert_eq!(f.get(1), Fanout::Max(5));
        assert_eq!(f.limits(), vec![Some(10), Some(5)]);
        assert_eq!(f.to_string(), "10,5");
        let mixed = Fanouts::parse("all,8").unwrap();
        assert_eq!(mixed.get(0), Fanout::All);
        assert_eq!(mixed.to_string(), "all,8");
        assert!(Fanouts::parse("10,x").is_err());
        assert_eq!(Fanouts::from(Fanout::Max(3)), Fanouts::single(Fanout::Max(3)));
        assert_eq!(Fanouts::all(3).as_slice(), &[Fanout::All; 3]);
    }

    #[test]
    #[should_panic(expected = "at least one fanout")]
    fn empty_fanout_list_rejected() {
        Fanouts::new(Vec::new());
    }
}
