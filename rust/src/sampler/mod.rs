//! GraphSAGE-style minibatch sampling: seed-node batching over train
//! splits plus fanout-bounded uniform neighbor sampling on
//! [`CsrGraph`](crate::graph::CsrGraph).
//!
//! This is the data path that makes minibatch training on
//! [`ComposeEngine::compose_batch`](crate::embedding::ComposeEngine::compose_batch)
//! possible: instead of composing all `n × d` node embeddings per epoch
//! (exactly what the paper says not to do at scale), the trainer asks the
//! sampler for one [`SampledBlock`] at a time — the batch's seed nodes
//! plus a bounded sampled neighborhood — and composes only those rows.
//!
//! **Determinism invariant.** Every random draw is keyed by
//! [`mix_seed`] over `(stream seed, epoch, batch, node)` and realized
//! with the crate's own [`Rng`](crate::util::rng::Rng), so a run is
//! reproducible bit-for-bit at any rayon thread count and regardless of
//! scheduling: the same `(seed, epoch, batch)` always yields the same
//! batches and the same sampled blocks. `rust/tests/minibatch.rs` pins
//! this at 1 vs 4 threads.
//!
//! **Oracle configuration.** [`SamplerConfig::oracle`] (fanout = ∞, one
//! batch = every train node, no shuffle) makes the minibatch data path
//! mathematically identical to full-batch training — the equivalence the
//! minibatch trainer is tested against.

mod batcher;
mod neighbor;
mod prefetch;

pub use batcher::SeedBatcher;
pub use neighbor::{NeighborSampler, SampledBlock};
pub use prefetch::BlockPrefetcher;

/// Per-seed neighbor cap for one sampled hop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fanout {
    /// Take every neighbor — the full-batch-equivalence oracle setting.
    All,
    /// Uniformly sample (without replacement) at most this many
    /// neighbors per seed.
    Max(usize),
}

impl Fanout {
    /// The cap as an option (`None` = unbounded).
    pub fn limit(self) -> Option<usize> {
        match self {
            Fanout::All => None,
            Fanout::Max(f) => Some(f),
        }
    }

    /// Parse a CLI-style fanout: an integer, or `all`/`inf` for ∞.
    pub fn parse(s: &str) -> Result<Self, String> {
        if s.eq_ignore_ascii_case("all") || s.eq_ignore_ascii_case("inf") {
            return Ok(Fanout::All);
        }
        s.parse::<usize>()
            .map(Fanout::Max)
            .map_err(|_| format!("bad fanout '{s}' (expected an integer or 'all')"))
    }
}

impl std::fmt::Display for Fanout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fanout::All => write!(f, "all"),
            Fanout::Max(x) => write!(f, "{x}"),
        }
    }
}

/// Sampling knobs for minibatch training (carried on
/// [`Experiment`](crate::config::Experiment); CLI flags override).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SamplerConfig {
    /// Seed nodes per batch.
    pub batch_size: usize,
    /// Neighbor fanout per seed.
    pub fanout: Fanout,
    /// Reshuffle the seed order every epoch (disable for oracle-parity
    /// runs, where batch order must match the full-batch split order).
    pub shuffle: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { batch_size: 512, fanout: Fanout::Max(10), shuffle: true }
    }
}

impl SamplerConfig {
    /// The full-batch-equivalence oracle configuration: one batch holding
    /// all `num_train` seeds, every neighbor taken, no epoch shuffle.
    /// With these knobs the minibatch trainer computes the same epoch
    /// update as the full-batch trainer (tested to 1e-5 per epoch).
    pub fn oracle(num_train: usize) -> Self {
        SamplerConfig { batch_size: num_train.max(1), fanout: Fanout::All, shuffle: false }
    }
}

/// Mix a word sequence into one 64-bit stream seed (SplitMix-style
/// avalanche per word). Used to derive independent, reproducible RNG
/// streams from `(seed, epoch, batch, node)` coordinates, so sampling is
/// deterministic no matter how work is scheduled across threads.
pub fn mix_seed(words: &[u64]) -> u64 {
    let mut h = 0x243F_6A88_85A3_08D3u64; // pi digits: arbitrary non-zero start
    for &w in words {
        h ^= w.wrapping_add(0x9E37_79B9_7F4A_7C15);
        h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 31;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_seed_is_deterministic_and_word_sensitive() {
        assert_eq!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 3]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[1, 2, 4]));
        assert_ne!(mix_seed(&[1, 2, 3]), mix_seed(&[3, 2, 1]));
        assert_ne!(mix_seed(&[0]), mix_seed(&[0, 0]));
    }

    #[test]
    fn fanout_parse_and_limit() {
        assert_eq!(Fanout::parse("all").unwrap(), Fanout::All);
        assert_eq!(Fanout::parse("INF").unwrap(), Fanout::All);
        assert_eq!(Fanout::parse("7").unwrap(), Fanout::Max(7));
        assert!(Fanout::parse("x").is_err());
        assert_eq!(Fanout::All.limit(), None);
        assert_eq!(Fanout::Max(3).limit(), Some(3));
        assert_eq!(Fanout::All.to_string(), "all");
        assert_eq!(Fanout::Max(5).to_string(), "5");
    }

    #[test]
    fn oracle_config_shape() {
        let c = SamplerConfig::oracle(123);
        assert_eq!(c.batch_size, 123);
        assert_eq!(c.fanout, Fanout::All);
        assert!(!c.shuffle);
        // degenerate split still yields a usable config
        assert_eq!(SamplerConfig::oracle(0).batch_size, 1);
    }
}
