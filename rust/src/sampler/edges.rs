//! Link-prediction data path: held-out edge splits, deterministic
//! positive-edge batching and seeded negative sampling.
//!
//! The link-prediction objective (per Hashing-Accelerated GNNs for Link
//! Prediction, Wu 2021) trains on *edges* instead of labeled nodes: a
//! batch is a slice of held-out positive edges plus `neg_per_pos`
//! corrupted negatives per positive, and the batch's seed set — the
//! unique endpoints — feeds the exact same multi-hop sampler / compose
//! engine / SAGE head the node-classification path uses.
//!
//! **Determinism invariant.** Everything here is a pure function of its
//! coordinates, mirroring [`SeedBatcher`](super::SeedBatcher): the edge
//! split is keyed by its seed, the per-epoch positive order by
//! `(seed, epoch)`, and every negative draw by
//! `(seed, epoch, batch, edge index)` via [`mix_seed`](super::mix_seed)
//! — so batch `(epoch, i)` can be recomputed identically on the
//! prefetch thread, the training thread and in tests, at any rayon
//! thread count (`rust/tests/link_prediction.rs` pins this at 1 vs 4
//! threads).
//!
//! **Negatives are never true edges.** A negative keeps one endpoint of
//! its positive (tail corruption first, head as fallback) and draws the
//! other uniformly, rejecting graph edges by binary search over the
//! CSR's sorted adjacency rows; after a bounded number of rejected
//! draws it falls back to a deterministic sweep, so sampling terminates
//! whenever the anchor has any non-neighbor at all.

use super::{mix_seed, SeedBatcher};
use crate::graph::GraphStore;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Stream-seed domain tag for the edge split's shuffle.
const SPLIT_STREAM_TAG: u64 = 0xED6E_5;
/// Stream-seed domain tag for the per-epoch positive-edge order.
const ORDER_STREAM_TAG: u64 = 0xE0_DA7;
/// Stream-seed domain tag for negative draws.
const NEG_STREAM_TAG: u64 = 0x6E6_A7;
/// Rejection draws per anchor before the deterministic sweep kicks in.
const NEG_REJECTION_TRIES: usize = 64;

/// A held-out edge split: train/val/test partitions of the graph's
/// undirected edge set (each edge stored once, `u < v`).
///
/// The split holds edges out of the *loss*, not out of message passing:
/// the graph every method trains on is identical, so a showdown between
/// embedding methods compares like with like (and the sampler, compose
/// engine and serving path stay untouched).
#[derive(Debug, Clone)]
pub struct EdgeSplit {
    /// Training positives (the [`EdgeBatcher`]'s edge pool).
    pub train: Vec<(u32, u32)>,
    /// Validation positives.
    pub val: Vec<(u32, u32)>,
    /// Test positives.
    pub test: Vec<(u32, u32)>,
}

impl EdgeSplit {
    /// Partition `graph`'s undirected edges into train/val/test by a
    /// Fisher–Yates shuffle keyed by `seed` (val takes the first
    /// `val_frac` of the shuffled order, test the next `test_frac`,
    /// train the rest). Pure in `(graph, fractions, seed)` — and the
    /// CSR row order is identical across storage backends, so so is
    /// the split.
    pub fn build<G: GraphStore + ?Sized>(
        graph: &G,
        val_frac: f64,
        test_frac: f64,
        seed: u64,
    ) -> Self {
        assert!(val_frac >= 0.0 && test_frac >= 0.0 && val_frac + test_frac < 1.0);
        let mut edges: Vec<(u32, u32)> = Vec::with_capacity(graph.num_edges());
        let mut adj = Vec::new();
        for u in 0..graph.num_nodes() as u32 {
            graph.neighbors_into(u, &mut adj);
            for &v in &adj {
                if u < v {
                    edges.push((u, v));
                }
            }
        }
        let mut rng = Rng::seed_from_u64(mix_seed(&[seed, SPLIT_STREAM_TAG]));
        rng.shuffle(&mut edges);
        let m = edges.len();
        let nv = (m as f64 * val_frac).round() as usize;
        let nt = (m as f64 * test_frac).round() as usize;
        let val = edges[..nv].to_vec();
        let test = edges[nv..nv + nt].to_vec();
        let train = edges[nv + nt..].to_vec();
        EdgeSplit { train, val, test }
    }

    /// Total edges across all three folds.
    pub fn num_edges(&self) -> usize {
        self.train.len() + self.val.len() + self.test.len()
    }
}

/// One link-prediction minibatch: positives, their sampled negatives,
/// and the unique-endpoint seed set the GNN composes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EdgeBatch {
    /// Positive edges (global node ids).
    pub pos: Vec<(u32, u32)>,
    /// Sampled negatives, `neg_per_pos` per positive in positive order.
    pub neg: Vec<(u32, u32)>,
    /// Unique endpoints of `pos ∪ neg`, first-occurrence order — the
    /// seed list handed to the neighbor sampler (distinct by
    /// construction, as [`NeighborSampler`](super::NeighborSampler)
    /// requires).
    pub seeds: Vec<u32>,
    /// `pos` re-indexed into `seeds` (local row pairs).
    pub pos_local: Vec<(u32, u32)>,
    /// `neg` re-indexed into `seeds`.
    pub neg_local: Vec<(u32, u32)>,
}

impl EdgeBatch {
    /// Total scored edges (positives + negatives).
    pub fn num_edges(&self) -> usize {
        self.pos.len() + self.neg.len()
    }

    fn from_edges(pos: Vec<(u32, u32)>, neg: Vec<(u32, u32)>) -> Self {
        let mut local: HashMap<u32, u32> = HashMap::with_capacity(2 * (pos.len() + neg.len()));
        let mut seeds: Vec<u32> = Vec::new();
        let mut localize = |e: &(u32, u32)| -> (u32, u32) {
            let mut row = |w: u32| -> u32 {
                *local.entry(w).or_insert_with(|| {
                    seeds.push(w);
                    seeds.len() as u32 - 1
                })
            };
            (row(e.0), row(e.1))
        };
        let pos_local: Vec<(u32, u32)> = pos.iter().map(&mut localize).collect();
        let neg_local: Vec<(u32, u32)> = neg.iter().map(&mut localize).collect();
        EdgeBatch { pos, neg, seeds, pos_local, neg_local }
    }
}

/// Sample one negative for the positive `(u, v)`: keep an anchor
/// endpoint (tail first, head as fallback) and draw the other uniformly
/// until it is neither the anchor nor one of its graph neighbors. After
/// [`NEG_REJECTION_TRIES`] rejected draws the search falls back to a
/// deterministic wrap-around sweep from a random start, so it
/// terminates whenever the anchor has any non-neighbor.
///
/// The returned pair is normalized `min ≤ max`; by construction it is
/// never an edge of `graph`. Membership tests go through
/// [`GraphStore::has_edge`] — a binary search over the anchor's sorted
/// row in every backend — and the RNG stream consumes one draw per
/// rejection either way, so the draw sequence (hence the negative) is
/// backend-independent.
pub fn sample_negative<G: GraphStore + ?Sized>(
    graph: &G,
    rng: &mut Rng,
    (u, v): (u32, u32),
) -> (u32, u32) {
    let n = graph.num_nodes() as u32;
    for anchor in [u, v] {
        for _ in 0..NEG_REJECTION_TRIES {
            let w = rng.gen_range(n as usize) as u32;
            if w != anchor && !graph.has_edge(anchor, w) {
                return (anchor.min(w), anchor.max(w));
            }
        }
        let start = rng.gen_range(n as usize) as u32;
        for off in 0..n {
            let w = (start + off) % n;
            if w != anchor && !graph.has_edge(anchor, w) {
                return (anchor.min(w), anchor.max(w));
            }
        }
    }
    panic!("cannot sample a negative edge: graph is complete");
}

/// Splits a fixed positive-edge pool (normally [`EdgeSplit::train`])
/// into per-epoch link-prediction batches, attaching `neg_per_pos`
/// seeded negatives per positive.
///
/// Like [`SeedBatcher`], every batch is a pure function of
/// `(stream seed, epoch, batch)` — no hidden iterator state — so the
/// prefetch thread's seed lists and the trainer's edge lists are
/// recomputed independently yet always agree.
#[derive(Debug, Clone)]
pub struct EdgeBatcher {
    edges: Vec<(u32, u32)>,
    batch_size: usize,
    shuffle: bool,
    neg_per_pos: usize,
    seed: u64,
}

impl EdgeBatcher {
    /// Batcher over `edges` with `batch_size` positives per batch.
    /// `seed` keys the epoch shuffles and all negative draws.
    pub fn new(
        edges: &[(u32, u32)],
        batch_size: usize,
        shuffle: bool,
        neg_per_pos: usize,
        seed: u64,
    ) -> Self {
        assert!(batch_size >= 1, "batch_size must be >= 1");
        assert!(!edges.is_empty(), "no positive edges to batch");
        assert!(neg_per_pos >= 1, "at least one negative per positive required");
        EdgeBatcher { edges: edges.to_vec(), batch_size, shuffle, neg_per_pos, seed }
    }

    /// Total positive edges per epoch.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Negatives sampled per positive.
    pub fn neg_per_pos(&self) -> usize {
        self.neg_per_pos
    }

    /// Batches per epoch (last batch may be ragged).
    pub fn num_batches(&self) -> usize {
        self.edges.len().div_ceil(self.batch_size)
    }

    /// One epoch's positive-edge order: the pool order with `shuffle`
    /// off, a Fisher–Yates shuffle keyed by `(seed, epoch)` with it on.
    fn epoch_order(&self, epoch: usize) -> Vec<(u32, u32)> {
        let mut edges = self.edges.clone();
        if self.shuffle {
            let mut rng =
                Rng::seed_from_u64(mix_seed(&[self.seed, epoch as u64, ORDER_STREAM_TAG]));
            rng.shuffle(&mut edges);
        }
        edges
    }

    /// Materialize batch `(epoch, bi)`: its positives, its negatives
    /// (one RNG stream per `(seed, epoch, batch, edge index)` draw,
    /// rejected against `graph`) and the localized seed set.
    pub fn batch<G: GraphStore + ?Sized>(&self, graph: &G, epoch: usize, bi: usize) -> EdgeBatch {
        let ordered = self.epoch_order(epoch);
        let lo = bi * self.batch_size;
        let hi = (lo + self.batch_size).min(ordered.len());
        assert!(lo < hi, "batch index {bi} out of range (epoch has {} batches)", self.num_batches());
        let pos = ordered[lo..hi].to_vec();
        let mut neg = Vec::with_capacity(pos.len() * self.neg_per_pos);
        for (i, &e) in pos.iter().enumerate() {
            for t in 0..self.neg_per_pos {
                let draw = (i * self.neg_per_pos + t) as u64;
                let mut rng = Rng::seed_from_u64(mix_seed(&[
                    self.seed,
                    epoch as u64,
                    bi as u64,
                    draw,
                    NEG_STREAM_TAG,
                ]));
                neg.push(sample_negative(graph, &mut rng, e));
            }
        }
        EdgeBatch::from_edges(pos, neg)
    }

    /// The seed lists of one epoch's batches — what the prefetch thread
    /// hands the neighbor sampler (bit-identical to the seed sets the
    /// trainer recomputes via [`batch`](EdgeBatcher::batch)).
    pub fn epoch_seed_batches<G: GraphStore + ?Sized>(
        &self,
        graph: &G,
        epoch: usize,
    ) -> Vec<Vec<u32>> {
        (0..self.num_batches()).map(|bi| self.batch(graph, epoch, bi).seeds).collect()
    }
}

/// What drives the epoch/batch schedule: labeled seed nodes (node
/// classification) or held-out positive edges (link prediction). Both
/// trainer paths and the [`BlockPrefetcher`](super::BlockPrefetcher)
/// consume this one interface, so prefetching, checkpoint cursors and
/// the pipelined engine work unchanged under either objective.
#[derive(Debug, Clone)]
pub enum SeedSource {
    /// Node-classification batches over a train split.
    Nodes(SeedBatcher),
    /// Link-prediction batches over a train edge pool.
    Edges(EdgeBatcher),
}

impl SeedSource {
    /// Batches per epoch.
    pub fn num_batches(&self) -> usize {
        match self {
            SeedSource::Nodes(b) => b.num_batches(),
            SeedSource::Edges(b) => b.num_batches(),
        }
    }

    /// Schedule units per epoch: seed nodes (node classification) or
    /// positive edges (link prediction).
    pub fn num_seeds(&self) -> usize {
        match self {
            SeedSource::Nodes(b) => b.num_seeds(),
            SeedSource::Edges(b) => b.num_edges(),
        }
    }

    /// One epoch's per-batch seed lists (each list holds distinct node
    /// ids, as the neighbor sampler requires). The graph is only
    /// consulted by the edge source (negative-draw rejection).
    pub fn epoch_batches<G: GraphStore + ?Sized>(&self, graph: &G, epoch: usize) -> Vec<Vec<u32>> {
        match self {
            SeedSource::Nodes(b) => b.epoch_batches(epoch),
            SeedSource::Edges(b) => b.epoch_seed_batches(graph, epoch),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CsrGraph, GraphBuilder};

    fn ring(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 {
            b.add_edge(u, (u + 1) % n as u32, 1.0);
        }
        b.build()
    }

    #[test]
    fn split_partitions_the_edge_set() {
        let g = ring(50); // 50 undirected edges
        let s = EdgeSplit::build(&g, 0.1, 0.2, 7);
        assert_eq!(s.num_edges(), 50);
        assert_eq!(s.val.len(), 5);
        assert_eq!(s.test.len(), 10);
        assert_eq!(s.train.len(), 35);
        let mut all: Vec<(u32, u32)> = Vec::new();
        all.extend(&s.train);
        all.extend(&s.val);
        all.extend(&s.test);
        for &(u, v) in &all {
            assert!(u < v, "edges stored once, low endpoint first");
            assert!(g.neighbors(u).binary_search(&v).is_ok(), "({u},{v}) is a real edge");
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 50, "folds are disjoint and cover every edge");
        // deterministic per seed, different across seeds
        let s2 = EdgeSplit::build(&g, 0.1, 0.2, 7);
        assert_eq!(s.train, s2.train);
        let s3 = EdgeSplit::build(&g, 0.1, 0.2, 8);
        assert_ne!(s.train, s3.train);
    }

    #[test]
    fn negatives_are_never_true_edges() {
        let g = ring(20);
        let mut rng = Rng::seed_from_u64(3);
        for i in 0..200u32 {
            let u = i % 20;
            let pos = (u, (u + 1) % 20);
            let (a, b) = sample_negative(&g, &mut rng, pos);
            assert!(a <= b);
            assert_ne!(a, b);
            assert!(g.neighbors(a).binary_search(&b).is_err(), "({a},{b}) is a true edge");
        }
    }

    #[test]
    fn negative_sweep_fallback_terminates_on_dense_anchors() {
        // K4 minus one edge: node 0 is adjacent to 1 and 2 but not 3,
        // so the only valid negative anchored anywhere is (0, 3).
        let mut b = GraphBuilder::new(4);
        for (u, v) in [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)] {
            b.add_edge(u, v, 1.0);
        }
        let g = b.build();
        let mut rng = Rng::seed_from_u64(0);
        for _ in 0..50 {
            assert_eq!(sample_negative(&g, &mut rng, (0, 1)), (0, 3));
        }
    }

    #[test]
    fn batches_are_pure_functions_of_their_coordinates() {
        let g = ring(40);
        let s = EdgeSplit::build(&g, 0.1, 0.1, 1);
        let b = EdgeBatcher::new(&s.train, 8, true, 2, 99);
        assert_eq!(b.num_batches(), s.train.len().div_ceil(8));
        let x = b.batch(&g, 3, 1);
        let y = b.batch(&g, 3, 1);
        assert_eq!(x, y, "same coordinates, same batch");
        assert_eq!(x.neg.len(), x.pos.len() * 2);
        for &(u, v) in &x.neg {
            assert!(g.neighbors(u).binary_search(&v).is_err());
        }
        // one epoch's batches partition the pool; epochs reshuffle it
        let epoch_pos = |e: usize| -> Vec<(u32, u32)> {
            (0..b.num_batches()).flat_map(|bi| b.batch(&g, e, bi).pos).collect()
        };
        let (e3, e4) = (epoch_pos(3), epoch_pos(4));
        let mut sorted = e3.clone();
        sorted.sort_unstable();
        let mut pool = s.train.clone();
        pool.sort_unstable();
        assert_eq!(sorted, pool, "epoch batches partition the train pool");
        assert_ne!(e3, e4, "epochs reshuffle");
    }

    #[test]
    fn seed_lists_localize_consistently() {
        let g = ring(30);
        let s = EdgeSplit::build(&g, 0.0, 0.0, 5);
        let b = EdgeBatcher::new(&s.train, 6, true, 1, 11);
        let eb = b.batch(&g, 0, 0);
        // seeds are distinct and local pairs map back to global edges
        let mut sorted = eb.seeds.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), eb.seeds.len(), "seed list has duplicates");
        for (&(u, v), &(a, bb)) in eb.pos.iter().zip(&eb.pos_local) {
            assert_eq!(eb.seeds[a as usize], u);
            assert_eq!(eb.seeds[bb as usize], v);
        }
        for (&(u, v), &(a, bb)) in eb.neg.iter().zip(&eb.neg_local) {
            assert_eq!(eb.seeds[a as usize], u);
            assert_eq!(eb.seeds[bb as usize], v);
        }
        // the prefetcher's seed lists match the trainer's recomputation
        let lists = b.epoch_seed_batches(&g, 0);
        assert_eq!(lists[0], eb.seeds);
        assert_eq!(lists.len(), b.num_batches());
    }

    #[test]
    fn no_shuffle_preserves_pool_order() {
        let g = ring(24);
        let s = EdgeSplit::build(&g, 0.0, 0.0, 2);
        let b = EdgeBatcher::new(&s.train, 5, false, 1, 0);
        let e0 = b.batch(&g, 0, 0);
        let e7 = b.batch(&g, 7, 0);
        assert_eq!(e0.pos, e7.pos, "no shuffle: every epoch walks the pool order");
        assert_eq!(e0.pos[..], s.train[..5]);
    }
}
