//! Seed-node batching: deterministic per-epoch shuffling and chunking of
//! a train split.

use super::mix_seed;
use crate::util::rng::Rng;

/// Splits a fixed seed-node set (normally `Dataset::splits.train`) into
/// per-epoch batches.
///
/// The epoch shuffle is a pure function of `(stream seed, epoch)` — no
/// hidden iterator state — so any epoch's batches can be recomputed
/// independently (and identically at any thread count), which is what
/// lets the trainer, the bench harness and the tests agree on what batch
/// `(epoch, i)` contains.
#[derive(Debug, Clone)]
pub struct SeedBatcher {
    ids: Vec<u32>,
    batch_size: usize,
    shuffle: bool,
    seed: u64,
}

impl SeedBatcher {
    /// Batcher over `seed_ids` (e.g. the train split) with the given
    /// batch size. `seed` keys the per-epoch shuffles.
    pub fn new(seed_ids: &[u32], batch_size: usize, shuffle: bool, seed: u64) -> Self {
        assert!(batch_size >= 1, "batch_size must be >= 1");
        assert!(!seed_ids.is_empty(), "no seed nodes to batch");
        SeedBatcher { ids: seed_ids.to_vec(), batch_size, shuffle, seed }
    }

    /// Total seed nodes per epoch.
    pub fn num_seeds(&self) -> usize {
        self.ids.len()
    }

    /// Batches per epoch (last batch may be ragged).
    pub fn num_batches(&self) -> usize {
        self.ids.len().div_ceil(self.batch_size)
    }

    /// The batches of one epoch. With `shuffle` off the split order is
    /// preserved exactly (the oracle-parity requirement); with it on, the
    /// order is a Fisher–Yates shuffle keyed by `(seed, epoch)`.
    pub fn epoch_batches(&self, epoch: usize) -> Vec<Vec<u32>> {
        let mut ids = self.ids.clone();
        if self.shuffle {
            let mut rng = Rng::seed_from_u64(mix_seed(&[self.seed, epoch as u64, 0xBA7C4]));
            rng.shuffle(&mut ids);
        }
        ids.chunks(self.batch_size).map(<[u32]>::to_vec).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_partition_the_seed_set() {
        let ids: Vec<u32> = (0..103).map(|i| i * 3).collect();
        let b = SeedBatcher::new(&ids, 10, true, 7);
        assert_eq!(b.num_seeds(), 103);
        assert_eq!(b.num_batches(), 11);
        let batches = b.epoch_batches(4);
        assert_eq!(batches.len(), 11);
        assert!(batches[..10].iter().all(|b| b.len() == 10));
        assert_eq!(batches[10].len(), 3);
        let mut all: Vec<u32> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, { let mut s = ids.clone(); s.sort_unstable(); s });
    }

    #[test]
    fn no_shuffle_preserves_split_order() {
        let ids: Vec<u32> = vec![9, 2, 5, 1, 7];
        let b = SeedBatcher::new(&ids, 2, false, 1);
        for epoch in 0..3 {
            assert_eq!(b.epoch_batches(epoch).concat(), ids);
        }
    }

    #[test]
    fn shuffle_is_deterministic_per_epoch_and_varies_across_epochs() {
        let ids: Vec<u32> = (0..200).collect();
        let b = SeedBatcher::new(&ids, 32, true, 42);
        assert_eq!(b.epoch_batches(3), b.epoch_batches(3));
        assert_ne!(b.epoch_batches(0).concat(), b.epoch_batches(1).concat());
        // a different stream seed reorders differently
        let b2 = SeedBatcher::new(&ids, 32, true, 43);
        assert_ne!(b.epoch_batches(0).concat(), b2.epoch_batches(0).concat());
    }

    #[test]
    #[should_panic(expected = "batch_size")]
    fn zero_batch_size_rejected() {
        SeedBatcher::new(&[1], 0, false, 0);
    }
}
