//! Bounded, double-buffered block prefetching: sample batch *b + 1*
//! while the trainer steps batch *b*.
//!
//! The prefetcher runs a dedicated sampler thread (a plain scoped OS
//! thread — rayon's pool stays free for the compute phases) that walks
//! the epoch/batch schedule in order and pushes each [`MultiHopBlock`]
//! through a fixed-capacity channel. Because every draw is keyed per
//! `(hop stream seed, epoch, batch, node)`
//! ([`mix_seed`](super::mix_seed)), sampling ahead of the trainer
//! **cannot** change what any block contains; because the channel is
//! ordered and single-producer / single-consumer, the trainer receives
//! blocks in exactly the serial loop's batch order. The only observable
//! difference from sampling inline is wall time. The stream can start
//! at any `(epoch, batch)` cursor, which is how a resumed run picks up
//! mid-epoch without resampling the consumed prefix.
//!
//! Failures on the sampler thread (a panic, or an injected
//! `prefetch.handover` fault) do **not** wait for the enclosing scope's
//! join: they are caught, converted to a typed [`PrefetchError`] and
//! delivered through the channel, so the trainer learns the exact
//! `(epoch, batch)` that failed on its very next [`recv`] — in time to
//! checkpoint at the last clean batch boundary and abort.
//!
//! Blocks the trainer has finished stepping flow back through an
//! unbounded return channel and are reused via
//! [`NeighborSampler::sample_multi_into`], so steady-state sampling is
//! allocation-free: after the first `depth + in-flight` blocks, every
//! batch recycles an earlier batch's per-hop vectors.
//!
//! [`recv`]: BlockPrefetcher::recv

use super::{Fanouts, MultiHopBlock, NeighborSampler, SeedSource};
use crate::graph::GraphStore;
use crate::util::fault;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::thread::Scope;

/// Why a prefetched block stream ended before delivering every batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PrefetchError {
    /// The sampler thread failed (panicked, or hit an injected fault)
    /// while producing the named batch. The stream ends here; blocks
    /// for every earlier batch were delivered intact, so the trainer
    /// sits at a clean batch boundary and can checkpoint before
    /// propagating the error.
    Batch {
        /// Epoch of the batch that failed to sample.
        epoch: usize,
        /// Batch index (within the epoch) that failed to sample.
        batch: usize,
        /// The panic payload or injected error, as text.
        detail: String,
    },
    /// The sampler thread went away without reporting a failure
    /// (only seen when receiving past the end of the schedule).
    Disconnected,
}

impl std::fmt::Display for PrefetchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PrefetchError::Batch { epoch, batch, detail } => {
                write!(f, "prefetch failed sampling epoch {epoch} batch {batch}: {detail}")
            }
            PrefetchError::Disconnected => write!(f, "prefetch stream disconnected"),
        }
    }
}

impl std::error::Error for PrefetchError {}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "sampler thread panicked".to_string()
    }
}

/// Receiving end of a prefetched block stream, plus the recycle pool.
///
/// Create with [`BlockPrefetcher::spawn`] inside a
/// [`std::thread::scope`]; the sampler thread is joined when the scope
/// ends (it exits on its own once all blocks are delivered, after
/// reporting a failure, or as soon as the receiver is dropped mid-run).
pub struct BlockPrefetcher {
    rx: Receiver<Result<MultiHopBlock, PrefetchError>>,
    pool: Sender<MultiHopBlock>,
}

impl BlockPrefetcher {
    /// Spawn the sampler thread on `scope`, streaming every batch from
    /// the `start` cursor (inclusive, `(epoch, batch)`) to the end of
    /// epoch `epochs - 1` in deterministic `(epoch, batch)` order. A
    /// fresh run passes `(0, 0)`; a resumed run passes the restored
    /// cursor and receives exactly the not-yet-consumed suffix.
    ///
    /// `depth` bounds how many sampled blocks may sit ready ahead of
    /// the trainer (clamped to ≥ 1; 2 is classic double buffering).
    /// `stream_seed` must be the same sampler stream seed a serial run
    /// would use — the blocks are then bit-identical to inline
    /// sampling, at any hop count and from any start cursor.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn<'scope, 'env>(
        scope: &'scope Scope<'scope, 'env>,
        graph: &'env dyn GraphStore,
        source: SeedSource,
        fanouts: Fanouts,
        stream_seed: u64,
        epochs: usize,
        start: (usize, usize),
        depth: usize,
    ) -> BlockPrefetcher {
        let (tx, rx) = sync_channel::<Result<MultiHopBlock, PrefetchError>>(depth.max(1));
        let (pool_tx, pool_rx) = channel::<MultiHopBlock>();
        scope.spawn(move || {
            let mut sampler = NeighborSampler::multi_hop(graph, &fanouts, stream_seed);
            for epoch in start.0..epochs {
                let batches = source.epoch_batches(graph, epoch);
                let skip = if epoch == start.0 { start.1 } else { 0 };
                for (bi, seeds) in batches.iter().enumerate().skip(skip) {
                    // recycle a stepped block's buffers when one is back
                    let mut block = pool_rx.try_recv().unwrap_or_default();
                    let sampled = catch_unwind(AssertUnwindSafe(|| {
                        fault::hit("prefetch.handover")?;
                        sampler.sample_multi_into(seeds, epoch, bi, &mut block);
                        Ok::<(), std::io::Error>(())
                    }));
                    let detail = match sampled {
                        Ok(Ok(())) => {
                            if tx.send(Ok(block)).is_err() {
                                // trainer dropped the stream (error
                                // mid-run): stop sampling and let the
                                // scope join us
                                return;
                            }
                            continue;
                        }
                        Ok(Err(e)) => e.to_string(),
                        Err(payload) => panic_text(payload.as_ref()),
                    };
                    let _ = tx.send(Err(PrefetchError::Batch { epoch, batch: bi, detail }));
                    return;
                }
            }
        });
        BlockPrefetcher { rx, pool: pool_tx }
    }

    /// Receive the next block, in `(epoch, batch)` order.
    ///
    /// A sampler-side failure surfaces here as
    /// [`PrefetchError::Batch`] naming the batch that failed — on the
    /// next call, not at scope join. Receiving after the schedule is
    /// exhausted (or after a failure was already reported) returns
    /// [`PrefetchError::Disconnected`].
    pub fn recv(&self) -> Result<MultiHopBlock, PrefetchError> {
        match self.rx.recv() {
            Ok(next) => next,
            Err(_) => Err(PrefetchError::Disconnected),
        }
    }

    /// Hand a stepped block's buffers back for reuse. Never fails: the
    /// prefetcher owns both channel ends' lifetimes within one scope,
    /// and a sampler thread that already exited simply ignores the pool.
    pub fn recycle(&self, block: MultiHopBlock) {
        let _ = self.pool.send(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CsrGraph, GraphBuilder};
    use crate::sampler::{Fanout, SeedBatcher};

    fn ring(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 {
            b.add_edge(u, (u + 1) % n as u32, 1.0);
        }
        b.build()
    }

    #[test]
    fn prefetched_stream_matches_inline_sampling_in_order() {
        let g = ring(64);
        let ids: Vec<u32> = (0..64).collect();
        let batcher = SeedBatcher::new(&ids, 10, true, 77);
        let (epochs, seed) = (3, 5u64);
        for fanouts in [Fanouts::single(Fanout::Max(1)), Fanouts::parse("2,1").unwrap()] {
            // inline reference: the serial trainer's sampling loop
            let mut inline = Vec::new();
            let mut sampler = NeighborSampler::multi_hop(&g, &fanouts, seed);
            for epoch in 0..epochs {
                for (bi, seeds) in batcher.epoch_batches(epoch).iter().enumerate() {
                    inline.push(sampler.sample_multi(seeds, epoch, bi));
                }
            }
            for depth in [1usize, 2, 7] {
                let mut streamed = Vec::new();
                let b = SeedSource::Nodes(batcher.clone());
                let f = fanouts.clone();
                std::thread::scope(|scope| {
                    let pf = BlockPrefetcher::spawn(scope, &g, b, f, seed, epochs, (0, 0), depth);
                    for _ in 0..inline.len() {
                        let block = pf.recv().expect("sampler thread alive");
                        streamed.push(block.clone());
                        pf.recycle(block); // exercise the buffer pool
                    }
                });
                assert_eq!(inline, streamed, "depth {depth}, fanouts {fanouts}");
            }
        }
    }

    #[test]
    fn streaming_from_a_cursor_delivers_exactly_the_suffix() {
        let g = ring(48);
        let ids: Vec<u32> = (0..48).collect();
        let batcher = SeedBatcher::new(&ids, 10, true, 9);
        let (epochs, seed) = (3, 21u64);
        let fanouts = Fanouts::parse("2,1").unwrap();
        let per_epoch = batcher.num_batches();

        let mut inline = Vec::new();
        let mut sampler = NeighborSampler::multi_hop(&g, &fanouts, seed);
        for epoch in 0..epochs {
            for (bi, seeds) in batcher.epoch_batches(epoch).iter().enumerate() {
                inline.push(sampler.sample_multi(seeds, epoch, bi));
            }
        }

        for start in [(0usize, 0usize), (0, 3), (1, 0), (1, 2), (2, per_epoch - 1)] {
            let expect = &inline[start.0 * per_epoch + start.1..];
            let mut streamed = Vec::new();
            let b = SeedSource::Nodes(batcher.clone());
            let f = fanouts.clone();
            std::thread::scope(|scope| {
                let pf = BlockPrefetcher::spawn(scope, &g, b, f, seed, epochs, start, 2);
                for _ in 0..expect.len() {
                    streamed.push(pf.recv().expect("sampler thread alive"));
                }
                assert_eq!(pf.recv(), Err(PrefetchError::Disconnected), "stream must end");
            });
            assert_eq!(expect, &streamed[..], "start cursor {start:?}");
        }
    }

    #[test]
    fn a_sampler_fault_surfaces_as_a_typed_error_on_recv() {
        let _guard = fault::test_guard();
        fault::reset();
        fault::arm("prefetch.handover=3").unwrap();
        let g = ring(32);
        let ids: Vec<u32> = (0..32).collect();
        let batcher = SeedSource::Nodes(SeedBatcher::new(&ids, 8, false, 0)); // 4 batches/epoch
        std::thread::scope(|scope| {
            let pf = BlockPrefetcher::spawn(scope, &g, batcher, Fanouts::all(2), 1, 2, (0, 0), 2);
            assert!(pf.recv().is_ok(), "batch (0,0) precedes the fault");
            assert!(pf.recv().is_ok(), "batch (0,1) precedes the fault");
            match pf.recv().unwrap_err() {
                PrefetchError::Batch { epoch, batch, detail } => {
                    assert_eq!((epoch, batch), (0, 2), "error names the failed batch");
                    assert!(detail.contains("injected fault"), "detail: {detail}");
                }
                other => panic!("expected a Batch error, got {other}"),
            }
            // after a failure the stream is over, not wedged
            assert_eq!(pf.recv(), Err(PrefetchError::Disconnected));
        });
        fault::reset();
    }

    #[test]
    fn dropping_the_stream_mid_run_stops_the_sampler_cleanly() {
        let g = ring(32);
        let ids: Vec<u32> = (0..32).collect();
        let batcher = SeedSource::Nodes(SeedBatcher::new(&ids, 4, false, 0));
        std::thread::scope(|scope| {
            let pf = BlockPrefetcher::spawn(scope, &g, batcher, Fanouts::all(2), 1, 50, (0, 0), 2);
            let first = pf.recv().expect("first block");
            assert_eq!(first.num_seeds(), 4);
            drop(pf); // scope must still join without hanging
        });
    }
}
