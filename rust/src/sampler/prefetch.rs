//! Bounded, double-buffered block prefetching: sample batch *b + 1*
//! while the trainer steps batch *b*.
//!
//! The prefetcher runs a dedicated sampler thread (a plain scoped OS
//! thread — rayon's pool stays free for the compute phases) that walks
//! the epoch/batch schedule in order and pushes each [`MultiHopBlock`]
//! through a fixed-capacity channel. Because every draw is keyed per
//! `(hop stream seed, epoch, batch, node)`
//! ([`mix_seed`](super::mix_seed)), sampling ahead of the trainer
//! **cannot** change what any block contains; because the channel is
//! ordered and single-producer / single-consumer, the trainer receives
//! blocks in exactly the serial loop's batch order. The only observable
//! difference from sampling inline is wall time.
//!
//! Blocks the trainer has finished stepping flow back through an
//! unbounded return channel and are reused via
//! [`NeighborSampler::sample_multi_into`], so steady-state sampling is
//! allocation-free: after the first `depth + in-flight` blocks, every
//! batch recycles an earlier batch's per-hop vectors.

use super::{Fanouts, MultiHopBlock, NeighborSampler, SeedBatcher};
use crate::graph::CsrGraph;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender};
use std::thread::Scope;

/// Receiving end of a prefetched block stream, plus the recycle pool.
///
/// Create with [`BlockPrefetcher::spawn`] inside a
/// [`std::thread::scope`]; the sampler thread is joined when the scope
/// ends (it exits on its own once all blocks are delivered, or as soon
/// as the receiver is dropped mid-run).
pub struct BlockPrefetcher {
    rx: Receiver<MultiHopBlock>,
    pool: Sender<MultiHopBlock>,
}

impl BlockPrefetcher {
    /// Spawn the sampler thread on `scope`, streaming every batch of
    /// epochs `0..epochs` in deterministic `(epoch, batch)` order.
    ///
    /// `depth` bounds how many sampled blocks may sit ready ahead of
    /// the trainer (clamped to ≥ 1; 2 is classic double buffering).
    /// `stream_seed` must be the same sampler stream seed a serial run
    /// would use — the blocks are then bit-identical to inline
    /// sampling, at any hop count.
    pub fn spawn<'scope, 'env>(
        scope: &'scope Scope<'scope, 'env>,
        graph: &'env CsrGraph,
        batcher: SeedBatcher,
        fanouts: Fanouts,
        stream_seed: u64,
        epochs: usize,
        depth: usize,
    ) -> BlockPrefetcher {
        let (tx, rx) = sync_channel::<MultiHopBlock>(depth.max(1));
        let (pool_tx, pool_rx) = channel::<MultiHopBlock>();
        scope.spawn(move || {
            let mut sampler = NeighborSampler::multi_hop(graph, &fanouts, stream_seed);
            for epoch in 0..epochs {
                let batches = batcher.epoch_batches(epoch);
                for (bi, seeds) in batches.iter().enumerate() {
                    // recycle a stepped block's buffers when one is back
                    let mut block = pool_rx.try_recv().unwrap_or_default();
                    sampler.sample_multi_into(seeds, epoch, bi, &mut block);
                    if tx.send(block).is_err() {
                        // trainer dropped the stream (error mid-run):
                        // stop sampling and let the scope join us
                        return;
                    }
                }
            }
        });
        BlockPrefetcher { rx, pool: pool_tx }
    }

    /// Receive the next block, in `(epoch, batch)` order. `Err` only if
    /// the sampler thread stopped early (it never does on its own — a
    /// panic over there surfaces when the enclosing scope joins).
    pub fn recv(&self) -> Result<MultiHopBlock, std::sync::mpsc::RecvError> {
        self.rx.recv()
    }

    /// Hand a stepped block's buffers back for reuse. Never fails: the
    /// prefetcher owns both channel ends' lifetimes within one scope,
    /// and a sampler thread that already exited simply ignores the pool.
    pub fn recycle(&self, block: MultiHopBlock) {
        let _ = self.pool.send(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::sampler::Fanout;

    fn ring(n: usize) -> CsrGraph {
        let mut b = GraphBuilder::new(n);
        for u in 0..n as u32 {
            b.add_edge(u, (u + 1) % n as u32, 1.0);
        }
        b.build()
    }

    #[test]
    fn prefetched_stream_matches_inline_sampling_in_order() {
        let g = ring(64);
        let ids: Vec<u32> = (0..64).collect();
        let batcher = SeedBatcher::new(&ids, 10, true, 77);
        let (epochs, seed) = (3, 5u64);
        for fanouts in [Fanouts::single(Fanout::Max(1)), Fanouts::parse("2,1").unwrap()] {
            // inline reference: the serial trainer's sampling loop
            let mut inline = Vec::new();
            let mut sampler = NeighborSampler::multi_hop(&g, &fanouts, seed);
            for epoch in 0..epochs {
                for (bi, seeds) in batcher.epoch_batches(epoch).iter().enumerate() {
                    inline.push(sampler.sample_multi(seeds, epoch, bi));
                }
            }
            for depth in [1usize, 2, 7] {
                let mut streamed = Vec::new();
                let b = batcher.clone();
                let f = fanouts.clone();
                std::thread::scope(|scope| {
                    let pf = BlockPrefetcher::spawn(scope, &g, b, f, seed, epochs, depth);
                    for _ in 0..inline.len() {
                        let block = pf.recv().expect("sampler thread alive");
                        streamed.push(block.clone());
                        pf.recycle(block); // exercise the buffer pool
                    }
                });
                assert_eq!(inline, streamed, "depth {depth}, fanouts {fanouts}");
            }
        }
    }

    #[test]
    fn dropping_the_stream_mid_run_stops_the_sampler_cleanly() {
        let g = ring(32);
        let ids: Vec<u32> = (0..32).collect();
        let batcher = SeedBatcher::new(&ids, 4, false, 0);
        std::thread::scope(|scope| {
            let pf = BlockPrefetcher::spawn(scope, &g, batcher, Fanouts::all(2), 1, 50, 2);
            let first = pf.recv().expect("first block");
            assert_eq!(first.num_seeds(), 4);
            drop(pf); // scope must still join without hanging
        });
    }
}
