//! RAII temporary directories for tests (tempfile is unavailable offline).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a unique directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let unique = format!(
            "{prefix}-{}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .subsec_nanos()
        );
        let path = std::env::temp_dir().join(unique);
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let t = TempDir::new("poshashemb-test").unwrap();
            p = t.path().to_path_buf();
            assert!(p.is_dir());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("x").unwrap();
        let b = TempDir::new("x").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
