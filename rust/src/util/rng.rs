//! Deterministic RNG: xoshiro256++ seeded via SplitMix64.
//!
//! Replaces `rand`/`rand_chacha` in the offline build. Statistical quality
//! is ample for graph generation, matching order shuffles and random
//! partitions; determinism across platforms is guaranteed (pure integer
//! arithmetic, no platform entropy).

/// xoshiro256++ PRNG (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's method, bias-free for our sizes).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // 128-bit multiply rejection sampling
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p.clamp(0.0, 1.0)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (used by param init helpers).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        let mut c = Rng::seed_from_u64(2);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.gen_range(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_uniformish() {
        let mut r = Rng::seed_from_u64(4);
        let mean: f64 = (0..10_000).map(|_| r.gen_f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gen_bool_matches_probability() {
        let mut r = Rng::seed_from_u64(5);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.03);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(6);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
