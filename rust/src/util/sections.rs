//! Checksummed little-endian binary sections plus the atomic
//! directory-publish protocol — the shared substrate under both model
//! artifacts ([`crate::serve`]) and training checkpoints
//! ([`crate::coordinator`]).
//!
//! A *section* is one raw little-endian binary file described by a
//! [`SectionSpec`] (dtype, shape, byte length, FNV-1a/64 checksum) in a
//! JSON manifest. [`write_section`] fsyncs every file it writes;
//! [`read_section`] verifies length, checksum and shape before decoding
//! and names the offending section in every error, so torn writes and
//! mixed-up files are diagnosable from the message alone.
//!
//! Directories of sections are *published atomically*: write everything
//! into a temp sibling ([`temp_sibling`]), write the manifest **last**
//! (a directory without a manifest is by definition not published),
//! then [`publish_dir`] — fsync, rename over the destination, fsync the
//! parent. A reader can observe the old directory or the new one, never
//! a half-written mix. Single-file pointers (e.g. a checkpoint `LATEST`
//! marker) get the same treatment from [`atomic_write_text`].
//!
//! Write-side entry points carry a [`crate::util::fault`] site so the
//! crash-safety tests can tear a publish at any named step.

use crate::util::checksum::checksum_string;
use crate::util::fault;
use anyhow::{bail, Context, Result};
use serde::{Deserialize, Serialize};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// One binary section of an on-disk directory (model artifact or
/// training checkpoint).
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct SectionSpec {
    /// Section name (tensor/index/graph-array name).
    pub name: String,
    /// File name inside the directory.
    pub file: String,
    /// Element dtype: `"f32"`, `"f64"`, `"u32"` or `"u64"`
    /// (little-endian).
    pub dtype: String,
    /// Logical shape; the element count is the product.
    pub shape: Vec<usize>,
    /// Exact file length in bytes.
    pub bytes: usize,
    /// Tagged checksum of the file bytes (`"fnv1a64:<hex>"`).
    pub checksum: String,
}

/// Decoded (or to-be-encoded) section payload.
#[derive(Debug, Clone, PartialEq)]
pub enum SectionData {
    /// f32 elements.
    F32(Vec<f32>),
    /// f64 elements (bit-exact loss trajectories and accumulators).
    F64(Vec<f64>),
    /// u32 elements.
    U32(Vec<u32>),
    /// u64 elements.
    U64(Vec<u64>),
}

impl SectionData {
    /// The manifest dtype tag for this payload.
    pub fn dtype(&self) -> &'static str {
        match self {
            SectionData::F32(_) => "f32",
            SectionData::F64(_) => "f64",
            SectionData::U32(_) => "u32",
            SectionData::U64(_) => "u64",
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            SectionData::F32(v) => v.len(),
            SectionData::F64(v) => v.len(),
            SectionData::U32(v) => v.len(),
            SectionData::U64(v) => v.len(),
        }
    }

    /// True when the payload has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn to_le(&self) -> Vec<u8> {
        match self {
            SectionData::F32(v) => {
                let mut out = Vec::with_capacity(v.len() * 4);
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
            SectionData::F64(v) => {
                let mut out = Vec::with_capacity(v.len() * 8);
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
            SectionData::U32(v) => {
                let mut out = Vec::with_capacity(v.len() * 4);
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
            SectionData::U64(v) => {
                let mut out = Vec::with_capacity(v.len() * 8);
                for &x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
                out
            }
        }
    }
}

/// Byte width of a manifest dtype tag.
pub fn dtype_width(dtype: &str) -> Result<usize> {
    match dtype {
        "f32" | "u32" => Ok(4),
        "f64" | "u64" => Ok(8),
        other => bail!("unsupported section dtype '{other}'"),
    }
}

/// Write one section file `{name}.bin` into `dir`, fsynced, and return
/// its spec. `fault_site` is hit before anything touches disk.
pub fn write_section(
    dir: &Path,
    name: &str,
    shape: &[usize],
    data: &SectionData,
    fault_site: &str,
) -> Result<SectionSpec> {
    let elems: usize = shape.iter().product();
    if elems != data.len() {
        bail!("section '{name}' shape {shape:?} does not match its {} elements", data.len());
    }
    fault::hit(fault_site).with_context(|| format!("writing section '{name}'"))?;
    let bytes = data.to_le();
    let file = format!("{name}.bin");
    let path = dir.join(&file);
    let mut f = File::create(&path)
        .with_context(|| format!("creating section '{name}' ({})", path.display()))?;
    f.write_all(&bytes).with_context(|| format!("writing section '{name}'"))?;
    f.sync_all().with_context(|| format!("fsyncing section '{name}'"))?;
    Ok(SectionSpec {
        name: name.to_string(),
        file,
        dtype: data.dtype().to_string(),
        shape: shape.to_vec(),
        bytes: bytes.len(),
        checksum: checksum_string(&bytes),
    })
}

/// Read, verify (byte length, checksum, shape × dtype width) and decode
/// one section. Every failure names the section.
pub fn read_section(dir: &Path, sec: &SectionSpec) -> Result<SectionData> {
    let path = dir.join(&sec.file);
    let bytes = fs::read(&path)
        .with_context(|| format!("reading section '{}' ({})", sec.name, path.display()))?;
    if bytes.len() != sec.bytes {
        bail!(
            "section '{}' ({}) is {} bytes on disk, manifest says {}",
            sec.name,
            sec.file,
            bytes.len(),
            sec.bytes
        );
    }
    let got = checksum_string(&bytes);
    if got != sec.checksum {
        bail!(
            "checksum mismatch in section '{}' ({}): manifest {}, file {}",
            sec.name,
            sec.file,
            sec.checksum,
            got
        );
    }
    let elems: usize = sec.shape.iter().product();
    if elems * dtype_width(&sec.dtype)? != bytes.len() {
        bail!("section '{}' shape {:?} does not match its byte length", sec.name, sec.shape);
    }
    Ok(match sec.dtype.as_str() {
        "f32" => SectionData::F32(
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
        ),
        "f64" => SectionData::F64(
            bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                .collect(),
        ),
        "u32" => SectionData::U32(
            bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
        ),
        _ => SectionData::U64(
            bytes
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
                .collect(),
        ),
    })
}

static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique temp sibling path for `dst` (same filesystem, so the final
/// rename is atomic). The caller creates/removes it.
pub fn temp_sibling(dst: &Path) -> PathBuf {
    let file = dst.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    let unique = format!(
        ".{file}.tmp-{}-{}",
        std::process::id(),
        TEMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    );
    match dst.parent() {
        Some(p) if p != Path::new("") => p.join(unique),
        _ => PathBuf::from(unique),
    }
}

/// Fsync a directory so renames inside it are durable. Best-effort:
/// platforms that cannot open directories for syncing are skipped
/// (every Linux/macOS target this repo builds on can).
pub fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    match File::open(dir) {
        Ok(f) => f.sync_all(),
        Err(_) => Ok(()),
    }
}

/// Atomically publish the fully-written temp directory `tmp` as `dst`:
/// fsync `tmp`, move any existing `dst` aside and delete it, rename
/// `tmp` → `dst`, fsync the parent. A concurrent reader sees the old
/// directory or the new one, never a mix.
pub fn publish_dir(tmp: &Path, dst: &Path) -> Result<()> {
    fsync_dir(tmp).with_context(|| format!("fsyncing {}", tmp.display()))?;
    if dst.exists() {
        let aside = temp_sibling(dst);
        fs::rename(dst, &aside)
            .with_context(|| format!("moving old {} aside", dst.display()))?;
        fs::remove_dir_all(&aside)
            .with_context(|| format!("removing old {}", aside.display()))?;
    }
    fs::rename(tmp, dst)
        .with_context(|| format!("publishing {} -> {}", tmp.display(), dst.display()))?;
    if let Some(parent) = dst.parent() {
        fsync_dir(parent).with_context(|| format!("fsyncing {}", parent.display()))?;
    }
    Ok(())
}

/// Atomically replace `path` with `text`: write a fsynced temp sibling,
/// rename it into place, fsync the parent.
pub fn atomic_write_text(path: &Path, text: &str) -> Result<()> {
    let tmp = temp_sibling(path);
    let mut f =
        File::create(&tmp).with_context(|| format!("creating temp file {}", tmp.display()))?;
    f.write_all(text.as_bytes()).with_context(|| format!("writing {}", tmp.display()))?;
    f.sync_all().with_context(|| format!("fsyncing {}", tmp.display()))?;
    drop(f);
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    if let Some(parent) = path.parent() {
        fsync_dir(parent).with_context(|| format!("fsyncing {}", parent.display()))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn sections_round_trip_every_dtype() {
        let t = TempDir::new("sections-rt").unwrap();
        let cases = vec![
            ("a", vec![2, 3], SectionData::F32(vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX])),
            ("b", vec![2], SectionData::F64(vec![1.0 / 3.0, -0.0])),
            ("c", vec![4], SectionData::U32(vec![0, 1, u32::MAX, 7])),
            ("d", vec![1, 2], SectionData::U64(vec![u64::MAX, 42])),
        ];
        for (name, shape, data) in cases {
            let spec = write_section(t.path(), name, &shape, &data, "test.none").unwrap();
            assert_eq!(spec.dtype, data.dtype());
            assert_eq!(spec.shape, shape);
            let back = read_section(t.path(), &spec).unwrap();
            assert_eq!(back, data, "round trip of '{name}'");
        }
    }

    #[test]
    fn read_rejects_shape_element_mismatch_at_write() {
        let t = TempDir::new("sections-shape").unwrap();
        let err = write_section(t.path(), "bad", &[3], &SectionData::U32(vec![1, 2]), "test.none")
            .unwrap_err();
        assert!(format!("{err:#}").contains("'bad'"), "{err:#}");
    }

    #[test]
    fn corruption_is_detected_and_named() {
        let t = TempDir::new("sections-corrupt").unwrap();
        let data = SectionData::F32(vec![1.0; 16]);
        let spec = write_section(t.path(), "table", &[16], &data, "test.none").unwrap();

        // flip one byte
        let path = t.path().join(&spec.file);
        let mut bytes = fs::read(&path).unwrap();
        bytes[5] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        let err = read_section(t.path(), &spec).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum mismatch") && msg.contains("'table'"), "{msg}");

        // truncate
        fs::write(&path, &bytes[..10]).unwrap();
        let err = read_section(t.path(), &spec).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("10 bytes on disk") && msg.contains("'table'"), "{msg}");

        // delete
        fs::remove_file(&path).unwrap();
        let err = read_section(t.path(), &spec).unwrap_err();
        assert!(format!("{err:#}").contains("'table'"));
    }

    #[test]
    fn publish_dir_replaces_atomically() {
        let t = TempDir::new("sections-publish").unwrap();
        let dst = t.path().join("model");

        let tmp1 = temp_sibling(&dst);
        fs::create_dir_all(&tmp1).unwrap();
        fs::write(tmp1.join("v.txt"), "one").unwrap();
        publish_dir(&tmp1, &dst).unwrap();
        assert_eq!(fs::read_to_string(dst.join("v.txt")).unwrap(), "one");
        assert!(!tmp1.exists());

        // publishing over an existing dir fully replaces it
        let tmp2 = temp_sibling(&dst);
        fs::create_dir_all(&tmp2).unwrap();
        fs::write(tmp2.join("w.txt"), "two").unwrap();
        publish_dir(&tmp2, &dst).unwrap();
        assert_eq!(fs::read_to_string(dst.join("w.txt")).unwrap(), "two");
        assert!(!dst.join("v.txt").exists(), "stale section survived the swap");
    }

    #[test]
    fn atomic_text_replaces_and_leaves_no_temp() {
        let t = TempDir::new("sections-text").unwrap();
        let p = t.path().join("LATEST");
        atomic_write_text(&p, "ckpt-1\n").unwrap();
        atomic_write_text(&p, "ckpt-2\n").unwrap();
        assert_eq!(fs::read_to_string(&p).unwrap(), "ckpt-2\n");
        let leftovers: Vec<_> = fs::read_dir(t.path())
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "LATEST")
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
    }

    #[test]
    fn temp_siblings_are_unique_and_colocated() {
        let dst = Path::new("/some/dir/model");
        let a = temp_sibling(dst);
        let b = temp_sibling(dst);
        assert_ne!(a, b);
        assert_eq!(a.parent(), dst.parent());
    }
}
