//! Section checksums for on-disk model artifacts.
//!
//! The offline dependency surface has no hash crates, so artifact
//! sections are fingerprinted with FNV-1a/64 — not cryptographic, but
//! ample for the failure mode it guards (torn writes, truncation, bit
//! rot, mismatched files). Checksums are stored as `"fnv1a64:<hex>"`
//! so the algorithm can be swapped without a format break.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a/64 over `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Tagged checksum string stored in artifact manifests.
pub fn checksum_string(bytes: &[u8]) -> String {
    format!("fnv1a64:{:016x}", fnv1a64(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a/64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let base = vec![0u8; 4096];
        let h0 = fnv1a64(&base);
        for i in [0usize, 1, 100, 4095] {
            let mut flipped = base.clone();
            flipped[i] ^= 0x01;
            assert_ne!(fnv1a64(&flipped), h0, "flip at byte {i} undetected");
        }
    }

    #[test]
    fn string_form_is_tagged_hex() {
        let s = checksum_string(b"abc");
        assert!(s.starts_with("fnv1a64:"));
        assert_eq!(s.len(), "fnv1a64:".len() + 16);
    }
}
