//! Section checksums for on-disk model artifacts.
//!
//! The offline dependency surface has no hash crates, so artifact
//! sections are fingerprinted with FNV-1a/64 — not cryptographic, but
//! ample for the failure mode it guards (torn writes, truncation, bit
//! rot, mismatched files). Checksums are stored as `"fnv1a64:<hex>"`
//! so the algorithm can be swapped without a format break.

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a/64 over `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a64::new();
    h.update(bytes);
    h.finish()
}

/// Tagged checksum string stored in artifact manifests.
pub fn checksum_string(bytes: &[u8]) -> String {
    tagged(fnv1a64(bytes))
}

/// The tagged string form of an already-computed FNV-1a/64 hash.
pub fn tagged(hash: u64) -> String {
    format!("fnv1a64:{hash:016x}")
}

/// Incremental FNV-1a/64 — FNV is byte-sequential, so feeding a file in
/// chunks produces exactly the hash of the concatenated bytes. Used by
/// the streaming on-disk graph writer and the chunked section verifier
/// ([`crate::graph::DiskCsr`]), which never hold a whole section in
/// memory.
#[derive(Debug, Clone)]
pub struct Fnv1a64 {
    state: u64,
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a64 {
    /// A fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Fnv1a64 { state: FNV_OFFSET }
    }

    /// Absorb `bytes`.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.state;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.state = h;
    }

    /// The hash of everything absorbed so far.
    pub fn finish(&self) -> u64 {
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a/64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn sensitive_to_single_bit_flips() {
        let base = vec![0u8; 4096];
        let h0 = fnv1a64(&base);
        for i in [0usize, 1, 100, 4095] {
            let mut flipped = base.clone();
            flipped[i] ^= 0x01;
            assert_ne!(fnv1a64(&flipped), h0, "flip at byte {i} undetected");
        }
    }

    #[test]
    fn string_form_is_tagged_hex() {
        let s = checksum_string(b"abc");
        assert!(s.starts_with("fnv1a64:"));
        assert_eq!(s.len(), "fnv1a64:".len() + 16);
    }

    #[test]
    fn incremental_matches_one_shot_at_any_split() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 31 % 251) as u8).collect();
        let whole = fnv1a64(&data);
        for split in [0, 1, 7, 512, 1023, 1024] {
            let mut h = Fnv1a64::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split}");
        }
        assert_eq!(tagged(whole), checksum_string(&data));
    }
}
