//! Tiny in-tree property-testing driver (shrink-free complement to the
//! `proptest` dev-dependency; keeps offline builds self-contained).
//!
//! `run_cases(n, seed, |rng| ...)` executes a property over `n` random
//! inputs drawn from a seeded RNG; on failure the panic message includes
//! the case seed so the exact input is reproducible with
//! `run_single(seed, ...)`.

use super::rng::Rng;

/// Run `property` over `cases` independent seeded RNGs. Panics (with the
/// failing case seed) if the property panics.
pub fn run_cases(cases: usize, base_seed: u64, mut property: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let case_seed = base_seed.wrapping_mul(0x100_0000).wrapping_add(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::seed_from_u64(case_seed);
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Re-run one failing case by its seed.
pub fn run_single(case_seed: u64, mut property: impl FnMut(&mut Rng)) {
    let mut rng = Rng::seed_from_u64(case_seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        run_cases(50, 1, |rng| {
            let x = rng.gen_range(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            run_cases(50, 2, |rng| {
                let x = rng.gen_range(10);
                assert!(x != 7, "hit the bad value");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("seed"), "msg: {msg}");
    }
}
