//! Micro-benchmark harness for `harness = false` benches (criterion is
//! unavailable offline). Warmup, timed iterations, mean/p50/p95 and
//! throughput reporting; `--quick` env knob for CI runs.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean wall time per iteration.
    pub mean: Duration,
    /// Median wall time.
    pub p50: Duration,
    /// 95th-percentile wall time.
    pub p95: Duration,
}

impl BenchResult {
    /// One-line report, optionally with a derived throughput
    /// (`items / mean`).
    pub fn report(&self, items: Option<(u64, &str)>) -> String {
        let tp = items
            .map(|(count, unit)| {
                let per_sec = count as f64 / self.mean.as_secs_f64();
                format!("  {:>12.0} {unit}/s", per_sec)
            })
            .unwrap_or_default();
        format!(
            "{:<44} {:>10.3?} (p50 {:>10.3?}, p95 {:>10.3?}, n={}){}",
            self.name, self.mean, self.p50, self.p95, self.iters, tp
        )
    }
}

/// Process-local quick-mode override (tests use this instead of mutating
/// the environment, which is unsound under the parallel test runner).
static FORCE_QUICK: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Force quick mode on/off for this process (overrides the env knob).
pub fn set_quick(on: bool) {
    FORCE_QUICK.store(on, std::sync::atomic::Ordering::Relaxed);
}

/// Is quick mode on? (`BENCH_QUICK=1` or [`set_quick`] → fewer iterations.)
pub fn quick() -> bool {
    FORCE_QUICK.load(std::sync::atomic::Ordering::Relaxed)
        || std::env::var("BENCH_QUICK").is_ok_and(|v| v == "1")
}

/// Run `f` repeatedly and collect timing statistics.
///
/// `f` should perform one logical operation; its return value is
/// black-boxed to stop the optimizer eliding the work.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    let (warmup, min_iters, budget) = if quick() {
        (1, 3, Duration::from_millis(200))
    } else {
        (2, 10, Duration::from_secs(2))
    };
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || (start.elapsed() < budget && samples.len() < 1000) {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let p50 = samples[samples.len() / 2];
    let p95 = samples[(samples.len() * 95 / 100).min(samples.len() - 1)];
    BenchResult { name: name.to_string(), iters: samples.len(), mean, p50, p95 }
}

/// Optimizer barrier (stable-Rust std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        set_quick(true);
        let r = bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.iters >= 3);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn report_includes_throughput() {
        set_quick(true);
        let r = bench("tp", || 1u32);
        let line = r.report(Some((1000, "ops")));
        assert!(line.contains("ops/s"));
    }
}
