//! Minimal JSON: value type, serializer, recursive-descent parser.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`), experiment configs and bench reports. Covers
//! the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always an f64, as in JavaScript).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys: deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // -- builders ---------------------------------------------------------

    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Number from anything convertible to f64.
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "hi\n\"q\""}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi\n\"q\""));
        // serialize → reparse → equal
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"αβγ\"").unwrap();
        assert_eq!(v.as_str(), Some("αβγ"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn python_style_manifest_parses() {
        let src = r#"{
          "artifacts": [
            {"name": "gcn_full", "path": "gcn_full.hlo.txt",
             "inputs": [{"name": "pos_0", "shape": [5, 32], "dtype": "f32"}],
             "num_outputs": 3}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("gcn_full"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(32));
    }
}
