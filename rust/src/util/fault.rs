//! Deterministic fault injection for crash-safety tests.
//!
//! A *fault point* is a named site in the code (e.g.
//! `checkpoint.manifest`, `artifact.rename`, `prefetch.handover`) that
//! calls [`hit`] before doing its real work. Normally that is a single
//! relaxed atomic load and nothing more; when a fault is *armed* for the
//! site, the Nth call either returns an injected [`std::io::Error`]
//! (mode `err`) or aborts the process on the spot (mode `abort` —
//! indistinguishable from a SIGKILL to everything downstream, which is
//! exactly what the crash-resume harness wants).
//!
//! Faults are armed from the `POSHASH_FAULT` environment variable (read
//! once, on first use — the subprocess path used by `crash-test` and
//! CI) or programmatically via [`arm`] / [`reset`] (the in-process path
//! used by integration tests). The spec grammar is a comma-separated
//! list of
//!
//! ```text
//! site=N[:mode]      mode ∈ {err, abort}, default err
//! ```
//!
//! meaning "on the Nth time `site` is hit, fire once". Hit counting is
//! global and monotonic per site, so the same spec always fires at the
//! same point of a deterministic run — that is the whole trick: a
//! "crash at batch 7 of epoch 2" is reproducible bit-for-bit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// Environment variable holding a fault spec for subprocess runs.
pub const FAULT_ENV: &str = "POSHASH_FAULT";

/// What an armed fault point does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// Return an injected `io::Error` from [`hit`].
    Err,
    /// Abort the process (no unwinding, no destructors — a crash).
    Abort,
}

#[derive(Debug)]
struct FaultPoint {
    /// Fire on the `trigger`-th hit (1-based).
    trigger: u64,
    mode: Mode,
    /// Hits observed so far.
    hits: u64,
}

/// Fast path: true iff any fault point is currently armed.
static ARMED: AtomicBool = AtomicBool::new(false);
static ENV_INIT: Once = Once::new();

fn points() -> &'static Mutex<HashMap<String, FaultPoint>> {
    static POINTS: OnceLock<Mutex<HashMap<String, FaultPoint>>> = OnceLock::new();
    POINTS.get_or_init(|| Mutex::new(HashMap::new()))
}

fn ensure_env_loaded() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var(FAULT_ENV) {
            let spec = spec.trim();
            if !spec.is_empty() {
                if let Err(e) = arm(spec) {
                    // A malformed spec must fail loudly, not silently
                    // run without faults (the test would then "pass"
                    // by never crashing).
                    panic!("invalid {FAULT_ENV} spec '{spec}': {e}");
                }
            }
        }
    });
}

/// Arm fault points from a spec string (see module docs for grammar).
/// Specs accumulate: arming `a=1` then `b=2:abort` leaves both live.
pub fn arm(spec: &str) -> Result<(), String> {
    let mut parsed = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, rest) = part
            .split_once('=')
            .ok_or_else(|| format!("'{part}': expected site=N[:mode]"))?;
        let (count, mode) = match rest.split_once(':') {
            Some((c, m)) => (c, m),
            None => (rest, "err"),
        };
        let trigger: u64 =
            count.parse().map_err(|_| format!("'{part}': hit count '{count}' is not a number"))?;
        if trigger == 0 {
            return Err(format!("'{part}': hit count is 1-based, 0 never fires"));
        }
        let mode = match mode {
            "err" => Mode::Err,
            "abort" => Mode::Abort,
            other => return Err(format!("'{part}': unknown mode '{other}' (err|abort)")),
        };
        if site.is_empty() {
            return Err(format!("'{part}': empty site name"));
        }
        parsed.push((site.to_string(), FaultPoint { trigger, mode, hits: 0 }));
    }
    if parsed.is_empty() {
        return Err("spec armed no fault points".to_string());
    }
    let mut map = points().lock().expect("fault registry poisoned");
    for (site, fp) in parsed {
        map.insert(site, fp);
    }
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Disarm every fault point and zero all hit counters. Tests that arm
/// faults must call this when done (and serialize against each other —
/// the registry is process-global).
pub fn reset() {
    let mut map = points().lock().expect("fault registry poisoned");
    map.clear();
    ARMED.store(false, Ordering::Relaxed);
}

/// Record a hit at `site`; returns the injected error if an armed
/// fault fires here (or aborts the process in `abort` mode).
///
/// Call this immediately *before* the operation the site names — a
/// fired `err` means the operation never happened, which is the torn
/// state the recovery paths must tolerate.
pub fn hit(site: &str) -> std::io::Result<()> {
    ensure_env_loaded();
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    let mut map = points().lock().expect("fault registry poisoned");
    let Some(p) = map.get_mut(site) else {
        return Ok(());
    };
    p.hits += 1;
    if p.hits != p.trigger {
        // fires exactly once: later hits sail past the trigger
        return Ok(());
    }
    match p.mode {
        Mode::Err => Err(std::io::Error::other(format!(
            "injected fault at '{site}' (hit {})",
            p.trigger
        ))),
        Mode::Abort => {
            eprintln!("poshashemb: injected abort at '{site}' (hit {})", p.trigger);
            std::process::abort();
        }
    }
}

/// Serialize tests that arm the process-global fault registry: take
/// this guard for the whole test, and [`reset`] before releasing it.
/// (Test-support API, but `pub`: unit tests in other modules and the
/// integration suites need the same lock.)
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_on_nth_hit_exactly_once() {
        let _g = test_guard();
        reset();
        arm("site.a=3:err").unwrap();
        assert!(hit("site.a").is_ok());
        assert!(hit("site.a").is_ok());
        let e = hit("site.a").unwrap_err();
        assert!(e.to_string().contains("site.a"), "error names the site: {e}");
        assert!(e.to_string().contains("hit 3"), "error names the hit: {e}");
        // past the trigger: never fires again
        for _ in 0..5 {
            assert!(hit("site.a").is_ok());
        }
        reset();
    }

    #[test]
    fn unarmed_sites_are_untouched() {
        let _g = test_guard();
        reset();
        arm("site.b=1").unwrap();
        assert!(hit("site.other").is_ok());
        assert!(hit("site.b").is_err());
        reset();
        // fully disarmed: even the armed site is clean again
        assert!(hit("site.b").is_ok());
    }

    #[test]
    fn default_mode_is_err_and_specs_accumulate() {
        let _g = test_guard();
        reset();
        arm("x=1").unwrap();
        arm("y=2:err").unwrap();
        assert!(hit("x").is_err());
        assert!(hit("y").is_ok());
        assert!(hit("y").is_err());
        reset();
    }

    #[test]
    fn rejects_malformed_specs() {
        let _g = test_guard();
        reset();
        for bad in ["noequals", "s=zero", "s=0", "s=1:boom", "=1", "", " ,, "] {
            assert!(arm(bad).is_err(), "spec '{bad}' should be rejected");
        }
        // nothing got armed along the way
        assert!(hit("s").is_ok());
        reset();
    }
}
