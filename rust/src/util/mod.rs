//! In-tree utility substrates.
//!
//! The build is fully offline, so the usual ecosystem crates (rand,
//! serde_json, criterion, proptest, tempfile, clap) are replaced by small
//! purpose-built implementations:
//!
//! * [`rng`] — deterministic xoshiro256++ RNG with the sampling helpers
//!   the partitioner/generators need.
//! * [`json`] — a minimal JSON value type, serializer and recursive-
//!   descent parser (artifact manifests, experiment configs, reports).
//! * [`bench`] — the measurement harness behind `cargo bench`
//!   (`harness = false` benches): warmup + timed iterations + stats.
//! * [`proptest`] — a tiny property-testing driver: seeded random inputs,
//!   shrink-free but reproducible (failing seed printed).
//! * [`tempdir`] — RAII temp directories for tests.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod tempdir;
