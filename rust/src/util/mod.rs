//! In-tree utility substrates.
//!
//! The crate keeps its dependency surface minimal (rayon, serde, anyhow;
//! proptest as a dev-dependency), so several ecosystem crates (rand,
//! criterion, tempfile, clap) are replaced by small purpose-built
//! implementations that also work in offline builds:
//!
//! * [`rng`] — deterministic xoshiro256++ RNG with the sampling helpers
//!   the partitioner/generators need.
//! * [`json`] — a minimal JSON value type, serializer and recursive-
//!   descent parser (artifact manifests, experiment configs, reports).
//! * [`bench`] — the measurement harness behind `cargo bench`
//!   (`harness = false` benches): warmup + timed iterations + stats.
//! * [`proptest`] — a tiny property-testing driver: seeded random inputs,
//!   shrink-free but reproducible (failing seed printed).
//! * [`tempdir`] — RAII temp directories for tests.
//! * [`checksum`] — FNV-1a/64 section fingerprints for model artifacts
//!   (no hash crates in the offline dependency set).
//! * [`sections`] — checksummed little-endian binary sections and the
//!   atomic directory-publish protocol shared by model artifacts and
//!   training checkpoints.
//! * [`fault`] — deterministic fault injection (`POSHASH_FAULT`) for
//!   the crash-safety tests.

pub mod bench;
pub mod checksum;
pub mod fault;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod sections;
pub mod tempdir;
