//! Embedding plans: the bridge between a method config and the tensors
//! the AOT-compiled model consumes.
//!
//! A plan fixes, for one (graph, method) pair:
//! * the **parameter shapes** in a canonical order (must match
//!   `python/compile/embeddings.py::param_order` exactly — checked by the
//!   `python/tests/test_param_layout.py` golden test),
//! * the **static index arrays** (hierarchy paths `z`, hash indices,
//!   identity indices) that are fed to the compiled HLO as inputs, and
//! * the DHE dense encoding where applicable.

use super::config::EmbeddingMethod;
use crate::hashing::{HashFamily, HashedIndices};
use crate::partition::{random_partition, Hierarchy};

/// Shape of a single trainable table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TableShape {
    /// Canonical parameter name (matches the python side).
    pub name: String,
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
}

impl TableShape {
    /// Number of scalar parameters.
    pub fn size(&self) -> usize {
        self.rows * self.cols
    }
}

/// Position-specific part of the plan (Eq. 11).
#[derive(Debug, Clone)]
pub struct PositionPlan {
    /// One table per level; level j has shape `[m_j, d/2^j]`.
    pub tables: Vec<TableShape>,
    /// `z[j][i]` = partition id of node i at level j.
    pub z: Vec<Vec<u32>>,
}

/// Node-specific part of the plan (Eq. 12/13 and all hashing baselines).
#[derive(Debug, Clone)]
pub struct NodePlan {
    /// The pooled table `X` (rows × d).
    pub table: TableShape,
    /// Number of hash functions `h` (row count of the conceptual
    /// hash-major index matrix; also `node_y`'s column count when
    /// `learned_weights` is set).
    pub h: usize,
    /// The hash indices, node-major — **the one and only index layout
    /// in the plan** (the former hash-major `indices` duplicate was
    /// dropped; this halves plan index memory at large `n`).
    ///
    /// Layout contract: `node_major[i * h + t]` = row of `X` used by
    /// node `i` under hash `t`, for `i < n`, `t < h`. One node's `h`
    /// entries are adjacent, so per-node gathers (the compose engine's
    /// hot loop, the trainers' gradient scatter, the scalar oracle)
    /// walk the array sequentially; hash-major consumers (the `h × n`
    /// HLO input built by
    /// [`node_indices_i32`](EmbeddingPlan::node_indices_i32)) transpose
    /// on export, which runs once per AOT request, never per step.
    pub node_major: Vec<u32>,
    /// Learn per-node importance weights `Y ∈ R^{n×h}`? (else `y ≡ 1`).
    pub learned_weights: bool,
}

impl NodePlan {
    /// Build a node plan from hash-major `indices` (`indices[t][i]` =
    /// row of X for node `i` under hash `t` — the natural layout hash
    /// builders produce), converting once to the node-major layout the
    /// plan stores.
    fn new(table: TableShape, indices: Vec<Vec<u32>>, learned_weights: bool) -> Self {
        let h = indices.len();
        let n = indices.first().map_or(0, Vec::len);
        let mut node_major = vec![0u32; n * h];
        for (t, idx) in indices.iter().enumerate() {
            assert_eq!(idx.len(), n, "hash {t} has {} entries, expected {n}", idx.len());
            for (i, &row) in idx.iter().enumerate() {
                node_major[i * h + t] = row;
            }
        }
        NodePlan { table, h, node_major, learned_weights }
    }
}

/// DHE plan: static dense encoding + MLP shapes.
#[derive(Debug, Clone)]
pub struct DhePlan {
    /// Row-major `n × encoding_dim` static encoding in [-1, 1].
    pub encoding: Vec<f32>,
    /// Dense encoding width.
    pub encoding_dim: usize,
    /// Hidden width of each MLP layer.
    pub hidden: usize,
    /// Number of hidden layers.
    pub layers: usize,
    /// MLP parameter shapes in order (w0, b0, w1, b1, ...).
    pub tables: Vec<TableShape>,
}

/// Complete embedding plan for one (graph, method) pair.
#[derive(Debug, Clone)]
pub struct EmbeddingPlan {
    /// The method this plan realizes.
    pub method: EmbeddingMethod,
    /// Number of nodes.
    pub n: usize,
    /// Output embedding dimension.
    pub d: usize,
    /// Position-specific component (Eq. 11), if the method has one.
    pub position: Option<PositionPlan>,
    /// Node-specific component (Eq. 12/13), if the method has one.
    pub node: Option<NodePlan>,
    /// DHE component, if the method is DHE.
    pub dhe: Option<DhePlan>,
}

impl EmbeddingPlan {
    /// Build a plan. `hierarchy` is required iff `method.needs_hierarchy()`.
    /// `seed` drives hash-function draws and RandomPart assignment.
    pub fn build(
        n: usize,
        d: usize,
        method: &EmbeddingMethod,
        hierarchy: Option<&Hierarchy>,
        seed: u64,
    ) -> Self {
        assert!(d >= 4 && d % 4 == 0, "d must be a multiple of 4 for 3-level dims");
        let mut plan = EmbeddingPlan {
            method: method.clone(),
            n,
            d,
            position: None,
            node: None,
            dhe: None,
        };
        // position-specific part
        if method.needs_hierarchy() {
            let h = hierarchy.expect("method requires a hierarchy");
            let levels = method.levels();
            assert!(
                h.levels() >= levels,
                "hierarchy has {} levels, method needs {}",
                h.levels(),
                levels
            );
            plan.position = Some(Self::position_plan(h, levels, d));
        }
        if let EmbeddingMethod::RandomPart { parts } = method {
            // same shapes as PosEmb 1-level, random membership
            let z = vec![random_partition(n, *parts, seed)];
            plan.position = Some(PositionPlan {
                tables: vec![TableShape { name: "pos_0".into(), rows: *parts, cols: d }],
                z,
            });
        }
        // node-specific part
        plan.node = match method {
            EmbeddingMethod::Full | EmbeddingMethod::PosFullEmb { .. } => Some(NodePlan::new(
                TableShape { name: "node_x".into(), rows: n, cols: d },
                vec![(0..n as u32).collect()],
                false,
            )),
            EmbeddingMethod::HashTrick { buckets } => {
                Some(Self::hashed_node_plan(n, d, *buckets, 1, false, seed))
            }
            EmbeddingMethod::Bloom { buckets, h } => {
                Some(Self::hashed_node_plan(n, d, *buckets, *h, false, seed))
            }
            EmbeddingMethod::HashEmb { buckets, h } => {
                Some(Self::hashed_node_plan(n, d, *buckets, *h, true, seed))
            }
            EmbeddingMethod::UniversalHash { buckets } => {
                Some(Self::hashed_node_plan(n, d, *buckets, 1, false, seed))
            }
            EmbeddingMethod::DoubleHash { buckets } => {
                Some(Self::double_hash_node_plan(n, d, *buckets, seed))
            }
            EmbeddingMethod::PosHashEmbInter { buckets, h, .. } => {
                Some(Self::hashed_node_plan(n, d, *buckets, *h, true, seed))
            }
            EmbeddingMethod::PosHashEmbIntra { compression, h, .. } => {
                let hier = hierarchy.expect("intra requires hierarchy");
                Some(Self::intra_node_plan(n, d, hier, *compression, *h, seed))
            }
            _ => None,
        };
        // DHE
        if let EmbeddingMethod::Dhe { encoding_dim, hidden, layers } = method {
            plan.dhe = Some(Self::dhe_plan(n, d, *encoding_dim, *hidden, *layers, seed));
        }
        plan
    }

    fn position_plan(h: &Hierarchy, levels: usize, d: usize) -> PositionPlan {
        let mut tables = Vec::with_capacity(levels);
        for j in 0..levels {
            let dj = (d >> j).max(1);
            tables.push(TableShape { name: format!("pos_{j}"), rows: h.m[j], cols: dj });
        }
        PositionPlan { tables, z: h.z[..levels].to_vec() }
    }

    fn hashed_node_plan(
        n: usize,
        d: usize,
        buckets: usize,
        h: usize,
        learned: bool,
        seed: u64,
    ) -> NodePlan {
        let hi = HashedIndices::build(n, h, buckets as u32, seed);
        NodePlan::new(
            TableShape { name: "node_x".into(), rows: buckets, cols: d },
            hi.indices,
            learned,
        )
    }

    /// Quotient–remainder double hashing: one universal hash into a
    /// `b²` domain, decomposed as `H mod b` (remainder half, rows
    /// `0..b`) and `H div b` (quotient half, rows `b..2b`) of a single
    /// `2b × d` table, summed unweighted. The two lookups are dependent
    /// (one draw, two digits), so every hash value in the `b²` domain
    /// gets a distinct row *pair* while the table pays for only `2b`
    /// rows — the compositional alternative to `h` independent hashes.
    fn double_hash_node_plan(n: usize, d: usize, b: usize, seed: u64) -> NodePlan {
        assert!(b > 0, "doublehash needs at least one bucket");
        assert!(b * b <= u32::MAX as usize, "doublehash domain b² must fit in u32");
        let f = HashFamily::new(seed).function(0, (b * b) as u32);
        let mut rem = Vec::with_capacity(n);
        let mut quo = Vec::with_capacity(n);
        for i in 0..n {
            let hv = f.hash(i as u64) as usize;
            rem.push((hv % b) as u32);
            quo.push((b + hv / b) as u32);
        }
        NodePlan::new(
            TableShape { name: "node_x".into(), rows: 2 * b, cols: d },
            vec![rem, quo],
            false,
        )
    }

    /// Intra-partition pools: one `c × d` pool per level-0 partition,
    /// realized as a single `(m_0 · c) × d` table with offset indices
    /// `z_0(i)·c + (H_t(i) mod c)`.
    fn intra_node_plan(
        n: usize,
        d: usize,
        hier: &Hierarchy,
        c: usize,
        h: usize,
        seed: u64,
    ) -> NodePlan {
        let m0 = hier.m[0];
        let hi = HashedIndices::build(n, h, c as u32, seed);
        let z0 = &hier.z[0];
        let indices: Vec<Vec<u32>> = (0..h)
            .map(|t| {
                (0..n)
                    .map(|i| z0[i] * c as u32 + hi.bucket(t, i))
                    .collect()
            })
            .collect();
        NodePlan::new(TableShape { name: "node_x".into(), rows: m0 * c, cols: d }, indices, true)
    }

    fn dhe_plan(
        n: usize,
        d: usize,
        encoding_dim: usize,
        hidden: usize,
        layers: usize,
        seed: u64,
    ) -> DhePlan {
        // dense encoding: encoding_dim universal hashes into a large range,
        // scaled to [-1, 1] (the paper's DHE uses uniform transform of
        // hashes; B=10^6 there — any large range works identically).
        const RANGE: u32 = 1 << 20;
        let hi = HashedIndices::build(n, encoding_dim, RANGE, seed ^ 0xD4E);
        let mut encoding = vec![0f32; n * encoding_dim];
        for t in 0..encoding_dim {
            for i in 0..n {
                encoding[i * encoding_dim + t] =
                    (hi.bucket(t, i) as f32 / (RANGE - 1) as f32) * 2.0 - 1.0;
            }
        }
        let mut tables = Vec::new();
        let mut in_dim = encoding_dim;
        for l in 0..layers {
            tables.push(TableShape { name: format!("dhe_w{l}"), rows: in_dim, cols: hidden });
            tables.push(TableShape { name: format!("dhe_b{l}"), rows: 1, cols: hidden });
            in_dim = hidden;
        }
        tables.push(TableShape { name: "dhe_wout".into(), rows: in_dim, cols: d });
        tables.push(TableShape { name: "dhe_bout".into(), rows: 1, cols: d });
        DhePlan { encoding, encoding_dim, hidden, layers, tables }
    }

    /// All trainable tables in canonical order:
    /// `pos_0..pos_{L-1}, node_x, node_y, dhe_*`.
    pub fn param_shapes(&self) -> Vec<TableShape> {
        let mut out = Vec::new();
        if let Some(p) = &self.position {
            out.extend(p.tables.iter().cloned());
        }
        if let Some(nx) = &self.node {
            out.push(nx.table.clone());
            if nx.learned_weights {
                out.push(TableShape { name: "node_y".into(), rows: self.n, cols: nx.h });
            }
        }
        if let Some(dhe) = &self.dhe {
            out.extend(dhe.tables.iter().cloned());
        }
        out
    }

    /// Total trainable parameters of the embedding layer.
    pub fn num_params(&self) -> usize {
        self.param_shapes().iter().map(|t| t.size()).sum()
    }

    /// Parameters of the FullEmb baseline at this (n, d) — the paper's
    /// "full size" reference for savings percentages.
    pub fn full_size(&self) -> usize {
        self.n * self.d
    }

    /// Memory savings vs FullEmb, as a fraction in [0, 1] (negative when
    /// the method is *larger* than full, e.g. PosFullEmb).
    pub fn savings(&self) -> f64 {
        1.0 - self.num_params() as f64 / self.full_size() as f64
    }

    /// Hash-index arrays flattened `h × n` row-major (HLO input), if
    /// any. The AOT ABI is hash-major (`out[t * n + i]` = node `i`'s
    /// row under hash `t`), so this transposes the plan's node-major
    /// layout on export — a once-per-AOT-request cost.
    pub fn node_indices_i32(&self) -> Option<Vec<i32>> {
        self.node.as_ref().map(|nx| {
            let h = nx.h;
            let n = self.n;
            let mut out = vec![0i32; n * h];
            for i in 0..n {
                for t in 0..h {
                    out[t * n + i] = nx.node_major[i * h + t] as i32;
                }
            }
            out
        })
    }

    /// Hierarchy paths flattened `L × n` row-major (HLO input), if any.
    pub fn z_indices_i32(&self) -> Option<Vec<i32>> {
        self.position.as_ref().map(|p| {
            p.z.iter().flat_map(|row| row.iter().map(|&x| x as i32)).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};
    use crate::partition::HierarchyConfig;

    fn hierarchy(n: usize, k: usize, levels: usize) -> Hierarchy {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 8.0,
            inter_degree: 1.0,
            seed: 51,
            ..Default::default()
        });
        Hierarchy::build(&g, &HierarchyConfig::new(k, levels))
    }

    #[test]
    fn full_plan_shapes() {
        let p = EmbeddingPlan::build(100, 16, &EmbeddingMethod::Full, None, 0);
        let shapes = p.param_shapes();
        assert_eq!(shapes.len(), 1);
        assert_eq!(shapes[0].rows, 100);
        assert_eq!(shapes[0].cols, 16);
        assert_eq!(p.num_params(), 1600);
        assert_eq!(p.savings(), 0.0);
        // identity indices
        let idx = p.node_indices_i32().unwrap();
        assert_eq!(idx[5], 5);
    }

    #[test]
    fn hashemb_plan_counts_match_eq6() {
        // size = B*d + n*h  (paper Eq. 6 commentary)
        let p =
            EmbeddingPlan::build(1000, 8, &EmbeddingMethod::HashEmb { buckets: 50, h: 2 }, None, 1);
        assert_eq!(p.num_params(), 50 * 8 + 1000 * 2);
        assert!(p.node.as_ref().unwrap().learned_weights);
    }

    #[test]
    fn bloom_has_no_importance_weights() {
        let p =
            EmbeddingPlan::build(1000, 8, &EmbeddingMethod::Bloom { buckets: 50, h: 2 }, None, 1);
        assert_eq!(p.num_params(), 50 * 8);
        assert!(!p.node.as_ref().unwrap().learned_weights);
    }

    #[test]
    fn uhash_is_single_unweighted_hash() {
        let p = EmbeddingPlan::build(
            1000,
            8,
            &EmbeddingMethod::UniversalHash { buckets: 50 },
            None,
            1,
        );
        let nx = p.node.as_ref().unwrap();
        assert_eq!(nx.h, 1);
        assert!(!nx.learned_weights);
        assert_eq!(p.num_params(), 50 * 8);
        assert!(nx.node_major.iter().all(|&r| (r as usize) < 50));
    }

    #[test]
    fn doublehash_rows_split_into_remainder_and_quotient_halves() {
        let b = 20usize;
        let p = EmbeddingPlan::build(1000, 8, &EmbeddingMethod::DoubleHash { buckets: b }, None, 1);
        let nx = p.node.as_ref().unwrap();
        assert_eq!(nx.h, 2);
        assert!(!nx.learned_weights);
        assert_eq!(nx.table.rows, 2 * b);
        assert_eq!(p.num_params(), 2 * b * 8);
        for i in 0..1000 {
            let rem = nx.node_major[i * 2] as usize;
            let quo = nx.node_major[i * 2 + 1] as usize;
            assert!(rem < b, "node {i}: remainder row {rem} outside its half");
            assert!((b..2 * b).contains(&quo), "node {i}: quotient row {quo} outside its half");
        }
        // the decomposition is injective over the b² hash domain:
        // distinct hash values get distinct (rem, quo) pairs, so two
        // nodes collide on BOTH rows only when the full hash collides
        let q = EmbeddingPlan::build(1000, 8, &EmbeddingMethod::DoubleHash { buckets: b }, None, 1);
        assert_eq!(nx.node_major, q.node.as_ref().unwrap().node_major, "plan is seeded");
    }

    #[test]
    fn posemb_3level_dims_halve() {
        let h = hierarchy(400, 3, 3);
        let p = EmbeddingPlan::build(400, 32, &EmbeddingMethod::PosEmb { levels: 3 }, Some(&h), 2);
        let shapes = p.param_shapes();
        assert_eq!(shapes.len(), 3);
        assert_eq!((shapes[0].rows, shapes[0].cols), (3, 32));
        assert_eq!((shapes[1].rows, shapes[1].cols), (9, 16));
        assert_eq!((shapes[2].rows, shapes[2].cols), (27, 8));
        // m*d sum (paper: Σ m_j d_j)
        assert_eq!(p.num_params(), 3 * 32 + 9 * 16 + 27 * 8);
    }

    #[test]
    fn intra_indices_stay_inside_partition_pool() {
        let h = hierarchy(600, 4, 3);
        let c = 7usize;
        let p = EmbeddingPlan::build(
            600,
            16,
            &EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 },
            Some(&h),
            3,
        );
        let nx = p.node.as_ref().unwrap();
        assert_eq!(nx.table.rows, 4 * c);
        for t in 0..2 {
            for i in 0..600 {
                let idx = nx.node_major[i * nx.h + t] as usize;
                let part = h.z[0][i] as usize;
                assert!(idx >= part * c && idx < (part + 1) * c, "node {i} escaped its pool");
            }
        }
    }

    #[test]
    fn node_major_layout_and_hlo_export_agree() {
        for method in [
            EmbeddingMethod::Full,
            EmbeddingMethod::HashEmb { buckets: 30, h: 3 },
            EmbeddingMethod::Bloom { buckets: 17, h: 2 },
        ] {
            let n = 200;
            let p = EmbeddingPlan::build(n, 8, &method, None, 9);
            let nx = p.node.as_ref().unwrap();
            assert_eq!(nx.node_major.len(), n * nx.h, "{}", method.name());
            assert!(nx.node_major.iter().all(|&r| (r as usize) < nx.table.rows));
            // the h × n HLO export is the exact transpose of node_major
            let exported = p.node_indices_i32().unwrap();
            assert_eq!(exported.len(), n * nx.h);
            for t in 0..nx.h {
                for i in 0..n {
                    assert_eq!(exported[t * n + i], nx.node_major[i * nx.h + t] as i32);
                }
            }
        }
    }

    #[test]
    fn posfullemb_larger_than_full() {
        let h = hierarchy(300, 3, 1);
        let p =
            EmbeddingPlan::build(300, 16, &EmbeddingMethod::PosFullEmb { levels: 1 }, Some(&h), 4);
        assert!(p.num_params() > p.full_size());
        assert!(p.savings() < 0.0);
    }

    #[test]
    fn paper_default_savings_band() {
        // paper claims 88–97% savings for PosHashEmb at paper defaults.
        let n = 16_900;
        let (method, k) = EmbeddingMethod::paper_default_intra(n);
        let h = hierarchy(n, k, 3);
        let p = EmbeddingPlan::build(n, 128, &method, Some(&h), 5);
        let s = p.savings();
        assert!(s > 0.80 && s < 0.99, "savings {s}");
    }

    #[test]
    fn dhe_plan_shapes() {
        let p = EmbeddingPlan::build(
            200,
            16,
            &EmbeddingMethod::Dhe { encoding_dim: 32, hidden: 64, layers: 1 },
            None,
            6,
        );
        let dhe = p.dhe.as_ref().unwrap();
        assert_eq!(dhe.encoding.len(), 200 * 32);
        assert!(dhe.encoding.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        let shapes = p.param_shapes();
        // w0 (32x64) b0 (1x64) wout (64x16) bout (1x16)
        assert_eq!(shapes.len(), 4);
        assert_eq!(p.num_params(), 32 * 64 + 64 + 64 * 16 + 16);
    }

    #[test]
    fn randompart_matches_posemb1_shape() {
        let h = hierarchy(500, 5, 1);
        let pos =
            EmbeddingPlan::build(500, 16, &EmbeddingMethod::PosEmb { levels: 1 }, Some(&h), 7);
        let rnd = EmbeddingPlan::build(500, 16, &EmbeddingMethod::RandomPart { parts: 5 }, None, 7);
        assert_eq!(pos.num_params(), rnd.num_params());
    }
}
