//! Embedding-layer methods — the paper's contribution plus every baseline.
//!
//! Everything in the paper reduces to (Eq. 7):
//!
//! ```text
//! v_i = p_i + x_i
//! p_i = Σ_j pad_d(P_j[z_i(j)])                         (Eq. 11, optional)
//! x_i = Σ_t y_i(t) · X[idx_t(i)]                        (Eq. 12/13, optional)
//! ```
//!
//! with every baseline a degenerate case:
//! * FullEmb     — no `p`; `X = W ∈ R^{n×d}`, `h=1`, `idx_0(i)=i`, `y≡1`.
//! * HashTrick   — no `p`; `h=1`, `idx` = one universal hash, `y≡1` (Eq. 4).
//! * Bloom       — no `p`; `h=2`, `y≡1` (double hashing, Eq. 5).
//! * HashEmb     — no `p`; `h=2`, learned `Y ∈ R^{n×h}` (Eq. 6).
//! * PosEmb      — no `x`; L-level hierarchy (Eq. 9/11).
//! * RandomPart  — PosEmb 1-level with uniform-random membership.
//! * PosFullEmb  — `p` + FullEmb-style `x`.
//! * PosHashEmb Inter — `p` + global pool of `b` rows (Eq. 13).
//! * PosHashEmb Intra — `p` + per-level-0-partition pools of `c = b/m_0`
//!   rows, realized as one `m_0·c × d` table with offset indices
//!   `idx_t(i) = z_i(0)·c + (H_t(i) mod c)` (Eq. 12).
//! * DHE — the odd one out: dense hash encoding + MLP (no tables).
//!
//! Because of this unification a *single* AOT-lowered composition (and a
//! single Pallas kernel) serves all table-based methods; only the static
//! index arrays and table shapes differ. `plan` builds those arrays,
//! `memory` prices them (paper §II/III cost model), `reference` is the
//! pure-Rust oracle the HLO output is tested against, and [`compose`] is
//! the blocked, rayon-parallel engine that serves the same computation at
//! hardware speed (full-matrix and minibatch entry points).
//!
//! **Dimension note.** Eq. 11 sums level embeddings of *different* widths
//! (`d_j = d/2^j`). The paper does not state the alignment; we zero-extend
//! each level vector to `d` (level j contributes to the first `d_j`
//! coordinates), which preserves both the stated parameter counts and the
//! sum form. Recorded in DESIGN.md §4.

pub mod compose;
mod config;
mod memory;
mod plan;
mod reference;

pub use compose::{ComposeEngine, ComposeOptions, PreparedCompose};
pub use config::{
    default_c, default_k, EmbeddingMethod, MethodFamily, MethodParseError, MethodSpec,
    ResolvedMethod,
};
pub use memory::{budget_for_fraction, BudgetedMethods, MemoryReport, PosBudget};
pub use plan::{DhePlan, EmbeddingPlan, NodePlan, PositionPlan, TableShape};
pub use reference::{compose_embeddings, init_params, ParamStore};
