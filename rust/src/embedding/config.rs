//! Embedding method configuration: the [`EmbeddingMethod`] enum, the
//! paper's scale-derived defaults (`k`, `c`, `b`), and the one tag
//! parser ([`MethodSpec`]) shared by the CLI, the experiment grid, the
//! bench harness and the serve path.

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

/// All embedding-layer methods evaluated in the paper.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EmbeddingMethod {
    /// One-hot full embedding table `W ∈ R^{n×d}` (paper's FullEmb).
    Full,
    /// Hashing trick [6]: one hash into `buckets` shared rows.
    HashTrick {
        /// Shared table rows.
        buckets: usize,
    },
    /// Bloom embeddings [9]: `h` hashes, unweighted sum.
    Bloom {
        /// Shared table rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// Hash embeddings [7]: `h` hashes + learned per-node importance.
    HashEmb {
        /// Shared table rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// Plain universal-hash bucketing: one 2-universal hash into
    /// `buckets` shared rows, no importance weights — the showdown's
    /// simplest hashing baseline (HashTrick with the crate's
    /// [`UniversalHash`](crate::hashing::UniversalHash) family made
    /// explicit as its own tag).
    UniversalHash {
        /// Shared table rows.
        buckets: usize,
    },
    /// Double-hash compositional scheme (quotient–remainder, after
    /// "Compositional embeddings using complementary partitions",
    /// Shi 2020): one universal hash into a `buckets²` domain split as
    /// `H mod buckets` and `H div buckets`, each indexing its own half
    /// of a `2·buckets` row table, summed unweighted. Two dependent
    /// lookups distinguish all `buckets²` hash values while paying for
    /// `2·buckets` rows.
    DoubleHash {
        /// Rows per half-table (the table holds `2·buckets` rows).
        buckets: usize,
    },
    /// Deep hash embeddings [8]: dense hash encoding + MLP.
    Dhe {
        /// Dense encoding width.
        encoding_dim: usize,
        /// Hidden width of each MLP layer.
        hidden: usize,
        /// Number of hidden layers.
        layers: usize,
    },
    /// Position-specific only (PosEmb L-level, Eq. 9/11).
    PosEmb {
        /// Hierarchy levels used.
        levels: usize,
    },
    /// PosEmb 1-level with random membership (Table III baseline).
    RandomPart {
        /// Number of random parts.
        parts: usize,
    },
    /// PosEmb + full node-specific table (Table III/V "PosFullEmb").
    PosFullEmb {
        /// Hierarchy levels used.
        levels: usize,
    },
    /// PosEmb + globally shared hash-embedding pool (Eq. 13).
    PosHashEmbInter {
        /// Hierarchy levels used.
        levels: usize,
        /// Shared pool rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// PosEmb + per-partition pools of `c` rows each (Eq. 12).
    /// `compression = c`; total pool is `m_0 · c` rows.
    PosHashEmbIntra {
        /// Hierarchy levels used.
        levels: usize,
        /// Pool rows per level-0 partition (the paper's `c`).
        compression: usize,
        /// Number of hash functions.
        h: usize,
    },
}

/// Coarse family grouping used for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodFamily {
    /// FullEmb.
    Full,
    /// Hash-based baselines (HashTrick / Bloom / HashEmb).
    Hashing,
    /// Position-specific only (PosEmb / RandomPart).
    Position,
    /// Position + node-specific combinations (the paper's contribution).
    PositionHash,
    /// Deep hash embeddings.
    Dhe,
}

impl EmbeddingMethod {
    /// Every tag accepted by the [`MethodSpec`] parser (and thus the
    /// CLI `--method` flag). `posemb1`/`posemb2`/`posemb3` are aliases
    /// for `posemb(levels=...)`.
    pub const VARIANTS: &[&str] = &[
        "full",
        "hashtrick",
        "bloom",
        "hashemb",
        "uhash",
        "doublehash",
        "dhe",
        "posemb",
        "posemb1",
        "posemb2",
        "posemb3",
        "randompart",
        "posfullemb",
        "inter",
        "intra",
    ];

    /// Short display name matching the paper's tables.
    pub fn name(&self) -> String {
        match self {
            EmbeddingMethod::Full => "FullEmb".into(),
            EmbeddingMethod::HashTrick { .. } => "HashTrick".into(),
            EmbeddingMethod::Bloom { .. } => "Bloom".into(),
            EmbeddingMethod::HashEmb { .. } => "HashEmb".into(),
            EmbeddingMethod::UniversalHash { .. } => "UHash".into(),
            EmbeddingMethod::DoubleHash { .. } => "DoubleHash".into(),
            EmbeddingMethod::Dhe { .. } => "DHE".into(),
            EmbeddingMethod::PosEmb { levels } => format!("PosEmb {levels}-level"),
            EmbeddingMethod::RandomPart { .. } => "RandomPart".into(),
            EmbeddingMethod::PosFullEmb { levels } => format!("PosFullEmb {levels}-level"),
            EmbeddingMethod::PosHashEmbInter { h, .. } => format!("PosHashEmb Inter (h={h})"),
            EmbeddingMethod::PosHashEmbIntra { h, .. } => format!("PosHashEmb Intra (h={h})"),
        }
    }

    /// Family for report grouping.
    pub fn family(&self) -> MethodFamily {
        match self {
            EmbeddingMethod::Full => MethodFamily::Full,
            EmbeddingMethod::HashTrick { .. }
            | EmbeddingMethod::Bloom { .. }
            | EmbeddingMethod::HashEmb { .. }
            | EmbeddingMethod::UniversalHash { .. }
            | EmbeddingMethod::DoubleHash { .. } => MethodFamily::Hashing,
            EmbeddingMethod::Dhe { .. } => MethodFamily::Dhe,
            EmbeddingMethod::PosEmb { .. } | EmbeddingMethod::RandomPart { .. } => {
                MethodFamily::Position
            }
            EmbeddingMethod::PosFullEmb { .. }
            | EmbeddingMethod::PosHashEmbInter { .. }
            | EmbeddingMethod::PosHashEmbIntra { .. } => MethodFamily::PositionHash,
        }
    }

    /// Does this method need a graph hierarchy?
    pub fn needs_hierarchy(&self) -> bool {
        matches!(
            self,
            EmbeddingMethod::PosEmb { .. }
                | EmbeddingMethod::PosFullEmb { .. }
                | EmbeddingMethod::PosHashEmbInter { .. }
                | EmbeddingMethod::PosHashEmbIntra { .. }
        )
    }

    /// Number of hierarchy levels used (0 for non-position methods).
    pub fn levels(&self) -> usize {
        match self {
            EmbeddingMethod::PosEmb { levels }
            | EmbeddingMethod::PosFullEmb { levels }
            | EmbeddingMethod::PosHashEmbInter { levels, .. }
            | EmbeddingMethod::PosHashEmbIntra { levels, .. } => *levels,
            EmbeddingMethod::RandomPart { .. } => 1,
            _ => 0,
        }
    }

    /// Paper-default PosHashEmb (§IV-D): `k = ⌈n^(1/4)⌉`, `L = 3`,
    /// `c = ⌈sqrt(n/k)⌉`, `b = c·k`, `h = 2`, Intra pools.
    pub fn paper_default_intra(n: usize) -> (Self, usize) {
        let k = (n as f64).powf(0.25).ceil() as usize;
        let c = ((n as f64 / k as f64).sqrt()).ceil() as usize;
        (EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 }, k)
    }
}

impl fmt::Display for EmbeddingMethod {
    /// Fully explicit tag form, round-trippable through [`FromStr`]
    /// (e.g. `intra(levels=3,c=90,h=2)`). Model-artifact manifests
    /// store this string so the serve path can re-parse the method
    /// without knowing the node count.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EmbeddingMethod::Full => write!(f, "full"),
            EmbeddingMethod::HashTrick { buckets } => write!(f, "hashtrick(b={buckets})"),
            EmbeddingMethod::Bloom { buckets, h } => write!(f, "bloom(b={buckets},h={h})"),
            EmbeddingMethod::HashEmb { buckets, h } => write!(f, "hashemb(b={buckets},h={h})"),
            EmbeddingMethod::UniversalHash { buckets } => write!(f, "uhash(b={buckets})"),
            EmbeddingMethod::DoubleHash { buckets } => write!(f, "doublehash(b={buckets})"),
            EmbeddingMethod::Dhe { encoding_dim, hidden, layers } => {
                write!(f, "dhe(e={encoding_dim},w={hidden},l={layers})")
            }
            EmbeddingMethod::PosEmb { levels } => write!(f, "posemb(levels={levels})"),
            EmbeddingMethod::RandomPart { parts } => write!(f, "randompart(parts={parts})"),
            EmbeddingMethod::PosFullEmb { levels } => write!(f, "posfullemb(levels={levels})"),
            EmbeddingMethod::PosHashEmbInter { levels, buckets, h } => {
                write!(f, "inter(levels={levels},b={buckets},h={h})")
            }
            EmbeddingMethod::PosHashEmbIntra { levels, compression, h } => {
                write!(f, "intra(levels={levels},c={compression},h={h})")
            }
        }
    }
}

/// Paper default `k` (Eq. 8: `k = n^alpha`, alpha = 1/4) — but `n`
/// there is the ORIGINAL OGB node count. The scaled-down synthetic
/// analogs keep the paper's realized k values (arxiv 21, products 40,
/// proteins 19) so the partitions-per-class regime matches the paper's;
/// every other size uses the formula directly.
pub fn default_k(n: usize) -> usize {
    match n {
        6_000 => 21,     // 169,343^(1/4)
        12_000 => 40,    // 2,449,029^(1/4)
        4_000 => 19,     // 132,534^(1/4)
        _ => (n as f64).powf(0.25).ceil() as usize,
    }
}

/// Paper default `c = ⌈sqrt(n/k)⌉`; the Inter pool is `b = c·k` (§IV-D).
pub fn default_c(n: usize, k: usize) -> usize {
    ((n as f64 / k as f64).sqrt()).ceil() as usize
}

/// Error from parsing a method tag or resolving its parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MethodParseError(String);

impl fmt::Display for MethodParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MethodParseError {}

fn perr(msg: impl Into<String>) -> MethodParseError {
    MethodParseError(msg.into())
}

/// Parameter keys each tag accepts in the `tag(key=val,...)` form.
fn allowed_keys(tag: &str) -> &'static [&'static str] {
    match tag {
        "hashtrick" | "uhash" | "doublehash" => &["b", "k"],
        "bloom" | "hashemb" => &["b", "h", "k"],
        "dhe" => &["e", "w", "l"],
        "posemb" | "posemb1" | "posemb2" | "posemb3" | "posfullemb" => &["levels", "k"],
        "randompart" => &["parts", "k"],
        "inter" => &["levels", "b", "h", "k"],
        "intra" => &["levels", "c", "h", "k"],
        _ => &[],
    }
}

/// A parsed-but-unresolved method tag: `tag` or `tag(key=val,...)`.
///
/// Scale-dependent defaults (hierarchy branching `k`, compression `c`,
/// bucket count `b`) are filled in by [`MethodSpec::resolve`] once the
/// node count is known; explicit `key=val` parameters always win. This
/// is the single parser behind the CLI `--method` flag, the experiment
/// grid, the bench harness and the serve path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MethodSpec {
    tag: String,
    params: BTreeMap<String, usize>,
}

/// A method resolved at a concrete node count, plus the hierarchy
/// branching factor `k` that position-family methods partition with.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResolvedMethod {
    /// The concrete method configuration.
    pub method: EmbeddingMethod,
    /// Hierarchy branching factor (used when `method.needs_hierarchy()`).
    pub k: usize,
}

impl FromStr for MethodSpec {
    type Err = MethodParseError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        let (tag, inner) = match s.find('(') {
            Some(i) => {
                let Some(inner) = s[i + 1..].strip_suffix(')') else {
                    return Err(perr(format!("method '{s}': missing closing ')'")));
                };
                (&s[..i], inner)
            }
            None => (s, ""),
        };
        if !EmbeddingMethod::VARIANTS.contains(&tag) {
            return Err(perr(format!(
                "unknown method '{tag}' (valid: {})",
                EmbeddingMethod::VARIANTS.join(", ")
            )));
        }
        let allowed = allowed_keys(tag);
        let mut params = BTreeMap::new();
        for kv in inner.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((key, val)) = kv.split_once('=') else {
                return Err(perr(format!("method '{tag}': expected key=value, got '{kv}'")));
            };
            let (key, val) = (key.trim(), val.trim());
            if !allowed.contains(&key) {
                return Err(if allowed.is_empty() {
                    perr(format!("method '{tag}' takes no parameters, got '{key}'"))
                } else {
                    perr(format!(
                        "method '{tag}': unknown parameter '{key}' (allowed: {})",
                        allowed.join(", ")
                    ))
                });
            }
            let v: usize = val.parse().map_err(|_| {
                perr(format!("method '{tag}': '{key}' must be an integer, got '{val}'"))
            })?;
            if v == 0 {
                return Err(perr(format!("method '{tag}': parameter '{key}' must be positive")));
            }
            params.insert(key.to_string(), v);
        }
        Ok(MethodSpec { tag: tag.to_string(), params })
    }
}

impl MethodSpec {
    /// Convenience alias for [`str::parse`].
    pub fn parse(s: &str) -> Result<Self, MethodParseError> {
        s.parse()
    }

    /// The bare tag this spec was parsed from.
    pub fn tag(&self) -> &str {
        &self.tag
    }

    fn get(&self, key: &str) -> Option<usize> {
        self.params.get(key).copied()
    }

    fn levels_default(&self) -> usize {
        match self.tag.as_str() {
            "posemb1" => 1,
            "posemb2" => 2,
            _ => 3,
        }
    }

    /// Resolve scale-dependent defaults at node count `n` (paper §IV-D:
    /// `k = default_k(n)`, `c = ⌈sqrt(n/k)⌉`, `b = c·k`, `h = 2`).
    pub fn resolve(&self, n: usize) -> Result<ResolvedMethod, MethodParseError> {
        let k = self.get("k").unwrap_or_else(|| default_k(n));
        let c = self.get("c").unwrap_or_else(|| default_c(n, k));
        let b = self.get("b").unwrap_or(c * k);
        let h = self.get("h").unwrap_or(2);
        let levels = self.get("levels").unwrap_or_else(|| self.levels_default());
        let method = match self.tag.as_str() {
            "full" => EmbeddingMethod::Full,
            "hashtrick" => EmbeddingMethod::HashTrick { buckets: b },
            "bloom" => EmbeddingMethod::Bloom { buckets: b, h },
            "hashemb" => EmbeddingMethod::HashEmb { buckets: b, h },
            "uhash" => EmbeddingMethod::UniversalHash { buckets: b },
            "doublehash" => EmbeddingMethod::DoubleHash { buckets: b },
            "dhe" => EmbeddingMethod::Dhe {
                encoding_dim: self.get("e").unwrap_or(32),
                hidden: self.get("w").unwrap_or(64),
                layers: self.get("l").unwrap_or(1),
            },
            "posemb" | "posemb1" | "posemb2" | "posemb3" => EmbeddingMethod::PosEmb { levels },
            "randompart" => EmbeddingMethod::RandomPart { parts: self.get("parts").unwrap_or(k) },
            "posfullemb" => EmbeddingMethod::PosFullEmb { levels },
            "inter" => EmbeddingMethod::PosHashEmbInter { levels, buckets: b, h },
            "intra" => EmbeddingMethod::PosHashEmbIntra { levels, compression: c, h },
            other => return Err(perr(format!("unknown method '{other}'"))),
        };
        Ok(ResolvedMethod { method, k })
    }
}

impl FromStr for EmbeddingMethod {
    type Err = MethodParseError;

    /// Parse the explicit form printed by [`fmt::Display`]
    /// (e.g. `intra(levels=3,c=90,h=2)`), or a bare tag when every
    /// parameter has a scale-free default (`full`, `posemb3`, `dhe`).
    /// Bare tags whose defaults depend on the node count (`hashtrick`,
    /// `inter`, ...) must go through [`MethodSpec::resolve`] instead.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let spec: MethodSpec = s.parse()?;
        let needs: &[&str] = match spec.tag.as_str() {
            "hashtrick" | "bloom" | "hashemb" | "uhash" | "doublehash" | "inter" => &["b"],
            "intra" => &["c"],
            "randompart" => &["parts"],
            _ => &[],
        };
        for key in needs {
            if spec.get(key).is_none() {
                return Err(perr(format!(
                    "method '{}' needs '{key}=' to parse without a node count \
                     (e.g. '{}({key}=64)'); or resolve a MethodSpec at a known n",
                    spec.tag, spec.tag
                )));
            }
        }
        // Every scale-dependent value is explicit (checked above), so
        // the node count passed to resolve() is never consulted.
        Ok(spec.resolve(1)?.method)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper_tables() {
        assert_eq!(EmbeddingMethod::Full.name(), "FullEmb");
        assert_eq!(EmbeddingMethod::PosEmb { levels: 3 }.name(), "PosEmb 3-level");
        assert_eq!(
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 8, h: 2 }.name(),
            "PosHashEmb Intra (h=2)"
        );
    }

    #[test]
    fn paper_defaults_for_arxiv_scale() {
        // paper: ogbn-arxiv n=169,343, alpha=1/4 -> k ≈ 21, c = ⌈sqrt(n/k)⌉ ≈ 90
        let (m, k) = EmbeddingMethod::paper_default_intra(169_343);
        assert_eq!(k, 21);
        match m {
            EmbeddingMethod::PosHashEmbIntra { levels, compression, h } => {
                assert_eq!(levels, 3);
                assert_eq!(h, 2);
                assert_eq!(compression, 90);
            }
            _ => panic!("wrong method"),
        }
    }

    #[test]
    fn hierarchy_requirements() {
        assert!(!EmbeddingMethod::Full.needs_hierarchy());
        assert!(!EmbeddingMethod::RandomPart { parts: 8 }.needs_hierarchy());
        assert!(EmbeddingMethod::PosEmb { levels: 2 }.needs_hierarchy());
        assert_eq!(EmbeddingMethod::RandomPart { parts: 8 }.levels(), 1);
    }

    #[test]
    fn display_fromstr_round_trips_every_variant() {
        let methods = [
            EmbeddingMethod::Full,
            EmbeddingMethod::HashTrick { buckets: 357 },
            EmbeddingMethod::Bloom { buckets: 357, h: 2 },
            EmbeddingMethod::HashEmb { buckets: 357, h: 3 },
            EmbeddingMethod::UniversalHash { buckets: 357 },
            EmbeddingMethod::DoubleHash { buckets: 78 },
            EmbeddingMethod::Dhe { encoding_dim: 32, hidden: 64, layers: 2 },
            EmbeddingMethod::PosEmb { levels: 2 },
            EmbeddingMethod::RandomPart { parts: 21 },
            EmbeddingMethod::PosFullEmb { levels: 3 },
            EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: 234, h: 2 },
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 17, h: 2 },
        ];
        for m in methods {
            let s = m.to_string();
            let back: EmbeddingMethod = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
            assert_eq!(back, m, "round trip failed for {s}");
        }
    }

    #[test]
    fn bare_tags_resolve_to_paper_defaults() {
        // n=6000 (synth-arxiv): k=21, c=⌈sqrt(6000/21)⌉=17, b=357
        let r = MethodSpec::parse("intra").unwrap().resolve(6000).unwrap();
        assert_eq!(r.k, 21);
        assert_eq!(
            r.method,
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 17, h: 2 }
        );
        let r = MethodSpec::parse("inter").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: 357, h: 2 });
        let r = MethodSpec::parse("posemb1").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::PosEmb { levels: 1 });
        let r = MethodSpec::parse("randompart").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::RandomPart { parts: 21 });
        let r = MethodSpec::parse("full").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::Full);
        let r = MethodSpec::parse("dhe").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::Dhe { encoding_dim: 32, hidden: 64, layers: 1 });
    }

    #[test]
    fn hashing_baseline_tags_resolve_and_report_as_hashing() {
        // bare tags get the same b = c·k default as the other hashing
        // baselines (n=6000: b=357), and overrides win
        let r = MethodSpec::parse("uhash").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::UniversalHash { buckets: 357 });
        assert_eq!(r.method.family(), MethodFamily::Hashing);
        assert_eq!(r.method.name(), "UHash");
        let r = MethodSpec::parse("doublehash(b=100)").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::DoubleHash { buckets: 100 });
        assert_eq!(r.method.family(), MethodFamily::Hashing);
        assert_eq!(r.method.name(), "DoubleHash");
        assert!(!r.method.needs_hierarchy());
        // parse → Display → parse round-trips the explicit form
        for s in ["uhash(b=64)", "doublehash(b=32)"] {
            let m: EmbeddingMethod = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
            assert_eq!(m.to_string().parse::<EmbeddingMethod>().unwrap(), m);
        }
        // a bare tag without b cannot parse as a concrete method
        assert!("uhash".parse::<EmbeddingMethod>().is_err());
        assert!("doublehash".parse::<EmbeddingMethod>().is_err());
    }

    #[test]
    fn explicit_params_override_scale_defaults() {
        // k=9 forces the paper-formula regime at synth scale:
        // c=⌈sqrt(6000/9)⌉=26, b=c*k=234
        let r = MethodSpec::parse("inter(k=9,h=1)").unwrap().resolve(6000).unwrap();
        assert_eq!(r.k, 9);
        assert_eq!(r.method, EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: 234, h: 1 });
        let r = MethodSpec::parse("hashtrick(b=100)").unwrap().resolve(6000).unwrap();
        assert_eq!(r.method, EmbeddingMethod::HashTrick { buckets: 100 });
    }

    #[test]
    fn unknown_tag_error_lists_variants() {
        let e = MethodSpec::parse("fulll").unwrap_err().to_string();
        assert!(e.contains("unknown method 'fulll'"), "{e}");
        for tag in EmbeddingMethod::VARIANTS {
            assert!(e.contains(tag), "error should list '{tag}': {e}");
        }
    }

    #[test]
    fn malformed_params_rejected() {
        assert!(MethodSpec::parse("intra(c=17").is_err()); // missing ')'
        assert!(MethodSpec::parse("intra(z=3)").is_err()); // unknown key
        assert!(MethodSpec::parse("full(b=3)").is_err()); // takes no params
        assert!(MethodSpec::parse("intra(c=abc)").is_err()); // non-integer
        assert!(MethodSpec::parse("intra(c=0)").is_err()); // zero
        let e = "inter".parse::<EmbeddingMethod>().unwrap_err().to_string();
        assert!(e.contains("needs 'b='"), "{e}");
    }

    #[test]
    fn default_scale_matches_registered_datasets() {
        assert_eq!(default_k(6_000), 21);
        assert_eq!(default_k(12_000), 40);
        assert_eq!(default_k(4_000), 19);
        assert_eq!(default_c(6_000, 21), 17);
    }
}
