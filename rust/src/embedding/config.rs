//! Embedding method configuration.

/// All embedding-layer methods evaluated in the paper.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EmbeddingMethod {
    /// One-hot full embedding table `W ∈ R^{n×d}` (paper's FullEmb).
    Full,
    /// Hashing trick [6]: one hash into `buckets` shared rows.
    HashTrick {
        /// Shared table rows.
        buckets: usize,
    },
    /// Bloom embeddings [9]: `h` hashes, unweighted sum.
    Bloom {
        /// Shared table rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// Hash embeddings [7]: `h` hashes + learned per-node importance.
    HashEmb {
        /// Shared table rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// Deep hash embeddings [8]: dense hash encoding + MLP.
    Dhe {
        /// Dense encoding width.
        encoding_dim: usize,
        /// Hidden width of each MLP layer.
        hidden: usize,
        /// Number of hidden layers.
        layers: usize,
    },
    /// Position-specific only (PosEmb L-level, Eq. 9/11).
    PosEmb {
        /// Hierarchy levels used.
        levels: usize,
    },
    /// PosEmb 1-level with random membership (Table III baseline).
    RandomPart {
        /// Number of random parts.
        parts: usize,
    },
    /// PosEmb + full node-specific table (Table III/V "PosFullEmb").
    PosFullEmb {
        /// Hierarchy levels used.
        levels: usize,
    },
    /// PosEmb + globally shared hash-embedding pool (Eq. 13).
    PosHashEmbInter {
        /// Hierarchy levels used.
        levels: usize,
        /// Shared pool rows.
        buckets: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// PosEmb + per-partition pools of `c` rows each (Eq. 12).
    /// `compression = c`; total pool is `m_0 · c` rows.
    PosHashEmbIntra {
        /// Hierarchy levels used.
        levels: usize,
        /// Pool rows per level-0 partition (the paper's `c`).
        compression: usize,
        /// Number of hash functions.
        h: usize,
    },
}

/// Coarse family grouping used for reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodFamily {
    /// FullEmb.
    Full,
    /// Hash-based baselines (HashTrick / Bloom / HashEmb).
    Hashing,
    /// Position-specific only (PosEmb / RandomPart).
    Position,
    /// Position + node-specific combinations (the paper's contribution).
    PositionHash,
    /// Deep hash embeddings.
    Dhe,
}

impl EmbeddingMethod {
    /// Short display name matching the paper's tables.
    pub fn name(&self) -> String {
        match self {
            EmbeddingMethod::Full => "FullEmb".into(),
            EmbeddingMethod::HashTrick { .. } => "HashTrick".into(),
            EmbeddingMethod::Bloom { .. } => "Bloom".into(),
            EmbeddingMethod::HashEmb { .. } => "HashEmb".into(),
            EmbeddingMethod::Dhe { .. } => "DHE".into(),
            EmbeddingMethod::PosEmb { levels } => format!("PosEmb {levels}-level"),
            EmbeddingMethod::RandomPart { .. } => "RandomPart".into(),
            EmbeddingMethod::PosFullEmb { levels } => format!("PosFullEmb {levels}-level"),
            EmbeddingMethod::PosHashEmbInter { h, .. } => format!("PosHashEmb Inter (h={h})"),
            EmbeddingMethod::PosHashEmbIntra { h, .. } => format!("PosHashEmb Intra (h={h})"),
        }
    }

    /// Family for report grouping.
    pub fn family(&self) -> MethodFamily {
        match self {
            EmbeddingMethod::Full => MethodFamily::Full,
            EmbeddingMethod::HashTrick { .. }
            | EmbeddingMethod::Bloom { .. }
            | EmbeddingMethod::HashEmb { .. } => MethodFamily::Hashing,
            EmbeddingMethod::Dhe { .. } => MethodFamily::Dhe,
            EmbeddingMethod::PosEmb { .. } | EmbeddingMethod::RandomPart { .. } => {
                MethodFamily::Position
            }
            EmbeddingMethod::PosFullEmb { .. }
            | EmbeddingMethod::PosHashEmbInter { .. }
            | EmbeddingMethod::PosHashEmbIntra { .. } => MethodFamily::PositionHash,
        }
    }

    /// Does this method need a graph hierarchy?
    pub fn needs_hierarchy(&self) -> bool {
        matches!(
            self,
            EmbeddingMethod::PosEmb { .. }
                | EmbeddingMethod::PosFullEmb { .. }
                | EmbeddingMethod::PosHashEmbInter { .. }
                | EmbeddingMethod::PosHashEmbIntra { .. }
        )
    }

    /// Number of hierarchy levels used (0 for non-position methods).
    pub fn levels(&self) -> usize {
        match self {
            EmbeddingMethod::PosEmb { levels }
            | EmbeddingMethod::PosFullEmb { levels }
            | EmbeddingMethod::PosHashEmbInter { levels, .. }
            | EmbeddingMethod::PosHashEmbIntra { levels, .. } => *levels,
            EmbeddingMethod::RandomPart { .. } => 1,
            _ => 0,
        }
    }

    /// Paper-default PosHashEmb (§IV-D): `k = ⌈n^(1/4)⌉`, `L = 3`,
    /// `c = ⌈sqrt(n/k)⌉`, `b = c·k`, `h = 2`, Intra pools.
    pub fn paper_default_intra(n: usize) -> (Self, usize) {
        let k = (n as f64).powf(0.25).ceil() as usize;
        let c = ((n as f64 / k as f64).sqrt()).ceil() as usize;
        (EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 }, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper_tables() {
        assert_eq!(EmbeddingMethod::Full.name(), "FullEmb");
        assert_eq!(EmbeddingMethod::PosEmb { levels: 3 }.name(), "PosEmb 3-level");
        assert_eq!(
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 8, h: 2 }.name(),
            "PosHashEmb Intra (h=2)"
        );
    }

    #[test]
    fn paper_defaults_for_arxiv_scale() {
        // paper: ogbn-arxiv n=169,343, alpha=1/4 -> k ≈ 21, c = ⌈sqrt(n/k)⌉ ≈ 90
        let (m, k) = EmbeddingMethod::paper_default_intra(169_343);
        assert_eq!(k, 21);
        match m {
            EmbeddingMethod::PosHashEmbIntra { levels, compression, h } => {
                assert_eq!(levels, 3);
                assert_eq!(h, 2);
                assert_eq!(compression, 90);
            }
            _ => panic!("wrong method"),
        }
    }

    #[test]
    fn hierarchy_requirements() {
        assert!(!EmbeddingMethod::Full.needs_hierarchy());
        assert!(!EmbeddingMethod::RandomPart { parts: 8 }.needs_hierarchy());
        assert!(EmbeddingMethod::PosEmb { levels: 2 }.needs_hierarchy());
        assert_eq!(EmbeddingMethod::RandomPart { parts: 8 }.levels(), 1);
    }
}
