//! Per-block compose kernels and the resolved (borrow-only) plan views.
//!
//! A "block" is a contiguous slice of the output matrix paired with the
//! node ids that fill it. All kernels accumulate into `out` in exactly
//! the same per-element order as `reference::compose_embeddings`
//! (position levels ascending, then hash functions ascending, then the
//! DHE MLP), so the engine is bitwise-deterministic and bit-identical to
//! the oracle regardless of block size or thread count — parallel blocks
//! touch disjoint output rows.

use super::dhe::{add_dhe, DheView};
use crate::embedding::plan::EmbeddingPlan;
use crate::embedding::reference::ParamStore;

/// One position level resolved to raw slices (Eq. 11 inputs).
pub(super) struct PosView<'a> {
    /// Level dimension `d_j` (columns of the level table).
    pub dj: usize,
    /// The level table, row-major `m_j × d_j`.
    pub table: &'a [f32],
    /// Per-node partition id at this level.
    pub z: &'a [u32],
}

/// The node-specific component resolved to raw slices (Eq. 12/13 inputs).
pub(super) struct NodeView<'a> {
    /// Number of hash functions `h`.
    pub h: usize,
    /// The pooled table `X`, row-major `rows × d`.
    pub table: &'a [f32],
    /// Node-major hash indices: `idx[i * h + t]` = row of X for node i
    /// under hash t (built once at plan time, so one node's `h` index
    /// entries share a cache line and the gather walks it sequentially).
    pub idx: &'a [u32],
    /// Learned importance weights `Y` (`n × h`), or `None` for `y ≡ 1`.
    pub y: Option<&'a [f32]>,
}

/// A plan with every tensor name resolved to a slice once per step, so
/// the hot loops never touch the `ParamStore` hash map.
pub(super) struct ResolvedPlan<'a> {
    pub position: Vec<PosView<'a>>,
    pub node: Option<NodeView<'a>>,
    pub dhe: Option<DheView<'a>>,
}

impl<'a> ResolvedPlan<'a> {
    /// Resolve all tables of `plan` against `params`.
    pub fn new(plan: &'a EmbeddingPlan, params: &'a ParamStore) -> Self {
        let mut position = Vec::new();
        if let Some(pos) = &plan.position {
            for (j, table) in pos.tables.iter().enumerate() {
                position.push(PosView {
                    dj: table.cols,
                    table: params.get(&table.name),
                    z: &pos.z[j],
                });
            }
        }
        let node = plan.node.as_ref().map(|nx| NodeView {
            h: nx.h,
            table: params.get(&nx.table.name),
            idx: &nx.node_major,
            y: nx.learned_weights.then(|| params.get("node_y")),
        });
        let dhe = plan.dhe.as_ref().map(|dp| DheView {
            encoding: &dp.encoding,
            encoding_dim: dp.encoding_dim,
            hidden: dp.hidden,
            layers: (0..dp.layers)
                .map(|l| (params.get(&format!("dhe_w{l}")), params.get(&format!("dhe_b{l}"))))
                .collect(),
            wout: params.get("dhe_wout"),
            bout: params.get("dhe_bout"),
        });
        ResolvedPlan { position, node, dhe }
    }
}

/// Compose embeddings for the nodes in `ids` into `out`
/// (`ids.len() × d`, row b holds node `ids[b]`). `out` must be zeroed.
pub(super) fn compose_chunk(rp: &ResolvedPlan, ids: &[u32], out: &mut [f32], d: usize) {
    debug_assert_eq!(out.len(), ids.len() * d);
    for pos in &rp.position {
        add_position(pos, ids, out, d);
    }
    if let Some(node) = &rp.node {
        add_node(node, ids, out, d);
    }
    if let Some(dhe) = &rp.dhe {
        add_dhe(dhe, ids, out, d);
    }
}

/// `dst[i] += src[i]`, in explicit 8-lane blocks with a scalar remainder.
///
/// Each lane block loads both sides into `[f32; 8]` arrays, does the
/// arithmetic lane by lane and stores the whole array back — fixed-size
/// array arithmetic the autovectorizer cannot miss (one `vaddps` per
/// block on AVX2, no trip-count analysis needed). Per-element operations
/// and their order are unchanged (one add per element), keeping the
/// engine bit-identical to the scalar oracle (`tests/compose_parity.rs`).
#[inline]
fn add_row(dst: &mut [f32], src: &[f32]) {
    let mut d8 = dst.chunks_exact_mut(8);
    let mut s8 = src.chunks_exact(8);
    for (dc, sc) in (&mut d8).zip(&mut s8) {
        let dl: &mut [f32; 8] = dc.try_into().expect("8-lane chunk");
        let sl: &[f32; 8] = sc.try_into().expect("8-lane chunk");
        let mut r = [0f32; 8];
        for l in 0..8 {
            r[l] = dl[l] + sl[l];
        }
        *dl = r;
    }
    for (o, s) in d8.into_remainder().iter_mut().zip(s8.remainder()) {
        *o += s;
    }
}

/// `dst[i] += w * src[i]`, in explicit 8-lane blocks like [`add_row`]
/// (the scalar `w` broadcasts across the lane arithmetic; per-element
/// math is the oracle's single `dst + w·src`).
#[inline]
fn add_row_scaled(dst: &mut [f32], src: &[f32], w: f32) {
    let mut d8 = dst.chunks_exact_mut(8);
    let mut s8 = src.chunks_exact(8);
    for (dc, sc) in (&mut d8).zip(&mut s8) {
        let dl: &mut [f32; 8] = dc.try_into().expect("8-lane chunk");
        let sl: &[f32; 8] = sc.try_into().expect("8-lane chunk");
        let mut r = [0f32; 8];
        for l in 0..8 {
            r[l] = dl[l] + w * sl[l];
        }
        *dl = r;
    }
    for (o, s) in d8.into_remainder().iter_mut().zip(s8.remainder()) {
        *o += w * s;
    }
}

/// `out[b][..d_j] += P_j[z_j(ids[b])]` — zero-extended level gather.
fn add_position(v: &PosView, ids: &[u32], out: &mut [f32], d: usize) {
    let dj = v.dj;
    for (b, &i) in ids.iter().enumerate() {
        let row = v.z[i as usize] as usize;
        let src = &v.table[row * dj..(row + 1) * dj];
        let dst = &mut out[b * d..b * d + dj];
        add_row(dst, src);
    }
}

/// `out[b] += Σ_t y[ids[b]][t] · X[idx_t(ids[b])]` — weighted hash gather.
///
/// Node-major traversal: per block row, the `h` index (and weight)
/// entries are read from one contiguous run of the node-major arrays,
/// and each output element still accumulates hash contributions in
/// ascending-`t` order — exactly the reference oracle's `i`-outer,
/// `t`-inner order, so float parity holds to the last ulp.
fn add_node(v: &NodeView, ids: &[u32], out: &mut [f32], d: usize) {
    let h = v.h;
    for (b, &i) in ids.iter().enumerate() {
        let i = i as usize;
        let dst = &mut out[b * d..(b + 1) * d];
        let idx = &v.idx[i * h..(i + 1) * h];
        for (t, &row) in idx.iter().enumerate() {
            let row = row as usize;
            let w = v.y.map_or(1.0, |y| y[i * h + t]);
            let src = &v.table[row * d..(row + 1) * d];
            add_row_scaled(dst, src, w);
        }
    }
}
