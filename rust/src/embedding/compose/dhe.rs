//! Blocked DHE forward pass (dense hash encoding → MLP, relu hidden
//! layers, linear output added into the composed embedding).
//!
//! The scalar loops mirror `reference::compose_embeddings` exactly so the
//! engine stays bit-identical to the oracle; the win over the reference
//! is batching (scratch activations are allocated once per block, not
//! once per node) and running blocks on all cores.

/// DHE plan + parameters resolved to raw slices.
pub(super) struct DheView<'a> {
    /// Row-major `n × encoding_dim` static encoding.
    pub encoding: &'a [f32],
    pub encoding_dim: usize,
    pub hidden: usize,
    /// Hidden layers in order: `(w_l, b_l)` with `w_l` row-major
    /// `in_dim × hidden`.
    pub layers: Vec<(&'a [f32], &'a [f32])>,
    /// Output projection `in_dim × d` and bias `d`.
    pub wout: &'a [f32],
    pub bout: &'a [f32],
}

/// `out[b] += MLP(encoding[ids[b]])` for every node in the block.
pub(super) fn add_dhe(v: &DheView, ids: &[u32], out: &mut [f32], d: usize) {
    let mut act: Vec<f32> = Vec::with_capacity(v.encoding_dim.max(v.hidden));
    let mut next: Vec<f32> = Vec::with_capacity(v.hidden);
    for (b, &i) in ids.iter().enumerate() {
        let i = i as usize;
        act.clear();
        act.extend_from_slice(&v.encoding[i * v.encoding_dim..(i + 1) * v.encoding_dim]);
        for (w, bias) in &v.layers {
            let out_dim = v.hidden;
            next.clear();
            next.resize(out_dim, 0.0);
            for (o, nv) in next.iter_mut().enumerate() {
                let mut s = bias[o];
                for (k, &a) in act.iter().enumerate() {
                    s += a * w[k * out_dim + o];
                }
                *nv = s.max(0.0); // relu
            }
            std::mem::swap(&mut act, &mut next);
        }
        let in_dim = act.len();
        let dst = &mut out[b * d..(b + 1) * d];
        for (o, dv) in dst.iter_mut().enumerate() {
            let mut s = v.bout[o];
            for k in 0..in_dim {
                s += act[k] * v.wout[k * d + o];
            }
            *dv += s;
        }
    }
}
