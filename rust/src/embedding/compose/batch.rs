//! Arbitrary-id composition: the shared fan-out that powers both
//! `compose_all` (ids = 0..n) and `compose_batch` (minibatch subsets).
//!
//! The id list is split into fixed-size blocks; each block owns a
//! disjoint slice of the output matrix, so blocks run on the rayon pool
//! with no synchronization and the result is independent of thread
//! count. Serial execution (small inputs, or `parallel = false`) runs
//! the identical kernel, so both paths produce identical bits.

use super::blocked::{compose_chunk, ResolvedPlan};
use super::ComposeOptions;
use rayon::prelude::*;

/// Compose rows for `ids` into `out` (`ids.len() × d`), overwriting it.
pub(super) fn compose_ids_into(
    rp: &ResolvedPlan,
    opts: &ComposeOptions,
    ids: &[u32],
    out: &mut [f32],
    d: usize,
) {
    assert_eq!(out.len(), ids.len() * d, "output buffer must be ids.len() × d");
    out.fill(0.0);
    let block = opts.block_nodes.max(1);
    if opts.parallel && ids.len() > block {
        out.par_chunks_mut(block * d)
            .zip(ids.par_chunks(block))
            .for_each(|(out_block, id_block)| compose_chunk(rp, id_block, out_block, d));
    } else {
        compose_chunk(rp, ids, out, d);
    }
}
