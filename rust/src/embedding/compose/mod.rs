//! Blocked, rayon-parallel embedding-compose engine — the servable
//! counterpart of the scalar oracle in `reference.rs`.
//!
//! The paper's entire contribution funnels through one computation
//! (Eq. 7): `v_i = p_i + x_i`, a sum of position-specific gathers
//! (Eq. 11), weighted node-specific hash gathers (Eq. 12/13) and, for
//! DHE, an MLP forward. [`ComposeEngine`] fuses all of it into
//! cache-friendly per-node-block passes:
//!
//! * [`ComposeEngine::compose_all`] — the full `n × d` matrix; drop-in
//!   replacement for [`reference::compose_embeddings`] (bit-identical
//!   output, parallel over node blocks).
//! * [`ComposeEngine::compose_batch`] — embeddings for an arbitrary node
//!   subset, the entry point minibatch training needs on graphs where
//!   materializing all `n × d` is exactly what the paper says to avoid.
//!
//! Table names are resolved against the [`ParamStore`] once per
//! [`ComposeEngine::prepare`] — the one-shot entry points resolve per
//! call; hot loops (the trainer's step, the evaluator's fold) resolve
//! once and compose many times through [`PreparedCompose`]. Blocks own
//! disjoint output slices (no locks, deterministic bits regardless of
//! thread count), and per-element accumulation order matches the
//! reference oracle exactly, so parity holds to the last ulp.
//! `reference.rs` stays as the oracle; `self_check` wires that parity
//! into the trainer as a startup invariant.
//!
//! [`reference::compose_embeddings`]: crate::embedding::compose_embeddings

mod batch;
mod blocked;
mod dhe;

use self::batch::compose_ids_into;
use self::blocked::ResolvedPlan;
use super::plan::EmbeddingPlan;
use super::reference::{compose_embeddings, ParamStore};

/// Tuning knobs for the engine.
#[derive(Debug, Clone)]
pub struct ComposeOptions {
    /// Nodes per parallel work unit. At `d = 64` the default keeps one
    /// block's output (~256 KiB) inside L2 while amortizing rayon's
    /// per-task overhead.
    pub block_nodes: usize,
    /// Run blocks on the rayon pool (`false` = same kernels, one thread).
    pub parallel: bool,
}

impl Default for ComposeOptions {
    fn default() -> Self {
        ComposeOptions { block_nodes: 1024, parallel: true }
    }
}

/// The compose engine: borrows a plan, composes against any parameter
/// state (parameters change every training step; the plan does not).
pub struct ComposeEngine<'p> {
    plan: &'p EmbeddingPlan,
    opts: ComposeOptions,
    /// `0..n`, materialized once so `compose_all_into` stays
    /// allocation-free on the hot path.
    all_ids: Vec<u32>,
}

impl<'p> ComposeEngine<'p> {
    /// Engine with default options.
    pub fn new(plan: &'p EmbeddingPlan) -> Self {
        Self::with_options(plan, ComposeOptions::default())
    }

    /// Engine with explicit options.
    pub fn with_options(plan: &'p EmbeddingPlan, opts: ComposeOptions) -> Self {
        assert!(opts.block_nodes >= 1, "block_nodes must be >= 1");
        let all_ids = (0..plan.n as u32).collect();
        ComposeEngine { plan, opts, all_ids }
    }

    /// The underlying plan.
    pub fn plan(&self) -> &EmbeddingPlan {
        self.plan
    }

    /// Compose the full `n × d` embedding matrix (row-major).
    pub fn compose_all(&self, params: &ParamStore) -> Vec<f32> {
        let mut out = vec![0f32; self.plan.n * self.plan.d];
        self.compose_all_into(params, &mut out);
        out
    }

    /// Resolve the plan's table names against one parameter snapshot,
    /// returning a [`PreparedCompose`] that can compose any number of
    /// id sets without re-touching the `ParamStore` hash map. The
    /// trainers resolve once per optimizer step (parameters change
    /// between steps, the plan never does); the evaluator resolves once
    /// per fold and composes every chunk through it.
    pub fn prepare<'a>(&'a self, params: &'a ParamStore) -> PreparedCompose<'a> {
        PreparedCompose {
            rp: ResolvedPlan::new(self.plan, params),
            opts: &self.opts,
            d: self.plan.d,
            n: self.plan.n as u32,
        }
    }

    /// Compose the full matrix into a caller-owned buffer (`n × d`),
    /// overwriting it — the allocation-free hot-loop variant (the id
    /// range is cached on the engine; only tiny per-call views are
    /// resolved).
    pub fn compose_all_into(&self, params: &ParamStore, out: &mut [f32]) {
        // the cached id range is 0..n by construction, so the bounds
        // pre-scan of the checked path would be pure overhead here
        self.prepare(params).compose_into_unchecked(&self.all_ids, out);
    }

    /// Compose embeddings for `nodes` only (row b = node `nodes[b]`,
    /// `nodes.len() × d` row-major). Ids may repeat and appear in any
    /// order; each must be `< n`.
    ///
    /// Subset compose returns exactly the corresponding `compose_all`
    /// rows — the invariant minibatch training rests on:
    ///
    /// ```
    /// use poshashemb::embedding::{init_params, ComposeEngine, EmbeddingMethod, EmbeddingPlan};
    ///
    /// let method = EmbeddingMethod::HashEmb { buckets: 16, h: 2 };
    /// let plan = EmbeddingPlan::build(100, 8, &method, None, 0);
    /// let params = init_params(&plan, 1);
    /// let engine = ComposeEngine::new(&plan);
    ///
    /// let full = engine.compose_all(&params);             // 100 × 8
    /// let rows = engine.compose_batch(&params, &[5, 99, 5]); // 3 × 8
    /// assert_eq!(&rows[0..8], &full[5 * 8..6 * 8]);   // row 0 = node 5
    /// assert_eq!(&rows[8..16], &full[99 * 8..100 * 8]); // row 1 = node 99
    /// assert_eq!(&rows[0..8], &rows[16..24]);         // repeats allowed
    /// ```
    pub fn compose_batch(&self, params: &ParamStore, nodes: &[u32]) -> Vec<f32> {
        let mut out = vec![0f32; nodes.len() * self.plan.d];
        self.compose_batch_into(params, nodes, &mut out);
        out
    }

    /// Batch compose into a caller-owned buffer (`nodes.len() × d`),
    /// overwriting it.
    pub fn compose_batch_into(&self, params: &ParamStore, nodes: &[u32], out: &mut [f32]) {
        self.prepare(params).compose_into(nodes, out);
    }
}

/// A compose plan resolved against one parameter snapshot: every table
/// name is looked up exactly once (in [`ComposeEngine::prepare`]), then
/// any number of id sets can be composed through the resolved views.
/// Output bits are identical to the engine's one-shot entry points —
/// this only hoists the name-resolution and view-building work out of
/// the per-call path.
pub struct PreparedCompose<'a> {
    rp: ResolvedPlan<'a>,
    opts: &'a ComposeOptions,
    d: usize,
    n: u32,
}

impl PreparedCompose<'_> {
    /// Compose rows for `nodes` into `out` (`nodes.len() × d`,
    /// overwriting it). Ids may repeat and appear in any order; each is
    /// validated `< n` before composing.
    pub fn compose_into(&self, nodes: &[u32], out: &mut [f32]) {
        let n = self.n;
        assert!(nodes.iter().all(|&i| i < n), "batch node id out of range (n = {n})");
        compose_ids_into(&self.rp, self.opts, nodes, out, self.d);
    }

    /// [`compose_into`](PreparedCompose::compose_into) without the
    /// per-call O(nodes) bounds pre-scan — for hot-path callers whose
    /// ids are in range by construction (the neighbor sampler asserts
    /// every id against `n` as it builds a block). Debug builds keep the
    /// full check; release builds fall back on the kernels' ordinary
    /// slice bounds checks, so a bad id still panics instead of reading
    /// out of bounds.
    pub(crate) fn compose_into_unchecked(&self, nodes: &[u32], out: &mut [f32]) {
        debug_assert!(
            nodes.iter().all(|&i| i < self.n),
            "batch node id out of range (n = {})",
            self.n
        );
        compose_ids_into(&self.rp, self.opts, nodes, out, self.d);
    }
}

/// Cross-check the engine against the scalar oracle on this exact
/// (plan, params) pair: full compose and a strided batch must agree
/// within `tol`. The trainer runs this at startup (cheap at our n) so an
/// engine/oracle divergence aborts a run instead of corrupting it.
pub fn self_check(plan: &EmbeddingPlan, params: &ParamStore, tol: f32) -> Result<(), String> {
    let oracle = compose_embeddings(plan, params);
    let engine = ComposeEngine::new(plan);
    let fast = engine.compose_all(params);
    let d = plan.d;
    for (i, (a, b)) in fast.iter().zip(oracle.iter()).enumerate() {
        if (a - b).abs() > tol {
            return Err(format!(
                "compose_all diverges from reference at node {} dim {}: {a} vs {b}",
                i / d,
                i % d
            ));
        }
    }
    // strided batch: prime stride to hit many blocks/partitions
    let nodes: Vec<u32> = (0..plan.n as u32).step_by(7).collect();
    let batch = engine.compose_batch(params, &nodes);
    for (b, &i) in nodes.iter().enumerate() {
        let row = &batch[b * d..(b + 1) * d];
        let want = &oracle[i as usize * d..(i as usize + 1) * d];
        for (c, (x, y)) in row.iter().zip(want).enumerate() {
            if (x - y).abs() > tol {
                return Err(format!(
                    "compose_batch diverges from reference at node {i} dim {c}: {x} vs {y}"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{init_params, EmbeddingMethod};
    use crate::graph::{planted_partition, PlantedPartitionConfig};
    use crate::partition::{Hierarchy, HierarchyConfig};

    fn hier(n: usize, k: usize, levels: usize) -> Hierarchy {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 8.0,
            inter_degree: 1.0,
            seed: 77,
            ..Default::default()
        });
        Hierarchy::build(&g, &HierarchyConfig::new(k, levels))
    }

    fn methods(n: usize) -> Vec<EmbeddingMethod> {
        let b = (n / 4).max(2);
        vec![
            EmbeddingMethod::Full,
            EmbeddingMethod::HashTrick { buckets: b },
            EmbeddingMethod::Bloom { buckets: b, h: 2 },
            EmbeddingMethod::HashEmb { buckets: b, h: 3 },
            EmbeddingMethod::UniversalHash { buckets: b },
            EmbeddingMethod::DoubleHash { buckets: b / 2 },
            EmbeddingMethod::Dhe { encoding_dim: 8, hidden: 16, layers: 1 },
            EmbeddingMethod::PosEmb { levels: 3 },
            EmbeddingMethod::RandomPart { parts: 5 },
            EmbeddingMethod::PosFullEmb { levels: 2 },
            EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: b, h: 2 },
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 4, h: 2 },
        ]
    }

    #[test]
    fn engine_is_bit_identical_to_reference_for_every_method() {
        let n = 257; // odd: exercises a ragged final block
        let h = hier(n, 3, 3);
        for method in methods(n) {
            let hr = method.needs_hierarchy().then_some(&h);
            let plan = EmbeddingPlan::build(n, 16, &method, hr, 5);
            let params = init_params(&plan, 6);
            let oracle = crate::embedding::compose_embeddings(&plan, &params);
            let engine = ComposeEngine::with_options(
                &plan,
                ComposeOptions { block_nodes: 64, parallel: true },
            );
            let fast = engine.compose_all(&params);
            assert_eq!(fast, oracle, "method {}", method.name());
        }
    }

    #[test]
    fn parallel_and_serial_agree_exactly() {
        let n = 500;
        let h = hier(n, 4, 3);
        let plan = EmbeddingPlan::build(
            n,
            32,
            &EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 6, h: 2 },
            Some(&h),
            1,
        );
        let params = init_params(&plan, 2);
        let popts = ComposeOptions { block_nodes: 32, parallel: true };
        let sopts = ComposeOptions { block_nodes: 32, parallel: false };
        let par = ComposeEngine::with_options(&plan, popts).compose_all(&params);
        let ser = ComposeEngine::with_options(&plan, sopts).compose_all(&params);
        assert_eq!(par, ser);
    }

    #[test]
    fn batch_rows_match_full_rows() {
        let n = 300;
        let h = hier(n, 3, 2);
        let plan = EmbeddingPlan::build(
            n,
            16,
            &EmbeddingMethod::PosHashEmbInter { levels: 2, buckets: 40, h: 2 },
            Some(&h),
            3,
        );
        let params = init_params(&plan, 4);
        let engine = ComposeEngine::new(&plan);
        let full = engine.compose_all(&params);
        // unordered, with repeats
        let nodes: Vec<u32> = vec![299, 0, 7, 7, 150, 3, 299];
        let batch = engine.compose_batch(&params, &nodes);
        for (b, &i) in nodes.iter().enumerate() {
            assert_eq!(
                &batch[b * 16..(b + 1) * 16],
                &full[i as usize * 16..(i as usize + 1) * 16],
                "row {b} (node {i})"
            );
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let plan = EmbeddingPlan::build(50, 8, &EmbeddingMethod::Full, None, 0);
        let params = init_params(&plan, 1);
        let engine = ComposeEngine::new(&plan);
        assert!(engine.compose_batch(&params, &[]).is_empty());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn batch_rejects_out_of_range_ids() {
        let plan = EmbeddingPlan::build(50, 8, &EmbeddingMethod::Full, None, 0);
        let params = init_params(&plan, 1);
        ComposeEngine::new(&plan).compose_batch(&params, &[50]);
    }

    #[test]
    fn block_size_one_still_correct() {
        let n = 65;
        let plan =
            EmbeddingPlan::build(n, 8, &EmbeddingMethod::HashEmb { buckets: 11, h: 2 }, None, 9);
        let params = init_params(&plan, 10);
        let opts = ComposeOptions { block_nodes: 1, parallel: true };
        let fast = ComposeEngine::with_options(&plan, opts).compose_all(&params);
        let oracle = crate::embedding::compose_embeddings(&plan, &params);
        assert_eq!(fast, oracle);
    }

    #[test]
    fn self_check_passes_and_catches_drift() {
        let n = 120;
        let h = hier(n, 3, 3);
        let (method, _) = EmbeddingMethod::paper_default_intra(n);
        let plan = EmbeddingPlan::build(n, 16, &method, Some(&h), 0);
        let params = init_params(&plan, 1);
        assert!(self_check(&plan, &params, 1e-5).is_ok());
        // exercise the failure path: a negative tolerance fails on the
        // very first element (|a - b| = 0 > -1), proving the check is live
        let err = self_check(&plan, &params, -1.0).unwrap_err();
        assert!(err.contains("diverges"), "err: {err}");
    }

    #[test]
    fn prepared_compose_matches_one_shot_entry_points() {
        let n = 310;
        let h = hier(n, 3, 2);
        let plan = EmbeddingPlan::build(
            n,
            16,
            &EmbeddingMethod::PosHashEmbInter { levels: 2, buckets: 35, h: 2 },
            Some(&h),
            8,
        );
        let params = init_params(&plan, 2);
        let engine = ComposeEngine::new(&plan);
        let prepared = engine.prepare(&params);
        let nodes: Vec<u32> = (0..n as u32).step_by(3).collect();
        let mut via_prepared = vec![f32::NAN; nodes.len() * 16];
        prepared.compose_into(&nodes, &mut via_prepared);
        assert_eq!(via_prepared, engine.compose_batch(&params, &nodes));
        // the unchecked variant composes the same bits
        let mut unchecked = vec![f32::NAN; nodes.len() * 16];
        prepared.compose_into_unchecked(&nodes, &mut unchecked);
        assert_eq!(unchecked, via_prepared);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn prepared_compose_checked_path_rejects_bad_ids() {
        let plan = EmbeddingPlan::build(50, 8, &EmbeddingMethod::Full, None, 0);
        let params = init_params(&plan, 1);
        let engine = ComposeEngine::new(&plan);
        let mut out = vec![0f32; 8];
        engine.prepare(&params).compose_into(&[50], &mut out);
    }

    #[test]
    fn into_variants_overwrite_stale_buffers() {
        let plan = EmbeddingPlan::build(40, 8, &EmbeddingMethod::Full, None, 0);
        let params = init_params(&plan, 3);
        let engine = ComposeEngine::new(&plan);
        let clean = engine.compose_all(&params);
        let mut dirty = vec![f32::NAN; 40 * 8];
        engine.compose_all_into(&params, &mut dirty);
        assert_eq!(clean, dirty);
    }
}
