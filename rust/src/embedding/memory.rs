//! Memory cost model and budget solver.
//!
//! Implements the parameter-count formulas of DESIGN.md §4 (from paper
//! §II-B/III) and, for Figure 4, solves for method hyperparameters that
//! hit a target fraction of the FullEmb size (the paper's 1/2, 1/6, 1/12
//! and 1/34 budgets).

use super::config::EmbeddingMethod;

/// A priced method: parameter count and savings vs full.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    /// Method display name.
    pub method_name: String,
    /// Trainable embedding-layer parameters.
    pub params: usize,
    /// FullEmb parameter count at the same (n, d).
    pub full_params: usize,
    /// `params / full_params`.
    pub fraction_of_full: f64,
    /// Savings vs FullEmb in percent (negative when larger than full).
    pub savings_pct: f64,
}

impl MemoryReport {
    /// Price an already-built plan.
    pub fn from_plan(plan: &super::EmbeddingPlan) -> Self {
        let params = plan.num_params();
        let full = plan.full_size();
        MemoryReport {
            method_name: plan.method.name(),
            params,
            full_params: full,
            fraction_of_full: params as f64 / full as f64,
            savings_pct: plan.savings() * 100.0,
        }
    }

    /// Paper-style row: "method  params  1/x of full  savings%".
    pub fn row(&self) -> String {
        format!(
            "| {:<26} | {:>12} | 1/{:<6.1} | {:>6.1}% |",
            self.method_name,
            self.params,
            1.0 / self.fraction_of_full.max(1e-12),
            self.savings_pct
        )
    }
}

/// Parameter count of the position-specific component for a hierarchy
/// with per-level partition counts `m` and top dimension `d`
/// (`d_j = d / 2^j`, Eq. 11 + Table IV note).
pub fn position_params(m: &[usize], d: usize) -> usize {
    m.iter().enumerate().map(|(j, &mj)| mj * (d >> j).max(1)).sum()
}

/// Solve for the method configuration that hits `fraction` of the full
/// `n·d` budget, mirroring the paper's Figure-4 protocol:
///
/// * table-based hashing baselines: choose `B` so `B·d (+ n·h) ≈ budget`;
/// * PosHashEmb: keep the 3-level position component fixed and set the
///   node-specific pool `b` to fill what remains; when the position
///   component alone exceeds the budget, fall back to PosEmb 1-level with
///   `k` chosen to fit (paper §IV-I: "when needed ... we use only the
///   position-specific component with k selected accordingly").
pub fn budget_for_fraction(
    n: usize,
    d: usize,
    m: &[usize],
    h: usize,
    fraction: f64,
) -> BudgetedMethods {
    let budget = (n as f64 * d as f64 * fraction) as usize;
    let hash_trick_b = (budget / d).max(1);
    let hash_emb_b = budget.saturating_sub(n * h).max(d) / d;
    let pos_cost = position_params(m, d);
    let m0 = m.first().copied().unwrap_or(1);
    let poshash = if pos_cost + n * h < budget {
        // fill the remainder with the node-specific pool
        let remaining = budget - pos_cost - n * h;
        let b = (remaining / d).max(m0); // at least one row per pool
        let c = (b / m0).max(1);
        PosBudget::Intra { c, h }
    } else {
        // position-only: pick k so k·d ≈ budget (1-level)
        let k = (budget / d).clamp(2, n);
        PosBudget::PositionOnly { k }
    };
    BudgetedMethods {
        budget_params: budget,
        hash_trick: EmbeddingMethod::HashTrick { buckets: hash_trick_b },
        bloom: EmbeddingMethod::Bloom { buckets: hash_trick_b, h },
        hash_emb: EmbeddingMethod::HashEmb { buckets: hash_emb_b.max(1), h },
        poshash,
    }
}

/// The PosHashEmb arm of a budget solution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PosBudget {
    /// 3-level position + intra pools of `c` rows.
    Intra {
        /// Pool rows per level-0 partition.
        c: usize,
        /// Number of hash functions.
        h: usize,
    },
    /// Budget too small for hierarchy+hash: PosEmb 1-level with `k` parts.
    PositionOnly {
        /// Partition count of the single level.
        k: usize,
    },
}

/// Methods configured to a common memory budget (one Figure-4 x-point).
#[derive(Debug, Clone)]
pub struct BudgetedMethods {
    /// The parameter budget all methods were fitted to.
    pub budget_params: usize,
    /// HashTrick at this budget.
    pub hash_trick: EmbeddingMethod,
    /// Bloom at this budget.
    pub bloom: EmbeddingMethod,
    /// HashEmb at this budget.
    pub hash_emb: EmbeddingMethod,
    /// PosHashEmb (or its position-only fallback) at this budget.
    pub poshash: PosBudget,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_params_formula() {
        // m = [4, 16, 64], d = 32: 4*32 + 16*16 + 64*8 = 128+256+512
        assert_eq!(position_params(&[4, 16, 64], 32), 896);
    }

    #[test]
    fn budget_half_gives_roughly_half_params() {
        let n = 10_000;
        let d = 64;
        let bm = budget_for_fraction(n, d, &[10, 100, 1000], 2, 0.5);
        // hash trick: B*d ≈ n*d/2
        if let EmbeddingMethod::HashTrick { buckets } = bm.hash_trick {
            let frac = (buckets * d) as f64 / (n * d) as f64;
            assert!((frac - 0.5).abs() < 0.01, "frac {frac}");
        } else {
            panic!()
        }
    }

    #[test]
    fn tiny_budget_falls_back_to_position_only() {
        let n = 10_000;
        let d = 64;
        // 1/34 of full = ~18.8k params; position component for m=[10,100,1000]
        // costs 10*64+100*32+1000*16 = 19,840 > budget - n*h  → fallback
        let bm = budget_for_fraction(n, d, &[10, 100, 1000], 2, 1.0 / 34.0);
        match bm.poshash {
            PosBudget::PositionOnly { k } => assert!(k >= 2 && k < n),
            PosBudget::Intra { .. } => panic!("expected position-only fallback"),
        }
    }

    #[test]
    fn generous_budget_gives_intra() {
        let bm = budget_for_fraction(10_000, 64, &[10, 100, 1000], 2, 0.5);
        match bm.poshash {
            PosBudget::Intra { c, h } => {
                assert!(c >= 1);
                assert_eq!(h, 2);
            }
            _ => panic!("expected intra"),
        }
    }

    #[test]
    fn hash_emb_accounts_for_importance_weights() {
        let n = 10_000;
        let d = 64;
        let bm = budget_for_fraction(n, d, &[10], 2, 0.25);
        if let EmbeddingMethod::HashEmb { buckets, h } = bm.hash_emb {
            let total = buckets * d + n * h;
            let budget = (n * d) / 4;
            assert!(total <= budget + d, "total {total} > budget {budget}");
        } else {
            panic!()
        }
    }
}
