//! Pure-Rust reference composition of embeddings.
//!
//! This is the L3-side oracle: it computes `v_i = p_i + x_i` exactly as
//! the paper defines, in plain loops. The AOT-compiled HLO (and the
//! Pallas kernel inside it) is verified against this in
//! `rust/tests/hlo_parity.rs`; it also powers the pure-Rust unit tests
//! and the `embedding_compose` criterion baseline.

use super::plan::EmbeddingPlan;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Named parameter tensors (row-major f32).
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    tensors: HashMap<String, (Vec<usize>, Vec<f32>)>,
    order: Vec<String>,
}

impl ParamStore {
    /// Insert a tensor; names must be unique.
    pub fn insert(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch for {name}");
        assert!(
            self.tensors.insert(name.to_string(), (shape, data)).is_none(),
            "duplicate tensor {name}"
        );
        self.order.push(name.to_string());
    }

    /// Tensor data by name.
    pub fn get(&self, name: &str) -> &[f32] {
        &self.tensors.get(name).unwrap_or_else(|| panic!("missing tensor {name}")).1
    }

    /// Mutable tensor data by name.
    pub fn get_mut(&mut self, name: &str) -> &mut [f32] {
        &mut self.tensors.get_mut(name).unwrap_or_else(|| panic!("missing tensor {name}")).1
    }

    /// Tensor shape by name.
    pub fn shape(&self, name: &str) -> &[usize] {
        &self.tensors.get(name).unwrap_or_else(|| panic!("missing tensor {name}")).0
    }

    /// Insertion order (canonical parameter order).
    pub fn names(&self) -> &[String] {
        &self.order
    }

    /// Total scalar count.
    pub fn num_params(&self) -> usize {
        self.tensors.values().map(|(_, d)| d.len()).sum()
    }
}

/// Deterministically initialize all tables of `plan`.
///
/// Embedding tables: uniform(-a, a) with `a = 1/sqrt(d)` (the usual
/// embedding init); importance weights `node_y`: constant 1 (paper's hash
/// embeddings start from equal contribution); DHE biases zero.
pub fn init_params(plan: &EmbeddingPlan, seed: u64) -> ParamStore {
    let mut rng = Rng::seed_from_u64(seed);
    let mut store = ParamStore::default();
    for t in plan.param_shapes() {
        let data: Vec<f32> = if t.name == "node_y" {
            vec![1.0; t.size()]
        } else if t.name.starts_with("dhe_b") {
            vec![0.0; t.size()]
        } else {
            let a = 1.0 / (t.cols as f32).sqrt();
            (0..t.size()).map(|_| rng.gen_f32_range(-a, a)).collect()
        };
        store.insert(&t.name, vec![t.rows, t.cols], data);
    }
    store
}

/// Compose the full `n × d` embedding matrix (row-major) from `plan` and
/// `params` — the reference implementation of Eq. 7/11/12/13 and the DHE
/// forward pass.
pub fn compose_embeddings(plan: &EmbeddingPlan, params: &ParamStore) -> Vec<f32> {
    let n = plan.n;
    let d = plan.d;
    let mut out = vec![0f32; n * d];

    // position-specific: v[i][..d_j] += P_j[z_j(i)]
    if let Some(pos) = &plan.position {
        for (j, table) in pos.tables.iter().enumerate() {
            let pj = params.get(&table.name);
            let dj = table.cols;
            let z = &pos.z[j];
            for i in 0..n {
                let row = z[i] as usize;
                debug_assert!(row < table.rows);
                let src = &pj[row * dj..(row + 1) * dj];
                let dst = &mut out[i * d..i * d + dj];
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += s;
                }
            }
        }
    }

    // node-specific: v[i] += Σ_t y[i][t] · X[idx_t(i)], reading the
    // plan's node-major index layout (node i's h rows are adjacent at
    // `node_major[i * h..(i + 1) * h]` — the same walk the engine does,
    // with the same i-outer / t-inner accumulation order)
    if let Some(node) = &plan.node {
        let x = params.get(&node.table.name);
        let h = node.h;
        let y: Option<&[f32]> = node.learned_weights.then(|| params.get("node_y"));
        for i in 0..n {
            for (t, &row) in node.node_major[i * h..(i + 1) * h].iter().enumerate() {
                let row = row as usize;
                debug_assert!(row < node.table.rows);
                let w = y.map_or(1.0, |y| y[i * h + t]);
                let src = &x[row * d..(row + 1) * d];
                let dst = &mut out[i * d..(i + 1) * d];
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    }

    // DHE: v[i] += MLP(encoding[i]); relu activations, linear output.
    if let Some(dhe) = &plan.dhe {
        let mut act: Vec<f32> = Vec::new();
        for i in 0..n {
            act.clear();
            act.extend_from_slice(&dhe.encoding[i * dhe.encoding_dim..(i + 1) * dhe.encoding_dim]);
            for l in 0..dhe.layers {
                let w = params.get(&format!("dhe_w{l}"));
                let b = params.get(&format!("dhe_b{l}"));
                let (in_dim, out_dim) = (act.len(), dhe.hidden);
                let mut next = vec![0f32; out_dim];
                for (o, nv) in next.iter_mut().enumerate() {
                    let mut s = b[o];
                    for (k, &a) in act.iter().enumerate() {
                        s += a * w[k * out_dim + o];
                    }
                    *nv = s.max(0.0); // relu
                }
                debug_assert_eq!(in_dim, params.shape(&format!("dhe_w{l}"))[0]);
                act = next;
            }
            let w = params.get("dhe_wout");
            let b = params.get("dhe_bout");
            let in_dim = act.len();
            let dst = &mut out[i * d..(i + 1) * d];
            for (o, dv) in dst.iter_mut().enumerate() {
                let mut s = b[o];
                for k in 0..in_dim {
                    s += act[k] * w[k * d + o];
                }
                *dv += s;
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingMethod;
    use crate::graph::{planted_partition, PlantedPartitionConfig};
    use crate::partition::{Hierarchy, HierarchyConfig};

    fn hier(n: usize, k: usize, levels: usize) -> Hierarchy {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n,
            communities: k,
            intra_degree: 8.0,
            inter_degree: 1.0,
            seed: 61,
            ..Default::default()
        });
        Hierarchy::build(&g, &HierarchyConfig::new(k, levels))
    }

    #[test]
    fn fullemb_is_table_lookup() {
        let plan = EmbeddingPlan::build(10, 4, &EmbeddingMethod::Full, None, 0);
        let params = init_params(&plan, 1);
        let v = compose_embeddings(&plan, &params);
        let w = params.get("node_x");
        assert_eq!(v, w); // identity indices, y=1: v == W exactly
    }

    #[test]
    fn posemb_nodes_in_same_partition_share_embedding() {
        let n = 200;
        let h = hier(n, 4, 1);
        let plan = EmbeddingPlan::build(n, 8, &EmbeddingMethod::PosEmb { levels: 1 }, Some(&h), 2);
        let params = init_params(&plan, 3);
        let v = compose_embeddings(&plan, &params);
        for i in 0..n {
            for j in (i + 1)..n {
                if h.z[0][i] == h.z[0][j] {
                    assert_eq!(v[i * 8..(i + 1) * 8], v[j * 8..(j + 1) * 8]);
                }
            }
        }
    }

    #[test]
    fn hierarchy_sum_matches_manual() {
        let n = 50;
        let h = hier(n, 2, 2);
        let plan = EmbeddingPlan::build(n, 8, &EmbeddingMethod::PosEmb { levels: 2 }, Some(&h), 4);
        let params = init_params(&plan, 5);
        let v = compose_embeddings(&plan, &params);
        // manual check node 7: P0[z0] zero-extended + P1[z1] zero-extended
        let i = 7usize;
        let p0 = params.get("pos_0");
        let p1 = params.get("pos_1");
        let z0 = h.z[0][i] as usize;
        let z1 = h.z[1][i] as usize;
        for c in 0..8 {
            let a = p0[z0 * 8 + c];
            let b = if c < 4 { p1[z1 * 4 + c] } else { 0.0 };
            assert!((v[i * 8 + c] - (a + b)).abs() < 1e-6);
        }
    }

    #[test]
    fn hashemb_weights_scale_contributions() {
        let n = 20;
        let plan =
            EmbeddingPlan::build(n, 4, &EmbeddingMethod::HashEmb { buckets: 6, h: 2 }, None, 6);
        let mut params = init_params(&plan, 7);
        // zero out the second hash's weight for node 3 and check v changes
        let v1 = compose_embeddings(&plan, &params);
        params.get_mut("node_y")[3 * 2 + 1] = 0.0;
        let v2 = compose_embeddings(&plan, &params);
        let node = plan.node.as_ref().unwrap();
        let x = params.get("node_x");
        let idx = node.node_major[3 * node.h + 1] as usize;
        for c in 0..4 {
            let expect = v1[3 * 4 + c] - x[idx * 4 + c];
            assert!((v2[3 * 4 + c] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn bloom_is_unweighted_sum_of_two_rows() {
        let n = 10;
        let plan =
            EmbeddingPlan::build(n, 4, &EmbeddingMethod::Bloom { buckets: 5, h: 2 }, None, 8);
        let params = init_params(&plan, 9);
        let v = compose_embeddings(&plan, &params);
        let node = plan.node.as_ref().unwrap();
        let x = params.get("node_x");
        for i in 0..n {
            let (r0, r1) = (node.node_major[i * 2] as usize, node.node_major[i * 2 + 1] as usize);
            for c in 0..4 {
                let expect = x[r0 * 4 + c] + x[r1 * 4 + c];
                assert!((v[i * 4 + c] - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn poshash_is_sum_of_components() {
        let n = 120;
        let h = hier(n, 3, 3);
        let full = EmbeddingPlan::build(
            n,
            16,
            &EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: 20, h: 2 },
            Some(&h),
            10,
        );
        let params = init_params(&full, 11);
        let v = compose_embeddings(&full, &params);

        // position-only plan with the same tables
        let pos_only =
            EmbeddingPlan::build(n, 16, &EmbeddingMethod::PosEmb { levels: 3 }, Some(&h), 10);
        let mut pos_params = ParamStore::default();
        for t in pos_only.param_shapes() {
            pos_params.insert(&t.name, vec![t.rows, t.cols], params.get(&t.name).to_vec());
        }
        let p = compose_embeddings(&pos_only, &pos_params);
        // x = v - p must equal the node-specific composition alone
        let node_only =
            EmbeddingPlan::build(n, 16, &EmbeddingMethod::HashEmb { buckets: 20, h: 2 }, None, 10);
        let mut node_params = ParamStore::default();
        node_params.insert("node_x", vec![20, 16], params.get("node_x").to_vec());
        node_params.insert("node_y", vec![n, 2], params.get("node_y").to_vec());
        let x = compose_embeddings(&node_only, &node_params);
        for i in 0..n * 16 {
            assert!((v[i] - (p[i] + x[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn dhe_forward_is_finite_and_nonzero() {
        let plan = EmbeddingPlan::build(
            30,
            8,
            &EmbeddingMethod::Dhe { encoding_dim: 16, hidden: 32, layers: 1 },
            None,
            12,
        );
        let params = init_params(&plan, 13);
        let v = compose_embeddings(&plan, &params);
        assert!(v.iter().all(|x| x.is_finite()));
        assert!(v.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_is_deterministic() {
        let plan = EmbeddingPlan::build(50, 8, &EmbeddingMethod::Full, None, 0);
        let a = init_params(&plan, 42);
        let b = init_params(&plan, 42);
        assert_eq!(a.get("node_x"), b.get("node_x"));
    }
}
