//! # poshashemb
//!
//! Production-grade reproduction of *"Position-based Hash Embeddings For
//! Scaling Graph Neural Networks"* (Kalantzi & Karypis, 2021) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — coordinator and substrates: CSR graphs,
//!   a from-scratch multilevel k-way partitioner (METIS substitute),
//!   universal hashing, embedding plans for every method in the paper,
//!   synthetic homophilous datasets, neighbor-sampled minibatch training
//!   on the compose engine, the training orchestrator, and the PJRT
//!   runtime that executes AOT-compiled training steps.
//! * **Layer 2** — GNN models (GCN / GraphSAGE / GAT) + loss + Adam in
//!   JAX, lowered once to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — the embedding gather/combine hot-spot as a Pallas
//!   kernel (`python/compile/kernels/gather_combine.py`).
//!
//! Python never runs at training time: the Rust binary loads
//! `artifacts/*.hlo.txt` via PJRT and owns the training loop. The
//! host-side minibatch trainer needs no artifacts at all.
//!
//! See `docs/ARCHITECTURE.md` for the end-to-end data flow and the
//! per-layer invariants, `DESIGN.md` for the full system inventory and
//! `EXPERIMENTS.md` for paper-vs-measured results.

#![warn(missing_docs)]

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embedding;
pub mod graph;
pub mod hashing;
pub mod metrics;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;
