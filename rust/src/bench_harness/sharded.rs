//! `sharded/v1` bench records for partition-sharded training runs.
//!
//! The `train-sharded` CLI subcommand trains a
//! [`ShardedTrainer`](crate::coordinator::ShardedTrainer) and emits one
//! [`ShardedBenchRecord`] per run: run-level aggregates (edge cut, halo
//! traffic, peak resident table bytes vs the FullEmb baseline, loss
//! trajectory) plus one [`ShardBenchRecord`] per shard (nodes/s, halo
//! bytes exchanged, resident bytes). CI's `train-sharded` smoke job
//! validates these records and asserts the per-shard memory bound
//! `resident_table_bytes ≤ 1.15 · full_table_bytes / k + halo-row
//! bytes` on them; the JSON key set is pinned by a test below.

use super::RecordMeta;
use crate::coordinator::ShardedOutcome;
use serde::Serialize;

/// Per-shard slice of a `sharded/v1` record.
#[derive(Debug, Clone, Serialize)]
pub struct ShardBenchRecord {
    /// Shard id in `[0, k)`.
    pub shard: usize,
    /// Nodes this shard owns.
    pub owned_nodes: usize,
    /// One-hop halo replicas resident on this shard.
    pub halo_nodes: usize,
    /// Undirected edges in the shard's local induced subgraph.
    pub local_edges: u64,
    /// Training seed nodes per epoch.
    pub train_seeds: usize,
    /// Resident embedding-table bytes (the shard's whole
    /// optimizer-visible table footprint).
    pub resident_table_bytes: u64,
    /// Rows one full halo exchange + node sync refreshes.
    pub halo_rows: usize,
    /// Bytes pulled by one per-epoch table exchange.
    pub halo_bytes_per_exchange: u64,
    /// Bytes pulled by one periodic node-table sync.
    pub node_sync_bytes: u64,
    /// Training seeds per second on this shard.
    pub nodes_per_sec: f64,
    /// Mean training loss of the shard's final epoch.
    pub final_loss: f64,
}

/// One `train-sharded` run, serializable for the CI `sharded-bench`
/// artifact.
#[derive(Debug, Clone, Serialize)]
pub struct ShardedBenchRecord {
    /// Graph/dataset display name.
    pub dataset: String,
    /// Method tag trained per shard.
    pub method: String,
    /// Number of shards.
    pub k: usize,
    /// Nodes in the global graph.
    pub n: usize,
    /// Undirected edges in the global graph.
    pub edges: u64,
    /// Embedding dimension.
    pub d: usize,
    /// Epochs trained.
    pub epochs: usize,
    /// Node-table sync period in epochs (0 = initial sync only).
    pub sync_every: usize,
    /// Weighted edge cut the sharding pays.
    pub edge_cut: f64,
    /// FullEmb reference table bytes at this (n, d): `n·d·4`.
    pub full_table_bytes: u64,
    /// Largest per-shard resident table bytes — the memory headline:
    /// bounded by `full_table_bytes / k` plus halo replica rows.
    pub peak_resident_table_bytes: u64,
    /// Total bytes moved by all halo exchanges and node syncs.
    pub halo_bytes_total: u64,
    /// Per-epoch table exchanges performed.
    pub exchanges: usize,
    /// Owned-node-weighted validation metric.
    pub val_metric: f64,
    /// Owned-node-weighted test metric.
    pub test_metric: f64,
    /// Aggregate mean loss of the final epoch.
    pub final_loss: f64,
    /// Wall-clock seconds for the whole run.
    pub wall_secs: f64,
    /// Run seed.
    pub seed: u64,
    /// Per-shard statistics, indexed by shard id.
    pub shards: Vec<ShardBenchRecord>,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl ShardedBenchRecord {
    /// Build the record from a finished run.
    pub fn from_outcome(
        dataset: &str,
        method: &str,
        n: usize,
        edges: u64,
        d: usize,
        sync_every: usize,
        seed: u64,
        out: &ShardedOutcome,
    ) -> Self {
        ShardedBenchRecord {
            dataset: dataset.to_string(),
            method: method.to_string(),
            k: out.k,
            n,
            edges,
            d,
            epochs: out.losses.len(),
            sync_every,
            edge_cut: out.edge_cut,
            full_table_bytes: out.full_table_bytes,
            peak_resident_table_bytes: out.peak_resident_table_bytes,
            halo_bytes_total: out.halo_bytes_total,
            exchanges: out.exchanges,
            val_metric: out.val_metric,
            test_metric: out.test_metric,
            final_loss: out.losses.last().copied().unwrap_or(f64::NAN),
            wall_secs: out.wall.as_secs_f64(),
            seed,
            shards: out
                .shards
                .iter()
                .map(|s| ShardBenchRecord {
                    shard: s.shard,
                    owned_nodes: s.owned_nodes,
                    halo_nodes: s.halo_nodes,
                    local_edges: s.local_edges,
                    train_seeds: s.train_seeds,
                    resident_table_bytes: s.resident_table_bytes,
                    halo_rows: s.halo_rows,
                    halo_bytes_per_exchange: s.halo_bytes_per_exchange,
                    node_sync_bytes: s.node_sync_bytes,
                    nodes_per_sec: s.nodes_per_sec,
                    final_loss: s.losses.last().copied().unwrap_or(f64::NAN),
                })
                .collect(),
            meta: RecordMeta::capture("sharded/v1"),
        }
    }

    /// Human-readable report line.
    pub fn row(&self) -> String {
        format!(
            "k={:<3} cut={:<10.0} peak_mem={:>5.1}% of full  halo={:>8}B/epoch  test={:.4}",
            self.k,
            self.edge_cut,
            self.peak_resident_table_bytes as f64 / self.full_table_bytes.max(1) as f64 * 100.0,
            self.shards.iter().map(|s| s.halo_bytes_per_exchange).sum::<u64>(),
            self.test_metric
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the exact JSON key set of the `sharded/v1` record — the CI
    /// smoke's inline validator (`.github/workflows/ci.yml`) reads
    /// these names.
    #[test]
    fn sharded_record_json_keys_are_stable() {
        let rec = ShardedBenchRecord {
            dataset: "rmat-powerlaw".into(),
            method: "intra(l=2,c=4,h=2)".into(),
            k: 2,
            n: 8,
            edges: 9,
            d: 8,
            epochs: 1,
            sync_every: 1,
            edge_cut: 3.0,
            full_table_bytes: 256,
            peak_resident_table_bytes: 160,
            halo_bytes_total: 64,
            exchanges: 1,
            val_metric: 0.5,
            test_metric: 0.5,
            final_loss: 1.0,
            wall_secs: 0.1,
            seed: 0,
            shards: vec![ShardBenchRecord {
                shard: 0,
                owned_nodes: 4,
                halo_nodes: 2,
                local_edges: 6,
                train_seeds: 3,
                resident_table_bytes: 160,
                halo_rows: 2,
                halo_bytes_per_exchange: 32,
                node_sync_bytes: 16,
                nodes_per_sec: 10.0,
                final_loss: 1.0,
            }],
            meta: RecordMeta::capture("sharded/v1"),
        };
        let v = serde_json::to_value(&rec).unwrap();
        let keys = |v: &serde_json::Value| -> Vec<String> {
            let mut k: Vec<String> = v.as_object().unwrap().keys().cloned().collect();
            k.sort();
            k
        };
        let mut want = vec![
            "dataset",
            "method",
            "k",
            "n",
            "edges",
            "d",
            "epochs",
            "sync_every",
            "edge_cut",
            "full_table_bytes",
            "peak_resident_table_bytes",
            "halo_bytes_total",
            "exchanges",
            "val_metric",
            "test_metric",
            "final_loss",
            "wall_secs",
            "seed",
            "shards",
            "schema",
            "threads",
            "git_sha",
        ];
        want.sort_unstable();
        assert_eq!(keys(&v), want);
        let mut shard_want = vec![
            "shard",
            "owned_nodes",
            "halo_nodes",
            "local_edges",
            "train_seeds",
            "resident_table_bytes",
            "halo_rows",
            "halo_bytes_per_exchange",
            "node_sync_bytes",
            "nodes_per_sec",
            "final_loss",
        ];
        shard_want.sort_unstable();
        assert_eq!(keys(&v["shards"][0]), shard_want);
        assert_eq!(v["schema"], "sharded/v1");
        assert!(rec.row().contains("peak_mem"));
    }
}
