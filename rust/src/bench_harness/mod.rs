//! Shared experiment harness for the benches and the `experiment` CLI
//! subcommand: runs groups of experiments over multiple seeds and prints
//! paper-style tables (mean ± std per cell).
//!
//! Seeds default to 2 and are controlled with `POSHASH_SEEDS`; epochs can
//! be capped with `POSHASH_EPOCHS` (useful for CI smoke runs).

use crate::config::{full_grid, Experiment};
use crate::coordinator::{run_experiment, TrainOptions, TrainOutcome};
use crate::metrics::fmt_cell;
use crate::runtime::{Manifest, RuntimeClient};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Reusable harness: PJRT client + manifest + options.
pub struct Harness {
    pub client: RuntimeClient,
    pub manifest: Manifest,
    pub opts: TrainOptions,
    pub seeds: Vec<u64>,
}

impl Harness {
    /// Build from the default `artifacts/` dir and env knobs.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("POSHASH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let client = RuntimeClient::cpu()?;
        let manifest = Manifest::load(Path::new(&dir))?;
        let num_seeds: usize = std::env::var("POSHASH_SEEDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2);
        let mut opts = TrainOptions::default();
        if let Ok(ep) = std::env::var("POSHASH_EPOCHS") {
            opts.epochs = ep.parse().ok();
        }
        if let Ok(p) = std::env::var("POSHASH_PATIENCE") {
            if let Ok(p) = p.parse() {
                opts.patience = p;
            }
        }
        opts.verbose = std::env::var("POSHASH_VERBOSE").map_or(false, |v| v == "1");
        Ok(Harness { client, manifest, opts, seeds: (0..num_seeds as u64).collect() })
    }

    /// All grid experiments in `group`, optionally filtered by dataset.
    pub fn group(&self, group: &str, dataset: Option<&str>) -> Vec<Experiment> {
        full_grid()
            .into_iter()
            .filter(|e| e.group == group)
            .filter(|e| dataset.map_or(true, |d| e.dataset == d))
            .filter(|e| self.manifest.contains(&format!("{}.train", e.name)))
            .collect()
    }

    /// Run one experiment over all seeds.
    pub fn run_seeds(&self, e: &Experiment) -> Result<Vec<TrainOutcome>> {
        let mut outs = Vec::new();
        for &seed in &self.seeds {
            let o = run_experiment(&self.client, &self.manifest, e, seed, &self.opts)?;
            eprintln!("    {}", o.row());
            outs.push(o);
        }
        Ok(outs)
    }

    /// Run a set of experiments, returning name → outcomes.
    pub fn run_all(&self, exps: &[Experiment]) -> Result<BTreeMap<String, Vec<TrainOutcome>>> {
        let mut map = BTreeMap::new();
        for e in exps {
            eprintln!("[{}] {}", e.group, e.name);
            map.insert(e.name.clone(), self.run_seeds(e)?);
        }
        Ok(map)
    }
}

/// One row of a paper-style table.
pub struct TableRow {
    pub label: String,
    /// (column label, metric samples, params) per dataset/model column.
    pub cells: Vec<(String, Vec<f64>, usize)>,
}

/// Print a paper-style table: rows = methods, columns = (dataset, model).
pub fn print_table(title: &str, rows: &[TableRow]) {
    println!("\n### {title}\n");
    if rows.is_empty() {
        println!("(no results — did `make artifacts` include this grid?)");
        return;
    }
    // header from the first row's columns
    print!("| {:<28} |", "Method");
    for (col, _, _) in &rows[0].cells {
        print!(" {col:<22} |");
    }
    println!();
    print!("|{}|", "-".repeat(30));
    for _ in &rows[0].cells {
        print!("{}|", "-".repeat(24));
    }
    println!();
    for row in rows {
        print!("| {:<28} |", row.label);
        for (_, samples, params) in &row.cells {
            if samples.is_empty() {
                print!(" {:<22} |", "—");
            } else {
                print!(" {:<22} |", format!("{} ({}p)", fmt_cell(samples), short(*params)));
            }
        }
        println!();
    }
}

fn short(params: usize) -> String {
    if params >= 1_000_000 {
        format!("{:.1}M", params as f64 / 1e6)
    } else if params >= 1_000 {
        format!("{:.0}k", params as f64 / 1e3)
    } else {
        params.to_string()
    }
}

/// Collect outcomes into table rows: one row per method tag, one column
/// per (dataset, model) pair present.
pub fn rows_from_outcomes(
    exps: &[Experiment],
    outcomes: &BTreeMap<String, Vec<TrainOutcome>>,
    label_of: impl Fn(&Experiment) -> String,
) -> Vec<TableRow> {
    // columns in stable order
    let mut columns: Vec<(String, String)> = Vec::new(); // (dataset, model)
    for e in exps {
        let col = (e.dataset.to_string(), e.model.as_str().to_string());
        if !columns.contains(&col) {
            columns.push(col);
        }
    }
    let mut labels: Vec<String> = Vec::new();
    for e in exps {
        let l = label_of(e);
        if !labels.contains(&l) {
            labels.push(l);
        }
    }
    labels
        .into_iter()
        .map(|label| {
            let cells = columns
                .iter()
                .map(|(ds, model)| {
                    let col_label = format!("{} / {}", ds.trim_start_matches("synth-"), model);
                    let mut samples = Vec::new();
                    let mut params = 0usize;
                    for e in exps {
                        if label_of(e) == label
                            && e.dataset == ds.as_str()
                            && e.model.as_str() == model
                        {
                            if let Some(outs) = outcomes.get(&e.name) {
                                samples.extend(outs.iter().map(|o| o.test_metric));
                                params = outs.first().map_or(0, |o| o.memory.params);
                            }
                        }
                    }
                    (col_label, samples, params)
                })
                .collect();
            TableRow { label, cells }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_formatting() {
        assert_eq!(short(42), "42");
        assert_eq!(short(12_000), "12k");
        assert_eq!(short(3_400_000), "3.4M");
    }
}
