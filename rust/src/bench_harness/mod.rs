//! Shared experiment harness for the benches and the CLI:
//!
//! * [`Harness`] + table printers — runs groups of experiments over
//!   multiple seeds through the PJRT runtime and prints paper-style
//!   tables (mean ± std per cell). Needs the `pjrt` feature at runtime.
//! * [`bench_compose`] — host-side compose benchmarking shared by
//!   `benches/embedding_compose.rs` and the `poshashemb compose`
//!   subcommand: reference oracle vs [`ComposeEngine`] full-matrix vs
//!   minibatch paths, with serde-serializable records for CI smoke.
//! * [`bench_minibatch`] — host-side minibatch-training benchmarking
//!   shared by `benches/minibatch.rs` and the `poshashemb
//!   train-minibatch` subcommand: trains a configuration end to end and
//!   records per-epoch timing, nodes/s and batches/s.
//! * [`run_showdown`] — the paper's memory/accuracy claim at the CLI:
//!   sweeps (method × task × memory budget), training every cell with
//!   the minibatch trainer and emitting one [`ShowdownRecord`] per cell
//!   (see the `showdown` submodule).
//!
//! Seeds default to 2 and are controlled with `POSHASH_SEEDS`; epochs can
//! be capped with `POSHASH_EPOCHS` (useful for CI smoke runs).

use crate::config::{full_grid, Experiment};
use crate::coordinator::{
    run_experiment, MinibatchOptions, MinibatchTrainer, TrainOptions, TrainOutcome,
};
use crate::data::Dataset;
use crate::embedding::{compose_embeddings, init_params, ComposeEngine, EmbeddingPlan};
use crate::graph::GraphStore;
use crate::metrics::fmt_cell;
use crate::partition::{
    coarsen, coarsen_reference, heavy_edge_matching, parallel_heavy_edge_matching, partition,
    Hierarchy, HierarchyConfig, PartitionConfig,
};
use crate::runtime::{Manifest, RuntimeClient};
use crate::sampler::SamplerConfig;
use crate::util::bench::{bench, black_box, BenchResult};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use serde::Serialize;
use std::collections::BTreeMap;
use std::path::Path;

mod sharded;
mod showdown;
pub use sharded::{ShardBenchRecord, ShardedBenchRecord};
pub use showdown::{run_showdown, ShowdownConfig, ShowdownRecord};

/// Reusable harness: PJRT client + manifest + options.
pub struct Harness {
    /// PJRT execution backend (stub without the `pjrt` feature).
    pub client: RuntimeClient,
    /// Parsed artifact manifest.
    pub manifest: Manifest,
    /// Training options shared by every run.
    pub opts: TrainOptions,
    /// Seeds each experiment is repeated over.
    pub seeds: Vec<u64>,
}

impl Harness {
    /// Build from the default `artifacts/` dir and env knobs.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("POSHASH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        let client = RuntimeClient::cpu()?;
        let manifest = Manifest::load(Path::new(&dir))?;
        let num_seeds: usize = std::env::var("POSHASH_SEEDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(2);
        let mut opts = TrainOptions::default();
        if let Ok(ep) = std::env::var("POSHASH_EPOCHS") {
            opts.epochs = ep.parse().ok();
        }
        if let Ok(p) = std::env::var("POSHASH_PATIENCE") {
            if let Ok(p) = p.parse() {
                opts.patience = p;
            }
        }
        opts.verbose = std::env::var("POSHASH_VERBOSE").is_ok_and(|v| v == "1");
        Ok(Harness { client, manifest, opts, seeds: (0..num_seeds as u64).collect() })
    }

    /// All grid experiments in `group`, optionally filtered by dataset.
    pub fn group(&self, group: &str, dataset: Option<&str>) -> Vec<Experiment> {
        full_grid()
            .into_iter()
            .filter(|e| e.group == group)
            .filter(|e| dataset.is_none_or(|d| e.dataset == d))
            .filter(|e| self.manifest.contains(&format!("{}.train", e.name)))
            .collect()
    }

    /// Run one experiment over all seeds.
    pub fn run_seeds(&self, e: &Experiment) -> Result<Vec<TrainOutcome>> {
        let mut outs = Vec::new();
        for &seed in &self.seeds {
            let o = run_experiment(&self.client, &self.manifest, e, seed, &self.opts)?;
            eprintln!("    {}", o.row());
            outs.push(o);
        }
        Ok(outs)
    }

    /// Run a set of experiments, returning name → outcomes.
    pub fn run_all(&self, exps: &[Experiment]) -> Result<BTreeMap<String, Vec<TrainOutcome>>> {
        let mut map = BTreeMap::new();
        for e in exps {
            eprintln!("[{}] {}", e.group, e.name);
            map.insert(e.name.clone(), self.run_seeds(e)?);
        }
        Ok(map)
    }
}

/// One row of a paper-style table.
pub struct TableRow {
    /// Row label (method name).
    pub label: String,
    /// (column label, metric samples, params) per dataset/model column.
    pub cells: Vec<(String, Vec<f64>, usize)>,
}

/// Print a paper-style table: rows = methods, columns = (dataset, model).
pub fn print_table(title: &str, rows: &[TableRow]) {
    println!("\n### {title}\n");
    if rows.is_empty() {
        println!("(no results — did `make artifacts` include this grid?)");
        return;
    }
    // header from the first row's columns
    print!("| {:<28} |", "Method");
    for (col, _, _) in &rows[0].cells {
        print!(" {col:<22} |");
    }
    println!();
    print!("|{}|", "-".repeat(30));
    for _ in &rows[0].cells {
        print!("{}|", "-".repeat(24));
    }
    println!();
    for row in rows {
        print!("| {:<28} |", row.label);
        for (_, samples, params) in &row.cells {
            if samples.is_empty() {
                print!(" {:<22} |", "—");
            } else {
                print!(" {:<22} |", format!("{} ({}p)", fmt_cell(samples), short(*params)));
            }
        }
        println!();
    }
}

fn short(params: usize) -> String {
    if params >= 1_000_000 {
        format!("{:.1}M", params as f64 / 1e6)
    } else if params >= 1_000 {
        format!("{:.0}k", params as f64 / 1e3)
    } else {
        params.to_string()
    }
}

/// Collect outcomes into table rows: one row per method tag, one column
/// per (dataset, model) pair present.
pub fn rows_from_outcomes(
    exps: &[Experiment],
    outcomes: &BTreeMap<String, Vec<TrainOutcome>>,
    label_of: impl Fn(&Experiment) -> String,
) -> Vec<TableRow> {
    // columns in stable order
    let mut columns: Vec<(String, String)> = Vec::new(); // (dataset, model)
    for e in exps {
        let col = (e.dataset.to_string(), e.model.as_str().to_string());
        if !columns.contains(&col) {
            columns.push(col);
        }
    }
    let mut labels: Vec<String> = Vec::new();
    for e in exps {
        let l = label_of(e);
        if !labels.contains(&l) {
            labels.push(l);
        }
    }
    labels
        .into_iter()
        .map(|label| {
            let cells = columns
                .iter()
                .map(|(ds, model)| {
                    let col_label = format!("{} / {}", ds.trim_start_matches("synth-"), model);
                    let mut samples = Vec::new();
                    let mut params = 0usize;
                    for e in exps {
                        if label_of(e) == label
                            && e.dataset == ds.as_str()
                            && e.model.as_str() == model
                        {
                            if let Some(outs) = outcomes.get(&e.name) {
                                samples.extend(outs.iter().map(|o| o.test_metric));
                                params = outs.first().map_or(0, |o| o.memory.params);
                            }
                        }
                    }
                    (col_label, samples, params)
                })
                .collect();
            TableRow { label, cells }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Host-side compose benchmarking (no PJRT needed)
// ---------------------------------------------------------------------

/// Rayon worker threads the current process runs benches with (recorded
/// on every bench record so throughput numbers are comparable across
/// machines and across the committed `BENCH_baseline.json`).
fn bench_threads() -> usize {
    rayon::current_num_threads()
}

/// The commit the record was produced at: `GITHUB_SHA` in CI (or a
/// `GIT_SHA` override), `"unknown"` when run outside CI — so the
/// per-commit throughput trajectory in the uploaded artifacts is
/// self-describing. Model-artifact manifests reuse the same convention.
pub fn bench_git_sha() -> String {
    std::env::var("GITHUB_SHA")
        .or_else(|_| std::env::var("GIT_SHA"))
        .unwrap_or_else(|_| "unknown".to_string())
}

/// Fields every bench record carries — the one envelope
/// `ci/bench_baseline.py` and the uploaded-artifact consumers read
/// uniformly. Embedded into each record via `#[serde(flatten)]` so the
/// JSON stays flat and the pre-envelope key names are preserved
/// (pinned by `record_json_envelopes_are_stable`).
#[derive(Debug, Clone, Serialize)]
pub struct RecordMeta {
    /// Record schema tag (`"<kind>-bench/v1"`) so mixed artifact files
    /// can be classified without guessing from field names.
    pub schema: String,
    /// Rayon worker threads available to the run.
    pub threads: usize,
    /// Commit the record was produced at (`GITHUB_SHA`, or "unknown").
    pub git_sha: String,
}

impl RecordMeta {
    /// Capture the environment for a record of the given schema tag.
    pub fn capture(schema: &str) -> Self {
        RecordMeta {
            schema: schema.to_string(),
            threads: bench_threads(),
            git_sha: bench_git_sha(),
        }
    }
}

/// One measured compose path, serializable for CI smoke artifacts.
#[derive(Debug, Clone, Serialize)]
pub struct ComposeBenchRecord {
    /// Method display name (paper table naming).
    pub method: String,
    /// "reference" | "parallel" | "batch".
    pub path: String,
    /// Nodes in the graph.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Rows composed per invocation (n, or the batch size).
    pub rows: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Mean wall time per invocation in nanoseconds.
    pub mean_ns: u64,
    /// Median wall time in nanoseconds.
    pub p50_ns: u64,
    /// 95th-percentile wall time in nanoseconds.
    pub p95_ns: u64,
    /// Composed elements (rows × d) per second.
    pub elements_per_sec: f64,
    /// Mean-time ratio vs the reference path, normalized per row
    /// (so the batch path is comparable). `None` for the reference row.
    pub speedup_vs_reference: Option<f64>,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl ComposeBenchRecord {
    fn from_result(plan: &EmbeddingPlan, path: &str, rows: usize, r: &BenchResult) -> Self {
        let elements = (rows * plan.d) as f64;
        ComposeBenchRecord {
            method: plan.method.name(),
            path: path.to_string(),
            n: plan.n,
            d: plan.d,
            rows,
            iters: r.iters,
            mean_ns: r.mean.as_nanos() as u64,
            p50_ns: r.p50.as_nanos() as u64,
            p95_ns: r.p95.as_nanos() as u64,
            elements_per_sec: elements / r.mean.as_secs_f64(),
            speedup_vs_reference: None,
            meta: RecordMeta::capture("compose-bench/v1"),
        }
    }

    /// Human-readable report line.
    pub fn row(&self) -> String {
        let speedup = self
            .speedup_vs_reference
            .map(|s| format!("  {s:>6.2}x vs reference"))
            .unwrap_or_default();
        format!(
            "{:<26} {:<9} rows={:<7} mean {:>10.3?} ({:>12.0} elem/s){speedup}",
            self.method,
            self.path,
            self.rows,
            std::time::Duration::from_nanos(self.mean_ns),
            self.elements_per_sec
        )
    }
}

/// Benchmark the three compose paths on one plan: the scalar reference
/// oracle, `ComposeEngine::compose_all`, and `ComposeEngine::
/// compose_batch` over `batch` uniformly-sampled node ids.
pub fn bench_compose(plan: &EmbeddingPlan, batch: usize) -> Vec<ComposeBenchRecord> {
    let params = init_params(plan, 1);
    let engine = ComposeEngine::new(plan);
    let n = plan.n;
    let label = plan.method.name();

    let reference = bench(&format!("{label} reference"), || {
        black_box(compose_embeddings(plan, &params))
    });
    let parallel =
        bench(&format!("{label} parallel"), || black_box(engine.compose_all(&params)));
    let batch = batch.clamp(1, n);
    let mut rng = Rng::seed_from_u64(0xBA7C);
    let ids: Vec<u32> = (0..batch).map(|_| rng.gen_range(n) as u32).collect();
    let batched =
        bench(&format!("{label} batch"), || black_box(engine.compose_batch(&params, &ids)));

    // per-row normalized speedups vs the reference path
    let ref_row_secs = reference.mean.as_secs_f64() / n as f64;
    let rec_ref = ComposeBenchRecord::from_result(plan, "reference", n, &reference);
    let mut rec_par = ComposeBenchRecord::from_result(plan, "parallel", n, &parallel);
    rec_par.speedup_vs_reference =
        Some(ref_row_secs * n as f64 / parallel.mean.as_secs_f64().max(1e-12));
    let mut rec_bat = ComposeBenchRecord::from_result(plan, "batch", batch, &batched);
    rec_bat.speedup_vs_reference =
        Some(ref_row_secs * batch as f64 / batched.mean.as_secs_f64().max(1e-12));
    vec![rec_ref, rec_par, rec_bat]
}

// ---------------------------------------------------------------------
// Host-side partitioner benchmarking (no PJRT needed)
// ---------------------------------------------------------------------

/// One measured partitioner stage, serializable for CI smoke artifacts.
#[derive(Debug, Clone, Serialize)]
pub struct PartitionBenchRecord {
    /// Pipeline stage: "matching/scalar", "matching/parallel",
    /// "contract/reference", "contract/csr", "partition/scalar",
    /// "partition/parallel", "hierarchy/parallel".
    pub stage: String,
    /// Nodes in the input graph.
    pub n: usize,
    /// Undirected edge count of the input graph.
    pub edges: usize,
    /// Parts per split (0 for k-independent stages).
    pub k: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Mean wall time per invocation in nanoseconds.
    pub mean_ns: u64,
    /// Median wall time in nanoseconds.
    pub p50_ns: u64,
    /// 95th-percentile wall time in nanoseconds.
    pub p95_ns: u64,
    /// Undirected edges processed per second (`edges / mean`).
    pub edges_per_sec: f64,
    /// Mean-time ratio vs the scalar/reference counterpart of the same
    /// stage; `None` on reference rows and unpaired stages.
    pub speedup_vs_reference: Option<f64>,
    /// Weighted edge cut (end-to-end partition stages only).
    pub edge_cut: Option<f64>,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl PartitionBenchRecord {
    fn from_result<G: GraphStore + ?Sized>(stage: &str, g: &G, k: usize, r: &BenchResult) -> Self {
        PartitionBenchRecord {
            stage: stage.to_string(),
            n: g.num_nodes(),
            edges: g.num_edges(),
            k,
            iters: r.iters,
            mean_ns: r.mean.as_nanos() as u64,
            p50_ns: r.p50.as_nanos() as u64,
            p95_ns: r.p95.as_nanos() as u64,
            edges_per_sec: g.num_edges() as f64 / r.mean.as_secs_f64().max(1e-12),
            speedup_vs_reference: None,
            edge_cut: None,
            meta: RecordMeta::capture("partition-bench/v1"),
        }
    }

    /// Human-readable report line.
    pub fn row(&self) -> String {
        let speedup = self
            .speedup_vs_reference
            .map(|s| format!("  {s:>6.2}x vs reference"))
            .unwrap_or_default();
        let cut = self.edge_cut.map(|c| format!("  cut={c:.0}")).unwrap_or_default();
        format!(
            "{:<20} n={:<7} m={:<8} mean {:>10.3?} ({:>12.0} edges/s){speedup}{cut}",
            self.stage,
            self.n,
            self.edges,
            std::time::Duration::from_nanos(self.mean_ns),
            self.edges_per_sec
        )
    }
}

/// Benchmark the partitioner pipeline on `g`: scalar vs parallel
/// heavy-edge matching, reference vs CSR-native contraction, end-to-end
/// k-way partitioning on both paths, and the sibling-parallel L-level
/// hierarchy build.
///
/// Before timing anything, the parallel kernels are validated against
/// their scalar oracles on this exact graph (involution property,
/// identical contraction structure) — a bench that silently measured a
/// broken kernel would be worse than no bench.
pub fn bench_partition<G: GraphStore + ?Sized>(
    g: &G,
    k: usize,
    levels: usize,
    seed: u64,
) -> Vec<PartitionBenchRecord> {
    // ---- correctness gates (outside timing) ----
    let par_m = parallel_heavy_edge_matching(g, seed);
    for (u, &v) in par_m.iter().enumerate() {
        assert_eq!(par_m[v as usize] as usize, u, "parallel matching not involutive at {u}");
    }
    let (cg_ref, map_ref) = coarsen_reference(g, &par_m);
    let (cg_csr, map_csr) = coarsen(g, &par_m);
    assert_eq!(map_ref, map_csr, "contraction maps diverge");
    assert_eq!(cg_ref.indptr(), cg_csr.indptr(), "contraction indptr diverges");
    assert_eq!(cg_ref.indices(), cg_csr.indices(), "contraction indices diverge");

    let mut recs = Vec::new();
    // ---- matching ----
    let r_ms = bench("matching scalar", || {
        let mut rng = Rng::seed_from_u64(seed);
        black_box(heavy_edge_matching(g, &mut rng))
    });
    let r_mp = bench("matching parallel", || black_box(parallel_heavy_edge_matching(g, seed)));
    recs.push(PartitionBenchRecord::from_result("matching/scalar", g, 0, &r_ms));
    let mut rec = PartitionBenchRecord::from_result("matching/parallel", g, 0, &r_mp);
    rec.speedup_vs_reference = Some(r_ms.mean.as_secs_f64() / r_mp.mean.as_secs_f64().max(1e-12));
    recs.push(rec);

    // ---- contraction ----
    let r_cr = bench("contract reference", || black_box(coarsen_reference(g, &par_m)));
    let r_cc = bench("contract csr", || black_box(coarsen(g, &par_m)));
    recs.push(PartitionBenchRecord::from_result("contract/reference", g, 0, &r_cr));
    let mut rec = PartitionBenchRecord::from_result("contract/csr", g, 0, &r_cc);
    rec.speedup_vs_reference = Some(r_cr.mean.as_secs_f64() / r_cc.mean.as_secs_f64().max(1e-12));
    recs.push(rec);

    // ---- end-to-end k-way partition ----
    // edge cuts are harvested from the first timed iteration (every
    // iteration is deterministic-identical) instead of extra runs
    let scfg = PartitionConfig { k, seed, parallel: false, ..Default::default() };
    let pcfg = PartitionConfig { k, seed, parallel: true, ..Default::default() };
    let mut scalar_cut = None;
    let r_ps = bench("partition scalar", || {
        let p = partition(g, &scfg);
        scalar_cut.get_or_insert(p.edge_cut);
        black_box(p)
    });
    let mut par_cut = None;
    let r_pp = bench("partition parallel", || {
        let p = partition(g, &pcfg);
        par_cut.get_or_insert(p.edge_cut);
        black_box(p)
    });
    let mut rec = PartitionBenchRecord::from_result("partition/scalar", g, k, &r_ps);
    rec.edge_cut = scalar_cut;
    recs.push(rec);
    let mut rec = PartitionBenchRecord::from_result("partition/parallel", g, k, &r_pp);
    rec.speedup_vs_reference = Some(r_ps.mean.as_secs_f64() / r_pp.mean.as_secs_f64().max(1e-12));
    rec.edge_cut = par_cut;
    recs.push(rec);

    // ---- hierarchy build ----
    let hcfg = HierarchyConfig::new(k.max(2), levels.max(1));
    let r_h = bench("hierarchy", || black_box(Hierarchy::build(g, &hcfg)));
    recs.push(PartitionBenchRecord::from_result("hierarchy/parallel", g, k, &r_h));
    recs
}

// ---------------------------------------------------------------------
// Host-side minibatch-training benchmarking (no PJRT needed)
// ---------------------------------------------------------------------

/// One measured minibatch training run, serializable for the CI
/// `minibatch-bench` artifact.
#[derive(Debug, Clone, Serialize)]
pub struct MinibatchBenchRecord {
    /// Dataset name.
    pub dataset: String,
    /// Embedding method display name.
    pub method: String,
    /// Training objective, in its round-trippable display form
    /// (`"nodeclass"`, `"linkpred(dot,neg=3)"`, ...).
    pub objective: String,
    /// Nodes in the graph.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Seed nodes per batch.
    pub batch_size: usize,
    /// Hop-0 neighbor fanout per seed (`null` in JSON = unbounded) —
    /// kept as the legacy scalar; `fanouts` carries the full list.
    pub fanout: Option<usize>,
    /// Per-hop neighbor fanouts (`null` entries = unbounded); the list
    /// length equals `layers`.
    pub fanouts: Vec<Option<usize>>,
    /// SAGE head depth (= sampled hops per block).
    pub layers: usize,
    /// Epochs trained.
    pub epochs: usize,
    /// Batches per epoch.
    pub batches_per_epoch: usize,
    /// Seed nodes per epoch (train-split size).
    pub seeds_per_epoch: usize,
    /// Largest row count composed for one batch (memory invariant:
    /// stays below n whenever batches are smaller than the graph).
    pub peak_compose_rows: usize,
    /// Mean epoch wall time in nanoseconds.
    pub mean_epoch_ns: u64,
    /// Median epoch wall time in nanoseconds.
    pub p50_epoch_ns: u64,
    /// 95th-percentile epoch wall time in nanoseconds.
    pub p95_epoch_ns: u64,
    /// Seed nodes trained per second (`seeds_per_epoch / mean epoch`).
    pub nodes_per_sec: f64,
    /// Batches per second (`batches_per_epoch / mean epoch`).
    pub batches_per_sec: f64,
    /// Mean training loss of the first epoch.
    pub first_loss: f64,
    /// Mean training loss of the final epoch.
    pub final_loss: f64,
    /// Full per-epoch loss trajectory — what the crash-resume harness
    /// compares bit-for-bit between an interrupted-and-resumed run and
    /// an uninterrupted control (JSON round-trips `f64` exactly).
    pub losses: Vec<f64>,
    /// Validation metric after training (accuracy / ROC-AUC for node
    /// classification; link AUC for link prediction).
    pub val_metric: f64,
    /// Test metric after training.
    pub test_metric: f64,
    /// Validation hits@k — link-prediction runs only (omitted from the
    /// JSON otherwise, so node-classification records are unchanged).
    #[serde(skip_serializing_if = "Option::is_none")]
    pub val_hits: Option<f64>,
    /// Test hits@k — link-prediction runs only.
    #[serde(skip_serializing_if = "Option::is_none")]
    pub test_hits: Option<f64>,
    /// Pipelined engine (parallel step + prefetch) or the serial oracle.
    pub parallel: bool,
    /// Prefetch depth the run used (0 = inline sampling).
    pub prefetch: usize,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl MinibatchBenchRecord {
    /// Human-readable report line.
    pub fn row(&self) -> String {
        let fanouts: Vec<String> = self
            .fanouts
            .iter()
            .map(|f| f.map_or("all".to_string(), |x| x.to_string()))
            .collect();
        format!(
            "{:<26} batch={:<5} L={} fanouts={:<7} epoch {:>10.3?} ({:>9.0} nodes/s, \
             {:>7.1} batch/s) loss {:.4}->{:.4} peak_rows={}",
            self.method,
            self.batch_size,
            self.layers,
            fanouts.join(","),
            std::time::Duration::from_nanos(self.mean_epoch_ns),
            self.nodes_per_sec,
            self.batches_per_sec,
            self.first_loss,
            self.final_loss,
            self.peak_compose_rows
        )
    }
}

/// Train `(ds, plan)` with the host minibatch trainer and record
/// throughput statistics from the run's real per-epoch wall times (no
/// separate measurement loop: training epochs are the samples).
pub fn bench_minibatch(
    dataset: &str,
    ds: &Dataset,
    plan: &EmbeddingPlan,
    cfg: &SamplerConfig,
    opts: &MinibatchOptions,
) -> Result<MinibatchBenchRecord> {
    if opts.epochs == 0 {
        bail!("bench_minibatch needs at least one epoch");
    }
    let mut trainer = MinibatchTrainer::new(ds, plan, cfg.clone(), opts.clone())?;
    let out = trainer.train()?;
    let mut sorted = out.epoch_ns.clone();
    sorted.sort_unstable();
    let mean_ns = (sorted.iter().sum::<u64>() / sorted.len() as u64).max(1);
    let p50 = sorted[sorted.len() / 2];
    let p95 = sorted[(sorted.len() * 95 / 100).min(sorted.len() - 1)];
    let mean_secs = mean_ns as f64 / 1e9;
    Ok(MinibatchBenchRecord {
        dataset: dataset.to_string(),
        method: plan.method.name(),
        objective: opts.objective.to_string(),
        n: plan.n,
        d: plan.d,
        batch_size: cfg.batch_size,
        fanout: cfg.fanouts.get(0).limit(),
        fanouts: cfg.fanouts.limits(),
        layers: cfg.fanouts.layers(),
        epochs: out.losses.len(),
        batches_per_epoch: out.batches_per_epoch,
        seeds_per_epoch: out.seeds_per_epoch,
        peak_compose_rows: out.peak_compose_rows,
        mean_epoch_ns: mean_ns,
        p50_epoch_ns: p50,
        p95_epoch_ns: p95,
        nodes_per_sec: out.seeds_per_epoch as f64 / mean_secs,
        batches_per_sec: out.batches_per_epoch as f64 / mean_secs,
        first_loss: out.losses.first().copied().unwrap_or(f64::NAN),
        final_loss: out.losses.last().copied().unwrap_or(f64::NAN),
        losses: out.losses.clone(),
        val_metric: out.val_metric,
        test_metric: out.test_metric,
        val_hits: out.val_hits,
        test_hits: out.test_hits,
        parallel: opts.parallel,
        prefetch: opts.prefetch,
        meta: RecordMeta::capture("minibatch-bench/v1"),
    })
}

// ---------------------------------------------------------------------
// Serve-path benchmarking (model artifact + query engine, no PJRT)
// ---------------------------------------------------------------------

/// Knobs for the synthetic serve load driver.
#[derive(Debug, Clone)]
pub struct ServeBenchOptions {
    /// Total queries to issue (clamped down under `BENCH_QUICK=1`).
    pub queries: usize,
    /// Node ids per `embed` call (one latency sample per call).
    pub batch: usize,
    /// Zipf exponent of the query-id distribution (s=0 ⇒ uniform).
    pub zipf_s: f64,
    /// Seed for the query stream and the rank→node permutation.
    pub seed: u64,
}

impl Default for ServeBenchOptions {
    fn default() -> Self {
        ServeBenchOptions { queries: 1_000_000, batch: 64, zipf_s: 0.99, seed: 0x5EB7E }
    }
}

/// One measured serve-load run, serializable for the CI `serve-bench`
/// artifact.
#[derive(Debug, Clone, Serialize)]
pub struct ServeBenchRecord {
    /// Method display name (paper table naming).
    pub method: String,
    /// Round-trippable method tag (the manifest's `method` string).
    pub method_tag: String,
    /// Dataset the artifact was trained on.
    pub dataset: String,
    /// Nodes in the graph.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Queries issued.
    pub queries: usize,
    /// Node ids per `embed` call.
    pub batch: usize,
    /// Hot-node LRU cache capacity in embedding rows.
    pub cache_rows: usize,
    /// Zipf exponent of the query stream.
    pub zipf_s: f64,
    /// Mean per-call latency in nanoseconds.
    pub mean_ns: u64,
    /// Median per-call latency in nanoseconds.
    pub p50_ns: u64,
    /// 99th-percentile per-call latency in nanoseconds.
    pub p99_ns: u64,
    /// Node embeddings served per second.
    pub queries_per_sec: f64,
    /// Fraction of queried ids answered from the LRU cache.
    pub cache_hit_rate: f64,
    /// Bytes of learned embedding-table sections resident in the
    /// engine (position tables + node tables; the paper's metric).
    pub resident_table_bytes: usize,
    /// Bytes of static index sections (level assignments, hash maps).
    pub resident_index_bytes: usize,
    /// Full-table baseline at equal dim: `n · d · 4` bytes.
    pub full_table_bytes: usize,
    /// `resident_table_bytes / full_table_bytes` (paper's 88–97%
    /// reduction band ⇒ ratios of 0.03–0.12 at paper scale).
    pub resident_ratio: f64,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl ServeBenchRecord {
    /// Human-readable report line.
    pub fn row(&self) -> String {
        format!(
            "{:<26} q={:<8} batch={:<4} p50 {:>9.3?} p99 {:>9.3?} ({:>10.0} q/s) \
             hit={:.1}% resident {}/{} ({:.1}%)",
            self.method,
            self.queries,
            self.batch,
            std::time::Duration::from_nanos(self.p50_ns),
            std::time::Duration::from_nanos(self.p99_ns),
            self.queries_per_sec,
            self.cache_hit_rate * 100.0,
            short(self.resident_table_bytes),
            short(self.full_table_bytes),
            self.resident_ratio * 100.0
        )
    }
}

/// Drive a loaded [`crate::serve::ServeEngine`] with a synthetic
/// Zipfian query stream and record latency percentiles, QPS, cache hit
/// rate and resident-memory footprint vs the Full-table baseline.
///
/// The Zipf(s) rank distribution is mapped onto node ids through a
/// seeded permutation so the hot set is spread across the id space
/// (adjacent ids sharing partitions would otherwise flatter the cache).
pub fn bench_serve(
    engine: &mut crate::serve::ServeEngine,
    opts: &ServeBenchOptions,
) -> Result<ServeBenchRecord> {
    let n = engine.n();
    let batch = opts.batch.clamp(1, n);
    let mut queries = opts.queries.max(batch);
    if crate::util::bench::quick() {
        queries = queries.min(20_000);
    }
    let calls = queries.div_ceil(batch);
    queries = calls * batch;

    // Zipf(s) over ranks 1..=n via inverse-CDF binary search.
    let mut cdf = Vec::with_capacity(n);
    let mut total = 0.0f64;
    for rank in 0..n {
        total += 1.0 / ((rank + 1) as f64).powf(opts.zipf_s);
        cdf.push(total);
    }
    let mut rng = Rng::seed_from_u64(opts.seed);
    let mut rank_to_node: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut rank_to_node);

    engine.reset_cache_stats();
    let mut ids = vec![0u32; batch];
    let mut lat_ns = Vec::with_capacity(calls);
    let started = std::time::Instant::now();
    for _ in 0..calls {
        for id in ids.iter_mut() {
            let u = rng.gen_f64() * total;
            let rank = cdf.partition_point(|&c| c < u).min(n - 1);
            *id = rank_to_node[rank];
        }
        let t0 = std::time::Instant::now();
        black_box(engine.embed(&ids)?);
        lat_ns.push(t0.elapsed().as_nanos() as u64);
    }
    let wall = started.elapsed().as_secs_f64().max(1e-12);

    lat_ns.sort_unstable();
    let mean_ns = (lat_ns.iter().sum::<u64>() / lat_ns.len() as u64).max(1);
    let p50 = lat_ns[lat_ns.len() / 2];
    let p99 = lat_ns[(lat_ns.len() * 99 / 100).min(lat_ns.len() - 1)];
    let (hits, misses) = engine.cache_stats();
    let looked_up = (hits + misses).max(1);

    let resident_table_bytes = engine.resident_table_bytes();
    let full_table_bytes = engine.full_table_bytes();
    let m = engine.manifest();
    Ok(ServeBenchRecord {
        method: m.method_name.clone(),
        method_tag: m.method.clone(),
        dataset: m.dataset.clone(),
        n,
        d: engine.d(),
        queries,
        batch,
        cache_rows: engine.cache_rows(),
        zipf_s: opts.zipf_s,
        mean_ns,
        p50_ns: p50,
        p99_ns: p99,
        queries_per_sec: queries as f64 / wall,
        cache_hit_rate: hits as f64 / looked_up as f64,
        resident_table_bytes,
        resident_index_bytes: engine.resident_index_bytes(),
        full_table_bytes,
        resident_ratio: resident_table_bytes as f64 / full_table_bytes.max(1) as f64,
        meta: RecordMeta::capture("serve-bench/v1"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingMethod;

    #[test]
    fn short_formatting() {
        assert_eq!(short(42), "42");
        assert_eq!(short(12_000), "12k");
        assert_eq!(short(3_400_000), "3.4M");
    }

    #[test]
    fn bench_compose_produces_three_serializable_records() {
        crate::util::bench::set_quick(true);
        let plan =
            EmbeddingPlan::build(400, 8, &EmbeddingMethod::HashEmb { buckets: 32, h: 2 }, None, 0);
        let recs = bench_compose(&plan, 64);
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].path, "reference");
        assert_eq!(recs[1].path, "parallel");
        assert_eq!(recs[2].path, "batch");
        assert_eq!(recs[2].rows, 64);
        assert!(recs[1].speedup_vs_reference.is_some());
        assert!(recs.iter().all(|r| r.meta.threads >= 1));
        let json = serde_json::to_string(&recs).unwrap();
        assert!(json.contains("\"elements_per_sec\""), "json: {json}");
        assert!(json.contains("\"threads\"") && json.contains("\"git_sha\""), "json: {json}");
        assert!(json.contains("\"schema\":\"compose-bench/v1\""), "json: {json}");
        for r in &recs {
            assert!(r.row().contains("elem/s"));
        }
    }

    #[test]
    fn bench_partition_produces_serializable_records() {
        crate::util::bench::set_quick(true);
        let (g, _) = crate::graph::planted_partition(&crate::graph::PlantedPartitionConfig {
            n: 400,
            communities: 4,
            intra_degree: 8.0,
            inter_degree: 1.5,
            seed: 9,
            ..Default::default()
        });
        let recs = bench_partition(&g, 4, 2, 1);
        assert_eq!(recs.len(), 7);
        let stages: Vec<&str> = recs.iter().map(|r| r.stage.as_str()).collect();
        assert_eq!(
            stages,
            [
                "matching/scalar",
                "matching/parallel",
                "contract/reference",
                "contract/csr",
                "partition/scalar",
                "partition/parallel",
                "hierarchy/parallel",
            ]
        );
        assert!(recs.iter().all(|r| r.edges_per_sec > 0.0));
        assert!(recs[1].speedup_vs_reference.is_some());
        assert!(recs[5].edge_cut.is_some());
        let json = serde_json::to_string(&recs).unwrap();
        assert!(json.contains("\"edges_per_sec\""), "json: {json}");
        for r in &recs {
            assert!(r.row().contains("edges/s"));
        }
    }

    #[test]
    fn bench_minibatch_produces_serializable_record() {
        use crate::sampler::Fanout;
        let mut spec = crate::data::spec("synth-arxiv").unwrap();
        spec.n = 400;
        spec.communities = 20;
        spec.d = 16;
        let ds = Dataset::generate(&spec);
        let plan = EmbeddingPlan::build(
            spec.n,
            spec.d,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            0,
        );
        let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(4).into(), shuffle: true };
        let opts = MinibatchOptions { epochs: 2, ..Default::default() };
        let rec = bench_minibatch("synth-arxiv", &ds, &plan, &cfg, &opts).unwrap();
        assert_eq!(rec.epochs, 2);
        assert_eq!(rec.batch_size, 64);
        assert_eq!(rec.fanout, Some(4));
        assert_eq!(rec.fanouts, vec![Some(4)]);
        assert_eq!(rec.layers, 1);
        assert!(rec.nodes_per_sec > 0.0);
        assert!(rec.batches_per_sec > 0.0);
        assert!(rec.peak_compose_rows < spec.n);
        assert!(rec.final_loss.is_finite());
        assert!(rec.parallel && rec.prefetch > 0, "pipelined engine is the default");
        assert_eq!(rec.objective, "nodeclass");
        assert!(rec.val_hits.is_none() && rec.test_hits.is_none());
        assert!(rec.meta.threads >= 1);
        let json = serde_json::to_string(&rec).unwrap();
        assert!(json.contains("\"nodes_per_sec\""), "json: {json}");
        assert!(json.contains("\"layers\"") && json.contains("\"fanouts\""), "json: {json}");
        assert!(json.contains("\"threads\"") && json.contains("\"git_sha\""), "json: {json}");
        assert!(rec.row().contains("nodes/s"));
        // zero epochs is rejected, not divided by
        let none = MinibatchOptions { epochs: 0, ..Default::default() };
        assert!(bench_minibatch("synth-arxiv", &ds, &plan, &cfg, &none).is_err());
    }

    #[test]
    fn bench_minibatch_records_layered_runs() {
        use crate::sampler::Fanouts;
        let mut spec = crate::data::spec("synth-arxiv").unwrap();
        spec.n = 400;
        spec.communities = 20;
        spec.d = 16;
        let ds = Dataset::generate(&spec);
        let plan = EmbeddingPlan::build(
            spec.n,
            spec.d,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            0,
        );
        let cfg = SamplerConfig {
            batch_size: 64,
            fanouts: Fanouts::parse("4,3").unwrap(),
            shuffle: true,
        };
        let opts = MinibatchOptions { epochs: 2, hidden: 16, ..Default::default() };
        let rec = bench_minibatch("synth-arxiv", &ds, &plan, &cfg, &opts).unwrap();
        assert_eq!(rec.layers, 2);
        assert_eq!(rec.fanouts, vec![Some(4), Some(3)]);
        assert_eq!(rec.fanout, Some(4), "legacy scalar is the hop-0 fanout");
        assert!(rec.nodes_per_sec > 0.0);
        assert!(rec.row().contains("L=2"));
    }

    /// Pins the exact JSON key set of every record type: the
    /// `RecordMeta` flatten must keep the pre-envelope field names
    /// (`threads`, `git_sha`) unchanged for `ci/bench_baseline.py` and
    /// the uploaded-artifact consumers.
    #[test]
    fn record_json_envelopes_are_stable() {
        fn sorted_keys(v: &serde_json::Value) -> Vec<String> {
            let mut k: Vec<String> = v.as_object().unwrap().keys().cloned().collect();
            k.sort();
            k
        }
        fn expect(mut want: Vec<&str>) -> Vec<&str> {
            want.extend(["schema", "threads", "git_sha"]);
            want.sort_unstable();
            want
        }
        let meta = RecordMeta::capture("x/v1");

        let c = ComposeBenchRecord {
            method: "m".into(),
            path: "p".into(),
            n: 1,
            d: 1,
            rows: 1,
            iters: 1,
            mean_ns: 1,
            p50_ns: 1,
            p95_ns: 1,
            elements_per_sec: 1.0,
            speedup_vs_reference: None,
            meta: meta.clone(),
        };
        let v = serde_json::to_value(&c).unwrap();
        assert_eq!(v["schema"], "x/v1");
        assert_eq!(
            sorted_keys(&v),
            expect(vec![
                "method",
                "path",
                "n",
                "d",
                "rows",
                "iters",
                "mean_ns",
                "p50_ns",
                "p95_ns",
                "elements_per_sec",
                "speedup_vs_reference",
            ])
        );

        let p = PartitionBenchRecord {
            stage: "s".into(),
            n: 1,
            edges: 1,
            k: 1,
            iters: 1,
            mean_ns: 1,
            p50_ns: 1,
            p95_ns: 1,
            edges_per_sec: 1.0,
            speedup_vs_reference: None,
            edge_cut: None,
            meta: meta.clone(),
        };
        assert_eq!(
            sorted_keys(&serde_json::to_value(&p).unwrap()),
            expect(vec![
                "stage",
                "n",
                "edges",
                "k",
                "iters",
                "mean_ns",
                "p50_ns",
                "p95_ns",
                "edges_per_sec",
                "speedup_vs_reference",
                "edge_cut",
            ])
        );

        let m = MinibatchBenchRecord {
            dataset: "d".into(),
            method: "m".into(),
            objective: "nodeclass".into(),
            n: 1,
            d: 1,
            batch_size: 1,
            fanout: None,
            fanouts: vec![None],
            layers: 1,
            epochs: 1,
            batches_per_epoch: 1,
            seeds_per_epoch: 1,
            peak_compose_rows: 1,
            mean_epoch_ns: 1,
            p50_epoch_ns: 1,
            p95_epoch_ns: 1,
            nodes_per_sec: 1.0,
            batches_per_sec: 1.0,
            first_loss: 0.0,
            final_loss: 0.0,
            losses: vec![0.0],
            val_metric: 0.0,
            test_metric: 0.0,
            val_hits: None,
            test_hits: None,
            parallel: true,
            prefetch: 1,
            meta: meta.clone(),
        };
        let nc_keys = vec![
            "dataset",
            "method",
            "objective",
            "n",
            "d",
            "batch_size",
            "fanout",
            "fanouts",
            "layers",
            "epochs",
            "batches_per_epoch",
            "seeds_per_epoch",
            "peak_compose_rows",
            "mean_epoch_ns",
            "p50_epoch_ns",
            "p95_epoch_ns",
            "nodes_per_sec",
            "batches_per_sec",
            "first_loss",
            "final_loss",
            "losses",
            "val_metric",
            "test_metric",
            "parallel",
            "prefetch",
        ];
        // node-classification records omit the hits@k keys entirely
        assert_eq!(sorted_keys(&serde_json::to_value(&m).unwrap()), expect(nc_keys.clone()));
        // link-prediction records add exactly the two hits@k keys
        let mut lp = m.clone();
        lp.objective = "linkpred(dot,neg=3)".into();
        lp.val_hits = Some(0.5);
        lp.test_hits = Some(0.5);
        let mut lp_keys = nc_keys;
        lp_keys.extend(["val_hits", "test_hits"]);
        assert_eq!(sorted_keys(&serde_json::to_value(&lp).unwrap()), expect(lp_keys));

        let s = ServeBenchRecord {
            method: "m".into(),
            method_tag: "full".into(),
            dataset: "d".into(),
            n: 1,
            d: 1,
            queries: 1,
            batch: 1,
            cache_rows: 1,
            zipf_s: 1.0,
            mean_ns: 1,
            p50_ns: 1,
            p99_ns: 1,
            queries_per_sec: 1.0,
            cache_hit_rate: 0.5,
            resident_table_bytes: 1,
            resident_index_bytes: 1,
            full_table_bytes: 1,
            resident_ratio: 1.0,
            meta,
        };
        assert_eq!(
            sorted_keys(&serde_json::to_value(&s).unwrap()),
            expect(vec![
                "method",
                "method_tag",
                "dataset",
                "n",
                "d",
                "queries",
                "batch",
                "cache_rows",
                "zipf_s",
                "mean_ns",
                "p50_ns",
                "p99_ns",
                "queries_per_sec",
                "cache_hit_rate",
                "resident_table_bytes",
                "resident_index_bytes",
                "full_table_bytes",
                "resident_ratio",
            ])
        );
        assert!(s.row().contains("q/s"));
    }
}
