//! The showdown: the paper's memory/accuracy claim, reproduced at the
//! CLI from a single config.
//!
//! [`run_showdown`] sweeps a (method × task × memory-budget) grid over
//! one dataset. Every cell fits the method's hyperparameters to the
//! cell's parameter budget (a fraction of the FullEmb `n·d` table,
//! mirroring the Figure-4 protocol in `embedding::budget_for_fraction`),
//! trains it end to end with the host minibatch trainer — node
//! classification or link prediction — and emits one schema-versioned
//! [`ShowdownRecord`] with the measured memory footprint, accuracy/AUC
//! and throughput. CI's smoke sweep asserts the paper's headline on
//! these records: the position-based method matches or beats the
//! universal-hash baseline at the same budget while holding a small
//! fraction of FullEmb's embedding bytes.
//!
//! Budget fitting per method tag (`budget` = `n·d·fraction` params):
//!
//! * `full` — ignores the budget (it IS the 100% baseline; the record
//!   still carries the cell's budget so the grid stays rectangular);
//! * `hashtrick` / `uhash` / `bloom` — `B = budget / d` shared rows;
//! * `doublehash` — `B = budget / 2d` (its table holds `2B` rows);
//! * `hashemb` — `B = (budget − n·h) / d` (importance weights billed);
//! * `intra` — `embedding::budget_for_fraction`: 3-level position
//!   component fixed, pools fill the remainder; falls back to 1-level
//!   position-only when the budget is too small for the hierarchy.

use super::RecordMeta;
use crate::coordinator::{MinibatchOptions, MinibatchTrainer, Objective};
use crate::data::{spec, Dataset, DatasetSpec};
use crate::embedding::{
    budget_for_fraction, default_k, EmbeddingMethod, EmbeddingPlan, MethodFamily, PosBudget,
};
use crate::graph::GraphStore;
use crate::partition::{Hierarchy, HierarchyConfig};
use crate::sampler::{Fanouts, SamplerConfig};
use anyhow::{anyhow, bail, Result};
use serde::Serialize;

/// Method tags the sweep fits by default: the full-table ceiling, the
/// hashing baselines, and the paper's position-based method.
pub const DEFAULT_METHODS: &[&str] = &["full", "uhash", "doublehash", "hashemb", "intra"];

/// One showdown sweep: which grid to run and how hard to train each
/// cell. Parsed from CLI flags by the `poshashemb showdown` subcommand.
#[derive(Debug, Clone)]
pub struct ShowdownConfig {
    /// Dataset name (see `data::DATASET_NAMES`).
    pub dataset: String,
    /// Method tags to fit per budget (`full`, `uhash`, `doublehash`,
    /// `hashtrick`, `bloom`, `hashemb`, `intra`).
    pub methods: Vec<String>,
    /// Training objectives to run each method under.
    pub tasks: Vec<Objective>,
    /// Memory budgets as fractions of the FullEmb `n·d` table.
    pub budgets: Vec<f64>,
    /// Training epochs per cell.
    pub epochs: usize,
    /// Seeds per minibatch.
    pub batch_size: usize,
    /// Per-hop fanouts; list length = SAGE head depth.
    pub fanouts: Fanouts,
    /// Hidden width of intermediate head layers (and the link-prediction
    /// embedding width).
    pub hidden: usize,
    /// Seed shared by every cell (splits, init, sampling).
    pub seed: u64,
    /// Override the synthetic dataset's node count (smoke runs).
    pub nodes: Option<usize>,
    /// Override the embedding dimension.
    pub dim: Option<usize>,
    /// Per-epoch progress lines from each cell's trainer.
    pub verbose: bool,
    /// Run grid cells rayon-parallel. Cells are independent (each owns
    /// its trainer and parameters) and every record is bit-identical to
    /// the sequential sweep's up to the measured throughput field, so
    /// this is purely a wall-clock knob (`--sequential` at the CLI).
    pub parallel: bool,
}

impl Default for ShowdownConfig {
    fn default() -> Self {
        ShowdownConfig {
            dataset: "synth-arxiv".to_string(),
            methods: DEFAULT_METHODS.iter().map(|s| s.to_string()).collect(),
            tasks: vec![
                Objective::NodeClassification,
                Objective::parse("linkpred").unwrap().with_neg_per_pos(3),
            ],
            budgets: vec![0.25, 1.0 / 12.0],
            epochs: 5,
            batch_size: 128,
            fanouts: Fanouts::parse("10,5").unwrap(),
            hidden: 32,
            seed: 0,
            nodes: None,
            dim: None,
            verbose: false,
            parallel: true,
        }
    }
}

/// One (method, task, budget) cell of a showdown sweep, serializable
/// for the CI `showdown` artifact. The memory fields are measured from
/// the built plan, not echoed from the budget — `memory_ratio` is the
/// number the paper's ≤15%-of-full claim is asserted on.
#[derive(Debug, Clone, Serialize)]
pub struct ShowdownRecord {
    /// Dataset name.
    pub dataset: String,
    /// Method display name (paper table naming).
    pub method: String,
    /// Round-trippable method tag with the fitted parameters explicit
    /// (e.g. `uhash(b=384)`), parseable by `EmbeddingMethod::from_str`.
    pub method_tag: String,
    /// Method family: `full`, `hashing`, `position`, `position-hash`
    /// or `dhe`.
    pub family: String,
    /// Training objective in display form (`nodeclass`,
    /// `linkpred(dot,neg=3)`, ...).
    pub task: String,
    /// The cell's budget as a fraction of the FullEmb table.
    pub budget_fraction: f64,
    /// The cell's budget in parameters (`n·d·budget_fraction`).
    pub budget_params: usize,
    /// Trainable embedding-layer parameters the fitted plan actually
    /// holds (importance weights included).
    pub params: usize,
    /// `params · 4` bytes (f32 tables).
    pub table_bytes: usize,
    /// FullEmb baseline at equal dim: `n·d·4` bytes.
    pub full_table_bytes: usize,
    /// `table_bytes / full_table_bytes` — the paper's headline metric.
    pub memory_ratio: f64,
    /// Nodes in the graph.
    pub n: usize,
    /// Embedding dimension.
    pub d: usize,
    /// Epochs trained.
    pub epochs: usize,
    /// Validation metric (accuracy / ROC-AUC for node classification,
    /// link AUC for link prediction).
    pub val_metric: f64,
    /// Test metric after training.
    pub test_metric: f64,
    /// Validation hits@k — link-prediction cells only.
    #[serde(skip_serializing_if = "Option::is_none")]
    pub val_hits: Option<f64>,
    /// Test hits@k — link-prediction cells only.
    #[serde(skip_serializing_if = "Option::is_none")]
    pub test_hits: Option<f64>,
    /// Mean training loss of the final epoch.
    pub final_loss: f64,
    /// Seed nodes (or edges, for link prediction) trained per second.
    pub nodes_per_sec: f64,
    /// Seed the cell trained with.
    pub seed: u64,
    /// Shared record envelope (schema/threads/git_sha), flattened.
    #[serde(flatten)]
    pub meta: RecordMeta,
}

impl ShowdownRecord {
    /// Human-readable report line.
    pub fn row(&self) -> String {
        let hits = self.test_hits.map(|h| format!(" hits@50={h:.3}")).unwrap_or_default();
        format!(
            "{:<22} {:<26} budget={:<6.4} mem={:>5.1}% test={:.4}{hits} ({:>8.0} seeds/s)",
            self.task,
            self.method,
            self.budget_fraction,
            self.memory_ratio * 100.0,
            self.test_metric,
            self.nodes_per_sec
        )
    }
}

fn family_name(m: &EmbeddingMethod) -> &'static str {
    match m.family() {
        MethodFamily::Full => "full",
        MethodFamily::Hashing => "hashing",
        MethodFamily::Position => "position",
        MethodFamily::PositionHash => "position-hash",
        MethodFamily::Dhe => "dhe",
    }
}

/// The shrunk synthetic spec for a showdown run — same clamping as the
/// CLI's `--nodes`/`--dim` overrides (community/super counts capped so
/// the planted structure stays valid).
fn shrunk_spec(dsname: &str, nodes: Option<usize>, dim: Option<usize>) -> Result<DatasetSpec> {
    let mut sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
    if let Some(n) = nodes {
        if n == 0 {
            bail!("node-count override must be >= 1");
        }
        sp.n = n;
        sp.communities = sp.communities.min(n.div_ceil(20)).max(1);
        sp.supers = sp.supers.min(sp.communities);
    }
    if let Some(d) = dim {
        if d == 0 {
            bail!("dim override must be >= 1");
        }
        sp.d = d;
    }
    Ok(sp)
}

/// Fit `tag` to a parameter budget: the concrete method plus the
/// hierarchy the position-family methods partition with (`None` for
/// table/hash methods). `budget` is `n·d·fraction` parameters.
fn fit_method<G: GraphStore + ?Sized>(
    tag: &str,
    n: usize,
    d: usize,
    budget: usize,
    fraction: f64,
    graph: &G,
) -> Result<(EmbeddingMethod, Option<Hierarchy>)> {
    let h = 2; // paper default hash count for multi-hash baselines
    let method = match tag {
        "full" => return Ok((EmbeddingMethod::Full, None)),
        "hashtrick" => EmbeddingMethod::HashTrick { buckets: (budget / d).max(1) },
        "uhash" => EmbeddingMethod::UniversalHash { buckets: (budget / d).max(1) },
        "doublehash" => EmbeddingMethod::DoubleHash { buckets: (budget / (2 * d)).max(1) },
        "bloom" => EmbeddingMethod::Bloom { buckets: (budget / d).max(1), h },
        "hashemb" => EmbeddingMethod::HashEmb {
            buckets: (budget.saturating_sub(n * h).max(d) / d).max(1),
            h,
        },
        "intra" => {
            // fit via the Figure-4 budget solver: the 3-level position
            // component is priced from the real hierarchy's partition
            // counts, and the node pool fills what remains
            let k = default_k(n);
            let hier = Hierarchy::build(graph, &HierarchyConfig::new(k, 3));
            return Ok(match budget_for_fraction(n, d, &hier.m, h, fraction).poshash {
                PosBudget::Intra { c, h } => (
                    EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h },
                    Some(hier),
                ),
                PosBudget::PositionOnly { k } => {
                    // budget too small for hierarchy + pools: 1-level
                    // position-only with k chosen to fit (paper §IV-I)
                    let flat = Hierarchy::build(graph, &HierarchyConfig::new(k, 1));
                    (EmbeddingMethod::PosEmb { levels: 1 }, Some(flat))
                }
            });
        }
        other => bail!(
            "showdown cannot budget-fit method '{other}' \
             (supported: full, hashtrick, uhash, doublehash, bloom, hashemb, intra)"
        ),
    };
    Ok((method, None))
}

/// Run the full (method × task × budget) sweep, one trained cell per
/// record, in deterministic grid order (tasks outermost, then budgets,
/// then methods — the order the config lists them).
///
/// With `cfg.parallel` the cells train rayon-parallel — each cell is
/// fully independent (own plan, trainer, optimizer state, seeds keyed
/// only by `cfg.seed`) and the results are collected back in grid
/// order, so every record matches the sequential sweep's bit for bit
/// apart from the measured `nodes_per_sec` (asserted in the grid test
/// below).
pub fn run_showdown(cfg: &ShowdownConfig) -> Result<Vec<ShowdownRecord>> {
    if cfg.methods.is_empty() || cfg.tasks.is_empty() || cfg.budgets.is_empty() {
        bail!("showdown needs at least one method, one task and one budget");
    }
    if cfg.epochs == 0 {
        bail!("showdown needs at least one epoch per cell");
    }
    for &f in &cfg.budgets {
        if !(f > 0.0 && f <= 1.0) || !f.is_finite() {
            bail!("budget fractions must be in (0, 1], got {f}");
        }
    }
    let sp = shrunk_spec(&cfg.dataset, cfg.nodes, cfg.dim)?;
    let ds = Dataset::generate(&sp);
    let (n, d) = (sp.n, sp.d);
    let full_table_bytes = n * d * 4;

    // the grid, flattened in its deterministic order
    let mut cells: Vec<(usize, Objective, f64, &str)> = Vec::new();
    for &task in &cfg.tasks {
        for &fraction in &cfg.budgets {
            for tag in &cfg.methods {
                cells.push((cells.len() + 1, task, fraction, tag.as_str()));
            }
        }
    }
    let total = cells.len();

    let run_cell = |cell: &(usize, Objective, f64, &str)| -> Result<ShowdownRecord> {
        let &(idx, task, fraction, tag) = cell;
        let budget_params = (n as f64 * d as f64 * fraction) as usize;
        let (method, hier) = fit_method(tag, n, d, budget_params, fraction, &ds.graph)?;
        let plan = EmbeddingPlan::build(n, d, &method, hier.as_ref(), cfg.seed);
        eprintln!(
            "[showdown {idx}/{total}] task={task} budget={fraction:.4} method={}",
            plan.method.name()
        );
        let scfg = SamplerConfig {
            batch_size: cfg.batch_size,
            fanouts: cfg.fanouts.clone(),
            shuffle: true,
        };
        let opts = MinibatchOptions {
            epochs: cfg.epochs,
            hidden: cfg.hidden,
            seed: cfg.seed,
            objective: task,
            verbose: cfg.verbose,
            ..Default::default()
        };
        let mut trainer = MinibatchTrainer::new(&ds, &plan, scfg, opts)?;
        let out = trainer.train()?;
        let mean_ns = (out.epoch_ns.iter().sum::<u64>() / out.epoch_ns.len().max(1) as u64).max(1);
        let params = plan.num_params();
        let table_bytes = params * 4;
        Ok(ShowdownRecord {
            dataset: cfg.dataset.clone(),
            method: plan.method.name(),
            method_tag: plan.method.to_string(),
            family: family_name(&plan.method).to_string(),
            task: task.to_string(),
            budget_fraction: fraction,
            budget_params,
            params,
            table_bytes,
            full_table_bytes,
            memory_ratio: table_bytes as f64 / full_table_bytes.max(1) as f64,
            n,
            d,
            epochs: out.losses.len(),
            val_metric: out.val_metric,
            test_metric: out.test_metric,
            val_hits: out.val_hits,
            test_hits: out.test_hits,
            final_loss: out.losses.last().copied().unwrap_or(f64::NAN),
            nodes_per_sec: out.seeds_per_epoch as f64 / (mean_ns as f64 / 1e9),
            seed: cfg.seed,
            meta: RecordMeta::capture("showdown/v1"),
        })
    };

    if cfg.parallel {
        use rayon::prelude::*;
        cells.par_iter().map(run_cell).collect()
    } else {
        cells.iter().map(run_cell).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EdgeDecoder;

    fn smoke_config() -> ShowdownConfig {
        ShowdownConfig {
            methods: vec!["full".into(), "uhash".into(), "intra".into()],
            tasks: vec![
                Objective::NodeClassification,
                Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 2 },
            ],
            budgets: vec![0.25],
            epochs: 1,
            batch_size: 64,
            fanouts: Fanouts::parse("4,3").unwrap(),
            hidden: 16,
            nodes: Some(400),
            dim: Some(16),
            ..Default::default()
        }
    }

    #[test]
    fn sweep_emits_one_record_per_cell_and_respects_budgets() {
        let cfg = smoke_config();
        let recs = run_showdown(&cfg).unwrap();
        assert_eq!(recs.len(), 3 * 2 * 1, "one record per (method, task, budget) cell");

        // the rayon-parallel sweep (the default) must be byte-identical
        // to the sequential one, record for record, modulo the one
        // wall-clock-measured field
        let seq = run_showdown(&ShowdownConfig { parallel: false, ..cfg.clone() }).unwrap();
        assert_eq!(seq.len(), recs.len());
        for (p, s) in recs.iter().zip(&seq) {
            let strip = |r: &ShowdownRecord| {
                let mut r = r.clone();
                r.nodes_per_sec = 0.0;
                serde_json::to_string(&r).unwrap()
            };
            assert_eq!(strip(p), strip(s), "parallel sweep diverged from sequential");
        }
        for r in &recs {
            assert!(r.test_metric.is_finite() && r.final_loss.is_finite());
            assert!(r.nodes_per_sec > 0.0);
            assert_eq!(r.meta.schema, "showdown/v1");
            assert_eq!(r.full_table_bytes, 400 * 16 * 4);
            let is_lp = r.task.starts_with("linkpred");
            assert_eq!(r.val_hits.is_some(), is_lp, "{}: hits iff link prediction", r.task);
            assert_eq!(r.test_hits.is_some(), is_lp);
            if is_lp {
                // AUC of a trained model on a connected synthetic graph
                assert!(r.test_metric > 0.0 && r.test_metric <= 1.0);
            }
            match r.family.as_str() {
                "full" => assert!((r.memory_ratio - 1.0).abs() < 1e-9),
                // fitted methods land on the budget (small slack: the
                // intra solver keeps at least one row per pool, which
                // can overshoot a little at smoke-test scale)
                _ => assert!(
                    r.memory_ratio <= r.budget_fraction + 0.05,
                    "{}: ratio {} over budget fraction {}",
                    r.method_tag,
                    r.memory_ratio,
                    r.budget_fraction
                ),
            }
            // the tag round-trips through the method parser
            let parsed: EmbeddingMethod = r.method_tag.parse().unwrap();
            assert_eq!(parsed.to_string(), r.method_tag);
        }
        // grid order is deterministic: tasks outermost, then methods
        assert_eq!(recs[0].task, "nodeclass");
        assert_eq!(recs[3].task, "linkpred(dot,neg=2)");
        assert_eq!(recs[0].method, "FullEmb");
        assert_eq!(recs[1].method, "UHash");
    }

    #[test]
    fn tiny_budget_fits_intra_as_position_only() {
        let sp = shrunk_spec("synth-arxiv", Some(400), Some(16)).unwrap();
        let ds = Dataset::generate(&sp);
        let budget = (400.0 * 16.0 * (1.0 / 34.0)) as usize;
        let (m, hier) = fit_method("intra", 400, 16, budget, 1.0 / 34.0, &ds.graph).unwrap();
        match m {
            EmbeddingMethod::PosEmb { levels } => assert_eq!(levels, 1),
            EmbeddingMethod::PosHashEmbIntra { .. } => { /* generous solve also legal */ }
            other => panic!("unexpected fit {other:?}"),
        }
        assert!(hier.is_some(), "position methods carry their hierarchy");
    }

    #[test]
    fn unknown_method_and_bad_budget_are_rejected() {
        let mut cfg = smoke_config();
        cfg.methods = vec!["dhe".into()];
        assert!(run_showdown(&cfg).is_err(), "dhe has no budget-fit rule");
        let mut cfg = smoke_config();
        cfg.budgets = vec![1.5];
        assert!(run_showdown(&cfg).is_err(), "fractions above 1 are rejected");
        let mut cfg = smoke_config();
        cfg.epochs = 0;
        assert!(run_showdown(&cfg).is_err());
    }

    /// Pins the exact JSON key set of the showdown record — the CI
    /// smoke's inline validator (`.github/workflows/ci.yml`) reads
    /// these names.
    #[test]
    fn showdown_record_json_keys_are_stable() {
        let rec = ShowdownRecord {
            dataset: "d".into(),
            method: "m".into(),
            method_tag: "uhash(b=1)".into(),
            family: "hashing".into(),
            task: "nodeclass".into(),
            budget_fraction: 0.25,
            budget_params: 1,
            params: 1,
            table_bytes: 4,
            full_table_bytes: 16,
            memory_ratio: 0.25,
            n: 1,
            d: 1,
            epochs: 1,
            val_metric: 0.0,
            test_metric: 0.0,
            val_hits: None,
            test_hits: None,
            final_loss: 0.0,
            nodes_per_sec: 1.0,
            seed: 0,
            meta: RecordMeta::capture("showdown/v1"),
        };
        let keys = |v: &serde_json::Value| -> Vec<String> {
            let mut k: Vec<String> = v.as_object().unwrap().keys().cloned().collect();
            k.sort();
            k
        };
        let mut want = vec![
            "dataset",
            "method",
            "method_tag",
            "family",
            "task",
            "budget_fraction",
            "budget_params",
            "params",
            "table_bytes",
            "full_table_bytes",
            "memory_ratio",
            "n",
            "d",
            "epochs",
            "val_metric",
            "test_metric",
            "final_loss",
            "nodes_per_sec",
            "seed",
            "schema",
            "threads",
            "git_sha",
        ];
        want.sort_unstable();
        assert_eq!(keys(&serde_json::to_value(&rec).unwrap()), want);
        let mut lp = rec.clone();
        lp.val_hits = Some(0.5);
        lp.test_hits = Some(0.5);
        let mut want_lp: Vec<&str> = want.clone();
        want_lp.extend(["val_hits", "test_hits"]);
        want_lp.sort_unstable();
        assert_eq!(keys(&serde_json::to_value(&lp).unwrap()), want_lp);
        assert!(rec.row().contains("seeds/s"));
    }
}
