//! `poshashemb` CLI launcher.
//!
//! Subcommands:
//! * `report datasets` — Table II analog (dataset statistics).
//! * `list [--group G]` — list experiment configs in the grid.
//! * `gen-manifest [--grid full|smoke] [--out PATH]` — write the AOT
//!   request consumed by `python/compile/aot.py`.
//! * `partition --dataset D --k K [--levels L]` — run the multilevel
//!   partitioner and report cut/imbalance/hierarchy stats.
//! * `train --experiment NAME [--seed S] [--epochs N] [--verbose]` —
//!   train one configuration via the PJRT runtime.
//! * `experiment --group t3|t4|t5|f3|f4 [--dataset D]` — regenerate one
//!   paper table/figure.
//! * `compose --dataset D [--method M] [--batch B] [--json]` — benchmark
//!   the host-side compose engine (reference vs parallel vs batch paths);
//!   runs without PJRT artifacts.
//! * `train-minibatch [...]` — host-side neighbor-sampled minibatch
//!   training on the compose engine; runs without PJRT artifacts, emits
//!   a JSON bench record, and with `--save-model DIR` writes a
//!   versioned model artifact (see `docs/ARCHITECTURE.md`, serving
//!   path). The fanout list's length is the SAGE head's depth
//!   (`--fanouts 10,5` = a 2-layer head over 2-hop blocks; `--hidden`
//!   sets its intermediate width). The pipelined engine is the
//!   default; `--serial` selects the single-threaded oracle path.
//!   The trainer is crash-safe: `--checkpoint-dir DIR` snapshots
//!   parameters, optimizer moments and the `(epoch, batch)` cursor
//!   every `--checkpoint-every` steps into atomically-published
//!   checkpoint directories, and `--resume` continues from the newest
//!   intact one with a bit-identical loss trajectory.
//!   The objective is selectable: `--task linkpred [--neg N]` trains
//!   link prediction (BCE over decoded edge scores, AUC + hits@k
//!   evaluation) instead of node classification.
//! * `crash-test [...]` — end-to-end crash/recovery harness: runs an
//!   uninterrupted control, kills a checkpointing victim subprocess
//!   mid-epoch with an injected fault (`POSHASH_FAULT`), resumes it,
//!   and asserts the resumed run's loss trajectory matches the control
//!   bit for bit.
//! * `showdown [...]` — the paper's memory/accuracy claim at the CLI:
//!   sweeps (method × task × memory budget) from one config, trains
//!   every cell with the minibatch trainer and emits one
//!   schema-versioned JSON record per cell (`--json`, `--out PATH`).
//! * `train-sharded [...]` — partition-sharded training: cuts a
//!   streamed synthetic power-law (R-MAT) graph into `--shards` parts
//!   with the multilevel partitioner, trains every shard's minibatch
//!   trainer in parallel over partition-aligned local tables with a
//!   per-epoch halo exchange (and a periodic `--sync-every` node-table
//!   sync), and emits one `sharded/v1` JSON record with per-shard
//!   nodes/s, halo bytes and resident table bytes. No global optimizer
//!   state is ever materialized. `--parity-check` instead proves the
//!   k = 1 sharded trainer reproduces the single-shard minibatch
//!   trainer's loss trajectory bit for bit (serial AND pipelined).
//! * `gen-graph --to-disk DIR [--scale S] [--edge-factor E] [--seed S]`
//!   — generate the R-MAT graph once and publish it as an on-disk CSR
//!   directory (manifest + checksummed section files, atomically).
//!   `--graph-dir DIR` on `train-minibatch`, `train-sharded` and
//!   `partition-bench` then runs straight off that directory through
//!   the out-of-core `DiskCsr` backend — bit-identical results to the
//!   in-memory run, without ever materializing the global graph.
//! * `partition-bench [--dataset D] [--k K] [--levels L] [--json]` —
//!   benchmark the partitioner pipeline; defaults to the acceptance
//!   SBM (n = 50k, 32 communities).
//! * `serve-bench --model DIR [--queries N] [--batch B]
//!   [--cache-rows R] [--zipf S] [--seed S] [--json]` — open a saved
//!   model artifact and drive it with a synthetic Zipfian query load
//!   (latency percentiles, QPS, cache hit rate, resident bytes vs the
//!   Full-table baseline).
//!
//! Method tags (`--method`) are parsed by
//! [`MethodSpec`](poshashemb::embedding::MethodSpec) — bare tags
//! (`intra`, `inter`, `full`, ...) resolve scale parameters from the
//! dataset size exactly as the experiment grid does, and explicit
//! parameters override (`inter(k=9,h=1)`, `hashemb(b=500)`).
//!
//! Argument parsing is hand-rolled (minimal-dependency build: no
//! clap): one static flag table per subcommand drives parsing,
//! `--flag value` / `--flag=value` syntax, per-subcommand help
//! (`poshashemb help <subcommand>`) and typo suggestions for unknown
//! flags.

use anyhow::{anyhow, bail, Result};
use poshashemb::bench_harness::{
    bench_compose, bench_minibatch, bench_partition, bench_serve, print_table,
    rows_from_outcomes, run_showdown, Harness, ServeBenchOptions, ShardedBenchRecord,
    ShowdownConfig,
};
use poshashemb::config::{full_grid, materialize, smoke_grid, write_aot_request};
use poshashemb::coordinator::{
    run_experiment, CheckpointConfig, MinibatchOptions, MinibatchTrainer, Objective,
    OptimizerKind, ShardedTrainer, TrainOptions,
};
use poshashemb::data::{
    spec, train_val_test_split, Dataset, DatasetSpec, TaskKind, DATASET_NAMES,
};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan, MethodSpec};
use poshashemb::graph::{
    planted_partition, rmat_streamed, write_graph_dir, DiskCsr, GraphHandle,
    PlantedPartitionConfig, RmatConfig,
};
use poshashemb::partition::{partition, Hierarchy, HierarchyConfig, PartitionConfig};
use poshashemb::runtime::{Manifest, RuntimeClient};
use poshashemb::sampler::{Fanout, Fanouts, SamplerConfig};
use poshashemb::serve::ServeEngine;
use poshashemb::util::fault::FAULT_ENV;
use poshashemb::util::tempdir::TempDir;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::Command;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

// ---------------------------------------------------------------------
// typed CLI argument layer
// ---------------------------------------------------------------------

/// Spec of one flag: boolean (`value: None`) or valued
/// (`value: Some("PLACEHOLDER")`).
struct FlagSpec {
    name: &'static str,
    value: Option<&'static str>,
    help: &'static str,
}

/// One subcommand: its flag table drives parsing, validation and the
/// generated help text — a flag that is not in the table does not
/// parse.
struct CommandSpec {
    name: &'static str,
    /// Optional positional word shown in usage (e.g. `report datasets`).
    positional: Option<&'static str>,
    about: &'static str,
    flags: &'static [FlagSpec],
}

const fn flag(name: &'static str, value: Option<&'static str>, help: &'static str) -> FlagSpec {
    FlagSpec { name, value, help }
}

static COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "report",
        positional: Some("datasets"),
        about: "dataset statistics (Table II)",
        flags: &[],
    },
    CommandSpec {
        name: "list",
        positional: None,
        about: "list experiment grid configs",
        flags: &[flag("group", Some("G"), "only configs of group G (t3|t4|t5|f3|f4)")],
    },
    CommandSpec {
        name: "gen-manifest",
        positional: None,
        about: "write the AOT compile request JSON",
        flags: &[
            flag("grid", Some("full|smoke"), "experiment grid to request (default full)"),
            flag("out", Some("PATH"), "output path (default artifacts/manifest_request.json)"),
        ],
    },
    CommandSpec {
        name: "partition",
        positional: None,
        about: "run the multilevel partitioner",
        flags: &[
            flag("dataset", Some("D"), "dataset name (default synth-arxiv)"),
            flag("k", Some("K"), "partitions per level (default 8)"),
            flag("levels", Some("L"), "hierarchy levels; 1 = flat partition (default 1)"),
        ],
    },
    CommandSpec {
        name: "train",
        positional: None,
        about: "train one grid config via the PJRT runtime",
        flags: &[
            flag("experiment", Some("NAME"), "grid experiment name (see `poshashemb list`)"),
            flag("seed", Some("S"), "random seed (default 0)"),
            flag("epochs", Some("N"), "override the config's epoch count"),
            flag("verbose", None, "per-epoch progress lines"),
        ],
    },
    CommandSpec {
        name: "train-minibatch",
        positional: None,
        about: "host-side neighbor-sampled minibatch training",
        flags: &[
            flag("experiment", Some("NAME"), "grid experiment name (fixes dataset + method)"),
            flag("dataset", Some("D"), "dataset name (default synth-arxiv)"),
            flag("method", Some("TAG"), "method tag, e.g. intra, inter(k=9,h=1) (default intra)"),
            flag("task", Some("T"), "objective: nodeclass|linkpred|linkpred-hadamard"),
            flag("neg", Some("N"), "negatives per positive edge (link prediction, default 3)"),
            flag("batch", Some("B"), "seeds per minibatch"),
            flag("fanout", Some("F|all"), "one-hop neighbor fanout"),
            flag("fanouts", Some("F1,F2,.."), "per-hop fanouts; list length = head depth"),
            flag("hidden", Some("W"), "hidden width of intermediate head layers"),
            flag("epochs", Some("N"), "training epochs"),
            flag("lr", Some("LR"), "learning rate"),
            flag("optimizer", Some("sgd|adam"), "update rule (default adam)"),
            flag("no-shuffle", None, "keep the train split in order each epoch"),
            flag("seed", Some("S"), "random seed (default 0)"),
            flag("serial", None, "single-threaded oracle path (bit-identical losses)"),
            flag("prefetch", Some("DEPTH"), "sampled blocks prefetched ahead of the trainer"),
            flag("save-model", Some("DIR"), "write a versioned model artifact after training"),
            flag("graph-dir", Some("DIR"), "train on an on-disk CSR graph (from `gen-graph`)"),
            flag("nodes", Some("N"), "override the synthetic dataset's node count"),
            flag("dim", Some("D"), "override the embedding dimension"),
            flag("checkpoint-dir", Some("DIR"), "enable crash-safe checkpointing under DIR"),
            flag("checkpoint-every", Some("N"), "steps between checkpoints (default 50)"),
            flag("checkpoint-keep", Some("K"), "retained checkpoints, 0 = all (default 3)"),
            flag("resume", None, "continue from the newest intact checkpoint in DIR"),
            flag("verbose", None, "per-epoch progress lines"),
            flag("json", None, "emit the bench record as JSON"),
        ],
    },
    CommandSpec {
        name: "crash-test",
        positional: None,
        about: "kill/resume harness: prove resumed losses match an uninterrupted control",
        flags: &[
            flag("dataset", Some("D"), "dataset name (default synth-arxiv)"),
            flag("method", Some("TAG"), "method tag (default intra)"),
            flag("nodes", Some("N"), "node-count override for a fast run (default 400)"),
            flag("dim", Some("D"), "embedding-dimension override (default 16)"),
            flag("batch", Some("B"), "seeds per minibatch (default 64)"),
            flag("fanouts", Some("F1,F2,.."), "per-hop fanouts (default 5,3)"),
            flag("epochs", Some("N"), "training epochs (default 3)"),
            flag("kill-step", Some("K"), "abort the victim before its K-th step (default 6)"),
            flag("checkpoint-every", Some("N"), "victim checkpoint period in steps (default 2)"),
            flag("serial", None, "run all three trainers on the serial oracle path"),
            flag("dir", Some("DIR"), "use (and keep) DIR for checkpoints instead of a temp dir"),
        ],
    },
    CommandSpec {
        name: "showdown",
        positional: None,
        about: "sweep (method x task x memory budget); one JSON record per cell",
        flags: &[
            flag("dataset", Some("D"), "dataset name (default synth-arxiv)"),
            flag("methods", Some("M1,M2,.."), "method tags to budget-fit (default full,uhash,doublehash,hashemb,intra)"),
            flag("tasks", Some("T1,T2,.."), "objectives to sweep (default nodeclass,linkpred)"),
            flag("budgets", Some("F1,F2,.."), "memory budgets as fractions of full n*d (default 0.25,0.0833)"),
            flag("neg", Some("N"), "negatives per positive edge for linkpred tasks (default 3)"),
            flag("epochs", Some("N"), "training epochs per cell (default 5)"),
            flag("batch", Some("B"), "seeds per minibatch (default 128)"),
            flag("fanouts", Some("F1,F2,.."), "per-hop fanouts; list length = head depth (default 10,5)"),
            flag("hidden", Some("W"), "head hidden width / linkpred embedding width (default 32)"),
            flag("seed", Some("S"), "random seed (default 0)"),
            flag("nodes", Some("N"), "override the synthetic dataset's node count"),
            flag("dim", Some("D"), "override the embedding dimension"),
            flag("out", Some("PATH"), "also write the records to PATH as JSON"),
            flag("sequential", None, "train grid cells one at a time instead of rayon-parallel"),
            flag("verbose", None, "per-epoch progress lines from every cell"),
            flag("json", None, "emit the records to stdout as JSON"),
        ],
    },
    CommandSpec {
        name: "train-sharded",
        positional: None,
        about: "partition-sharded training with halo exchange on a streamed power-law graph",
        flags: &[
            flag("scale", Some("S"), "log2 of the R-MAT node count (default 13)"),
            flag("edge-factor", Some("E"), "sampled edges per node before dedup (default 8)"),
            flag("graph-dir", Some("DIR"), "train on an on-disk CSR graph (from `gen-graph`)"),
            flag("shards", Some("K"), "number of graph shards to train in parallel (default 4)"),
            flag("method", Some("TAG"), "per-shard method tag, e.g. intra, posemb (default intra)"),
            flag("dim", Some("D"), "embedding dimension, multiple of 4 (default 32)"),
            flag("epochs", Some("N"), "training epochs (default 3)"),
            flag("batch", Some("B"), "seeds per minibatch (default 512)"),
            flag("fanouts", Some("F1,F2,.."), "per-hop fanouts; list length = head depth"),
            flag("hidden", Some("W"), "hidden width of intermediate head layers"),
            flag("sync-every", Some("N"), "node-table sync period in epochs; 0 = initial only"),
            flag("seed", Some("S"), "random seed (default 0)"),
            flag("serial", None, "serial oracle path inside each shard's trainer"),
            flag("parity-check", None, "prove k=1 matches the minibatch trainer, then exit"),
            flag("out", Some("PATH"), "also write the record to PATH as JSON"),
            flag("verbose", None, "per-epoch progress lines from every shard"),
            flag("json", None, "emit the bench record as JSON"),
        ],
    },
    CommandSpec {
        name: "experiment",
        positional: None,
        about: "regenerate a paper table/figure from artifacts",
        flags: &[
            flag("group", Some("G"), "table/figure group: t3|t4|t5|f3|f4"),
            flag("dataset", Some("D"), "restrict to one dataset"),
        ],
    },
    CommandSpec {
        name: "compose",
        positional: None,
        about: "benchmark the host-side compose engine",
        flags: &[
            flag("dataset", Some("D"), "dataset name (default synth-arxiv)"),
            flag("method", Some("TAG"), "method tag (default intra)"),
            flag("batch", Some("B"), "rows per compose_batch call (default 1024)"),
            flag("json", None, "emit bench records as JSON"),
        ],
    },
    CommandSpec {
        name: "partition-bench",
        positional: None,
        about: "benchmark the partitioner pipeline",
        flags: &[
            flag("dataset", Some("D"), "dataset name (default: acceptance SBM, n=50k)"),
            flag("graph-dir", Some("DIR"), "bench an on-disk CSR graph (from `gen-graph`)"),
            flag("k", Some("K"), "partitions per level (default 32)"),
            flag("levels", Some("L"), "hierarchy levels (default 3)"),
            flag("seed", Some("S"), "random seed (default 1)"),
            flag("json", None, "emit bench records as JSON"),
        ],
    },
    CommandSpec {
        name: "gen-graph",
        positional: None,
        about: "generate an R-MAT graph and publish it as an on-disk CSR directory",
        flags: &[
            flag("scale", Some("S"), "log2 of the R-MAT node count (default 13)"),
            flag("edge-factor", Some("E"), "sampled edges per node before dedup (default 8)"),
            flag("seed", Some("S"), "generation seed (default 0)"),
            flag("to-disk", Some("DIR"), "output directory for the on-disk CSR (required)"),
        ],
    },
    CommandSpec {
        name: "serve-bench",
        positional: None,
        about: "drive a saved model artifact with a Zipfian query load",
        flags: &[
            flag("model", Some("DIR"), "model artifact directory (from --save-model)"),
            flag("queries", Some("N"), "total embed queries (default 1000000)"),
            flag("batch", Some("B"), "node ids per embed call (default 64)"),
            flag("cache-rows", Some("R"), "hot-node LRU capacity in rows (default 4096)"),
            flag("zipf", Some("S"), "Zipf exponent of the query stream (default 0.99)"),
            flag("seed", Some("S"), "query-stream seed"),
            flag("json", None, "emit the bench record as JSON"),
        ],
    },
];

fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

/// Classic two-row Levenshtein distance (flag-typo suggestions).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let cost = usize::from(ca != cb);
            cur[j + 1] = (prev[j] + cost).min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

fn unknown_flag_error(spec: &CommandSpec, key: &str) -> anyhow::Error {
    let mut best: Option<(usize, &str)> = None;
    for f in spec.flags {
        let d = levenshtein(key, f.name);
        if best.is_none_or(|(bd, _)| d < bd) {
            best = Some((d, f.name));
        }
    }
    match best.filter(|&(d, _)| d <= 2) {
        Some((_, name)) => {
            anyhow!("unknown flag '--{key}' for {} (did you mean '--{name}'?)", spec.name)
        }
        None => {
            anyhow!("unknown flag '--{key}' for {} (see `poshashemb help {}`)", spec.name, spec.name)
        }
    }
}

/// Parsed flags for one subcommand, validated against its
/// [`CommandSpec`] table.
struct CliArgs {
    values: HashMap<&'static str, String>,
}

impl CliArgs {
    /// Parse `--flag value` / `--flag=value` / boolean `--flag` tokens.
    /// Unknown flags error with a nearest-name suggestion; valued flags
    /// without a value, booleans given one, and repeated flags all
    /// error.
    fn parse(spec: &CommandSpec, args: &[String]) -> Result<CliArgs> {
        let mut values: HashMap<&'static str, String> = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let tok = &args[i];
            let body = tok.strip_prefix("--").ok_or_else(|| {
                anyhow!("expected --flag, got '{tok}' (see `poshashemb help {}`)", spec.name)
            })?;
            let (key, inline) = match body.split_once('=') {
                Some((k, v)) => (k, Some(v.to_string())),
                None => (body, None),
            };
            let fs = spec
                .flags
                .iter()
                .find(|f| f.name == key)
                .ok_or_else(|| unknown_flag_error(spec, key))?;
            let val = match (fs.value, inline) {
                (Some(_), Some(v)) => {
                    i += 1;
                    v
                }
                (Some(ph), None) => {
                    let v = args
                        .get(i + 1)
                        .filter(|v| !v.starts_with("--"))
                        .ok_or_else(|| anyhow!("--{key} requires a value ({ph})"))?;
                    i += 2;
                    v.clone()
                }
                (None, Some(_)) => bail!("--{key} takes no value"),
                (None, None) => {
                    i += 1;
                    "true".to_string()
                }
            };
            if values.insert(fs.name, val).is_some() {
                bail!("--{key} given more than once");
            }
        }
        Ok(CliArgs { values })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    fn has(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    /// Parse a valued flag, wrapping parse failures with the flag name.
    fn parse_as<T>(&self, name: &str) -> Result<Option<T>>
    where
        T: std::str::FromStr,
        T::Err: std::fmt::Display,
    {
        self.get(name)
            .map(|v| v.parse::<T>().map_err(|e| anyhow!("--{name} '{v}': {e}")))
            .transpose()
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.get(1..).unwrap_or(&[]).to_vec();
    if matches!(cmd, "help" | "--help" | "-h") {
        match rest.first().map(String::as_str) {
            Some(sub) => match command_spec(sub) {
                Some(spec) => print_command_help(spec),
                None => bail!("unknown subcommand '{sub}' (see `poshashemb help`)"),
            },
            None => print_help(),
        }
        return Ok(());
    }
    // `datasets` is an alias for `report datasets`
    let canonical = if cmd == "datasets" { "report" } else { cmd };
    let spec = command_spec(canonical)
        .ok_or_else(|| anyhow!("unknown subcommand '{cmd}' (see `poshashemb help`)"))?;
    if rest.iter().any(|a| a == "--help" || a == "-h") {
        print_command_help(spec);
        return Ok(());
    }
    // allow `report datasets` (positional) by skipping non-flag tokens
    let flag_args: Vec<String> =
        rest.iter().skip_while(|a| !a.starts_with("--")).cloned().collect();
    let parsed = CliArgs::parse(spec, &flag_args)?;
    match spec.name {
        "report" => cmd_report(),
        "list" => cmd_list(&parsed),
        "gen-manifest" => cmd_gen_manifest(&parsed),
        "partition" => cmd_partition(&parsed),
        "train" => cmd_train(&parsed),
        "train-minibatch" => cmd_train_minibatch(&parsed),
        "crash-test" => cmd_crash_test(&parsed),
        "showdown" => cmd_showdown(&parsed),
        "train-sharded" => cmd_train_sharded(&parsed),
        "experiment" => cmd_experiment(&parsed),
        "compose" => cmd_compose(&parsed),
        "partition-bench" => cmd_partition_bench(&parsed),
        "gen-graph" => cmd_gen_graph(&parsed),
        "serve-bench" => cmd_serve_bench(&parsed),
        other => bail!("unknown subcommand '{other}' (see `poshashemb help`)"),
    }
}

fn print_help() {
    println!("poshashemb — Position-based Hash Embeddings for GNNs (paper reproduction)\n");
    println!("USAGE: poshashemb <subcommand> [--flags]\n");
    for c in COMMANDS {
        let label = match c.positional {
            Some(p) => format!("{} {p}", c.name),
            None => c.name.to_string(),
        };
        println!("  {label:<18} {}", c.about);
    }
    println!("\nRun `poshashemb help <subcommand>` for its flags.");
}

fn print_command_help(spec: &CommandSpec) {
    let label = match spec.positional {
        Some(p) => format!("{} {p}", spec.name),
        None => spec.name.to_string(),
    };
    println!("poshashemb {label} — {}\n", spec.about);
    if spec.flags.is_empty() {
        println!("(no flags)");
        return;
    }
    println!("FLAGS:");
    for f in spec.flags {
        let head = match f.value {
            Some(ph) => format!("--{} {ph}", f.name),
            None => format!("--{}", f.name),
        };
        println!("  {head:<26} {}", f.help);
    }
}

fn cmd_report() -> Result<()> {
    println!("| {:<16} | {:>9} | {:>10} | degree | homophily |", "Dataset", "#Nodes", "#Edges");
    for name in DATASET_NAMES {
        let ds = Dataset::generate(&spec(name).unwrap());
        println!("{}", ds.stats().table_row(name));
    }
    Ok(())
}

fn cmd_list(args: &CliArgs) -> Result<()> {
    let group = args.get("group");
    for e in full_grid() {
        if group.is_none_or(|g| e.group == g) {
            println!("{:<40} {:<6} {:<16} {}", e.name, e.group, e.dataset, e.method.name());
        }
    }
    Ok(())
}

fn cmd_gen_manifest(args: &CliArgs) -> Result<()> {
    let grid = match args.get("grid").unwrap_or("full") {
        "full" => full_grid(),
        "smoke" => smoke_grid(),
        other => bail!("unknown grid '{other}'"),
    };
    let out = args
        .get("out")
        .map(str::to_string)
        .unwrap_or_else(|| "artifacts/manifest_request.json".to_string());
    std::fs::create_dir_all(Path::new(&out).parent().unwrap_or(Path::new(".")))?;
    write_aot_request(&grid, Path::new(&out))?;
    println!("wrote {} configs to {out}", grid.len());
    Ok(())
}

fn cmd_partition(args: &CliArgs) -> Result<()> {
    let dsname = args.get("dataset").unwrap_or("synth-arxiv");
    let sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
    let ds = Dataset::generate(&sp);
    let k: usize = args.parse_as("k")?.unwrap_or(8);
    let levels: usize = args.parse_as("levels")?.unwrap_or(1);
    let t0 = std::time::Instant::now();
    if levels <= 1 {
        let p = partition(&ds.graph, &PartitionConfig::with_k(k));
        println!(
            "{dsname}: n={} m={} k={k} cut={:.0} imbalance={:.3} sizes={:?} [{:?}]",
            ds.graph.num_nodes(),
            ds.graph.num_edges(),
            p.edge_cut,
            p.imbalance,
            &p.part_sizes()[..k.min(12)],
            t0.elapsed()
        );
    } else {
        let h = Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, levels));
        h.validate().map_err(|e| anyhow!(e))?;
        println!(
            "{dsname}: {levels}-level hierarchy k={k} m={:?} total={} [{:?}]",
            h.m,
            h.total_partitions(),
            t0.elapsed()
        );
    }
    Ok(())
}

fn cmd_train(args: &CliArgs) -> Result<()> {
    let name = args.get("experiment").ok_or_else(|| anyhow!("--experiment NAME required"))?;
    let e = full_grid()
        .into_iter()
        .find(|e| e.name == name)
        .ok_or_else(|| anyhow!("unknown experiment '{name}' (see `poshashemb list`)"))?;
    let seed: u64 = args.parse_as("seed")?.unwrap_or(0);
    let mut opts = TrainOptions { verbose: args.has("verbose"), ..Default::default() };
    if let Some(ep) = args.parse_as("epochs")? {
        opts.epochs = Some(ep);
    }
    let dir = std::env::var("POSHASH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(Path::new(&dir))?;
    let outcome = run_experiment(&client, &manifest, &e, seed, &opts)?;
    println!("{}", outcome.row());
    Ok(())
}

/// Materialize the (dataset, plan) for a CLI `(--dataset, --method)`
/// pair — the shared front half of the `compose` and `train-minibatch`
/// subcommands. The tag goes through [`MethodSpec`]: bare tags resolve
/// paper-default scale knobs from `n` (exactly as the experiment grid
/// does), explicit parameters like `inter(k=9,h=1)` override them.
/// `nodes`/`dim` shrink (or grow) the synthetic spec before generation
/// — community/super counts are capped so the planted structure stays
/// valid — letting smoke runs like `crash-test` finish in seconds.
fn dataset_and_plan(
    dsname: &str,
    tag: &str,
    seed: u64,
    nodes: Option<usize>,
    dim: Option<usize>,
) -> Result<(Dataset, EmbeddingPlan)> {
    let mut sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
    if let Some(n) = nodes {
        if n == 0 {
            bail!("--nodes must be >= 1");
        }
        sp.n = n;
        sp.communities = sp.communities.min(n.div_ceil(20)).max(1);
        sp.supers = sp.supers.min(sp.communities);
    }
    if let Some(d) = dim {
        if d == 0 {
            bail!("--dim must be >= 1");
        }
        sp.d = d;
    }
    let resolved = MethodSpec::parse(tag)?.resolve(sp.n)?;
    let ds = Dataset::generate(&sp);
    let hier = if resolved.method.needs_hierarchy() {
        let levels = resolved.method.levels().max(1);
        Some(Hierarchy::build(&ds.graph, &HierarchyConfig::new(resolved.k, levels)))
    } else {
        None
    };
    let plan = EmbeddingPlan::build(sp.n, sp.d, &resolved.method, hier.as_ref(), seed);
    Ok((ds, plan))
}

/// Host-side compose-engine benchmark: no PJRT artifacts required.
fn cmd_compose(args: &CliArgs) -> Result<()> {
    let dsname = args.get("dataset").unwrap_or("synth-arxiv");
    let tag = args.get("method").unwrap_or("intra");
    let batch: usize = args.parse_as("batch")?.unwrap_or(1024);
    let (_ds, plan) = dataset_and_plan(dsname, tag, 0, None, None)?;
    eprintln!("compose bench: {dsname} n={} d={} method={}", plan.n, plan.d, plan.method.name());
    let records = bench_compose(&plan, batch);
    if args.has("json") {
        println!("{}", serde_json::to_string_pretty(&records)?);
    } else {
        for r in &records {
            println!("{}", r.row());
        }
    }
    Ok(())
}

/// Host-side neighbor-sampled minibatch training on the compose engine:
/// no PJRT artifacts required. Defaults come from the experiment grid
/// (`--experiment`) or from `SamplerConfig::default()`; flags override.
fn cmd_train_minibatch(args: &CliArgs) -> Result<()> {
    let seed: u64 = args.parse_as("seed")?.unwrap_or(0);
    let exp_flag = args.get("experiment");
    if exp_flag.is_some() && (args.has("dataset") || args.has("method")) {
        bail!("--experiment already fixes the dataset and method; drop --dataset/--method");
    }
    if exp_flag.is_some() && (args.has("nodes") || args.has("dim")) {
        bail!("--experiment already fixes the dataset size; drop --nodes/--dim");
    }
    let graph_dir = args.get("graph-dir");
    if graph_dir.is_some() {
        if exp_flag.is_some() || args.has("dataset") || args.has("nodes") {
            bail!("--graph-dir loads a pre-generated graph; drop --experiment/--dataset/--nodes");
        }
        if args.has("save-model") {
            bail!(
                "--save-model embeds the resident graph in the artifact, which a \
                 disk-backed run never materializes; drop --graph-dir or --save-model"
            );
        }
    }
    let (label, dsname, ds, plan, mut cfg, mut opts) = if let Some(name) = exp_flag {
        let e = full_grid()
            .into_iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("unknown experiment '{name}' (see `poshashemb list`)"))?;
        let (ds, _hier, plan) = materialize(&e, seed);
        let opts =
            MinibatchOptions { epochs: e.epochs, lr: e.lr as f32, seed, ..Default::default() };
        (e.name.clone(), e.dataset.to_string(), ds, plan, e.sampling, opts)
    } else if let Some(dir) = graph_dir {
        let d: usize = args.parse_as("dim")?.unwrap_or(32);
        if d == 0 {
            bail!("--dim must be >= 1");
        }
        let tag = args.get("method").unwrap_or("intra");
        eprintln!("minibatch train: opening on-disk graph at {dir}");
        let graph: GraphHandle = DiskCsr::open(Path::new(dir))?.into();
        let n = graph.num_nodes();
        let resolved = MethodSpec::parse(tag)?.resolve(n)?;
        let ds = powerlaw_dataset(graph, d, seed);
        let hier = if resolved.method.needs_hierarchy() {
            let levels = resolved.method.levels().max(1);
            Some(Hierarchy::build(&ds.graph, &HierarchyConfig::new(resolved.k, levels)))
        } else {
            None
        };
        let plan = EmbeddingPlan::build(n, d, &resolved.method, hier.as_ref(), seed);
        let opts = MinibatchOptions { seed, ..Default::default() };
        let label = format!("disk:{dir}");
        (label, "rmat-powerlaw".to_string(), ds, plan, SamplerConfig::default(), opts)
    } else {
        let dsname = args.get("dataset").unwrap_or("synth-arxiv");
        let tag = args.get("method").unwrap_or("intra");
        let (nodes, dim) = (args.parse_as("nodes")?, args.parse_as("dim")?);
        let (ds, plan) = dataset_and_plan(dsname, tag, seed, nodes, dim)?;
        let opts = MinibatchOptions { seed, ..Default::default() };
        (dsname.to_string(), dsname.to_string(), ds, plan, SamplerConfig::default(), opts)
    };
    if let Some(b) = args.parse_as("batch")? {
        cfg.batch_size = b;
        if cfg.batch_size == 0 {
            bail!("--batch must be >= 1");
        }
    }
    if args.has("fanout") && args.has("fanouts") {
        bail!("--fanouts already sets every hop's fanout; drop --fanout");
    }
    if let Some(f) = args.get("fanout") {
        cfg.fanouts = Fanouts::single(Fanout::parse(f).map_err(|e| anyhow!(e))?);
    }
    if let Some(f) = args.get("fanouts") {
        cfg.fanouts = Fanouts::parse(f).map_err(|e| anyhow!(e))?;
    }
    if let Some(w) = args.parse_as("hidden")? {
        opts.hidden = w;
        if opts.hidden == 0 {
            bail!("--hidden must be >= 1");
        }
    }
    if let Some(t) = args.get("task") {
        let obj = Objective::parse(t).map_err(|e| anyhow!(e))?;
        if args.has("neg") && !obj.is_link() {
            bail!("--neg only applies to link-prediction tasks");
        }
        let neg: usize = args.parse_as("neg")?.unwrap_or(3);
        if neg == 0 {
            bail!("--neg must be >= 1");
        }
        if obj.is_link() && opts.hidden == 0 {
            // link prediction embeds nodes at the head's hidden width;
            // give unflagged runs a working default instead of a bail
            opts.hidden = 32;
        }
        opts.objective = obj.with_neg_per_pos(neg);
    } else if args.has("neg") {
        bail!("--neg needs --task linkpred or --task linkpred-hadamard");
    }
    if args.has("no-shuffle") {
        cfg.shuffle = false;
    }
    if let Some(e) = args.parse_as("epochs")? {
        opts.epochs = e;
    }
    if let Some(lr) = args.parse_as("lr")? {
        opts.lr = lr;
        if !opts.lr.is_finite() || opts.lr <= 0.0 {
            bail!("--lr must be a positive number");
        }
    }
    if let Some(o) = args.get("optimizer") {
        opts.optimizer = OptimizerKind::parse(o).map_err(|e| anyhow!(e))?;
    }
    if args.has("serial") && args.has("prefetch") {
        bail!("--serial already disables prefetching; drop --prefetch");
    }
    if args.has("serial") {
        // the single-threaded oracle path: same losses, no pipeline
        opts.parallel = false;
        opts.prefetch = 0;
    }
    if let Some(p) = args.parse_as("prefetch")? {
        opts.prefetch = p;
    }
    if let Some(dir) = args.get("save-model") {
        opts.save_model = Some(PathBuf::from(dir));
    }
    let wants_ckpt = args.has("checkpoint-every") || args.has("checkpoint-keep");
    if args.get("checkpoint-dir").is_none() && (wants_ckpt || args.has("resume")) {
        bail!("--checkpoint-every/--checkpoint-keep/--resume need --checkpoint-dir DIR");
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        let every: usize = args.parse_as("checkpoint-every")?.unwrap_or(50);
        let keep: usize = args.parse_as("checkpoint-keep")?.unwrap_or(3);
        opts.checkpoint = Some(CheckpointConfig { dir: PathBuf::from(dir), every, keep });
        opts.resume = args.has("resume");
    }
    opts.verbose = args.has("verbose");
    eprintln!(
        "minibatch train: {label} n={} d={} method={} batch={} fanouts={} layers={} epochs={} \
         {} lr={} {} prefetch={}",
        plan.n,
        plan.d,
        plan.method.name(),
        cfg.batch_size,
        cfg.fanouts,
        cfg.fanouts.layers(),
        opts.epochs,
        opts.optimizer.as_str(),
        opts.lr,
        if opts.parallel { "pipelined" } else { "serial" },
        opts.prefetch
    );
    let save_dir = opts.save_model.clone();
    let record = bench_minibatch(&dsname, &ds, &plan, &cfg, &opts)?;
    if let Some(dir) = save_dir {
        eprintln!("saved model artifact to {}", dir.display());
    }
    if args.has("json") {
        println!("{}", serde_json::to_string_pretty(&record)?);
    } else {
        println!("{}", record.row());
    }
    Ok(())
}

/// Run `train-minibatch` as a subprocess of this same binary,
/// optionally with an injected fault armed in its environment (and the
/// parent's fault spec, if any, scrubbed otherwise).
fn run_trainer_subprocess(argv: &[String], fault: Option<&str>) -> Result<std::process::Output> {
    let exe = std::env::current_exe()?;
    let mut cmd = Command::new(exe);
    cmd.arg("train-minibatch").args(argv);
    if let Some(spec) = fault {
        cmd.env(FAULT_ENV, spec);
    } else {
        cmd.env_remove(FAULT_ENV);
    }
    cmd.output().map_err(|e| anyhow!("spawning trainer subprocess: {e}"))
}

/// Parse the `losses` trajectory out of a `train-minibatch --json`
/// record. JSON round-trips `f64` exactly (shortest-round-trip
/// printing), so comparing the parsed values bit for bit is exact.
fn losses_from_json(stdout: &[u8]) -> Result<Vec<f64>> {
    let v: serde_json::Value = serde_json::from_slice(stdout)
        .map_err(|e| anyhow!("trainer emitted unparseable JSON: {e}"))?;
    let arr = v["losses"].as_array().ok_or_else(|| anyhow!("record has no losses array"))?;
    arr.iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("non-numeric loss in record")))
        .collect()
}

/// End-to-end crash/recovery proof. Three runs of this same binary:
/// an uninterrupted control; a checkpointing victim aborted mid-epoch
/// by a deterministic injected fault; and a `--resume` of the victim.
/// Passes iff the victim really died, really left checkpoints, really
/// resumed from one — and the resumed run's per-epoch loss trajectory
/// matches the control **bit for bit**.
fn cmd_crash_test(args: &CliArgs) -> Result<()> {
    let kill_step: u64 = args.parse_as("kill-step")?.unwrap_or(6);
    let every: usize = args.parse_as("checkpoint-every")?.unwrap_or(2);
    if kill_step == 0 {
        bail!("--kill-step must be >= 1");
    }
    if every == 0 {
        bail!("--checkpoint-every must be >= 1");
    }
    let epochs = args.get("epochs").unwrap_or("3");
    let mut base: Vec<String> = [
        ("--dataset", args.get("dataset").unwrap_or("synth-arxiv")),
        ("--method", args.get("method").unwrap_or("intra")),
        ("--nodes", args.get("nodes").unwrap_or("400")),
        ("--dim", args.get("dim").unwrap_or("16")),
        ("--batch", args.get("batch").unwrap_or("64")),
        ("--fanouts", args.get("fanouts").unwrap_or("5,3")),
        ("--epochs", epochs),
        ("--seed", "0"),
    ]
    .iter()
    .flat_map(|(k, v)| [k.to_string(), v.to_string()])
    .collect();
    if args.has("serial") {
        base.push("--serial".to_string());
    }
    let (_tmp, dir) = match args.get("dir") {
        Some(d) => (None, PathBuf::from(d)),
        None => {
            let t = TempDir::new("poshashemb-crash-test")?;
            let p = t.path().to_path_buf();
            (Some(t), p)
        }
    };

    eprintln!("crash-test: control run ({epochs} epochs, uninterrupted)");
    let mut control_args = base.clone();
    control_args.push("--json".to_string());
    let control = run_trainer_subprocess(&control_args, None)?;
    if !control.status.success() {
        bail!("control run failed:\n{}", String::from_utf8_lossy(&control.stderr));
    }
    let control_losses = losses_from_json(&control.stdout)?;

    let mut victim_args = base.clone();
    victim_args.extend([
        "--checkpoint-dir".to_string(),
        dir.display().to_string(),
        "--checkpoint-every".to_string(),
        every.to_string(),
    ]);
    let fault = format!("trainer.step={kill_step}:abort");
    eprintln!("crash-test: victim run (aborted by injected fault {fault})");
    let victim = run_trainer_subprocess(&victim_args, Some(&fault))?;
    if victim.status.success() {
        bail!("victim run survived — it never reached step {kill_step}; lower --kill-step");
    }
    let ckpts: Vec<String> = std::fs::read_dir(&dir)
        .map_err(|e| anyhow!("reading checkpoint dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("ckpt-"))
        .collect();
    if ckpts.is_empty() {
        bail!("victim died before any checkpoint; raise --kill-step or lower --checkpoint-every");
    }

    eprintln!("crash-test: resume run ({} checkpoint(s) on disk)", ckpts.len());
    let mut resume_args = victim_args.clone();
    resume_args.extend(["--resume".to_string(), "--json".to_string()]);
    let resume = run_trainer_subprocess(&resume_args, None)?;
    if !resume.status.success() {
        bail!("resume run failed:\n{}", String::from_utf8_lossy(&resume.stderr));
    }
    let resume_stderr = String::from_utf8_lossy(&resume.stderr);
    if !resume_stderr.contains("resumed from checkpoint") {
        bail!("resume run started from scratch instead of a checkpoint:\n{resume_stderr}");
    }
    let resumed_losses = losses_from_json(&resume.stdout)?;

    if control_losses.len() != resumed_losses.len() {
        bail!(
            "loss trajectories differ in length: control {} vs resumed {}",
            control_losses.len(),
            resumed_losses.len()
        );
    }
    for (i, (c, r)) in control_losses.iter().zip(&resumed_losses).enumerate() {
        if c.to_bits() != r.to_bits() {
            bail!("loss diverged at epoch {i}: control {c:.17e} vs resumed {r:.17e}");
        }
    }
    println!(
        "crash-test PASS: victim killed before step {kill_step}, resumed, {} epoch losses \
         bit-identical to the uninterrupted control",
        control_losses.len()
    );
    Ok(())
}

/// The paper's memory/accuracy claim at the CLI: sweep a
/// (method × task × memory-budget) grid with the minibatch trainer and
/// emit one schema-versioned record per cell (see
/// `bench_harness::run_showdown`).
fn cmd_showdown(args: &CliArgs) -> Result<()> {
    let mut cfg = ShowdownConfig::default();
    if let Some(d) = args.get("dataset") {
        cfg.dataset = d.to_string();
    }
    if let Some(m) = args.get("methods") {
        cfg.methods =
            m.split(',').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect();
    }
    let neg: usize = args.parse_as("neg")?.unwrap_or(3);
    if neg == 0 {
        bail!("--neg must be >= 1");
    }
    let tasks = match args.get("tasks") {
        Some(t) => t
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| Objective::parse(s).map_err(|e| anyhow!(e)))
            .collect::<Result<Vec<_>>>()?,
        None => cfg.tasks,
    };
    cfg.tasks = tasks.into_iter().map(|o| o.with_neg_per_pos(neg)).collect();
    if let Some(b) = args.get("budgets") {
        cfg.budgets = b
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().map_err(|e| anyhow!("--budgets '{s}': {e}")))
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(e) = args.parse_as("epochs")? {
        cfg.epochs = e;
    }
    if let Some(b) = args.parse_as("batch")? {
        cfg.batch_size = b;
        if cfg.batch_size == 0 {
            bail!("--batch must be >= 1");
        }
    }
    if let Some(f) = args.get("fanouts") {
        cfg.fanouts = Fanouts::parse(f).map_err(|e| anyhow!(e))?;
    }
    if let Some(w) = args.parse_as("hidden")? {
        cfg.hidden = w;
        if cfg.hidden == 0 {
            bail!("--hidden must be >= 1");
        }
    }
    if let Some(s) = args.parse_as("seed")? {
        cfg.seed = s;
    }
    cfg.nodes = args.parse_as("nodes")?;
    cfg.dim = args.parse_as("dim")?;
    cfg.verbose = args.has("verbose");
    cfg.parallel = !args.has("sequential");
    eprintln!(
        "showdown: {} methods=[{}] tasks=[{}] budgets={:?} epochs={} batch={} fanouts={}",
        cfg.dataset,
        cfg.methods.join(","),
        cfg.tasks.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(","),
        cfg.budgets,
        cfg.epochs,
        cfg.batch_size,
        cfg.fanouts
    );
    let records = run_showdown(&cfg)?;
    let json = serde_json::to_string_pretty(&records)?;
    if let Some(path) = args.get("out") {
        if let Some(parent) = Path::new(path).parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &json)?;
        eprintln!("wrote {} records to {path}", records.len());
    }
    if args.has("json") {
        println!("{json}");
    } else {
        for r in &records {
            println!("{}", r.row());
        }
    }
    Ok(())
}

/// Wrap a generated power-law graph in a [`Dataset`] for sharded
/// training. The labels are degree buckets (`log2(degree + 1)`, capped
/// at 8 classes) — learnable from graph structure alone, so loss
/// actually falls — and the communities mirror them so budget math
/// stays well-defined. Splits come from the shared 80/10/10
/// `train_val_test_split`. The handle may be disk-backed: degrees come
/// from the resident indptr, so labels (and everything derived from
/// them) are bit-identical across backends.
fn powerlaw_dataset(graph: GraphHandle, d: usize, seed: u64) -> Dataset {
    let n = graph.num_nodes();
    let labels: Vec<u32> =
        (0..n as u32).map(|u| (graph.degree(u) as u64 + 1).ilog2().min(7)).collect();
    let communities = labels.clone();
    let splits = train_val_test_split(n, 0.8, 0.1, seed);
    let spec = DatasetSpec {
        name: "rmat-powerlaw",
        n,
        classes: 8,
        communities: 8,
        supers: 1,
        intra_degree: 0.0,
        super_degree: 0.0,
        inter_degree: 0.0,
        super_label_weight: 0.0,
        train_frac: 0.8,
        label_flip: 0.0,
        task: TaskKind::MultiClass,
        d,
        seed,
    };
    Dataset { spec, graph, communities, labels, splits }
}

/// The `--parity-check` harness behind `train-sharded`: prove on this
/// exact (dataset, method) that a k=1 [`ShardedTrainer`] reproduces the
/// plain [`MinibatchTrainer`]'s per-epoch loss trajectory **bit for
/// bit**, in both the serial and the pipelined engine. Prints a
/// greppable `PASS` line for CI; any divergence is a hard error.
fn sharded_parity_check(
    ds: &Dataset,
    method: &EmbeddingMethod,
    hier_k: usize,
    sync_every: usize,
    cfg: &SamplerConfig,
    opts: &MinibatchOptions,
    seed: u64,
) -> Result<()> {
    for (label, parallel, prefetch) in [("serial", false, 0usize), ("pipelined", true, 2)] {
        let mut o = opts.clone();
        o.parallel = parallel;
        o.prefetch = prefetch;
        let hier = if method.needs_hierarchy() {
            let levels = method.levels().max(1);
            Some(Hierarchy::build(&ds.graph, &HierarchyConfig::new(hier_k, levels)))
        } else {
            None
        };
        let plan = EmbeddingPlan::build(ds.spec.n, ds.spec.d, method, hier.as_ref(), seed);
        let reference = MinibatchTrainer::new(ds, &plan, cfg.clone(), o.clone())?.train()?;
        let sharded = ShardedTrainer::new(ds, method, hier_k, 1, sync_every, cfg.clone(), o)?
            .train()?;
        if reference.losses.len() != sharded.losses.len() {
            bail!(
                "k=1 parity FAIL ({label}): {} reference epochs vs {} sharded",
                reference.losses.len(),
                sharded.losses.len()
            );
        }
        for (e, (a, b)) in reference.losses.iter().zip(&sharded.losses).enumerate() {
            if a.to_bits() != b.to_bits() {
                bail!(
                    "k=1 parity FAIL ({label}): epoch {e} loss {a:.17e} (reference) vs {b:.17e} \
                     (sharded)"
                );
            }
        }
        eprintln!(
            "parity ok ({label}): {} epoch losses bit-identical to the minibatch trainer",
            reference.losses.len()
        );
    }
    println!(
        "sharded parity PASS: k=1 reproduces the minibatch trainer bit for bit \
         (serial + pipelined)"
    );
    Ok(())
}

/// Partition-sharded training on a streamed synthetic power-law graph
/// (see `coordinator::ShardedTrainer`): multilevel-partition into
/// `--shards` shards, train shard-parallel epochs with per-epoch halo
/// exchange, and emit one `sharded/v1` record. `--parity-check` instead
/// runs the k=1 bit-parity harness on the same graph.
fn cmd_train_sharded(args: &CliArgs) -> Result<()> {
    let graph_dir = args.get("graph-dir");
    if graph_dir.is_some() && (args.has("scale") || args.has("edge-factor")) {
        bail!("--graph-dir loads a pre-generated graph; drop --scale/--edge-factor");
    }
    let scale: u32 = args.parse_as("scale")?.unwrap_or(13);
    if !(1..=30).contains(&scale) {
        bail!("--scale must be in 1..=30");
    }
    let edge_factor: usize = args.parse_as("edge-factor")?.unwrap_or(8);
    if edge_factor == 0 {
        bail!("--edge-factor must be >= 1");
    }
    let shards: usize = args.parse_as("shards")?.unwrap_or(4);
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    let tag = args.get("method").unwrap_or("intra");
    let d: usize = args.parse_as("dim")?.unwrap_or(32);
    if d < 4 || d % 4 != 0 {
        bail!("--dim must be a multiple of 4, at least 4");
    }
    let sync_every: usize = args.parse_as("sync-every")?.unwrap_or(1);
    let seed: u64 = args.parse_as("seed")?.unwrap_or(0);
    let graph: GraphHandle = match graph_dir {
        Some(dir) => {
            eprintln!("train-sharded: opening on-disk graph at {dir}");
            DiskCsr::open(Path::new(dir))?.into()
        }
        None => {
            let n = 1usize << scale;
            eprintln!(
                "train-sharded: generating R-MAT graph (scale={scale}, n={n}, ~{} sampled edges)",
                n * edge_factor
            );
            rmat_streamed(&RmatConfig { scale, edge_factor, seed, ..Default::default() }).into()
        }
    };
    let n = graph.num_nodes();
    let edges = graph.num_edges() as u64;
    let ds = powerlaw_dataset(graph, d, seed);
    let resolved = MethodSpec::parse(tag)?.resolve(n)?;
    let mut cfg = SamplerConfig::default();
    if let Some(b) = args.parse_as("batch")? {
        cfg.batch_size = b;
        if cfg.batch_size == 0 {
            bail!("--batch must be >= 1");
        }
    }
    if let Some(f) = args.get("fanouts") {
        cfg.fanouts = Fanouts::parse(f).map_err(|e| anyhow!(e))?;
    }
    let mut opts = MinibatchOptions { seed, epochs: 3, ..Default::default() };
    if let Some(e) = args.parse_as("epochs")? {
        opts.epochs = e;
    }
    if let Some(w) = args.parse_as("hidden")? {
        opts.hidden = w;
        if opts.hidden == 0 {
            bail!("--hidden must be >= 1");
        }
    }
    if args.has("serial") {
        opts.parallel = false;
        opts.prefetch = 0;
    }
    opts.verbose = args.has("verbose");
    if args.has("parity-check") {
        return sharded_parity_check(
            &ds,
            &resolved.method,
            resolved.k,
            sync_every,
            &cfg,
            &opts,
            seed,
        );
    }
    let (epochs, engine) = (opts.epochs, if opts.parallel { "pipelined" } else { "serial" });
    let trainer =
        ShardedTrainer::new(&ds, &resolved.method, resolved.k, shards, sync_every, cfg, opts)?;
    eprintln!(
        "train-sharded: n={n} edges={edges} d={d} method={} k={} edge_cut={:.0} \
         epochs={epochs} sync_every={sync_every} {engine}",
        resolved.method.name(),
        trainer.k(),
        trainer.edge_cut(),
    );
    let out = trainer.train()?;
    let record = ShardedBenchRecord::from_outcome(
        "rmat-powerlaw",
        resolved.method.name(),
        n,
        edges,
        d,
        sync_every,
        seed,
        &out,
    );
    let json = serde_json::to_string_pretty(&record)?;
    if let Some(path) = args.get("out") {
        if let Some(parent) = Path::new(path).parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &json)?;
        eprintln!("wrote sharded/v1 record to {path}");
    }
    if args.has("json") {
        println!("{json}");
    } else {
        println!("{}", record.row());
        for s in &record.shards {
            println!(
                "  shard {:<3} owned={:<8} halo={:<7} resident={:>10}B  {:>9.0} nodes/s  \
                 loss={:.4}",
                s.shard,
                s.owned_nodes,
                s.halo_nodes,
                s.resident_table_bytes,
                s.nodes_per_sec,
                s.final_loss
            );
        }
    }
    Ok(())
}

/// Partitioner pipeline benchmark: no PJRT artifacts required. Without
/// `--dataset` it runs on the acceptance SBM graph (n = 50k, 32
/// communities) that `cargo bench --bench partitioner` also uses.
fn cmd_partition_bench(args: &CliArgs) -> Result<()> {
    let k: usize = args.parse_as("k")?.unwrap_or(32);
    let levels: usize = args.parse_as("levels")?.unwrap_or(3);
    let seed: u64 = args.parse_as("seed")?.unwrap_or(1);
    let (graph, label): (GraphHandle, String) = match (args.get("graph-dir"), args.get("dataset"))
    {
        (Some(_), Some(_)) => bail!("--graph-dir and --dataset are mutually exclusive"),
        (Some(dir), None) => (DiskCsr::open(Path::new(dir))?.into(), format!("disk:{dir}")),
        (None, Some(dsname)) => {
            let sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
            (Dataset::generate(&sp).graph, dsname.to_string())
        }
        (None, None) => {
            let (g, _) = planted_partition(&PlantedPartitionConfig {
                n: 50_000,
                communities: 32,
                intra_degree: 12.0,
                inter_degree: 2.0,
                seed: 3,
                ..Default::default()
            });
            (g.into(), "sbm-50k".to_string())
        }
    };
    eprintln!(
        "partition bench: {label} n={} m={} k={k} levels={levels}",
        graph.num_nodes(),
        graph.num_edges()
    );
    let records = bench_partition(&graph, k, levels, seed);
    if args.has("json") {
        println!("{}", serde_json::to_string_pretty(&records)?);
    } else {
        for r in &records {
            println!("{}", r.row());
        }
    }
    Ok(())
}

/// Generate a deterministic R-MAT graph and publish it as an on-disk
/// CSR directory (`graph::write_graph_dir`): a manifest plus raw
/// little-endian section files with per-section checksums, written to a
/// temp sibling and atomically renamed into place. The directory feeds
/// `train-minibatch`, `train-sharded` and `partition-bench` via
/// `--graph-dir`, whose results are bit-identical to the corresponding
/// in-memory runs.
fn cmd_gen_graph(args: &CliArgs) -> Result<()> {
    let scale: u32 = args.parse_as("scale")?.unwrap_or(13);
    if !(1..=30).contains(&scale) {
        bail!("--scale must be in 1..=30");
    }
    let edge_factor: usize = args.parse_as("edge-factor")?.unwrap_or(8);
    if edge_factor == 0 {
        bail!("--edge-factor must be >= 1");
    }
    let seed: u64 = args.parse_as("seed")?.unwrap_or(0);
    let dir = args.get("to-disk").ok_or_else(|| anyhow!("--to-disk DIR required"))?;
    let n = 1usize << scale;
    eprintln!("gen-graph: R-MAT scale={scale} (n={n}, ~{} sampled edges)", n * edge_factor);
    let graph = rmat_streamed(&RmatConfig { scale, edge_factor, seed, ..Default::default() });
    write_graph_dir(Path::new(dir), &graph)?;
    println!(
        "wrote disk-csr graph to {dir}: n={} edges={} ({} adjacency entries)",
        graph.num_nodes(),
        graph.num_edges(),
        graph.num_adjacency_entries()
    );
    Ok(())
}

/// Open a saved model artifact and measure it under a synthetic
/// Zipfian query load (see `crate::bench_harness::bench_serve`).
fn cmd_serve_bench(args: &CliArgs) -> Result<()> {
    let model = args.get("model").ok_or_else(|| anyhow!("--model DIR required"))?;
    let cache_rows: usize = args.parse_as("cache-rows")?.unwrap_or(4096);
    let mut opts = ServeBenchOptions::default();
    if let Some(q) = args.parse_as("queries")? {
        opts.queries = q;
    }
    if let Some(b) = args.parse_as("batch")? {
        opts.batch = b;
        if opts.batch == 0 {
            bail!("--batch must be >= 1");
        }
    }
    if let Some(s) = args.parse_as::<f64>("zipf")? {
        if !s.is_finite() || s < 0.0 {
            bail!("--zipf must be a finite non-negative exponent");
        }
        opts.zipf_s = s;
    }
    if let Some(s) = args.parse_as("seed")? {
        opts.seed = s;
    }
    let mut engine = ServeEngine::open(Path::new(model), cache_rows)?;
    let m = engine.manifest();
    eprintln!(
        "serve bench: {} method={} n={} d={} layers={} cache_rows={cache_rows}",
        m.dataset, m.method, m.n, m.d, m.layers
    );
    let record = bench_serve(&mut engine, &opts)?;
    if args.has("json") {
        println!("{}", serde_json::to_string_pretty(&record)?);
    } else {
        println!("{}", record.row());
    }
    Ok(())
}

fn cmd_experiment(args: &CliArgs) -> Result<()> {
    let group = args.get("group").ok_or_else(|| anyhow!("--group t3|t4|t5|f3|f4 required"))?;
    let harness = Harness::from_env()?;
    let exps = harness.group(group, args.get("dataset"));
    if exps.is_empty() {
        bail!("no artifacts for group {group}; run `make artifacts` with the full grid");
    }
    let outcomes = harness.run_all(&exps)?;
    let rows = rows_from_outcomes(&exps, &outcomes, |e| e.method.name());
    print_table(&format!("group {group}"), &rows);
    Ok(())
}
