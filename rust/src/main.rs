//! `poshashemb` CLI launcher.
//!
//! Subcommands:
//! * `report datasets` — Table II analog (dataset statistics).
//! * `list [--group G]` — list experiment configs in the grid.
//! * `gen-manifest [--grid full|smoke] [--out PATH]` — write the AOT
//!   request consumed by `python/compile/aot.py`.
//! * `partition --dataset D --k K [--levels L]` — run the multilevel
//!   partitioner and report cut/imbalance/hierarchy stats.
//! * `train --experiment NAME [--seed S] [--epochs N] [--verbose]` —
//!   train one configuration via the PJRT runtime.
//! * `experiment --group t3|t4|t5|f3|f4 [--dataset D]` — regenerate one
//!   paper table/figure.
//! * `compose --dataset D [--method M] [--batch B] [--json]` — benchmark
//!   the host-side compose engine (reference vs parallel vs batch paths);
//!   runs without PJRT artifacts.
//! * `train-minibatch [--experiment NAME | --dataset D --method M]
//!   [--batch B] [--fanout F|all | --fanouts F1,F2,..] [--hidden W]
//!   [--epochs N] [--lr LR] [--optimizer sgd|adam] [--no-shuffle]
//!   [--seed S] [--serial] [--prefetch DEPTH] [--json]` — host-side
//!   neighbor-sampled minibatch training on the compose engine; runs
//!   without PJRT artifacts and emits a JSON bench record. The fanout
//!   list's length is the SAGE head's depth (`--fanouts 10,5` = a
//!   2-layer head over 2-hop blocks; `--hidden` sets its intermediate
//!   width). The pipelined engine (prefetched sampling + parallel
//!   step) is the default; `--serial` selects the single-threaded
//!   oracle path (bit-identical losses, slower wall clock).
//! * `partition-bench [--dataset D] [--k K] [--levels L] [--json]` —
//!   benchmark the partitioner pipeline (scalar vs parallel matching,
//!   reference vs CSR contraction, end-to-end partition, hierarchy);
//!   defaults to the acceptance SBM (n = 50k, 32 communities).
//!
//! Argument parsing is hand-rolled (minimal-dependency build: no clap).

use anyhow::{anyhow, bail, Result};
use poshashemb::bench_harness::{
    bench_compose, bench_minibatch, bench_partition, print_table, rows_from_outcomes, Harness,
};
use poshashemb::config::{
    default_c, default_k, full_grid, materialize, smoke_grid, write_aot_request,
};
use poshashemb::coordinator::{run_experiment, MinibatchOptions, OptimizerKind, TrainOptions};
use poshashemb::data::{spec, Dataset, DATASET_NAMES};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::graph::{planted_partition, PlantedPartitionConfig};
use poshashemb::partition::{partition, Hierarchy, HierarchyConfig, PartitionConfig};
use poshashemb::runtime::{Manifest, RuntimeClient};
use poshashemb::sampler::{Fanout, Fanouts, SamplerConfig};
use std::collections::HashMap;
use std::path::Path;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` / `--flag` style args after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| anyhow!("expected --flag, got '{}'", args[i]))?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            map.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(map)
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = args.get(1..).unwrap_or(&[]).to_vec();
    // allow `report datasets` (positional) by skipping non-flag tokens
    let flag_args: Vec<String> =
        rest.iter().skip_while(|a| !a.starts_with("--")).cloned().collect();
    let flags = parse_flags(&flag_args)?;
    match cmd {
        "report" | "datasets" => cmd_report(),
        "list" => cmd_list(&flags),
        "gen-manifest" => cmd_gen_manifest(&flags),
        "partition" => cmd_partition(&flags),
        "train" => cmd_train(&flags),
        "train-minibatch" => cmd_train_minibatch(&flags),
        "experiment" => cmd_experiment(&flags),
        "compose" => cmd_compose(&flags),
        "partition-bench" => cmd_partition_bench(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see `poshashemb help`)"),
    }
}

fn print_help() {
    println!(
        "poshashemb — Position-based Hash Embeddings for GNNs (paper reproduction)\n\n\
         USAGE: poshashemb <subcommand> [--flags]\n\n\
         report datasets                        dataset statistics (Table II)\n\
         list [--group G]                       list experiment grid configs\n\
         gen-manifest [--grid full|smoke]       write artifacts/manifest_request.json\n\
         partition --dataset D --k K [--levels L]   run the multilevel partitioner\n\
         train --experiment NAME [--seed S] [--epochs N] [--verbose]\n\
         train-minibatch [--experiment NAME | --dataset D --method M] [--batch B]\n\
                         [--fanout F|all | --fanouts F1,F2,..] [--hidden W]\n\
                         [--epochs N] [--lr LR] [--optimizer sgd|adam]\n\
                         [--no-shuffle] [--seed S] [--serial] [--prefetch DEPTH]\n\
                         [--verbose] [--json]\n\
         experiment --group t3|t4|t5|f3|f4 [--dataset D]   regenerate a paper table\n\
         compose [--dataset D] [--method M] [--batch B] [--json]   bench the compose engine\n\
         partition-bench [--dataset D] [--k K] [--levels L] [--json]   bench the partitioner"
    );
}

fn cmd_report() -> Result<()> {
    println!("| {:<16} | {:>9} | {:>10} | degree | homophily |", "Dataset", "#Nodes", "#Edges");
    for name in DATASET_NAMES {
        let ds = Dataset::generate(&spec(name).unwrap());
        println!("{}", ds.stats().table_row(name));
    }
    Ok(())
}

fn cmd_list(flags: &HashMap<String, String>) -> Result<()> {
    let group = flags.get("group").map(String::as_str);
    for e in full_grid() {
        if group.is_none_or(|g| e.group == g) {
            println!("{:<40} {:<6} {:<16} {}", e.name, e.group, e.dataset, e.method.name());
        }
    }
    Ok(())
}

fn cmd_gen_manifest(flags: &HashMap<String, String>) -> Result<()> {
    let grid = match flags.get("grid").map(String::as_str).unwrap_or("full") {
        "full" => full_grid(),
        "smoke" => smoke_grid(),
        other => bail!("unknown grid '{other}'"),
    };
    let out = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "artifacts/manifest_request.json".to_string());
    std::fs::create_dir_all(Path::new(&out).parent().unwrap_or(Path::new(".")))?;
    write_aot_request(&grid, Path::new(&out))?;
    println!("wrote {} configs to {out}", grid.len());
    Ok(())
}

fn cmd_partition(flags: &HashMap<String, String>) -> Result<()> {
    let dsname = flags.get("dataset").map(String::as_str).unwrap_or("synth-arxiv");
    let sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
    let ds = Dataset::generate(&sp);
    let k: usize = flags.get("k").map(|v| v.parse()).transpose()?.unwrap_or(8);
    let levels: usize = flags.get("levels").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let t0 = std::time::Instant::now();
    if levels <= 1 {
        let p = partition(&ds.graph, &PartitionConfig::with_k(k));
        println!(
            "{dsname}: n={} m={} k={k} cut={:.0} imbalance={:.3} sizes={:?} [{:?}]",
            ds.graph.num_nodes(),
            ds.graph.num_edges(),
            p.edge_cut,
            p.imbalance,
            &p.part_sizes()[..k.min(12)],
            t0.elapsed()
        );
    } else {
        let h = Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, levels));
        h.validate().map_err(|e| anyhow!(e))?;
        println!(
            "{dsname}: {levels}-level hierarchy k={k} m={:?} total={} [{:?}]",
            h.m,
            h.total_partitions(),
            t0.elapsed()
        );
    }
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("experiment").ok_or_else(|| anyhow!("--experiment NAME required"))?;
    let e = full_grid()
        .into_iter()
        .find(|e| &e.name == name)
        .ok_or_else(|| anyhow!("unknown experiment '{name}' (see `poshashemb list`)"))?;
    let seed: u64 = flags.get("seed").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let mut opts = TrainOptions { verbose: flags.contains_key("verbose"), ..Default::default() };
    if let Some(ep) = flags.get("epochs") {
        opts.epochs = Some(ep.parse()?);
    }
    let dir = std::env::var("POSHASH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let client = RuntimeClient::cpu()?;
    let manifest = Manifest::load(Path::new(&dir))?;
    let outcome = run_experiment(&client, &manifest, &e, seed, &opts)?;
    println!("{}", outcome.row());
    Ok(())
}

/// Resolve a CLI method tag to a concrete method at dataset scale
/// (paper-default k / c / b derived from n, as in `config`).
fn method_from_tag(tag: &str, n: usize) -> Result<EmbeddingMethod> {
    let k = default_k(n);
    let c = default_c(n, k);
    let b = c * k;
    Ok(match tag {
        "full" => EmbeddingMethod::Full,
        "hashtrick" => EmbeddingMethod::HashTrick { buckets: b },
        "bloom" => EmbeddingMethod::Bloom { buckets: b, h: 2 },
        "hashemb" => EmbeddingMethod::HashEmb { buckets: b, h: 2 },
        "dhe" => EmbeddingMethod::Dhe { encoding_dim: 32, hidden: 64, layers: 1 },
        "posemb1" => EmbeddingMethod::PosEmb { levels: 1 },
        "posemb3" => EmbeddingMethod::PosEmb { levels: 3 },
        "randompart" => EmbeddingMethod::RandomPart { parts: k },
        "posfullemb" => EmbeddingMethod::PosFullEmb { levels: 3 },
        "inter" => EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: b, h: 2 },
        "intra" => EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 },
        other => bail!("unknown method '{other}' (see `poshashemb help`)"),
    })
}

/// Materialize the (dataset, plan) for a CLI `(--dataset, --method)`
/// pair at paper-default scale knobs (`default_k` / `default_c` via
/// [`method_from_tag`]) — the shared front half of the `compose` and
/// `train-minibatch` subcommands.
fn dataset_and_plan(dsname: &str, tag: &str, seed: u64) -> Result<(Dataset, EmbeddingPlan)> {
    let sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
    let method = method_from_tag(tag, sp.n)?;
    let ds = Dataset::generate(&sp);
    let hier = if method.needs_hierarchy() {
        let levels = method.levels().max(1);
        let k = default_k(sp.n);
        Some(Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, levels)))
    } else {
        None
    };
    let plan = EmbeddingPlan::build(sp.n, sp.d, &method, hier.as_ref(), seed);
    Ok((ds, plan))
}

/// Host-side compose-engine benchmark: no PJRT artifacts required.
fn cmd_compose(flags: &HashMap<String, String>) -> Result<()> {
    let dsname = flags.get("dataset").map(String::as_str).unwrap_or("synth-arxiv");
    let tag = flags.get("method").map(String::as_str).unwrap_or("intra");
    let batch: usize = flags.get("batch").map(|v| v.parse()).transpose()?.unwrap_or(1024);
    let (_ds, plan) = dataset_and_plan(dsname, tag, 0)?;
    eprintln!("compose bench: {dsname} n={} d={} method={}", plan.n, plan.d, plan.method.name());
    let records = bench_compose(&plan, batch);
    if flags.contains_key("json") {
        println!("{}", serde_json::to_string_pretty(&records)?);
    } else {
        for r in &records {
            println!("{}", r.row());
        }
    }
    Ok(())
}

/// Host-side neighbor-sampled minibatch training on the compose engine:
/// no PJRT artifacts required. Defaults come from the experiment grid
/// (`--experiment`) or from `SamplerConfig::default()`; flags override.
fn cmd_train_minibatch(flags: &HashMap<String, String>) -> Result<()> {
    let seed: u64 = flags.get("seed").map(|v| v.parse()).transpose()?.unwrap_or(0);
    let exp_flag = flags.get("experiment");
    if exp_flag.is_some() && (flags.contains_key("dataset") || flags.contains_key("method")) {
        bail!("--experiment already fixes the dataset and method; drop --dataset/--method");
    }
    let (label, dsname, ds, plan, mut cfg, mut opts) = if let Some(name) = exp_flag {
        let e = full_grid()
            .into_iter()
            .find(|e| &e.name == name)
            .ok_or_else(|| anyhow!("unknown experiment '{name}' (see `poshashemb list`)"))?;
        let (ds, _hier, plan) = materialize(&e, seed);
        let opts =
            MinibatchOptions { epochs: e.epochs, lr: e.lr as f32, seed, ..Default::default() };
        (e.name.clone(), e.dataset.to_string(), ds, plan, e.sampling, opts)
    } else {
        let dsname = flags.get("dataset").map(String::as_str).unwrap_or("synth-arxiv");
        let tag = flags.get("method").map(String::as_str).unwrap_or("intra");
        let (ds, plan) = dataset_and_plan(dsname, tag, seed)?;
        let opts = MinibatchOptions { seed, ..Default::default() };
        (dsname.to_string(), dsname.to_string(), ds, plan, SamplerConfig::default(), opts)
    };
    if let Some(b) = flags.get("batch") {
        cfg.batch_size = b.parse()?;
        if cfg.batch_size == 0 {
            bail!("--batch must be >= 1");
        }
    }
    if flags.contains_key("fanout") && flags.contains_key("fanouts") {
        bail!("--fanouts already sets every hop's fanout; drop --fanout");
    }
    if let Some(f) = flags.get("fanout") {
        cfg.fanouts = Fanouts::single(Fanout::parse(f).map_err(|e| anyhow!(e))?);
    }
    if let Some(f) = flags.get("fanouts") {
        cfg.fanouts = Fanouts::parse(f).map_err(|e| anyhow!(e))?;
    }
    if let Some(w) = flags.get("hidden") {
        opts.hidden = w.parse()?;
        if opts.hidden == 0 {
            bail!("--hidden must be >= 1");
        }
    }
    if flags.contains_key("no-shuffle") {
        cfg.shuffle = false;
    }
    if let Some(e) = flags.get("epochs") {
        opts.epochs = e.parse()?;
    }
    if let Some(lr) = flags.get("lr") {
        opts.lr = lr.parse()?;
        if !opts.lr.is_finite() || opts.lr <= 0.0 {
            bail!("--lr must be a positive number");
        }
    }
    if let Some(o) = flags.get("optimizer") {
        opts.optimizer = OptimizerKind::parse(o).map_err(|e| anyhow!(e))?;
    }
    if flags.contains_key("serial") && flags.contains_key("prefetch") {
        bail!("--serial already disables prefetching; drop --prefetch");
    }
    if flags.contains_key("serial") {
        // the single-threaded oracle path: same losses, no pipeline
        opts.parallel = false;
        opts.prefetch = 0;
    }
    if let Some(p) = flags.get("prefetch") {
        opts.prefetch = p.parse()?;
    }
    opts.verbose = flags.contains_key("verbose");
    eprintln!(
        "minibatch train: {label} n={} d={} method={} batch={} fanouts={} layers={} epochs={} \
         {} lr={} {} prefetch={}",
        plan.n,
        plan.d,
        plan.method.name(),
        cfg.batch_size,
        cfg.fanouts,
        cfg.fanouts.layers(),
        opts.epochs,
        opts.optimizer.as_str(),
        opts.lr,
        if opts.parallel { "pipelined" } else { "serial" },
        opts.prefetch
    );
    let record = bench_minibatch(&dsname, &ds, &plan, &cfg, &opts)?;
    if flags.contains_key("json") {
        println!("{}", serde_json::to_string_pretty(&record)?);
    } else {
        println!("{}", record.row());
    }
    Ok(())
}

/// Partitioner pipeline benchmark: no PJRT artifacts required. Without
/// `--dataset` it runs on the acceptance SBM graph (n = 50k, 32
/// communities) that `cargo bench --bench partitioner` also uses.
fn cmd_partition_bench(flags: &HashMap<String, String>) -> Result<()> {
    let k: usize = flags.get("k").map(|v| v.parse()).transpose()?.unwrap_or(32);
    let levels: usize = flags.get("levels").map(|v| v.parse()).transpose()?.unwrap_or(3);
    let seed: u64 = flags.get("seed").map(|v| v.parse()).transpose()?.unwrap_or(1);
    let (graph, label) = match flags.get("dataset").map(String::as_str) {
        Some(dsname) => {
            let sp = spec(dsname).ok_or_else(|| anyhow!("unknown dataset {dsname}"))?;
            (Dataset::generate(&sp).graph, dsname.to_string())
        }
        None => {
            let (g, _) = planted_partition(&PlantedPartitionConfig {
                n: 50_000,
                communities: 32,
                intra_degree: 12.0,
                inter_degree: 2.0,
                seed: 3,
                ..Default::default()
            });
            (g, "sbm-50k".to_string())
        }
    };
    eprintln!(
        "partition bench: {label} n={} m={} k={k} levels={levels}",
        graph.num_nodes(),
        graph.num_edges()
    );
    let records = bench_partition(&graph, k, levels, seed);
    if flags.contains_key("json") {
        println!("{}", serde_json::to_string_pretty(&records)?);
    } else {
        for r in &records {
            println!("{}", r.row());
        }
    }
    Ok(())
}

fn cmd_experiment(flags: &HashMap<String, String>) -> Result<()> {
    let group = flags.get("group").ok_or_else(|| anyhow!("--group t3|t4|t5|f3|f4 required"))?;
    let harness = Harness::from_env()?;
    let exps = harness.group(group, flags.get("dataset").map(String::as_str));
    if exps.is_empty() {
        bail!("no artifacts for group {group}; run `make artifacts` with the full grid");
    }
    let outcomes = harness.run_all(&exps)?;
    let rows = rows_from_outcomes(&exps, &outcomes, |e| e.method.name());
    print_table(&format!("group {group}"), &rows);
    Ok(())
}
