//! Experiment configuration: the full grid of (dataset, model, method)
//! combinations behind every table and figure, and the JSON request that
//! tells `python/compile/aot.py` which artifacts to lower.
//!
//! Naming convention: `<ds>_<model>_<method-tag>` (e.g.
//! `arxiv_gcn_posemb3`, `products_sage_f4_b34_poshash`). The same name
//! keys the manifest artifact (`<name>.train` / `<name>.eval`), so the
//! benches, the trainer and the AOT layer agree by construction.

use crate::data::{self, Dataset, TaskKind};
use crate::embedding::{budget_for_fraction, EmbeddingMethod, EmbeddingPlan, MethodSpec, PosBudget};

// Scale-derived paper defaults now live beside the `MethodSpec` parser;
// re-exported here so existing `config::default_k` callers keep working.
pub use crate::embedding::{default_c, default_k};
use crate::partition::{Hierarchy, HierarchyConfig};
use crate::sampler::SamplerConfig;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// GNN architecture used by an experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    /// Graph convolutional network (Kipf & Welling).
    Gcn,
    /// GraphSAGE with mean aggregation.
    Sage,
    /// Graph attention network.
    Gat,
}

impl ModelKind {
    /// Lower-case tag used in config names and the CLI.
    pub fn as_str(self) -> &'static str {
        match self {
            ModelKind::Gcn => "gcn",
            ModelKind::Sage => "sage",
            ModelKind::Gat => "gat",
        }
    }

    /// Parse a CLI tag.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gcn" => Ok(ModelKind::Gcn),
            "sage" => Ok(ModelKind::Sage),
            "gat" => Ok(ModelKind::Gat),
            _ => Err(anyhow!("unknown model '{s}' (gcn|sage|gat)")),
        }
    }
}

/// One experiment: everything needed to lower, train and evaluate.
#[derive(Debug, Clone)]
pub struct Experiment {
    /// Unique config name (artifact key).
    pub name: String,
    /// Registered dataset name (`data::spec`).
    pub dataset: &'static str,
    /// GNN architecture.
    pub model: ModelKind,
    /// Embedding-layer method under test.
    pub method: EmbeddingMethod,
    /// Branching factor for the hierarchy (when the method needs one).
    pub k: usize,
    /// Which paper artifact this belongs to (reporting group).
    pub group: &'static str,
    /// Training epochs (full batch).
    pub epochs: usize,
    /// Learning rate.
    pub lr: f64,
    /// Minibatch sampling knobs for `train-minibatch` (defaults here;
    /// CLI flags override per run).
    pub sampling: SamplerConfig,
}

/// Paper defaults for the GNN stack.
pub const HIDDEN: usize = 64;
/// GNN depth (paper default: 2 message-passing layers).
pub const NUM_LAYERS: usize = 2;
/// Default epochs (full-batch Adam converges quickly on the synth sets).
pub const EPOCHS: usize = 80;
/// Paper default alpha (Eq. 8).
pub const ALPHA: f64 = 0.25;

/// Paper's model pairs per dataset (§IV-C): arxiv GCN+GAT, products
/// SAGE+GAT, proteins EW-GCN(≈GCN)+GAT.
pub fn model_pairs(dataset: &str) -> [ModelKind; 2] {
    match dataset {
        "synth-arxiv" => [ModelKind::Gcn, ModelKind::Gat],
        "synth-products" => [ModelKind::Sage, ModelKind::Gat],
        "synth-proteins" => [ModelKind::Gcn, ModelKind::Gat],
        _ => [ModelKind::Gcn, ModelKind::Gat],
    }
}

/// Short dataset tag for config names.
fn ds_tag(dataset: &str) -> &'static str {
    match dataset {
        "synth-arxiv" => "arxiv",
        "synth-products" => "products",
        "synth-proteins" => "proteins",
        _ => "ds",
    }
}

/// Build one experiment with defaults.
fn exp(
    dataset: &'static str,
    model: ModelKind,
    tag: &str,
    method: EmbeddingMethod,
    k: usize,
    group: &'static str,
) -> Experiment {
    Experiment {
        name: format!("{}_{}_{}", ds_tag(dataset), model.as_str(), tag),
        dataset,
        model,
        method,
        k,
        group,
        epochs: EPOCHS,
        lr: 0.01,
        sampling: SamplerConfig::default(),
    }
}

/// The full experiment grid: every config used by Tables III–V and
/// Figures 3–4 (paper-default hyperparameters, DESIGN.md §5).
pub fn full_grid() -> Vec<Experiment> {
    let mut out = Vec::new();
    for dataset in data::DATASET_NAMES {
        let spec = data::spec(dataset).unwrap();
        let n = spec.n;
        let k = default_k(n);
        // t3/t4/t5 entries go through the shared tag parser so the grid
        // can never drift from what `--method <tag>` builds on the CLI.
        let parse = |tag: &str| {
            MethodSpec::parse(tag)
                .unwrap_or_else(|e| panic!("grid tag '{tag}': {e}"))
                .resolve(n)
                .unwrap_or_else(|e| panic!("grid tag '{tag}' at n={n}: {e}"))
        };
        for model in model_pairs(dataset) {
            // --- Table III / IV ------------------------------------------------
            for (name, tag, group) in [
                ("full", "full", "t3"),
                ("posemb1", "posemb1", "t3"),
                ("randompart", "randompart", "t3"),
                ("posfullemb1", "posfullemb(levels=1)", "t3"),
                ("posemb2", "posemb2", "t4"),
                ("posemb3", "posemb3", "t4"),
                // --- Table V ---------------------------------------------------
                ("posfullemb3", "posfullemb(levels=3)", "t5"),
                ("inter_h1", "inter(h=1)", "t5"),
                ("inter_h2", "inter(h=2)", "t5"),
                ("intra_h1", "intra(h=1)", "t5"),
                ("intra_h2", "intra(h=2)", "t5"),
            ] {
                let r = parse(tag);
                out.push(exp(dataset, model, name, r.method, r.k, group));
            }
            // --- Figure 3: alpha sweep (PosEmb 1-level) ------------------------
            for (num, den) in [(1u32, 8u32), (2, 8), (3, 8), (4, 8), (6, 8)] {
                let alpha = num as f64 / den as f64;
                let ka = (n as f64).powf(alpha).ceil() as usize;
                let ka = ka.clamp(2, n / 2);
                out.push(exp(
                    dataset,
                    model,
                    &format!("f3_a{num}{den}"),
                    EmbeddingMethod::PosEmb { levels: 1 },
                    ka,
                    "f3",
                ));
            }
            // --- Figure 4: memory-budget sweep ---------------------------------
            let fractions: [(u32, f64); 3] = if dataset == "synth-products" {
                [(34, 1.0 / 34.0), (18, 1.0 / 18.0), (2, 0.5)]
            } else {
                [(12, 1.0 / 12.0), (6, 1.0 / 6.0), (2, 0.5)]
            };
            for (tag_den, frac) in fractions {
                // hierarchy m-counts for the default k (3 levels)
                let m = [k, k * k, k * k * k];
                let bm = budget_for_fraction(n, spec.d, &m, 2, frac);
                let mut push = |mtag: &str, method: EmbeddingMethod, kk: usize| {
                    out.push(exp(
                        dataset,
                        model,
                        &format!("f4_b{tag_den}_{mtag}"),
                        method,
                        kk,
                        "f4",
                    ));
                };
                push("hashtrick", bm.hash_trick.clone(), k);
                push("bloom", bm.bloom.clone(), k);
                push("hashemb", bm.hash_emb.clone(), k);
                match bm.poshash {
                    PosBudget::Intra { c, h } => push(
                        "poshash",
                        EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h },
                        k,
                    ),
                    PosBudget::PositionOnly { k: kk } => {
                        push("poshash", EmbeddingMethod::PosEmb { levels: 1 }, kk)
                    }
                }
                // DHE: paper could not run it on the largest dataset; same here.
                if dataset != "synth-products" {
                    let budget = (n as f64 * spec.d as f64 * frac) as usize;
                    let enc = 32usize;
                    let hidden = (budget.saturating_sub(spec.d)) / (enc + 1 + spec.d);
                    if hidden >= 8 {
                        // DHE's MLP makes its step ~10x costlier than the
                        // table methods; cap epochs so Fig. 4 stays
                        // tractable (the paper hit the analogous wall on
                        // GPU memory instead).
                        let mut e = exp(
                            dataset,
                            model,
                            &format!("f4_b{tag_den}_dhe"),
                            EmbeddingMethod::Dhe { encoding_dim: enc, hidden, layers: 1 },
                            k,
                            "f4",
                        );
                        e.epochs = 40;
                        out.push(e);
                    }
                }
            }
        }
    }
    out
}

/// A much smaller grid for smoke/CI runs: one dataset, one model, the
/// core methods.
pub fn smoke_grid() -> Vec<Experiment> {
    full_grid()
        .into_iter()
        .filter(|e| {
            e.dataset == "synth-arxiv"
                && e.model == ModelKind::Gcn
                && matches!(e.group, "t3" | "t4" | "t5")
        })
        .collect()
}

/// Realize the dataset + hierarchy + plan for an experiment.
/// `seed` perturbs hashing/random-partition draws (not the dataset).
pub fn materialize(e: &Experiment, seed: u64) -> (Dataset, Option<Hierarchy>, EmbeddingPlan) {
    let spec = data::spec(e.dataset).expect("unknown dataset");
    let ds = Dataset::generate(&spec);
    let hierarchy = if e.method.needs_hierarchy() {
        let levels = e.method.levels().max(1);
        let mut cfg = HierarchyConfig::new(e.k, levels);
        cfg.base.seed = 1; // hierarchy fixed across seeds: shapes must match AOT
        Some(Hierarchy::build(&ds.graph, &cfg))
    } else {
        None
    };
    let plan = EmbeddingPlan::build(spec.n, spec.d, &e.method, hierarchy.as_ref(), seed);
    (ds, hierarchy, plan)
}

/// The JSON config entry `python/compile/aot.py` consumes for `e`.
pub fn aot_config(e: &Experiment) -> Json {
    let spec = data::spec(e.dataset).expect("unknown dataset");
    let ds = Dataset::generate(&spec);
    let (_, _, plan) = materialize(e, 0);
    let pos_tables: Vec<Json> = plan
        .position
        .as_ref()
        .map(|p| {
            p.tables
                .iter()
                .map(|t| Json::arr([Json::num(t.rows as f64), Json::num(t.cols as f64)]))
                .collect()
        })
        .unwrap_or_default();
    let dhe = plan
        .dhe
        .as_ref()
        .map(|d| {
            Json::obj(vec![
                ("encoding_dim", Json::num(d.encoding_dim as f64)),
                ("hidden", Json::num(d.hidden as f64)),
                ("layers", Json::num(d.layers as f64)),
            ])
        })
        .unwrap_or(Json::Null);
    let emb = Json::obj(vec![
        ("pos_tables", Json::Arr(pos_tables)),
        ("node_rows", Json::num(plan.node.as_ref().map_or(0, |nx| nx.table.rows) as f64)),
        ("h", Json::num(plan.node.as_ref().map_or(0, |nx| nx.h) as f64)),
        ("learned_y", Json::Bool(plan.node.as_ref().is_some_and(|nx| nx.learned_weights))),
        ("dhe", dhe),
    ]);
    let task = match spec.task {
        TaskKind::MultiClass => "multiclass",
        TaskKind::MultiLabel => "multilabel",
    };
    // pad_k = max adjacency row length + 1 (self loop slot)
    let max_deg = (0..ds.graph.num_nodes() as u32).map(|u| ds.graph.degree(u)).max().unwrap_or(0);
    Json::obj(vec![
        ("name", Json::str(e.name.clone())),
        ("model", Json::str(e.model.as_str())),
        ("task", Json::str(task)),
        ("n", Json::num(spec.n as f64)),
        ("d", Json::num(spec.d as f64)),
        ("classes", Json::num(spec.classes as f64)),
        ("hidden", Json::num(HIDDEN as f64)),
        ("num_layers", Json::num(NUM_LAYERS as f64)),
        ("edges", Json::num(ds.graph.num_adjacency_entries() as f64)),
        ("pad_k", Json::num((max_deg + 1) as f64)),
        ("lr", Json::Num(e.lr)),
        ("embedding", emb),
    ])
}

/// Write the full AOT request for `experiments` to `path`.
pub fn write_aot_request(experiments: &[Experiment], path: &std::path::Path) -> Result<()> {
    let configs: Vec<Json> = experiments.iter().map(aot_config).collect();
    let root = Json::obj(vec![("configs", Json::Arr(configs))]);
    std::fs::write(path, root.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_names_are_unique() {
        let grid = full_grid();
        let mut names: Vec<&str> = grid.iter().map(|e| e.name.as_str()).collect();
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate config names");
        assert!(total > 100, "grid unexpectedly small: {total}");
    }

    #[test]
    fn grid_covers_all_groups_and_datasets() {
        let grid = full_grid();
        for g in ["t3", "t4", "t5", "f3", "f4"] {
            assert!(grid.iter().any(|e| e.group == g), "missing group {g}");
        }
        for d in data::DATASET_NAMES {
            assert!(grid.iter().any(|e| e.dataset == d));
        }
    }

    #[test]
    fn paper_pairs_respected() {
        let grid = full_grid();
        assert!(grid
            .iter()
            .filter(|e| e.dataset == "synth-products")
            .all(|e| matches!(e.model, ModelKind::Sage | ModelKind::Gat)));
    }

    #[test]
    fn smoke_grid_is_small_and_single_model() {
        let g = smoke_grid();
        assert!(g.len() >= 8 && g.len() <= 15, "smoke grid {}", g.len());
        assert!(g.iter().all(|e| e.model == ModelKind::Gcn));
    }

    #[test]
    fn aot_config_shape_sanity() {
        let grid = smoke_grid();
        let full = grid.iter().find(|e| e.name.ends_with("_full")).unwrap();
        let cfg = aot_config(full);
        assert_eq!(cfg.get("model").unwrap().as_str(), Some("gcn"));
        assert_eq!(cfg.get("n").unwrap().as_usize(), Some(6000));
        let emb = cfg.get("embedding").unwrap();
        assert_eq!(emb.get("node_rows").unwrap().as_usize(), Some(6000));
        assert_eq!(emb.get("learned_y").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn materialize_is_deterministic_for_shapes() {
        let e = &smoke_grid()[1];
        let (_, _, p1) = materialize(e, 0);
        let (_, _, p2) = materialize(e, 7);
        // different seeds may change hash indices but never table shapes
        let s1: Vec<_> = p1.param_shapes().iter().map(|t| (t.rows, t.cols)).collect();
        let s2: Vec<_> = p2.param_shapes().iter().map(|t| (t.rows, t.cols)).collect();
        assert_eq!(s1, s2);
    }

    #[test]
    fn experiments_carry_minibatch_sampling_defaults() {
        let grid = smoke_grid();
        assert!(!grid.is_empty());
        for e in &grid {
            assert!(e.sampling.batch_size >= 1, "{}: zero batch size", e.name);
            assert!(e.sampling.shuffle, "{}: shuffle should default on", e.name);
        }
    }

    #[test]
    fn dhe_excluded_on_products() {
        let grid = full_grid();
        let is_dhe = |e: &Experiment| matches!(e.method, EmbeddingMethod::Dhe { .. });
        assert!(!grid.iter().any(|e| e.dataset == "synth-products" && is_dhe(e)));
        assert!(grid.iter().any(|e| e.dataset == "synth-arxiv" && is_dhe(e)));
    }
}
