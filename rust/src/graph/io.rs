//! Edge-list IO.
//!
//! Plain-text interchange: one `u v [w]` per line, `#` comments, blank
//! lines skipped. Used by `poshashemb partition --graph <file>` and the
//! partition-explorer example so users can feed their own graphs.

use super::csr::{CsrGraph, GraphBuilder};
use anyhow::{anyhow, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Read an undirected edge list. Node count is `max id + 1` unless
/// `num_nodes` forces a larger graph (for isolated-tail nodes).
pub fn read_edge_list(path: &Path, num_nodes: Option<usize>) -> Result<CsrGraph> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut edges: Vec<(u32, u32, f32)> = Vec::new();
    let mut max_id = 0u32;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let u: u32 = it
            .next()
            .ok_or_else(|| anyhow!("line {}: missing src", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad src", lineno + 1))?;
        let v: u32 = it
            .next()
            .ok_or_else(|| anyhow!("line {}: missing dst", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let w: f32 = match it.next() {
            Some(tok) => tok.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?,
            None => 1.0,
        };
        max_id = max_id.max(u).max(v);
        edges.push((u, v, w));
    }
    let n = num_nodes.unwrap_or(max_id as usize + 1);
    if n <= max_id as usize {
        return Err(anyhow!("num_nodes {} <= max node id {}", n, max_id));
    }
    let mut b = GraphBuilder::new(n);
    for (u, v, w) in edges {
        b.add_edge(u, v, w);
    }
    Ok(b.build())
}

/// Write the graph as an undirected edge list (each edge once, u < v).
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# poshashemb edge list: n={} m={}", g.num_nodes(), g.num_edges())?;
    for u in 0..g.num_nodes() as u32 {
        for (v, wt) in g.edges(u) {
            if u < v {
                if (wt - 1.0).abs() < f32::EPSILON {
                    writeln!(w, "{u} {v}")?;
                } else {
                    writeln!(w, "{u} {v} {wt}")?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};

    #[test]
    fn roundtrip_preserves_structure() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 200,
            communities: 4,
            intra_degree: 6.0,
            inter_degree: 1.0,
            seed: 9,
            ..Default::default()
        });
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path, Some(g.num_nodes())).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.indices(), g2.indices());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "# header\n\n0 1\n1 2 2.5\n").unwrap();
        let g = read_edge_list(&path, None).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edge_weights(2), &[2.5]);
    }

    #[test]
    fn bad_num_nodes_rejected() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "0 5\n").unwrap();
        assert!(read_edge_list(&path, Some(3)).is_err());
    }

    #[test]
    fn malformed_line_is_error() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(read_edge_list(&path, None).is_err());
    }
}
