//! Edge-list IO.
//!
//! Plain-text interchange: one `u v [w]` per line, `#` comments, blank
//! lines skipped. Used by `poshashemb partition --graph <file>` and the
//! partition-explorer example so users can feed their own graphs.

use super::csr::CsrGraph;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Parse one edge-list line into `(u, v, w)`. Comments and blank lines
/// yield `None`; a missing weight defaults to 1.
fn parse_edge_line(line: &str, lineno: usize) -> Result<Option<(u32, u32, f32)>> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut it = line.split_whitespace();
    let u: u32 = it
        .next()
        .ok_or_else(|| anyhow!("line {}: missing src", lineno + 1))?
        .parse()
        .with_context(|| format!("line {}: bad src", lineno + 1))?;
    let v: u32 = it
        .next()
        .ok_or_else(|| anyhow!("line {}: missing dst", lineno + 1))?
        .parse()
        .with_context(|| format!("line {}: bad dst", lineno + 1))?;
    let w: f32 = match it.next() {
        Some(tok) => tok.parse().with_context(|| format!("line {}: bad weight", lineno + 1))?,
        None => 1.0,
    };
    Ok(Some((u, v, w)))
}

/// Read an undirected edge list. Node count is `max id + 1` unless
/// `num_nodes` forces a larger graph (for isolated-tail nodes).
///
/// Streams the file in two passes — a counting pass (per-node slot
/// upper bounds, max id, per-line validation) and a scatter pass that
/// fills preallocated CSR arrays — so peak memory is the CSR output
/// itself, never an intermediate edge-list `Vec` (the old reader
/// buffered every parsed edge *and* the builder's pending copy; pinned
/// by `streaming_reader_matches_builder_semantics`). Duplicate edges
/// merge by summing weights and self loops drop, exactly as
/// `GraphBuilder` does.
pub fn read_edge_list(path: &Path, num_nodes: Option<usize>) -> Result<CsrGraph> {
    // ---- pass 1 (counting): validate lines, bound per-node degrees ----
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut max_id = 0u32;
    let mut kept = 0u64;
    let mut deg: Vec<u64> = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let Some((u, v, _)) = parse_edge_line(&line?, lineno)? else { continue };
        max_id = max_id.max(u).max(v);
        if u == v {
            continue; // self loops drop, as in GraphBuilder::add_edge
        }
        let hi = u.max(v) as usize;
        if deg.len() <= hi {
            deg.resize(hi + 1, 0);
        }
        deg[u as usize] += 1;
        deg[v as usize] += 1;
        kept += 1;
    }
    let n = num_nodes.unwrap_or(max_id as usize + 1);
    if n <= max_id as usize {
        return Err(anyhow!("num_nodes {} <= max node id {}", n, max_id));
    }
    deg.resize(n, 0);
    let mut indptr = vec![0u64; n + 1];
    for i in 0..n {
        indptr[i + 1] = indptr[i] + deg[i];
    }
    let total = indptr[n] as usize;
    let mut indices = vec![0u32; total];
    let mut weights = vec![0f32; total];
    let mut cursor: Vec<u64> = indptr[..n].to_vec();

    // ---- pass 2 (scatter): both directions of each edge, file order ----
    let f = std::fs::File::open(path).with_context(|| format!("re-open {}", path.display()))?;
    let mut seen = 0u64;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let Some((u, v, w)) = parse_edge_line(&line?, lineno)? else { continue };
        if u == v {
            continue;
        }
        seen += 1;
        if seen > kept || u.max(v) as usize >= n {
            bail!("{} changed between read passes", path.display());
        }
        for (a, b) in [(u, v), (v, u)] {
            let c = cursor[a as usize] as usize;
            indices[c] = b;
            weights[c] = w;
            cursor[a as usize] += 1;
        }
    }
    if seen != kept {
        bail!("{} changed between read passes", path.display());
    }

    // ---- finalize: per-row sort, merge duplicates, compact in place ----
    // The sort is STABLE so duplicate runs keep file order in both
    // endpoint rows — their weights sum in the same order on each side
    // and the result stays weight-symmetric. The compaction cursor only
    // trails the row starts, so rewriting `indices`/`weights` in place
    // is safe.
    let mut out_indptr = vec![0u64; n + 1];
    let mut write = 0usize;
    let mut row: Vec<(u32, f32)> = Vec::new();
    for u in 0..n {
        let (s, e) = (indptr[u] as usize, indptr[u + 1] as usize);
        row.clear();
        row.extend(indices[s..e].iter().copied().zip(weights[s..e].iter().copied()));
        row.sort_by_key(|&(v, _)| v);
        let mut i = 0usize;
        while i < row.len() {
            let (v0, mut wsum) = row[i];
            i += 1;
            while i < row.len() && row[i].0 == v0 {
                wsum += row[i].1;
                i += 1;
            }
            indices[write] = v0;
            weights[write] = wsum;
            write += 1;
        }
        out_indptr[u + 1] = write as u64;
    }
    indices.truncate(write);
    weights.truncate(write);
    Ok(CsrGraph::from_parts(out_indptr, indices, weights, vec![1; n]))
}

/// Write the graph as an undirected edge list (each edge once, u < v).
pub fn write_edge_list(g: &CsrGraph, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# poshashemb edge list: n={} m={}", g.num_nodes(), g.num_edges())?;
    for u in 0..g.num_nodes() as u32 {
        for (v, wt) in g.edges(u) {
            if u < v {
                if (wt - 1.0).abs() < f32::EPSILON {
                    writeln!(w, "{u} {v}")?;
                } else {
                    writeln!(w, "{u} {v} {wt}")?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, PlantedPartitionConfig};

    #[test]
    fn roundtrip_preserves_structure() {
        let (g, _) = planted_partition(&PlantedPartitionConfig {
            n: 200,
            communities: 4,
            intra_degree: 6.0,
            inter_degree: 1.0,
            seed: 9,
            ..Default::default()
        });
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = read_edge_list(&path, Some(g.num_nodes())).unwrap();
        assert_eq!(g.num_nodes(), g2.num_nodes());
        assert_eq!(g.num_edges(), g2.num_edges());
        assert_eq!(g.indices(), g2.indices());
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "# header\n\n0 1\n1 2 2.5\n").unwrap();
        let g = read_edge_list(&path, None).unwrap();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edge_weights(2), &[2.5]);
    }

    /// Regression for the streaming rewrite: the two-pass reader must
    /// reproduce [`GraphBuilder`]'s exact output — duplicate edges merge
    /// by summing, self loops drop, rows sort ascending — on a file that
    /// exercises all three plus reversed endpoint order.
    #[test]
    fn streaming_reader_matches_builder_semantics() {
        use crate::graph::GraphBuilder;
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "# dup + loop + reversed\n0 1 0.5\n2 2 9.0\n1 2\n1 0 0.25\n\n3 0\n")
            .unwrap();
        let g = read_edge_list(&path, Some(5)).unwrap();
        let mut b = GraphBuilder::new(5);
        b.add_edge(0, 1, 0.5);
        b.add_edge(2, 2, 9.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(1, 0, 0.25);
        b.add_edge(3, 0, 1.0);
        let want = b.build();
        assert_eq!(g.indptr(), want.indptr());
        assert_eq!(g.indices(), want.indices());
        for u in 0..5u32 {
            assert_eq!(g.edge_weights(u), want.edge_weights(u), "row {u}");
        }
        g.validate().unwrap();
    }

    #[test]
    fn bad_num_nodes_rejected() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "0 5\n").unwrap();
        assert!(read_edge_list(&path, Some(3)).is_err());
    }

    #[test]
    fn malformed_line_is_error() {
        let dir = crate::util::tempdir::TempDir::new("poshashemb").unwrap();
        let path = dir.path().join("g.txt");
        std::fs::write(&path, "0 x\n").unwrap();
        assert!(read_edge_list(&path, None).is_err());
    }
}
