//! On-disk CSR graphs: a checksummed binary layout plus the
//! [`DiskCsr`] backend that reads it without materializing the
//! adjacency.
//!
//! ## Layout
//!
//! A graph directory holds four raw little-endian section files plus a
//! JSON manifest, in the same section format as model artifacts and
//! checkpoints ([`crate::util::sections`]):
//!
//! | section   | file          | dtype | shape        |
//! |-----------|---------------|-------|--------------|
//! | `indptr`  | `indptr.bin`  | u64   | `[n + 1]`    |
//! | `indices` | `indices.bin` | u32   | `[2m]`       |
//! | `weights` | `weights.bin` | f32   | `[2m]`       |
//! | `vwgts`   | `vwgts.bin`   | u32   | `[n]`        |
//!
//! `manifest.json` ([`DiskGraphManifest`]) carries per-section FNV-1a
//! checksums, byte lengths and shapes. Directories are published
//! atomically (sections into a temp sibling, manifest last, then
//! rename — see [`write_graph_dir`]), so a killed writer leaves either
//! nothing or the previous intact directory, never a torn one.
//!
//! ## Reading
//!
//! [`DiskCsr::open`] verifies every section (length, checksum, shape,
//! CSR invariants) before returning — every failure names the
//! offending section. `indptr` and `vwgts` stay resident (12 bytes per
//! node); `indices`/`weights` rows are answered with positioned reads
//! (`pread(2)`) against file handles held open, so adjacency memory is
//! O(row) regardless of graph size. The `memmap2` zero-copy path is
//! not available in the offline dependency set; the pread reader sits
//! behind the same [`GraphStore`] trait, so it is the single swap
//! point once a mapping crate can be vendored.

use super::csr::CsrGraph;
use super::store::GraphStore;
use crate::util::checksum::{tagged, Fnv1a64};
use crate::util::fault;
use crate::util::sections::{
    dtype_width, publish_dir, read_section, temp_sibling, SectionData, SectionSpec,
};
use anyhow::{anyhow, bail, Context, Result};
use serde::{Deserialize, Serialize};
use std::cell::RefCell;
use std::fs::{self, File};
use std::io::{Read, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

/// On-disk graph format version; bumped on any layout change.
pub const DISK_GRAPH_VERSION: u32 = 1;
/// Manifest `kind` tag distinguishing graph directories from model
/// artifacts and checkpoints.
const DISK_GRAPH_KIND: &str = "disk-csr";
/// Manifest file name.
const MANIFEST: &str = "manifest.json";
/// Elements per write chunk in the streaming writer (bounds the
/// writer's transient buffer to ~512 KiB regardless of graph size).
const WRITE_CHUNK: usize = 1 << 16;
/// Bytes per read chunk when verifying section checksums on open.
const VERIFY_CHUNK: usize = 1 << 20;

/// JSON manifest of an on-disk graph directory.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct DiskGraphManifest {
    /// Layout version ([`DISK_GRAPH_VERSION`]).
    pub format_version: u32,
    /// Always `"disk-csr"` — a cheap guard against opening a model
    /// artifact or checkpoint directory as a graph.
    pub kind: String,
    /// Number of nodes.
    pub num_nodes: usize,
    /// Number of directed adjacency entries (`2 * num_edges`).
    pub num_adjacency_entries: usize,
    /// Per-section specs (name, file, dtype, shape, bytes, checksum).
    pub sections: Vec<SectionSpec>,
}

impl DiskGraphManifest {
    fn section(&self, name: &str) -> Result<&SectionSpec> {
        self.sections
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("graph manifest has no section '{name}'"))
    }
}

// ---------------------------------------------------------------------
// writing
// ---------------------------------------------------------------------

/// One section file written incrementally: bytes stream through an
/// FNV-1a hasher and a running length, so the spec is produced without
/// ever holding the encoded section in memory (unlike
/// `sections::write_section`, which buffers the full little-endian
/// image).
struct StreamingSection {
    name: String,
    file: String,
    f: File,
    hash: Fnv1a64,
    bytes: usize,
    buf: Vec<u8>,
}

impl StreamingSection {
    fn create(dir: &Path, name: &str) -> Result<Self> {
        fault::hit("diskgraph.section").with_context(|| format!("writing section '{name}'"))?;
        let file = format!("{name}.bin");
        let path = dir.join(&file);
        let f = File::create(&path)
            .with_context(|| format!("creating section '{name}' ({})", path.display()))?;
        Ok(StreamingSection {
            name: name.to_string(),
            file,
            f,
            hash: Fnv1a64::new(),
            bytes: 0,
            buf: Vec::with_capacity(WRITE_CHUNK * 8),
        })
    }

    fn write_bytes(&mut self) -> Result<()> {
        self.hash.update(&self.buf);
        self.bytes += self.buf.len();
        self.f
            .write_all(&self.buf)
            .with_context(|| format!("writing section '{}'", self.name))?;
        self.buf.clear();
        Ok(())
    }

    fn put_u64(&mut self, xs: &[u64]) -> Result<()> {
        for chunk in xs.chunks(WRITE_CHUNK) {
            for &x in chunk {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes()?;
        }
        Ok(())
    }

    fn put_u32(&mut self, xs: &[u32]) -> Result<()> {
        for chunk in xs.chunks(WRITE_CHUNK) {
            for &x in chunk {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes()?;
        }
        Ok(())
    }

    fn put_f32(&mut self, xs: &[f32]) -> Result<()> {
        for chunk in xs.chunks(WRITE_CHUNK) {
            for &x in chunk {
                self.buf.extend_from_slice(&x.to_le_bytes());
            }
            self.write_bytes()?;
        }
        Ok(())
    }

    fn finish(self, dtype: &str, shape: Vec<usize>) -> Result<SectionSpec> {
        let elems: usize = shape.iter().product();
        if elems * dtype_width(dtype)? != self.bytes {
            bail!(
                "section '{}' shape {:?} does not match its {} written bytes",
                self.name,
                shape,
                self.bytes
            );
        }
        self.f
            .sync_all()
            .with_context(|| format!("fsyncing section '{}'", self.name))?;
        Ok(SectionSpec {
            name: self.name,
            file: self.file,
            dtype: dtype.to_string(),
            shape,
            bytes: self.bytes,
            checksum: tagged(self.hash.finish()),
        })
    }
}

/// Atomically write `g` as an on-disk graph directory at `dir`:
/// sections stream into a temp sibling (fsynced), the manifest is
/// written last, then the directory is published with a rename. A
/// fault or crash at any point leaves either no directory or the
/// previous intact one (`diskgraph.section` / `diskgraph.manifest` /
/// `diskgraph.rename` fault sites, mirrored from model artifacts).
pub fn write_graph_dir(dir: &Path, g: &CsrGraph) -> Result<()> {
    if let Some(parent) = dir.parent() {
        if parent != Path::new("") {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating graph parent {}", parent.display()))?;
        }
    }
    let tmp = temp_sibling(dir);
    fs::create_dir_all(&tmp)
        .with_context(|| format!("creating graph temp dir {}", tmp.display()))?;
    let res = write_graph_contents(&tmp, g).and_then(|()| publish_dir(&tmp, dir));
    if res.is_err() {
        let _ = fs::remove_dir_all(&tmp);
    }
    res
}

/// Write all four sections plus the manifest into `tmp` (not yet
/// published).
fn write_graph_contents(tmp: &Path, g: &CsrGraph) -> Result<()> {
    let n = g.num_nodes();
    let adj = g.num_adjacency_entries();
    let mut sections = Vec::with_capacity(4);

    let mut s = StreamingSection::create(tmp, "indptr")?;
    s.put_u64(g.indptr())?;
    sections.push(s.finish("u64", vec![n + 1])?);

    let mut s = StreamingSection::create(tmp, "indices")?;
    s.put_u32(g.indices())?;
    sections.push(s.finish("u32", vec![adj])?);

    let mut s = StreamingSection::create(tmp, "weights")?;
    s.put_f32(g.weights())?;
    sections.push(s.finish("f32", vec![adj])?);

    let mut s = StreamingSection::create(tmp, "vwgts")?;
    s.put_u32(g.vertex_weights())?;
    sections.push(s.finish("u32", vec![n])?);

    fault::hit("diskgraph.manifest").context("writing graph manifest")?;
    let manifest = DiskGraphManifest {
        format_version: DISK_GRAPH_VERSION,
        kind: DISK_GRAPH_KIND.to_string(),
        num_nodes: n,
        num_adjacency_entries: adj,
        sections,
    };
    let text = serde_json::to_string_pretty(&manifest).context("encoding graph manifest")?;
    let path = tmp.join(MANIFEST);
    let mut f = File::create(&path)
        .with_context(|| format!("creating graph manifest {}", path.display()))?;
    f.write_all(text.as_bytes()).context("writing graph manifest")?;
    f.sync_all().context("fsyncing graph manifest")?;
    fault::hit("diskgraph.rename").context("publishing graph directory")?;
    Ok(())
}

// ---------------------------------------------------------------------
// reading
// ---------------------------------------------------------------------

thread_local! {
    /// Per-thread row byte buffer for positioned reads — reused across
    /// calls so steady-state sampling does not allocate per row.
    static ROW_BUF: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// The on-disk CSR backend: resident `indptr`/`vwgts`, pread-backed
/// adjacency rows. See the module docs for the layout and the
/// verification performed by [`DiskCsr::open`].
#[derive(Debug)]
pub struct DiskCsr {
    dir: PathBuf,
    indptr: Vec<u64>,
    vwgts: Vec<u32>,
    indices: File,
    weights: File,
    num_adj: usize,
}

impl DiskCsr {
    /// Open and fully verify a graph directory. Every section's byte
    /// length, checksum and shape are checked against the manifest
    /// (the adjacency sections in streaming chunks, never resident),
    /// then the CSR invariants are checked; every failure names the
    /// offending section.
    pub fn open(dir: &Path) -> Result<Self> {
        let mpath = dir.join(MANIFEST);
        let text = fs::read_to_string(&mpath)
            .with_context(|| format!("reading graph manifest {}", mpath.display()))?;
        let manifest: DiskGraphManifest = serde_json::from_str(&text)
            .with_context(|| format!("parsing graph manifest {}", mpath.display()))?;
        if manifest.kind != DISK_GRAPH_KIND {
            bail!("{} is a '{}' directory, not a disk-csr graph", dir.display(), manifest.kind);
        }
        if manifest.format_version != DISK_GRAPH_VERSION {
            bail!(
                "graph directory {} has format version {}, this build reads {}",
                dir.display(),
                manifest.format_version,
                DISK_GRAPH_VERSION
            );
        }
        let n = manifest.num_nodes;
        let adj = manifest.num_adjacency_entries;

        // resident sections: read_section verifies length, checksum and
        // shape, naming the section in every failure
        let ip_spec = manifest.section("indptr")?;
        check_shape(ip_spec, &[n + 1])?;
        let indptr = match read_section(dir, ip_spec)? {
            SectionData::U64(v) => v,
            other => bail!("section 'indptr' decoded as {}, expected u64", other.dtype()),
        };
        let vw_spec = manifest.section("vwgts")?;
        check_shape(vw_spec, &[n])?;
        let vwgts = match read_section(dir, vw_spec)? {
            SectionData::U32(v) => v,
            other => bail!("section 'vwgts' decoded as {}, expected u32", other.dtype()),
        };

        // CSR invariants (a stale manifest paired with the wrong
        // section files fails here if the checksums happen to match)
        if indptr[0] != 0 {
            bail!("section 'indptr' is not a CSR row-pointer array (does not start at 0)");
        }
        if indptr.windows(2).any(|w| w[1] < w[0]) {
            bail!("section 'indptr' is not a CSR row-pointer array (not monotone)");
        }
        if *indptr.last().unwrap() as usize != adj {
            bail!(
                "section 'indptr' ends at {} entries, manifest says {} adjacency entries",
                indptr.last().unwrap(),
                adj
            );
        }

        // adjacency sections: verify in streaming chunks, keep handles
        let ix_spec = manifest.section("indices")?;
        check_shape(ix_spec, &[adj])?;
        let indices = verify_and_open(dir, ix_spec)?;
        let wt_spec = manifest.section("weights")?;
        check_shape(wt_spec, &[adj])?;
        let weights = verify_and_open(dir, wt_spec)?;

        Ok(DiskCsr { dir: dir.to_path_buf(), indptr, vwgts, indices, weights, num_adj: adj })
    }

    /// The directory this graph was opened from.
    pub fn path(&self) -> &Path {
        &self.dir
    }

    /// Load the whole graph into an in-memory [`CsrGraph`] — for tools
    /// and tests that want resident arrays; training paths never call
    /// this.
    pub fn to_mem(&self) -> Result<CsrGraph> {
        let mut indices = vec![0u8; self.num_adj * 4];
        self.indices.read_exact_at(&mut indices, 0).context("reading section 'indices'")?;
        let mut weights = vec![0u8; self.num_adj * 4];
        self.weights.read_exact_at(&mut weights, 0).context("reading section 'weights'")?;
        Ok(CsrGraph::from_parts(
            self.indptr.clone(),
            indices
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
            weights
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
            self.vwgts.clone(),
        ))
    }

    #[inline]
    fn range(&self, u: u32) -> (u64, usize) {
        let s = self.indptr[u as usize];
        let e = self.indptr[u as usize + 1];
        (s, (e - s) as usize)
    }

    /// One u32 element of `indices` at global element position `pos`.
    /// Post-open reads go to a verified, held-open file: a failure here
    /// means the file vanished or the device died mid-run, which is
    /// not recoverable — panic with the section name.
    #[inline]
    fn index_at(&self, pos: u64) -> u32 {
        let mut b = [0u8; 4];
        self.indices
            .read_exact_at(&mut b, pos * 4)
            .expect("positioned read of section 'indices' failed after open");
        u32::from_le_bytes(b)
    }
}

/// Shape guard against a stale manifest (e.g. a manifest copied from a
/// differently-sized graph over matching-by-accident checksums).
fn check_shape(spec: &SectionSpec, expect: &[usize]) -> Result<()> {
    if spec.shape != expect {
        bail!(
            "section '{}' ({}) has manifest shape {:?}, graph metadata implies {:?} \
             (stale or mismatched manifest)",
            spec.name,
            spec.file,
            spec.shape,
            expect
        );
    }
    Ok(())
}

/// Verify one section's byte length and checksum by streaming chunked
/// reads (the section is never resident), then return the handle
/// positioned-read access will use. Error messages mirror
/// `sections::read_section` so diagnosis is uniform.
fn verify_and_open(dir: &Path, spec: &SectionSpec) -> Result<File> {
    let path = dir.join(&spec.file);
    let mut f = File::open(&path)
        .with_context(|| format!("reading section '{}' ({})", spec.name, path.display()))?;
    let len = f
        .metadata()
        .with_context(|| format!("reading section '{}' ({})", spec.name, path.display()))?
        .len() as usize;
    if len != spec.bytes {
        bail!(
            "section '{}' ({}) is {} bytes on disk, manifest says {}",
            spec.name,
            spec.file,
            len,
            spec.bytes
        );
    }
    let elems: usize = spec.shape.iter().product();
    if elems * dtype_width(&spec.dtype)? != len {
        bail!("section '{}' shape {:?} does not match its byte length", spec.name, spec.shape);
    }
    let mut hash = Fnv1a64::new();
    let mut buf = vec![0u8; VERIFY_CHUNK.min(len.max(1))];
    let mut remaining = len;
    while remaining > 0 {
        let take = remaining.min(buf.len());
        f.read_exact(&mut buf[..take])
            .with_context(|| format!("reading section '{}' ({})", spec.name, spec.file))?;
        hash.update(&buf[..take]);
        remaining -= take;
    }
    let got = tagged(hash.finish());
    if got != spec.checksum {
        bail!(
            "checksum mismatch in section '{}' ({}): manifest {}, file {}",
            spec.name,
            spec.file,
            spec.checksum,
            got
        );
    }
    Ok(f)
}

impl GraphStore for DiskCsr {
    fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    fn num_adjacency_entries(&self) -> usize {
        self.num_adj
    }

    fn indptr(&self) -> &[u64] {
        &self.indptr
    }

    fn vertex_weight(&self, u: u32) -> u32 {
        self.vwgts[u as usize]
    }

    fn total_vertex_weight(&self) -> u64 {
        self.vwgts.iter().map(|&w| w as u64).sum()
    }

    fn neighbors_into(&self, u: u32, out: &mut Vec<u32>) {
        out.clear();
        let (start, len) = self.range(u);
        if len == 0 {
            return;
        }
        ROW_BUF.with(|b| {
            let mut buf = b.borrow_mut();
            buf.resize(len * 4, 0);
            self.indices
                .read_exact_at(&mut buf, start * 4)
                .expect("positioned read of section 'indices' failed after open");
            out.extend(
                buf.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
        });
    }

    fn edges_into(&self, u: u32, nbrs: &mut Vec<u32>, wts: &mut Vec<f32>) {
        self.neighbors_into(u, nbrs);
        wts.clear();
        let (start, len) = self.range(u);
        if len == 0 {
            return;
        }
        ROW_BUF.with(|b| {
            let mut buf = b.borrow_mut();
            buf.resize(len * 4, 0);
            self.weights
                .read_exact_at(&mut buf, start * 4)
                .expect("positioned read of section 'weights' failed after open");
            wts.extend(
                buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
        });
    }

    fn has_edge(&self, u: u32, v: u32) -> bool {
        // binary search over u's sorted on-disk row: log(deg) 4-byte
        // positioned reads, allocation-free — same answer as the
        // in-memory slice search by the row-ordering invariant
        let (start, len) = self.range(u);
        let (mut lo, mut hi) = (0u64, len as u64);
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let x = self.index_at(start + mid);
            match x.cmp(&v) {
                std::cmp::Ordering::Equal => return true,
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{rmat_streamed, GraphBuilder, RmatConfig};
    use crate::util::tempdir::TempDir;

    fn small_graph() -> CsrGraph {
        let mut b = GraphBuilder::new(6);
        for (u, v, w) in [(0, 1, 1.0), (1, 2, 2.0), (2, 3, 0.5), (3, 4, 1.0), (0, 5, 4.0)] {
            b.add_edge(u, v, w);
        }
        b.build()
    }

    #[test]
    fn write_open_roundtrip_matches_memory() {
        let t = TempDir::new("diskcsr-rt").unwrap();
        let dir = t.path().join("g");
        let g = small_graph();
        write_graph_dir(&dir, &g).unwrap();
        let d = DiskCsr::open(&dir).unwrap();
        assert_eq!(GraphStore::num_nodes(&d), g.num_nodes());
        assert_eq!(GraphStore::num_edges(&d), g.num_edges());
        assert_eq!(GraphStore::indptr(&d), g.indptr());
        let (mut nbrs, mut wts) = (Vec::new(), Vec::new());
        for u in 0..g.num_nodes() as u32 {
            assert_eq!(GraphStore::degree(&d, u), g.degree(u));
            assert_eq!(d.vertex_weight(u), g.vertex_weight(u));
            d.edges_into(u, &mut nbrs, &mut wts);
            assert_eq!(nbrs, g.neighbors(u), "row {u}");
            assert_eq!(wts, g.edge_weights(u), "row {u}");
            for v in 0..g.num_nodes() as u32 {
                assert_eq!(d.has_edge(u, v), g.neighbors(u).contains(&v), "({u},{v})");
            }
        }
        let back = d.to_mem().unwrap();
        assert_eq!(back.indptr(), g.indptr());
        assert_eq!(back.indices(), g.indices());
        back.validate().unwrap();
    }

    #[test]
    fn rmat_roundtrip_bit_identical() {
        let t = TempDir::new("diskcsr-rmat").unwrap();
        let dir = t.path().join("g");
        let g = rmat_streamed(&RmatConfig {
            scale: 7,
            edge_factor: 6,
            seed: 11,
            ..Default::default()
        });
        write_graph_dir(&dir, &g).unwrap();
        let d = DiskCsr::open(&dir).unwrap();
        let back = d.to_mem().unwrap();
        assert_eq!(back.indptr(), g.indptr());
        assert_eq!(back.indices(), g.indices());
        for u in 0..g.num_nodes() as u32 {
            assert_eq!(back.edge_weights(u), g.edge_weights(u));
        }
    }

    #[test]
    fn republish_replaces_previous_directory() {
        let t = TempDir::new("diskcsr-republish").unwrap();
        let dir = t.path().join("g");
        let g1 = small_graph();
        write_graph_dir(&dir, &g1).unwrap();
        let g2 = rmat_streamed(&RmatConfig {
            scale: 5,
            edge_factor: 4,
            seed: 2,
            ..Default::default()
        });
        write_graph_dir(&dir, &g2).unwrap();
        let d = DiskCsr::open(&dir).unwrap();
        assert_eq!(GraphStore::num_nodes(&d), g2.num_nodes());
        // exactly the published directory remains — no temp siblings
        let entries = fs::read_dir(t.path()).unwrap().count();
        assert_eq!(entries, 1);
    }

    #[test]
    fn open_rejects_wrong_kind() {
        let t = TempDir::new("diskcsr-kind").unwrap();
        let dir = t.path().join("g");
        write_graph_dir(&dir, &small_graph()).unwrap();
        let text = fs::read_to_string(dir.join(MANIFEST)).unwrap();
        fs::write(dir.join(MANIFEST), text.replace("disk-csr", "model")).unwrap();
        let err = DiskCsr::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("not a disk-csr graph"), "{err:#}");
    }
}
