//! Graph substrate: compressed-sparse-row storage, builders, synthetic
//! generators and statistics.
//!
//! The paper evaluates on OGB graphs loaded through DGL; here the graph
//! store is built from scratch. All graphs are undirected and stored
//! symmetrically (every edge appears in both adjacency lists), matching
//! OGB's `to_bidirected` preprocessing noted under Table II of the paper.

mod csr;
mod disk;
mod generate;
mod io;
mod stats;
mod store;

pub use csr::{CsrGraph, GraphBuilder};
pub use disk::{write_graph_dir, DiskCsr, DiskGraphManifest, DISK_GRAPH_VERSION};
pub use generate::{planted_partition, rmat, rmat_streamed, PlantedPartitionConfig, RmatConfig};
pub use io::{read_edge_list, write_edge_list};
pub use stats::GraphStats;
pub use store::{GraphHandle, GraphStore};
