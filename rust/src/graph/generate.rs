//! Synthetic graph generators.
//!
//! The paper evaluates on OGB graphs we cannot ship; the datasets in
//! `crate::data` are built on the planted-partition (stochastic block
//! model) generator below, which reproduces the *homophily* property the
//! paper's method exploits (DESIGN.md §3). R-MAT is provided for
//! heavy-tailed stress tests of the partitioner and samplers.

use super::csr::{CsrGraph, GraphBuilder};
use crate::util::rng::Rng;

/// Configuration for the planted-partition / SBM generator.
///
/// Supports a *two-level* hierarchy: communities are grouped into
/// `supers` super-communities; `super_degree` adds edges between
/// communities of the same super-community. Real graphs (e.g. OGB's
/// citation/co-purchase networks) exhibit homophily at multiple scales —
/// exactly what the paper's hierarchical position embeddings exploit —
/// so the synthetic analogs must too (DESIGN.md §3).
#[derive(Debug, Clone)]
pub struct PlantedPartitionConfig {
    /// Number of nodes.
    pub n: usize,
    /// Number of planted (fine) communities.
    pub communities: usize,
    /// Number of super-communities (1 = flat SBM). Communities are
    /// assigned contiguously: super s owns communities
    /// [s·C/S, (s+1)·C/S).
    pub supers: usize,
    /// Expected intra-community degree per node.
    pub intra_degree: f64,
    /// Expected same-super (but cross-community) degree per node.
    pub super_degree: f64,
    /// Expected global inter-community degree per node.
    pub inter_degree: f64,
    /// RNG seed (deterministic generation).
    pub seed: u64,
}

impl Default for PlantedPartitionConfig {
    fn default() -> Self {
        PlantedPartitionConfig {
            n: 1000,
            communities: 10,
            supers: 1,
            intra_degree: 8.0,
            super_degree: 0.0,
            inter_degree: 2.0,
            seed: 0,
        }
    }
}

/// Generate a planted-partition graph. Returns the graph and the planted
/// community assignment (ground truth used by `crate::data` to derive
/// homophilous labels).
///
/// Edges are sampled by expected-degree: each node draws
/// `Poisson-ish(intra_degree)` partners uniformly within its block and
/// `inter_degree` partners outside. Duplicates merge; the realized degree
/// distribution is binomial-like, matching the sparse SBM regime.
pub fn planted_partition(cfg: &PlantedPartitionConfig) -> (CsrGraph, Vec<u32>) {
    assert!(cfg.communities >= 1 && cfg.n >= cfg.communities);
    let supers = cfg.supers.clamp(1, cfg.communities);
    let comms_per_super = cfg.communities.div_ceil(supers);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let n = cfg.n;
    let k = cfg.communities;
    // contiguous block assignment, then shuffled ids would lose locality
    // information which is fine — membership is returned explicitly. Keep
    // contiguous blocks (block i = ids [i*n/k, (i+1)*n/k)) for simplicity;
    // the partitioner never sees the membership.
    let mut membership = vec![0u32; n];
    let block = n / k;
    for (i, m) in membership.iter_mut().enumerate() {
        *m = ((i / block).min(k - 1)) as u32;
    }
    // index nodes per community
    let mut by_comm: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (i, &c) in membership.iter().enumerate() {
        by_comm[c as usize].push(i as u32);
    }
    let mut builder = GraphBuilder::new(n);
    for u in 0..n as u32 {
        let c = membership[u as usize] as usize;
        // intra edges: each node initiates intra_degree/2 (each edge counted
        // from both sides in expectation)
        let n_intra = sample_count(&mut rng, cfg.intra_degree / 2.0);
        for _ in 0..n_intra {
            let peers = &by_comm[c];
            if peers.len() > 1 {
                let v = peers[rng.gen_range(peers.len())];
                builder.add_edge(u, v, 1.0);
            }
        }
        // same-super edges (multi-scale homophily)
        let my_super = c / comms_per_super;
        let lo = my_super * comms_per_super;
        let hi = ((my_super + 1) * comms_per_super).min(k);
        if hi - lo > 1 {
            let n_super = sample_count(&mut rng, cfg.super_degree / 2.0);
            for _ in 0..n_super {
                let mut oc = lo + rng.gen_range(hi - lo);
                if oc == c {
                    oc = lo + (oc - lo + 1) % (hi - lo);
                }
                let peers = &by_comm[oc];
                if !peers.is_empty() {
                    let v = peers[rng.gen_range(peers.len())];
                    builder.add_edge(u, v, 1.0);
                }
            }
        }
        let n_inter = sample_count(&mut rng, cfg.inter_degree / 2.0);
        for _ in 0..n_inter {
            if k > 1 {
                let mut oc = rng.gen_range(k);
                if oc == c {
                    oc = (oc + 1) % k;
                }
                let peers = &by_comm[oc];
                let v = peers[rng.gen_range(peers.len())];
                builder.add_edge(u, v, 1.0);
            }
        }
    }
    (builder.build(), membership)
}

/// Poor-man's Poisson: floor + Bernoulli on the fractional part. Exact in
/// expectation, cheap, and deterministic under the seeded RNG.
fn sample_count(rng: &mut Rng, expectation: f64) -> usize {
    let base = expectation.floor() as usize;
    let frac = expectation - expectation.floor();
    base + usize::from(rng.gen_bool(frac.clamp(0.0, 1.0)))
}

/// Configuration for the R-MAT generator (power-law stress graphs).
#[derive(Debug, Clone)]
pub struct RmatConfig {
    /// log2 of node count.
    pub scale: u32,
    /// Average directed edges per node before symmetrization/dedup.
    pub edge_factor: usize,
    /// R-MAT quadrant probabilities; must sum to 1. Kronecker defaults:
    /// (0.57, 0.19, 0.19, 0.05).
    pub probabilities: (f64, f64, f64, f64),
    /// RNG seed.
    pub seed: u64,
}

impl Default for RmatConfig {
    fn default() -> Self {
        RmatConfig { scale: 12, edge_factor: 8, probabilities: (0.57, 0.19, 0.19, 0.05), seed: 7 }
    }
}

/// One R-MAT quadrant walk: sample a `(u, v)` pair, or `None` for a
/// self-loop (the RNG advances identically either way, so count and
/// fill passes over the same stream see the same pairs).
fn rmat_pair(rng: &mut Rng, scale: u32, probs: (f64, f64, f64, f64)) -> Option<(u32, u32)> {
    let (a, b, c, _d) = probs;
    let (mut u, mut v) = (0usize, 0usize);
    for _bit in 0..scale {
        let r = rng.gen_f64();
        let (du, dv) = if r < a {
            (0, 0)
        } else if r < a + b {
            (0, 1)
        } else if r < a + b + c {
            (1, 0)
        } else {
            (1, 1)
        };
        u = (u << 1) | du;
        v = (v << 1) | dv;
    }
    (u != v).then_some((u as u32, v as u32))
}

/// Generate an R-MAT graph (Chakrabarti et al.), symmetrized and deduped.
pub fn rmat(cfg: &RmatConfig) -> CsrGraph {
    let n = 1usize << cfg.scale;
    let m = n * cfg.edge_factor;
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut builder = GraphBuilder::new(n);
    for _ in 0..m {
        if let Some((u, v)) = rmat_pair(&mut rng, cfg.scale, cfg.probabilities) {
            builder.add_edge(u, v, 1.0);
        }
    }
    builder.build()
}

/// Edges per regenerated chunk of the streamed R-MAT edge stream. A
/// fixed constant (never derived from thread count) so the per-chunk
/// RNG streams — and therefore the output — are identical no matter
/// how many workers rayon schedules.
const RMAT_CHUNK: usize = 1 << 19;

/// Per-chunk RNG stream seed (SplitMix-style avalanche over the chunk
/// index, so neighboring chunks get uncorrelated streams).
fn chunk_seed(seed: u64, chunk: u64) -> u64 {
    let mut h = seed ^ chunk.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// Streamed, chunk-parallel R-MAT generation straight into CSR.
///
/// [`rmat`] routes every sampled pair through [`GraphBuilder`], which
/// buffers the full edge list and sorts it — fine at bench scale, but
/// roughly 3× the final graph's footprint and single-threaded at the
/// 100M-edge scale the sharded trainer targets. This variant never
/// materializes an edge list: fixed-size chunks of the edge stream are
/// regenerated twice from per-chunk RNG streams (a parallel degree
/// count, then a parallel fill into preallocated CSR arrays via
/// per-node atomic cursors), rows are sorted in parallel, and duplicate
/// entries merge by summing their unit weights.
///
/// Deterministic for a fixed config **independent of thread count**
/// (pinned in `tests/powerlaw.rs`): chunk streams are keyed by chunk
/// index alone, the fill pass's scheduling races only permute entries
/// *within* a row, and the per-row sort plus the order-independent
/// duplicate merge (all pre-merge weights are 1.0) erase that
/// permutation. Self-loops are dropped and each kept pair lands in both
/// endpoint rows, mirroring [`GraphBuilder`] semantics — but the RNG
/// streams differ from [`rmat`]'s single sequential stream, so the two
/// generators produce different (equally valid) graphs for one seed.
pub fn rmat_streamed(cfg: &RmatConfig) -> CsrGraph {
    use rayon::prelude::*;
    use std::sync::atomic::{AtomicU32, AtomicU64, Ordering::Relaxed};

    let n = 1usize << cfg.scale;
    let m = n * cfg.edge_factor;
    let chunks = m.div_ceil(RMAT_CHUNK).max(1);
    let chunk_range = |c: usize| (c * RMAT_CHUNK, ((c + 1) * RMAT_CHUNK).min(m));

    // pass 1: degree count (order-independent atomic adds)
    let deg: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    (0..chunks).into_par_iter().for_each(|c| {
        let (lo, hi) = chunk_range(c);
        let mut rng = Rng::seed_from_u64(chunk_seed(cfg.seed, c as u64));
        for _ in lo..hi {
            if let Some((u, v)) = rmat_pair(&mut rng, cfg.scale, cfg.probabilities) {
                deg[u as usize].fetch_add(1, Relaxed);
                deg[v as usize].fetch_add(1, Relaxed);
            }
        }
    });
    let mut indptr = Vec::with_capacity(n + 1);
    indptr.push(0u64);
    let mut acc = 0u64;
    for d in &deg {
        acc += d.load(Relaxed);
        indptr.push(acc);
    }
    drop(deg);

    // pass 2: regenerate the identical stream and scatter into rows
    let cursor: Vec<AtomicU64> = indptr[..n].iter().map(|&o| AtomicU64::new(o)).collect();
    let slots: Vec<AtomicU32> = (0..acc).map(|_| AtomicU32::new(0)).collect();
    (0..chunks).into_par_iter().for_each(|c| {
        let (lo, hi) = chunk_range(c);
        let mut rng = Rng::seed_from_u64(chunk_seed(cfg.seed, c as u64));
        for _ in lo..hi {
            if let Some((u, v)) = rmat_pair(&mut rng, cfg.scale, cfg.probabilities) {
                let iu = cursor[u as usize].fetch_add(1, Relaxed) as usize;
                slots[iu].store(v, Relaxed);
                let iv = cursor[v as usize].fetch_add(1, Relaxed) as usize;
                slots[iv].store(u, Relaxed);
            }
        }
    });
    drop(cursor);
    let mut indices: Vec<u32> = slots.into_iter().map(AtomicU32::into_inner).collect();

    // parallel per-row sort restores a scheduling-independent order
    let mut rows: Vec<&mut [u32]> = Vec::with_capacity(n);
    let mut rest: &mut [u32] = &mut indices;
    for u in 0..n {
        let len = (indptr[u + 1] - indptr[u]) as usize;
        let (row, tail) = rest.split_at_mut(len);
        rows.push(row);
        rest = tail;
    }
    rows.into_par_iter().for_each(|row| row.sort_unstable());

    // merge duplicates (run-length → summed unit weight) and compact
    let mut f_indptr = Vec::with_capacity(n + 1);
    f_indptr.push(0u64);
    let mut f_indices: Vec<u32> = Vec::new();
    let mut f_weights: Vec<f32> = Vec::new();
    for u in 0..n {
        let (s, e) = (indptr[u] as usize, indptr[u + 1] as usize);
        let mut i = s;
        while i < e {
            let v = indices[i];
            let mut j = i + 1;
            while j < e && indices[j] == v {
                j += 1;
            }
            f_indices.push(v);
            f_weights.push((j - i) as f32);
            i = j;
        }
        f_indptr.push(f_indices.len() as u64);
    }
    CsrGraph::from_parts(f_indptr, f_indices, f_weights, vec![1; n])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_partition_is_deterministic() {
        let cfg = PlantedPartitionConfig {
            n: 500,
            communities: 5,
            intra_degree: 8.0,
            inter_degree: 2.0,
            seed: 42,
            ..Default::default()
        };
        let (g1, m1) = planted_partition(&cfg);
        let (g2, m2) = planted_partition(&cfg);
        assert_eq!(m1, m2);
        assert_eq!(g1.indptr(), g2.indptr());
        assert_eq!(g1.indices(), g2.indices());
    }

    #[test]
    fn planted_partition_has_homophily() {
        let cfg = PlantedPartitionConfig {
            n: 1000,
            communities: 10,
            intra_degree: 10.0,
            inter_degree: 2.0,
            seed: 1,
            ..Default::default()
        };
        let (g, membership) = planted_partition(&cfg);
        g.validate().unwrap();
        let mut intra = 0usize;
        let mut total = 0usize;
        for u in 0..g.num_nodes() as u32 {
            for &v in g.neighbors(u) {
                total += 1;
                if membership[u as usize] == membership[v as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total as f64;
        // expected ~10/12 ≈ 0.83 intra fraction
        assert!(frac > 0.7, "intra fraction too low: {frac}");
    }

    #[test]
    fn planted_partition_degree_close_to_expectation() {
        let cfg = PlantedPartitionConfig {
            n: 2000,
            communities: 4,
            intra_degree: 6.0,
            inter_degree: 2.0,
            seed: 3,
            ..Default::default()
        };
        let (g, _) = planted_partition(&cfg);
        let avg_deg = g.num_adjacency_entries() as f64 / g.num_nodes() as f64;
        // duplicates merge so realized < 8; accept wide band
        assert!(avg_deg > 5.0 && avg_deg < 9.0, "avg degree {avg_deg}");
    }

    #[test]
    fn rmat_shape() {
        let g = rmat(&RmatConfig { scale: 8, edge_factor: 4, ..Default::default() });
        g.validate().unwrap();
        assert_eq!(g.num_nodes(), 256);
        assert!(g.num_edges() > 200);
        // heavy tail: max degree well above mean
        let max_deg = (0..256u32).map(|u| g.degree(u)).max().unwrap();
        let mean = g.num_adjacency_entries() / 256;
        assert!(max_deg > 2 * mean, "max {max_deg} mean {mean}");
    }
}
