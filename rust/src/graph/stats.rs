//! Graph statistics — backs Table II ("Dataset statistics") of the paper
//! and the `poshashemb report datasets` subcommand.

use super::csr::CsrGraph;

/// Summary statistics of a graph (paper Table II columns plus degree
/// distribution details used in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct GraphStats {
    /// Number of nodes.
    pub num_nodes: usize,
    /// Number of undirected edges.
    pub num_edges: usize,
    /// Minimum degree.
    pub min_degree: usize,
    /// Maximum degree.
    pub max_degree: usize,
    /// Mean degree.
    pub mean_degree: f64,
    /// Median degree.
    pub median_degree: usize,
    /// Nodes with no edges.
    pub isolated_nodes: usize,
    /// Fraction of adjacency entries within the given communities (edge
    /// homophily); `None` when no membership supplied.
    pub edge_homophily: Option<f64>,
}

impl GraphStats {
    /// Compute stats; `membership` (e.g. planted communities or labels)
    /// enables the homophily column.
    pub fn compute(g: &CsrGraph, membership: Option<&[u32]>) -> Self {
        let n = g.num_nodes();
        let mut degrees: Vec<usize> = (0..n as u32).map(|u| g.degree(u)).collect();
        let isolated = degrees.iter().filter(|&&d| d == 0).count();
        let mean = degrees.iter().sum::<usize>() as f64 / n.max(1) as f64;
        degrees.sort_unstable();
        let edge_homophily = membership.map(|m| {
            assert_eq!(m.len(), n);
            let mut same = 0usize;
            let mut total = 0usize;
            for u in 0..n as u32 {
                for &v in g.neighbors(u) {
                    total += 1;
                    same += usize::from(m[u as usize] == m[v as usize]);
                }
            }
            if total == 0 {
                0.0
            } else {
                same as f64 / total as f64
            }
        });
        GraphStats {
            num_nodes: n,
            num_edges: g.num_edges(),
            min_degree: degrees.first().copied().unwrap_or(0),
            max_degree: degrees.last().copied().unwrap_or(0),
            mean_degree: mean,
            median_degree: degrees.get(n / 2).copied().unwrap_or(0),
            isolated_nodes: isolated,
            edge_homophily,
        }
    }

    /// Paper-style one-line row (Table II format).
    pub fn table_row(&self, name: &str) -> String {
        format!(
            "| {:<16} | {:>9} | {:>10} | deg μ={:>6.2} max={:>5} | homophily={} |",
            name,
            self.num_nodes,
            self.num_edges,
            self.mean_degree,
            self.max_degree,
            self.edge_homophily.map_or("n/a".to_string(), |h| format!("{h:.3}")),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{planted_partition, GraphBuilder, PlantedPartitionConfig};

    #[test]
    fn stats_on_path_graph() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 3, 1.0);
        let g = b.build();
        let s = GraphStats::compute(&g, None);
        assert_eq!(s.num_nodes, 4);
        assert_eq!(s.num_edges, 3);
        assert_eq!(s.min_degree, 1);
        assert_eq!(s.max_degree, 2);
        assert_eq!(s.isolated_nodes, 0);
        assert!(s.edge_homophily.is_none());
    }

    #[test]
    fn homophily_reflects_planted_structure() {
        let (g, m) = planted_partition(&PlantedPartitionConfig {
            n: 800,
            communities: 8,
            intra_degree: 9.0,
            inter_degree: 1.0,
            seed: 5,
            ..Default::default()
        });
        let s = GraphStats::compute(&g, Some(&m));
        assert!(s.edge_homophily.unwrap() > 0.8);
    }

    #[test]
    fn homophily_zero_when_all_distinct() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        let g = b.build();
        let m = vec![0, 1, 2];
        let s = GraphStats::compute(&g, Some(&m));
        assert_eq!(s.edge_homophily.unwrap(), 0.0);
    }
}
