//! CSR graph storage.
//!
//! `CsrGraph` is the single in-memory graph representation used by the
//! partitioner, the samplers and the dataset registry. Node ids are dense
//! `u32` in `[0, n)`. Edge weights are `f32` (uniform `1.0` unless the
//! generator or loader supplies weights); the multilevel coarsener relies
//! on integer-like accumulated weights, so weights are kept exact for
//! small sums.

/// Immutable undirected graph in compressed-sparse-row form.
///
/// Invariants (checked by `debug_validate`, exercised by proptests):
/// * `indptr.len() == n + 1`, `indptr[0] == 0`, monotone non-decreasing.
/// * `indices.len() == indptr[n] == 2 * m` for `m` undirected edges.
/// * symmetric: `v ∈ adj(u)  ⇔  u ∈ adj(v)` with equal weight.
/// * no self loops.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    indptr: Vec<u64>,
    indices: Vec<u32>,
    weights: Vec<f32>,
    /// Per-node vertex weight (1 for plain graphs; coarse graphs carry the
    /// number of fine nodes collapsed into each super-node).
    vwgts: Vec<u32>,
}

impl CsrGraph {
    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Number of *undirected* edges (each stored twice).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    /// Number of directed adjacency entries (`2 * num_edges`).
    #[inline]
    pub fn num_adjacency_entries(&self) -> usize {
        self.indices.len()
    }

    /// Neighbors of `u`.
    #[inline]
    pub fn neighbors(&self, u: u32) -> &[u32] {
        let (s, e) = self.range(u);
        &self.indices[s..e]
    }

    /// Edge weights aligned with `neighbors(u)`.
    #[inline]
    pub fn edge_weights(&self, u: u32) -> &[f32] {
        let (s, e) = self.range(u);
        &self.weights[s..e]
    }

    /// Neighbor/weight pairs of `u`.
    #[inline]
    pub fn edges(&self, u: u32) -> impl Iterator<Item = (u32, f32)> + '_ {
        let (s, e) = self.range(u);
        self.indices[s..e].iter().copied().zip(self.weights[s..e].iter().copied())
    }

    /// Degree of `u`.
    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        let (s, e) = self.range(u);
        e - s
    }

    /// Vertex weight of `u` (number of original nodes it represents).
    #[inline]
    pub fn vertex_weight(&self, u: u32) -> u32 {
        self.vwgts[u as usize]
    }

    /// Total vertex weight of the graph.
    pub fn total_vertex_weight(&self) -> u64 {
        self.vwgts.iter().map(|&w| w as u64).sum()
    }

    /// Raw CSR row pointer array (length `n + 1`).
    pub fn indptr(&self) -> &[u64] {
        &self.indptr
    }

    /// Raw CSR column index array.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Raw edge-weight array aligned with `indices()`.
    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// Raw per-node vertex-weight array (length `n`).
    pub fn vertex_weights(&self) -> &[u32] {
        &self.vwgts
    }

    #[inline]
    fn range(&self, u: u32) -> (usize, usize) {
        (self.indptr[u as usize] as usize, self.indptr[u as usize + 1] as usize)
    }

    /// Assemble a graph directly from CSR arrays — the entry point for
    /// kernels that produce CSR natively (the partitioner's two-pass
    /// contraction, induced-subgraph extraction) without paying
    /// `GraphBuilder`'s edge-list sort.
    ///
    /// The caller must uphold the type invariants documented above
    /// (monotone `indptr`, symmetric adjacency, per-row ascending
    /// neighbor ids, no self loops). Cheap shape checks run always;
    /// `validate()` is the exhaustive check used by tests.
    pub fn from_parts(
        indptr: Vec<u64>,
        indices: Vec<u32>,
        weights: Vec<f32>,
        vwgts: Vec<u32>,
    ) -> Self {
        assert!(!indptr.is_empty() && indptr[0] == 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap() as usize, indices.len(), "indptr tail mismatch");
        assert_eq!(weights.len(), indices.len(), "weights length mismatch");
        assert_eq!(vwgts.len(), indptr.len() - 1, "vwgts length mismatch");
        debug_assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr not monotone");
        CsrGraph { indptr, indices, weights, vwgts }
    }

    /// COO edge arrays `(src, dst)` over all directed adjacency entries.
    /// This is the exact layout the AOT-compiled GNN consumes
    /// (`segment_sum` over `dst`).
    pub fn to_coo(&self) -> (Vec<u32>, Vec<u32>) {
        let mut src = Vec::with_capacity(self.indices.len());
        let mut dst = Vec::with_capacity(self.indices.len());
        for u in 0..self.num_nodes() as u32 {
            for &v in self.neighbors(u) {
                src.push(u);
                dst.push(v);
            }
        }
        (src, dst)
    }

    /// Symmetric-normalized edge coefficients `1/sqrt(deg(u)*deg(v))`
    /// aligned with `to_coo` order, with self-degree+1 (GCN renormalization
    /// trick: \hat{A} = A + I handled by adding self loops downstream).
    pub fn gcn_norm_coefficients(&self) -> Vec<f32> {
        let mut coefs = Vec::with_capacity(self.indices.len());
        for u in 0..self.num_nodes() as u32 {
            let du = (self.degree(u) + 1) as f32;
            for &v in self.neighbors(u) {
                let dv = (self.degree(v) + 1) as f32;
                coefs.push(1.0 / (du * dv).sqrt());
            }
        }
        coefs
    }

    /// Exhaustive structural validation; O(m log m). Used by tests.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        if self.indptr[0] != 0 {
            return Err("indptr[0] != 0".into());
        }
        if *self.indptr.last().unwrap() as usize != self.indices.len() {
            return Err("indptr tail mismatch".into());
        }
        if self.weights.len() != self.indices.len() {
            return Err("weights length mismatch".into());
        }
        if self.vwgts.len() != n {
            return Err("vwgts length mismatch".into());
        }
        for w in self.indptr.windows(2) {
            if w[1] < w[0] {
                return Err("indptr not monotone".into());
            }
        }
        // symmetry + no self loops
        use std::collections::HashMap;
        let mut seen: HashMap<(u32, u32), f32> = HashMap::new();
        for u in 0..n as u32 {
            for (v, w) in self.edges(u) {
                if v == u {
                    return Err(format!("self loop at {u}"));
                }
                if v as usize >= n {
                    return Err(format!("neighbor {v} out of range"));
                }
                seen.insert((u, v), w);
            }
        }
        for (&(u, v), &w) in &seen {
            match seen.get(&(v, u)) {
                Some(&w2) if (w - w2).abs() < 1e-6 => {}
                Some(_) => return Err(format!("asymmetric weight on ({u},{v})")),
                None => return Err(format!("missing reverse edge ({v},{u})")),
            }
        }
        Ok(())
    }
}

/// Incremental builder that deduplicates and symmetrizes edges.
///
/// Parallel edges are merged by summing weights (the behaviour the
/// coarsener needs); self loops are dropped.
pub struct GraphBuilder {
    n: usize,
    /// (u, v, w) with u < v — canonical undirected form.
    edges: Vec<(u32, u32, f32)>,
    vwgts: Option<Vec<u32>>,
}

impl GraphBuilder {
    /// Builder for a graph on `n` nodes.
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new(), vwgts: None }
    }

    /// Supply per-node vertex weights (coarse graphs).
    pub fn with_vertex_weights(mut self, vwgts: Vec<u32>) -> Self {
        assert_eq!(vwgts.len(), self.n);
        self.vwgts = Some(vwgts);
        self
    }

    /// Add an undirected edge; self loops silently dropped.
    pub fn add_edge(&mut self, u: u32, v: u32, w: f32) {
        if u == v {
            return;
        }
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        let (a, b) = if u < v { (u, v) } else { (v, u) };
        self.edges.push((a, b, w));
    }

    /// Number of (possibly duplicated) edges added so far.
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Finalize into CSR, merging duplicates.
    pub fn build(mut self) -> CsrGraph {
        self.edges.sort_unstable_by_key(|&(u, v, _)| (u, v));
        // merge duplicates
        let mut merged: Vec<(u32, u32, f32)> = Vec::with_capacity(self.edges.len());
        for (u, v, w) in self.edges {
            match merged.last_mut() {
                Some(last) if last.0 == u && last.1 == v => last.2 += w,
                _ => merged.push((u, v, w)),
            }
        }
        let n = self.n;
        let mut deg = vec![0u64; n];
        for &(u, v, _) in &merged {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut indptr = vec![0u64; n + 1];
        for i in 0..n {
            indptr[i + 1] = indptr[i] + deg[i];
        }
        let total = indptr[n] as usize;
        let mut indices = vec![0u32; total];
        let mut weights = vec![0f32; total];
        let mut cursor: Vec<u64> = indptr[..n].to_vec();
        for &(u, v, w) in &merged {
            let cu = cursor[u as usize] as usize;
            indices[cu] = v;
            weights[cu] = w;
            cursor[u as usize] += 1;
            let cv = cursor[v as usize] as usize;
            indices[cv] = u;
            weights[cv] = w;
            cursor[v as usize] += 1;
        }
        // per-row sort for deterministic layout + binary-searchable rows
        for u in 0..n {
            let (s, e) = (indptr[u] as usize, indptr[u + 1] as usize);
            let mut row: Vec<(u32, f32)> =
                indices[s..e].iter().copied().zip(weights[s..e].iter().copied()).collect();
            row.sort_unstable_by_key(|&(v, _)| v);
            for (i, (v, w)) in row.into_iter().enumerate() {
                indices[s + i] = v;
                weights[s + i] = w;
            }
        }
        CsrGraph {
            indptr,
            indices,
            weights,
            vwgts: self.vwgts.unwrap_or_else(|| vec![1; n]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> CsrGraph {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 1.0);
        b.add_edge(2, 0, 1.0);
        b.build()
    }

    #[test]
    fn triangle_structure() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.neighbors(2), &[0, 1]);
        g.validate().unwrap();
    }

    #[test]
    fn self_loops_dropped() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 0, 1.0);
        b.add_edge(0, 1, 1.0);
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        g.validate().unwrap();
    }

    #[test]
    fn parallel_edges_merge_weights() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 0, 2.5);
        let g = b.build();
        assert_eq!(g.num_edges(), 1);
        assert_eq!(g.edge_weights(0), &[3.5]);
        assert_eq!(g.edge_weights(1), &[3.5]);
    }

    #[test]
    fn coo_roundtrip_counts() {
        let g = triangle();
        let (src, dst) = g.to_coo();
        assert_eq!(src.len(), 6);
        assert_eq!(dst.len(), 6);
        // every coo entry is a real adjacency
        for (s, d) in src.iter().zip(dst.iter()) {
            assert!(g.neighbors(*s).contains(d));
        }
    }

    #[test]
    fn gcn_norm_symmetric_on_regular_graph() {
        let g = triangle();
        let coefs = g.gcn_norm_coefficients();
        // 3-regular-ish: all degrees 2, so coef = 1/3 everywhere
        for c in coefs {
            assert!((c - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn isolated_nodes_allowed() {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        let g = b.build();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.degree(3), 0);
        g.validate().unwrap();
    }

    #[test]
    fn default_vertex_weights_are_one() {
        let g = triangle();
        assert_eq!(g.total_vertex_weight(), 3);
    }

    #[test]
    fn from_parts_roundtrips_builder_output() {
        let g = triangle();
        let re = CsrGraph::from_parts(
            g.indptr().to_vec(),
            g.indices().to_vec(),
            (0..g.num_nodes() as u32).flat_map(|u| g.edge_weights(u).to_vec()).collect(),
            (0..g.num_nodes() as u32).map(|u| g.vertex_weight(u)).collect(),
        );
        re.validate().unwrap();
        assert_eq!(re.indptr(), g.indptr());
        assert_eq!(re.indices(), g.indices());
    }

    #[test]
    #[should_panic(expected = "indptr tail mismatch")]
    fn from_parts_rejects_bad_shape() {
        CsrGraph::from_parts(vec![0, 2], vec![1], vec![1.0], vec![1]);
    }
}
