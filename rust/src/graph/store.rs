//! The graph storage-backend abstraction.
//!
//! [`GraphStore`] is the read interface every topology consumer
//! (sampler, edge batcher, partitioner first-level pass, shard builder)
//! goes through. Two backends implement it: the in-memory [`CsrGraph`]
//! and the on-disk [`DiskCsr`](super::DiskCsr), which answers row reads
//! with positioned reads against the section files instead of resident
//! arrays. Because the trait hands out *values* (rows copied into
//! caller scratch, membership answers) rather than borrowed slices,
//! the two backends are interchangeable without forking call sites —
//! and because every consumer keys its RNG streams by coordinates, not
//! by access order, a disk-backed run is **bit-identical** to an
//! in-memory run (pinned by `tests/disk_graph.rs`).
//!
//! [`GraphHandle`] is the owning enum datasets carry: `Mem` wraps a
//! [`CsrGraph`], `Disk` wraps a shared [`DiskCsr`]. Paths that
//! genuinely need resident arrays (full-batch oracle, PJRT statics,
//! model-artifact save) call [`GraphHandle::mem`] and are unreachable
//! from disk-backed datasets by construction.

use super::csr::CsrGraph;
use super::disk::DiskCsr;
use std::sync::Arc;

/// Read-only topology access, backend-agnostic.
///
/// `indptr` stays resident in every backend (8 bytes per node — the
/// one array whose random access pattern makes positioned reads
/// pathological); adjacency rows are copied out on demand. All row
/// contents are per-row ascending neighbor ids, exactly as
/// [`CsrGraph`] stores them, so backends can never disagree on the
/// bytes a consumer sees.
pub trait GraphStore: Sync {
    /// Number of nodes.
    fn num_nodes(&self) -> usize;

    /// Number of directed adjacency entries (`2 * num_edges`).
    fn num_adjacency_entries(&self) -> usize;

    /// Number of *undirected* edges (each stored twice).
    fn num_edges(&self) -> usize {
        self.num_adjacency_entries() / 2
    }

    /// Resident CSR row-pointer array (length `n + 1`).
    fn indptr(&self) -> &[u64];

    /// Degree of `u`.
    fn degree(&self, u: u32) -> usize {
        let p = self.indptr();
        (p[u as usize + 1] - p[u as usize]) as usize
    }

    /// Vertex weight of `u` (number of original nodes it represents).
    fn vertex_weight(&self, u: u32) -> u32;

    /// Total vertex weight of the graph.
    fn total_vertex_weight(&self) -> u64 {
        (0..self.num_nodes() as u32).map(|u| self.vertex_weight(u) as u64).sum()
    }

    /// Copy the neighbor row of `u` into `out` (cleared first;
    /// ascending ids).
    fn neighbors_into(&self, u: u32, out: &mut Vec<u32>);

    /// Copy the neighbor row and aligned edge weights of `u` into
    /// `nbrs`/`wts` (both cleared first).
    fn edges_into(&self, u: u32, nbrs: &mut Vec<u32>, wts: &mut Vec<f32>);

    /// Whether the undirected edge `(u, v)` exists. Binary search over
    /// `u`'s (sorted) row — backends answer identically by the row
    /// ordering invariant.
    fn has_edge(&self, u: u32, v: u32) -> bool;
}

impl GraphStore for CsrGraph {
    fn num_nodes(&self) -> usize {
        CsrGraph::num_nodes(self)
    }

    fn num_adjacency_entries(&self) -> usize {
        CsrGraph::num_adjacency_entries(self)
    }

    fn num_edges(&self) -> usize {
        CsrGraph::num_edges(self)
    }

    fn indptr(&self) -> &[u64] {
        CsrGraph::indptr(self)
    }

    fn degree(&self, u: u32) -> usize {
        CsrGraph::degree(self, u)
    }

    fn vertex_weight(&self, u: u32) -> u32 {
        CsrGraph::vertex_weight(self, u)
    }

    fn total_vertex_weight(&self) -> u64 {
        CsrGraph::total_vertex_weight(self)
    }

    fn neighbors_into(&self, u: u32, out: &mut Vec<u32>) {
        out.clear();
        out.extend_from_slice(self.neighbors(u));
    }

    fn edges_into(&self, u: u32, nbrs: &mut Vec<u32>, wts: &mut Vec<f32>) {
        nbrs.clear();
        wts.clear();
        nbrs.extend_from_slice(self.neighbors(u));
        wts.extend_from_slice(self.edge_weights(u));
    }

    fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }
}

/// The owning graph handle a [`crate::data::Dataset`] carries: either
/// the classic in-memory CSR or a shared on-disk store. Cloning is
/// cheap for the disk backend (`Arc`) and a full array copy for the
/// in-memory one, matching the previous `Dataset.graph: CsrGraph`
/// semantics.
#[derive(Debug, Clone)]
pub enum GraphHandle {
    /// In-memory CSR (the historical default).
    Mem(CsrGraph),
    /// On-disk CSR opened from a `--to-disk` directory.
    Disk(Arc<DiskCsr>),
}

impl GraphHandle {
    /// This handle as a trait object — resolves the enum once so hot
    /// loops pay one dynamic dispatch instead of a per-call match.
    #[inline]
    pub fn store(&self) -> &dyn GraphStore {
        match self {
            GraphHandle::Mem(g) => g,
            GraphHandle::Disk(d) => d.as_ref(),
        }
    }

    /// The in-memory graph, for the few paths that genuinely need
    /// resident arrays (full-batch oracle, PJRT statics, model-artifact
    /// save). Panics on a disk-backed handle — callers on those paths
    /// gate disk-backed datasets out at the CLI layer.
    #[inline]
    pub fn mem(&self) -> &CsrGraph {
        match self {
            GraphHandle::Mem(g) => g,
            GraphHandle::Disk(_) => {
                panic!("this path needs the in-memory graph, but the dataset is disk-backed")
            }
        }
    }

    /// Number of nodes.
    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.store().num_nodes()
    }

    /// Number of *undirected* edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.store().num_edges()
    }

    /// Number of directed adjacency entries.
    #[inline]
    pub fn num_adjacency_entries(&self) -> usize {
        self.store().num_adjacency_entries()
    }

    /// Degree of `u`.
    #[inline]
    pub fn degree(&self, u: u32) -> usize {
        self.store().degree(u)
    }

    /// Vertex weight of `u`.
    #[inline]
    pub fn vertex_weight(&self, u: u32) -> u32 {
        self.store().vertex_weight(u)
    }
}

impl From<CsrGraph> for GraphHandle {
    fn from(g: CsrGraph) -> Self {
        GraphHandle::Mem(g)
    }
}

impl From<DiskCsr> for GraphHandle {
    fn from(d: DiskCsr) -> Self {
        GraphHandle::Disk(Arc::new(d))
    }
}

impl GraphStore for GraphHandle {
    fn num_nodes(&self) -> usize {
        self.store().num_nodes()
    }

    fn num_adjacency_entries(&self) -> usize {
        self.store().num_adjacency_entries()
    }

    fn num_edges(&self) -> usize {
        self.store().num_edges()
    }

    fn indptr(&self) -> &[u64] {
        self.store().indptr()
    }

    fn degree(&self, u: u32) -> usize {
        self.store().degree(u)
    }

    fn vertex_weight(&self, u: u32) -> u32 {
        self.store().vertex_weight(u)
    }

    fn total_vertex_weight(&self) -> u64 {
        self.store().total_vertex_weight()
    }

    fn neighbors_into(&self, u: u32, out: &mut Vec<u32>) {
        self.store().neighbors_into(u, out)
    }

    fn edges_into(&self, u: u32, nbrs: &mut Vec<u32>, wts: &mut Vec<f32>) {
        self.store().edges_into(u, nbrs, wts)
    }

    fn has_edge(&self, u: u32, v: u32) -> bool {
        self.store().has_edge(u, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn path4() -> CsrGraph {
        let mut b = GraphBuilder::new(4);
        b.add_edge(0, 1, 1.0);
        b.add_edge(1, 2, 2.0);
        b.add_edge(2, 3, 3.0);
        b.build()
    }

    #[test]
    fn trait_view_matches_inherent_api() {
        let g = path4();
        let s: &dyn GraphStore = &g;
        assert_eq!(s.num_nodes(), 4);
        assert_eq!(s.num_edges(), 3);
        assert_eq!(s.num_adjacency_entries(), 6);
        assert_eq!(s.indptr(), g.indptr());
        assert_eq!(s.total_vertex_weight(), 4);
        let mut nbrs = Vec::new();
        let mut wts = Vec::new();
        for u in 0..4u32 {
            assert_eq!(s.degree(u), g.degree(u));
            s.neighbors_into(u, &mut nbrs);
            assert_eq!(nbrs, g.neighbors(u));
            s.edges_into(u, &mut nbrs, &mut wts);
            assert_eq!(nbrs, g.neighbors(u));
            assert_eq!(wts, g.edge_weights(u));
        }
        assert!(s.has_edge(1, 2) && s.has_edge(2, 1));
        assert!(!s.has_edge(0, 3) && !s.has_edge(0, 0));
    }

    #[test]
    fn handle_delegates_and_coerces() {
        let h: GraphHandle = path4().into();
        assert_eq!(h.num_nodes(), 4);
        assert_eq!(h.degree(1), 2);
        assert_eq!(h.mem().neighbors(1), &[0, 2]);
        // &GraphHandle coerces to &dyn GraphStore at call sites
        let s: &dyn GraphStore = &h;
        assert_eq!(s.num_edges(), 3);
    }
}
