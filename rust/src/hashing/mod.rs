//! Universal hashing for integers — the substrate behind the
//! node-specific component (paper §III-B) and the HashTrick / Bloom /
//! HashEmb baselines (§II-B).
//!
//! The paper uses Carter–Wegman universal hashing for integers [13]:
//! `H(x) = ((a·x + b) mod p) mod B` with `p` a prime larger than the
//! universe and `a ∈ [1, p)`, `b ∈ [0, p)` drawn per function.

mod universal;

pub use universal::{HashFamily, UniversalHash};

/// Precomputed multi-hash index table: `indices[t][i] = H_t(i)` for node
/// `i` and hash function `t`. This is exactly the static `u` index array
/// the AOT-lowered embedding computation consumes (the HLO takes hashed
/// indices as an input so one compiled artifact serves any hash seeds).
#[derive(Debug, Clone)]
pub struct HashedIndices {
    /// `h` rows of `n` bucket ids each.
    pub indices: Vec<Vec<u32>>,
    /// Number of buckets each row maps into.
    pub buckets: u32,
}

impl HashedIndices {
    /// Hash every node id in `[0, n)` with `h` independent functions into
    /// `buckets` buckets.
    pub fn build(n: usize, h: usize, buckets: u32, seed: u64) -> Self {
        assert!(buckets >= 1);
        let family = HashFamily::new(seed);
        let fns: Vec<UniversalHash> = (0..h).map(|t| family.function(t as u64, buckets)).collect();
        let indices = fns
            .iter()
            .map(|f| (0..n as u64).map(|i| f.hash(i)).collect())
            .collect();
        HashedIndices { indices, buckets }
    }

    /// Number of hash functions.
    pub fn num_functions(&self) -> usize {
        self.indices.len()
    }

    /// Bucket of node `i` under hash `t`.
    pub fn bucket(&self, t: usize, i: usize) -> u32 {
        self.indices[t][i]
    }

    /// Flatten to a single row-major `h × n` i32 array (HLO input layout).
    pub fn flatten_i32(&self) -> Vec<i32> {
        self.indices.iter().flat_map(|row| row.iter().map(|&x| x as i32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_buckets_in_range() {
        let hi = HashedIndices::build(5000, 2, 37, 9);
        for row in &hi.indices {
            assert!(row.iter().all(|&b| b < 37));
        }
    }

    #[test]
    fn rows_are_independent() {
        let hi = HashedIndices::build(2000, 2, 64, 3);
        let same = hi.indices[0]
            .iter()
            .zip(hi.indices[1].iter())
            .filter(|(a, b)| a == b)
            .count();
        // two independent uniform maps agree w.p. 1/64: expect ~31 of 2000
        assert!(same < 120, "rows too correlated: {same}");
    }

    #[test]
    fn load_is_roughly_uniform() {
        let hi = HashedIndices::build(64_000, 1, 64, 5);
        let mut load = vec![0usize; 64];
        for &b in &hi.indices[0] {
            load[b as usize] += 1;
        }
        // expectation 1000; universal hashing keeps this within ~3 sigma
        for &l in &load {
            assert!(l > 700 && l < 1300, "bucket load {l}");
        }
    }

    #[test]
    fn flatten_layout() {
        let hi = HashedIndices::build(3, 2, 10, 1);
        let flat = hi.flatten_i32();
        assert_eq!(flat.len(), 6);
        assert_eq!(flat[0], hi.bucket(0, 0) as i32);
        assert_eq!(flat[3], hi.bucket(1, 0) as i32);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = HashedIndices::build(100, 2, 16, 42);
        let b = HashedIndices::build(100, 2, 16, 42);
        let c = HashedIndices::build(100, 2, 16, 43);
        assert_eq!(a.indices, b.indices);
        assert_ne!(a.indices, c.indices);
    }
}
