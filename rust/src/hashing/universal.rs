//! Carter–Wegman universal hash functions over 64-bit integers.
//!
//! `h_{a,b}(x) = ((a·x + b) mod p) mod B` with Mersenne prime
//! `p = 2^61 - 1`. Multiplication is done in 128 bits with the standard
//! fast mod-Mersenne reduction, giving an exactly-universal family (not
//! just an ad-hoc mixer) as required by the paper's reference [13].

use crate::util::rng::Rng;

/// Mersenne prime 2^61 - 1.
pub const P: u64 = (1u64 << 61) - 1;

/// A single universal hash function into `buckets` buckets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UniversalHash {
    a: u64, // in [1, p)
    b: u64, // in [0, p)
    buckets: u32,
}

impl UniversalHash {
    /// Construct from explicit coefficients (testing); panics if invalid.
    pub fn from_coefficients(a: u64, b: u64, buckets: u32) -> Self {
        assert!(a >= 1 && a < P && b < P && buckets >= 1);
        UniversalHash { a, b, buckets }
    }

    /// Hash `x` into `[0, buckets)`.
    #[inline]
    pub fn hash(&self, x: u64) -> u32 {
        let v = mod_p(mul_mod_p(self.a, mod_p(x)) + self.b);
        (v % self.buckets as u64) as u32
    }
}

/// Seeded family of independent universal hash functions.
#[derive(Debug, Clone)]
pub struct HashFamily {
    seed: u64,
}

impl HashFamily {
    /// Family keyed by `seed`.
    pub fn new(seed: u64) -> Self {
        HashFamily { seed }
    }

    /// The `index`-th function of the family, into `buckets` buckets.
    /// Functions for different indices are drawn independently.
    pub fn function(&self, index: u64, buckets: u32) -> UniversalHash {
        let key = self.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(index);
        let mut rng = Rng::seed_from_u64(key);
        let a = gen_below_p(&mut rng, 1);
        let b = gen_below_p(&mut rng, 0);
        UniversalHash { a, b, buckets }
    }
}

/// Uniform draw in [lo, P) by rejection sampling 61-bit values
/// (rejection probability ~2^-61, effectively zero).
fn gen_below_p(rng: &mut Rng, lo: u64) -> u64 {
    loop {
        let x = rng.next_u64() >> 3; // 61 bits
        if x >= lo && x < P {
            return x;
        }
    }
}

/// x mod (2^61 - 1), for x < 2^64.
#[inline]
fn mod_p(x: u64) -> u64 {
    let mut r = (x & P) + (x >> 61);
    if r >= P {
        r -= P;
    }
    r
}

/// (a * b) mod (2^61 - 1) via 128-bit product.
#[inline]
fn mul_mod_p(a: u64, b: u64) -> u64 {
    let prod = (a as u128) * (b as u128);
    let lo = (prod & P as u128) as u64;
    let hi = (prod >> 61) as u64;
    mod_p(lo + mod_p(hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mod_p_correct_small() {
        assert_eq!(mod_p(0), 0);
        assert_eq!(mod_p(P), 0);
        assert_eq!(mod_p(P + 5), 5);
        assert_eq!(mod_p(u64::MAX), u64::MAX % P);
    }

    #[test]
    fn mul_mod_matches_u128_reference() {
        let cases = [
            (1u64, 1u64),
            (P - 1, P - 1),
            (123_456_789, 987_654_321),
            (1u64 << 60, 3),
            (0x0123_4567_89AB_CDEF % P, 0xFEDC_BA98_7654_3210 % P),
        ];
        for (a, b) in cases {
            let expect = ((a as u128 * b as u128) % P as u128) as u64;
            assert_eq!(mul_mod_p(a, b), expect, "a={a} b={b}");
        }
    }

    #[test]
    fn hash_in_range_and_deterministic() {
        let f = HashFamily::new(7).function(0, 97);
        for x in 0..10_000u64 {
            let h1 = f.hash(x);
            assert!(h1 < 97);
            assert_eq!(h1, f.hash(x));
        }
    }

    #[test]
    fn pairwise_collision_rate_near_universal_bound() {
        // universal: Pr[h(x)=h(y)] <= ~1/B. Empirically check over many
        // pairs and functions.
        let b = 50u32;
        let family = HashFamily::new(11);
        let mut collisions = 0usize;
        let mut trials = 0usize;
        for fi in 0..20u64 {
            let f = family.function(fi, b);
            for x in 0..100u64 {
                for y in (x + 1)..100 {
                    trials += 1;
                    collisions += usize::from(f.hash(x) == f.hash(y));
                }
            }
        }
        let rate = collisions as f64 / trials as f64;
        assert!(rate < 2.0 / b as f64, "collision rate {rate} vs 1/B {}", 1.0 / b as f64);
    }

    #[test]
    fn different_indices_give_different_functions() {
        let family = HashFamily::new(3);
        let f0 = family.function(0, 1000);
        let f1 = family.function(1, 1000);
        let same = (0..1000u64).filter(|&x| f0.hash(x) == f1.hash(x)).count();
        assert!(same < 30, "functions too similar: {same}/1000");
    }

    #[test]
    fn explicit_coefficients() {
        let f = UniversalHash::from_coefficients(1, 0, 10);
        assert_eq!(f.hash(7), 7);
        assert_eq!(f.hash(17), 7);
    }

    #[test]
    #[should_panic]
    fn zero_a_rejected() {
        UniversalHash::from_coefficients(0, 0, 10);
    }
}
