//! Runtime client: host tensors plus the execution backend.
//!
//! Two backends share one API surface so the trainer and harness are
//! backend-agnostic:
//!
//! * **`pjrt` feature on** — the `xla` crate's PJRT CPU client:
//!   compile-from-HLO-text with an executable cache and host↔device
//!   tensor transfer.
//! * **`pjrt` feature off (default)** — a stub whose constructor fails
//!   with a clear message. Everything that does not execute HLO (plans,
//!   partitioner, compose engine, manifests) works without the feature;
//!   only `train`/`experiment`-style commands need it.

use super::artifact::{ArtifactSpec, Dtype, Manifest};
use anyhow::{bail, Result};

/// A host-side tensor matched to an artifact input slot.
#[derive(Debug, Clone)]
pub enum HostTensor {
    /// f32 data + shape.
    F32(Vec<f32>, Vec<usize>),
    /// i32 data + shape.
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    /// Scalar f32 (rank-0).
    pub fn scalar(x: f32) -> Self {
        HostTensor::F32(vec![x], vec![])
    }

    /// Shape of the tensor.
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    /// Dtype tag.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32(..) => Dtype::F32,
            HostTensor::I32(..) => Dtype::I32,
        }
    }

    /// Check this tensor against an input spec.
    pub fn check(&self, spec: &super::InputSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!("input {}: shape {:?} != spec {:?}", spec.name, self.shape(), spec.shape);
        }
        if self.dtype() != spec.dtype {
            bail!("input {}: dtype mismatch", spec.name);
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::{ArtifactSpec, HostTensor, Manifest};
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::{Arc, Mutex};

    /// A compiled (loaded) executable.
    pub struct Executable(xla::PjRtLoadedExecutable);

    /// A device-resident buffer.
    pub struct DeviceBuffer(xla::PjRtBuffer);

    /// PJRT client + executable cache.
    pub struct RuntimeClient {
        client: xla::PjRtClient,
        /// Compiled executables keyed by artifact name.
        cache: Mutex<HashMap<String, Arc<Executable>>>,
    }

    impl RuntimeClient {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
            Ok(RuntimeClient { client, cache: Mutex::new(HashMap::new()) })
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile the HLO text at `path` (no caching).
        pub fn compile_hlo_file(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", path.display()))?;
            Ok(Executable(exe))
        }

        /// Compile (or fetch from cache) the executable for `spec`.
        pub fn load(&self, manifest: &Manifest, spec: &ArtifactSpec) -> Result<Arc<Executable>> {
            {
                let cache = self.cache.lock().unwrap();
                if let Some(exe) = cache.get(&spec.name) {
                    return Ok(exe.clone());
                }
            }
            let exe = Arc::new(
                self.compile_hlo_file(&manifest.hlo_path(spec))
                    .with_context(|| format!("loading artifact {}", spec.name))?,
            );
            self.cache.lock().unwrap().insert(spec.name.clone(), exe.clone());
            Ok(exe)
        }

        /// Upload a host tensor to the device.
        pub fn upload(&self, t: &HostTensor) -> Result<DeviceBuffer> {
            let buf = match t {
                HostTensor::F32(data, shape) => {
                    self.client.buffer_from_host_buffer::<f32>(data, shape, None)
                }
                HostTensor::I32(data, shape) => {
                    self.client.buffer_from_host_buffer::<i32>(data, shape, None)
                }
            };
            buf.map(DeviceBuffer).map_err(|e| anyhow!("upload: {e}"))
        }

        /// Execute on device buffers; returns the flat output buffers of
        /// replica 0 (the modules are lowered with `return_tuple=True`, so
        /// PJRT returns one buffer per tuple element).
        pub fn execute(
            &self,
            exe: &Executable,
            args: &[&DeviceBuffer],
        ) -> Result<Vec<DeviceBuffer>> {
            let raw: Vec<&xla::PjRtBuffer> = args.iter().map(|b| &b.0).collect();
            let mut out =
                exe.0.execute_b::<&xla::PjRtBuffer>(&raw).map_err(|e| anyhow!("execute: {e}"))?;
            if out.is_empty() {
                bail!("execute returned no replica output");
            }
            Ok(out.swap_remove(0).into_iter().map(DeviceBuffer).collect())
        }

        /// Download a device buffer as f32 (works for rank-N f32 outputs).
        pub fn download_f32(&self, buf: &DeviceBuffer) -> Result<Vec<f32>> {
            let lit = buf.0.to_literal_sync().map_err(|e| anyhow!("to_literal: {e}"))?;
            lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
        }

        /// Download a scalar f32 output.
        pub fn download_scalar(&self, buf: &DeviceBuffer) -> Result<f32> {
            Ok(self.download_f32(buf)?[0])
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{ArtifactSpec, HostTensor, Manifest};
    use anyhow::{bail, Result};
    use std::sync::Arc;

    const UNAVAILABLE: &str = "poshashemb was built without the `pjrt` feature: PJRT/HLO \
         execution (train, experiment, eval) is unavailable. Plans, partitioning and the \
         compose engine still work. The `pjrt` feature is not wired yet — it needs the \
         `xla` bindings and a vendored XLA runtime added to rust/Cargo.toml first (ROADMAP: \
         \"PJRT runtime wiring\")";

    /// A compiled executable (stub — never constructed without `pjrt`).
    pub struct Executable {
        _priv: (),
    }

    /// A device-resident buffer (stub — never constructed without `pjrt`).
    pub struct DeviceBuffer {
        _priv: (),
    }

    /// Stub runtime client: construction fails with a clear message, so
    /// callers hit one actionable error instead of scattered panics.
    pub struct RuntimeClient {
        _priv: (),
    }

    impl RuntimeClient {
        /// Always fails without the `pjrt` feature.
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        /// Unreachable without `pjrt` (no client can be constructed).
        pub fn load(&self, _manifest: &Manifest, _spec: &ArtifactSpec) -> Result<Arc<Executable>> {
            bail!(UNAVAILABLE)
        }

        /// Unreachable without `pjrt`.
        pub fn upload(&self, _t: &HostTensor) -> Result<DeviceBuffer> {
            bail!(UNAVAILABLE)
        }

        /// Unreachable without `pjrt`.
        pub fn execute(
            &self,
            _exe: &Executable,
            _args: &[&DeviceBuffer],
        ) -> Result<Vec<DeviceBuffer>> {
            bail!(UNAVAILABLE)
        }

        /// Unreachable without `pjrt`.
        pub fn download_f32(&self, _buf: &DeviceBuffer) -> Result<Vec<f32>> {
            bail!(UNAVAILABLE)
        }

        /// Unreachable without `pjrt`.
        pub fn download_scalar(&self, _buf: &DeviceBuffer) -> Result<f32> {
            bail!(UNAVAILABLE)
        }
    }
}

pub use imp::{DeviceBuffer, Executable, RuntimeClient};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::InputSpec;

    #[test]
    fn host_tensor_check() {
        let spec = InputSpec { name: "x".into(), shape: vec![2, 3], dtype: Dtype::F32 };
        let ok = HostTensor::F32(vec![0.0; 6], vec![2, 3]);
        assert!(ok.check(&spec).is_ok());
        let bad_shape = HostTensor::F32(vec![0.0; 4], vec![2, 2]);
        assert!(bad_shape.check(&spec).is_err());
        let bad_dtype = HostTensor::I32(vec![0; 6], vec![2, 3]);
        assert!(bad_dtype.check(&spec).is_err());
    }

    #[test]
    fn scalar_shape_is_rank0() {
        let s = HostTensor::scalar(1.5);
        assert!(s.shape().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_client_fails_with_actionable_message() {
        let err = RuntimeClient::cpu().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "err: {err}");
    }
}
