//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.json` lists every lowered HLO module with its flat
//! input ABI (name/shape/dtype in positional order) and output arity.
//! The trainer never guesses an input position — it resolves names
//! against this spec (`python/tests/test_model.py::
//! test_input_specs_abi_is_stable` pins the producer side).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Element type of an artifact input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

/// One positional input of a lowered HLO module.
#[derive(Debug, Clone)]
pub struct InputSpec {
    /// Input name (resolved by the trainer, never positional guessing).
    pub name: String,
    /// Tensor shape (empty = rank 0).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: Dtype,
}

impl InputSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact (train or eval module of one experiment config).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (`<config>.train` / `<config>.eval`).
    pub name: String,
    /// Path of the HLO text file, relative to the manifest dir.
    pub path: String,
    /// "train" or "eval".
    pub mode: String,
    /// Positional input ABI.
    pub inputs: Vec<InputSpec>,
    /// Number of trainable parameter tensors (first `num_params` inputs).
    pub num_params: usize,
    /// Output tuple arity.
    pub num_outputs: usize,
}

impl ArtifactSpec {
    /// Position of a named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input named {name}", self.name))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and its HLO files) live in.
    pub dir: PathBuf,
    artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let root = Json::parse(text).context("manifest.json parse error")?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts' array"))?;
        let mut artifacts = HashMap::new();
        for a in arts {
            let spec = Self::parse_artifact(a)?;
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    fn parse_artifact(a: &Json) -> Result<ArtifactSpec> {
        let name = a
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact missing name"))?
            .to_string();
        let name2 = name.clone();
        let field_str = move |k: &str| -> Result<String> {
            Ok(a.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact {name2}: missing {k}"))?
                .to_string())
        };
        let name3 = name.clone();
        let field_num = move |k: &str| -> Result<usize> {
            a.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("artifact {name3}: missing {k}"))
        };
        let mut inputs = Vec::new();
        for i in a
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact {name}: missing inputs"))?
        {
            let iname = i
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("input missing name"))?
                .to_string();
            let shape: Vec<usize> = i
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("input {iname}: missing shape"))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?;
            let dtype = match i.get("dtype").and_then(Json::as_str) {
                Some("f32") => Dtype::F32,
                Some("i32") => Dtype::I32,
                other => bail!("input {iname}: bad dtype {other:?}"),
            };
            inputs.push(InputSpec { name: iname, shape, dtype });
        }
        Ok(ArtifactSpec {
            name,
            path: field_str("path")?,
            mode: field_str("mode")?,
            inputs,
            num_params: field_num("num_params")?,
            num_outputs: field_num("num_outputs")?,
        })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} available); re-run `make artifacts`",
                self.artifacts.len()
            )
        })
    }

    /// Does the manifest contain this artifact?
    pub fn contains(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.path)
    }

    /// All artifact names (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "x.train", "path": "x.train.hlo.txt", "mode": "train",
         "inputs": [
           {"name": "pos_0", "shape": [5, 8], "dtype": "f32"},
           {"name": "z", "shape": [1, 40], "dtype": "i32"}
         ],
         "num_params": 1, "num_outputs": 4}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("x.train").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![5, 8]);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.input_index("z").unwrap(), 1);
        assert!(a.input_index("nope").is_err());
        assert_eq!(m.hlo_path(a), Path::new("/tmp/a/x.train.hlo.txt"));
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "b"}]}"#, Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{}"#, Path::new(".")).is_err());
    }

    #[test]
    fn unknown_artifact_mentions_make() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        let err = m.get("missing").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn elements_product() {
        let i = InputSpec { name: "a".into(), shape: vec![3, 4, 2], dtype: Dtype::F32 };
        assert_eq!(i.elements(), 24);
        let s = InputSpec { name: "s".into(), shape: vec![], dtype: Dtype::F32 };
        assert_eq!(s.elements(), 1);
    }
}
