//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them
//! on the request path. Python is never invoked here — artifacts are
//! produced once by `make artifacts` (python/compile/aot.py) and this
//! module is the only consumer.
//!
//! * `artifact` — `artifacts/manifest.json` schema: per-artifact input
//!   specs (the ABI the train/eval HLO was lowered against).
//! * `client` — execution backend behind one API: with the `pjrt`
//!   feature, the `xla` crate (compile-from-text, executable cache,
//!   host↔device transfer); without it, a stub that fails construction
//!   with a clear message so the rest of the crate builds dependency-free.
//!
//! Hot-loop design: parameters and optimizer state live as `PjRtBuffer`s
//! on the device; each training step consumes the previous step's output
//! buffers directly (`execute_b`), so the per-step host traffic is one
//! scalar (the loss).

mod artifact;
mod client;

pub use artifact::{ArtifactSpec, Dtype, InputSpec, Manifest};
pub use client::{DeviceBuffer, Executable, HostTensor, RuntimeClient};
