//! Evaluation metrics: accuracy (arxiv/products) and ROC-AUC
//! (proteins, mean over binary tasks) — the paper's Table II metrics —
//! plus the link-prediction pair ([`binary_auc`], [`hits_at_k`]) and
//! mean/std aggregation for the `x.xxx ± y.yyy` rows.

/// Classification accuracy from logits (`rows × classes`, row-major) over
/// the node ids in `fold`.
pub fn accuracy(logits: &[f32], classes: usize, labels: &[u32], fold: &[u32]) -> f64 {
    assert!(!fold.is_empty());
    let mut correct = 0usize;
    for &i in fold {
        let i = i as usize;
        let row = &logits[i * classes..(i + 1) * classes];
        let pred = argmax(row);
        correct += usize::from(pred == labels[i] as usize);
    }
    correct as f64 / fold.len() as f64
}

/// Mean ROC-AUC over `tasks` binary tasks. `scores` is `rows × tasks`
/// row-major; `labels` likewise in {0,1}. Tasks that are single-class in
/// the fold are skipped (OGB convention).
pub fn mean_roc_auc(scores: &[f32], tasks: usize, labels: &[u32], fold: &[u32]) -> f64 {
    let mut total = 0f64;
    let mut counted = 0usize;
    for t in 0..tasks {
        let mut pairs: Vec<(f32, u32)> = fold
            .iter()
            .map(|&i| (scores[i as usize * tasks + t], labels[i as usize * tasks + t]))
            .collect();
        let pos = pairs.iter().filter(|&&(_, y)| y == 1).count();
        let neg = pairs.len() - pos;
        if pos == 0 || neg == 0 {
            continue;
        }
        // rank-based AUC (Mann–Whitney U) with midrank ties
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut rank_sum_pos = 0f64;
        let mut i = 0usize;
        while i < pairs.len() {
            let mut j = i;
            while j + 1 < pairs.len() && pairs[j + 1].0 == pairs[i].0 {
                j += 1;
            }
            let midrank = (i + j) as f64 / 2.0 + 1.0;
            for p in &pairs[i..=j] {
                if p.1 == 1 {
                    rank_sum_pos += midrank;
                }
            }
            i = j + 1;
        }
        let auc =
            (rank_sum_pos - pos as f64 * (pos as f64 + 1.0) / 2.0) / (pos as f64 * neg as f64);
        total += auc;
        counted += 1;
    }
    assert!(counted > 0, "no scorable task");
    total / counted as f64
}

/// Binary ROC-AUC between a positive and a negative score set — the
/// rank-based Mann–Whitney U estimator with midrank tie handling, i.e.
/// the probability a uniformly drawn positive outscores a uniformly
/// drawn negative (ties count half). The degenerate all-one-class case
/// (either side empty) scores 0.5, the random-classifier convention —
/// no ordering information exists to reward or punish.
pub fn binary_auc(pos: &[f32], neg: &[f32]) -> f64 {
    if pos.is_empty() || neg.is_empty() {
        return 0.5;
    }
    let mut pairs: Vec<(f32, bool)> = pos
        .iter()
        .map(|&s| (s, true))
        .chain(neg.iter().map(|&s| (s, false)))
        .collect();
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut rank_sum_pos = 0f64;
    let mut i = 0usize;
    while i < pairs.len() {
        let mut j = i;
        while j + 1 < pairs.len() && pairs[j + 1].0 == pairs[i].0 {
            j += 1;
        }
        let midrank = (i + j) as f64 / 2.0 + 1.0;
        for p in &pairs[i..=j] {
            if p.1 {
                rank_sum_pos += midrank;
            }
        }
        i = j + 1;
    }
    let np = pos.len() as f64;
    let nn = neg.len() as f64;
    (rank_sum_pos - np * (np + 1.0) / 2.0) / (np * nn)
}

/// OGB-style hits@k: the fraction of positives scored **strictly**
/// above the k-th highest negative (ties with the threshold do not
/// count — a positive must beat it outright). Fewer than `k` negatives
/// means no negative can block the k-th slot, so every positive is a
/// hit (the OGB convention); `k = 0` offers no slots at all.
pub fn hits_at_k(pos: &[f32], neg: &[f32], k: usize) -> f64 {
    assert!(!pos.is_empty(), "no positive edges to rank");
    if k == 0 {
        return 0.0;
    }
    if neg.len() < k {
        return 1.0;
    }
    let mut ns = neg.to_vec();
    ns.sort_by(|a, b| b.total_cmp(a));
    let threshold = ns[k - 1];
    pos.iter().filter(|&&s| s > threshold).count() as f64 / pos.len() as f64
}

/// Index of the max element (first on ties).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Mean and (population) standard deviation — the paper's `± std` rows.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Paper-style cell: `0.671 ± 0.004`.
pub fn fmt_cell(xs: &[f64]) -> String {
    let (m, s) = mean_std(xs);
    format!("{m:.3} ± {s:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_exact() {
        // 3 nodes, 2 classes
        let logits = [1.0, 0.0, 0.0, 1.0, 0.9, 0.1];
        let labels = [0, 1, 1];
        let fold = [0, 1, 2];
        let a = accuracy(&logits, 2, &labels, &fold);
        assert!((a - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_respects_fold() {
        let logits = [1.0, 0.0, 0.0, 1.0];
        let labels = [0, 0];
        assert_eq!(accuracy(&logits, 2, &labels, &[0]), 1.0);
        assert_eq!(accuracy(&logits, 2, &labels, &[1]), 0.0);
    }

    #[test]
    fn auc_perfect_separation() {
        // scores: positives all higher
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        let fold = [0, 1, 2, 3];
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn auc_random_is_half() {
        // interleaved equal scores -> 0.5 via midranks
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [0, 1, 0, 1];
        let fold = [0, 1, 2, 3];
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn auc_known_value() {
        // classic example: scores 1..8, pos = {3,6,7,8} (1-indexed)
        let scores = [1., 2., 3., 4., 5., 6., 7., 8.];
        let labels = [0, 0, 1, 0, 0, 1, 1, 1];
        let fold: Vec<u32> = (0..8).collect();
        // pairs: pos>neg count = (1)+(3)+(4)+(4)=12? compute: neg at ranks
        // 1,2,4,5; pos at 3,6,7,8. For each pos count negs below:
        // 3→2, 6→4, 7→4, 8→4 = 14 of 16 → 0.875
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 0.875).abs() < 1e-9);
    }

    #[test]
    fn multi_task_auc_averages_and_skips_degenerate() {
        // task 0 perfect, task 1 degenerate (all zeros) -> skipped
        let scores = [0.1, 0.3, 0.9, 0.3];
        let labels = [0, 0, 1, 0];
        let fold = [0, 1];
        let auc = mean_roc_auc(&scores, 2, &labels, &fold);
        assert!((auc - 1.0).abs() < 1e-9);
    }

    #[test]
    fn binary_auc_golden_values() {
        // perfect separation
        assert!((binary_auc(&[0.8, 0.9], &[0.1, 0.2]) - 1.0).abs() < 1e-9);
        // perfectly wrong
        assert!((binary_auc(&[0.1, 0.2], &[0.8, 0.9]) - 0.0).abs() < 1e-9);
        // hand-computed: pos {3,6,7,8}, neg {1,2,4,5} of ranks 1..8 →
        // 14 winning pairs of 16 = 0.875 (same case mean_roc_auc pins)
        let auc = binary_auc(&[3., 6., 7., 8.], &[1., 2., 4., 5.]);
        assert!((auc - 0.875).abs() < 1e-9);
        // one positive, one negative, different scores
        assert!((binary_auc(&[2.0], &[1.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn binary_auc_ties_use_midranks() {
        // all scores equal → every pair ties → 0.5
        assert!((binary_auc(&[0.5, 0.5], &[0.5, 0.5]) - 0.5).abs() < 1e-9);
        // pos {1, 2}, neg {1, 0}: pairs (1v1 tie=0.5) (1v0 win) (2v1 win)
        // (2v0 win) → 3.5 / 4 = 0.875
        let auc = binary_auc(&[1.0, 2.0], &[1.0, 0.0]);
        assert!((auc - 0.875).abs() < 1e-9);
    }

    #[test]
    fn binary_auc_degenerate_folds_score_half() {
        assert!((binary_auc(&[], &[1.0, 2.0]) - 0.5).abs() < 1e-9);
        assert!((binary_auc(&[1.0, 2.0], &[]) - 0.5).abs() < 1e-9);
        assert!((binary_auc(&[], &[]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn hits_at_k_golden_values() {
        let pos = [0.9, 0.6, 0.3, 0.1];
        let neg = [0.5, 0.4, 0.2];
        // k=1: threshold 0.5 → only 0.9 and 0.6 beat it → 2/4
        assert!((hits_at_k(&pos, &neg, 1) - 0.5).abs() < 1e-9);
        // k=2: threshold 0.4 → same two → 2/4
        assert!((hits_at_k(&pos, &neg, 2) - 0.5).abs() < 1e-9);
        // k=3: threshold 0.2 → 0.9, 0.6, 0.3 → 3/4
        assert!((hits_at_k(&pos, &neg, 3) - 0.75).abs() < 1e-9);
        // k beyond the negative count: every positive is a hit
        assert!((hits_at_k(&pos, &neg, 4) - 1.0).abs() < 1e-9);
        assert!((hits_at_k(&pos, &[], 50) - 1.0).abs() < 1e-9);
        // k=0: no slots
        assert!(hits_at_k(&pos, &neg, 0).abs() < 1e-9);
    }

    #[test]
    fn hits_at_k_ties_do_not_count() {
        // positive tied with the threshold is not strictly above it
        assert!(hits_at_k(&[0.5], &[0.5], 1).abs() < 1e-9);
        assert!((hits_at_k(&[0.6, 0.5], &[0.5], 1) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mean_std_matches_paper_format() {
        let xs = [0.67, 0.68, 0.66];
        let cell = fmt_cell(&xs);
        assert!(cell.starts_with("0.670 ±"));
    }
}
