//! Evaluation metrics: accuracy (arxiv/products) and ROC-AUC
//! (proteins, mean over binary tasks) — the paper's Table II metrics —
//! plus mean/std aggregation for the `x.xxx ± y.yyy` rows.

/// Classification accuracy from logits (`rows × classes`, row-major) over
/// the node ids in `fold`.
pub fn accuracy(logits: &[f32], classes: usize, labels: &[u32], fold: &[u32]) -> f64 {
    assert!(!fold.is_empty());
    let mut correct = 0usize;
    for &i in fold {
        let i = i as usize;
        let row = &logits[i * classes..(i + 1) * classes];
        let pred = argmax(row);
        correct += usize::from(pred == labels[i] as usize);
    }
    correct as f64 / fold.len() as f64
}

/// Mean ROC-AUC over `tasks` binary tasks. `scores` is `rows × tasks`
/// row-major; `labels` likewise in {0,1}. Tasks that are single-class in
/// the fold are skipped (OGB convention).
pub fn mean_roc_auc(scores: &[f32], tasks: usize, labels: &[u32], fold: &[u32]) -> f64 {
    let mut total = 0f64;
    let mut counted = 0usize;
    for t in 0..tasks {
        let mut pairs: Vec<(f32, u32)> = fold
            .iter()
            .map(|&i| (scores[i as usize * tasks + t], labels[i as usize * tasks + t]))
            .collect();
        let pos = pairs.iter().filter(|&&(_, y)| y == 1).count();
        let neg = pairs.len() - pos;
        if pos == 0 || neg == 0 {
            continue;
        }
        // rank-based AUC (Mann–Whitney U) with midrank ties
        pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut rank_sum_pos = 0f64;
        let mut i = 0usize;
        while i < pairs.len() {
            let mut j = i;
            while j + 1 < pairs.len() && pairs[j + 1].0 == pairs[i].0 {
                j += 1;
            }
            let midrank = (i + j) as f64 / 2.0 + 1.0;
            for p in &pairs[i..=j] {
                if p.1 == 1 {
                    rank_sum_pos += midrank;
                }
            }
            i = j + 1;
        }
        let auc =
            (rank_sum_pos - pos as f64 * (pos as f64 + 1.0) / 2.0) / (pos as f64 * neg as f64);
        total += auc;
        counted += 1;
    }
    assert!(counted > 0, "no scorable task");
    total / counted as f64
}

/// Index of the max element (first on ties).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Mean and (population) standard deviation — the paper's `± std` rows.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Paper-style cell: `0.671 ± 0.004`.
pub fn fmt_cell(xs: &[f64]) -> String {
    let (m, s) = mean_std(xs);
    format!("{m:.3} ± {s:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_exact() {
        // 3 nodes, 2 classes
        let logits = [1.0, 0.0, 0.0, 1.0, 0.9, 0.1];
        let labels = [0, 1, 1];
        let fold = [0, 1, 2];
        let a = accuracy(&logits, 2, &labels, &fold);
        assert!((a - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_respects_fold() {
        let logits = [1.0, 0.0, 0.0, 1.0];
        let labels = [0, 0];
        assert_eq!(accuracy(&logits, 2, &labels, &[0]), 1.0);
        assert_eq!(accuracy(&logits, 2, &labels, &[1]), 0.0);
    }

    #[test]
    fn auc_perfect_separation() {
        // scores: positives all higher
        let scores = [0.1, 0.2, 0.8, 0.9];
        let labels = [0, 0, 1, 1];
        let fold = [0, 1, 2, 3];
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn auc_random_is_half() {
        // interleaved equal scores -> 0.5 via midranks
        let scores = [0.5, 0.5, 0.5, 0.5];
        let labels = [0, 1, 0, 1];
        let fold = [0, 1, 2, 3];
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn auc_known_value() {
        // classic example: scores 1..8, pos = {3,6,7,8} (1-indexed)
        let scores = [1., 2., 3., 4., 5., 6., 7., 8.];
        let labels = [0, 0, 1, 0, 0, 1, 1, 1];
        let fold: Vec<u32> = (0..8).collect();
        // pairs: pos>neg count = (1)+(3)+(4)+(4)=12? compute: neg at ranks
        // 1,2,4,5; pos at 3,6,7,8. For each pos count negs below:
        // 3→2, 6→4, 7→4, 8→4 = 14 of 16 → 0.875
        assert!((mean_roc_auc(&scores, 1, &labels, &fold) - 0.875).abs() < 1e-9);
    }

    #[test]
    fn multi_task_auc_averages_and_skips_degenerate() {
        // task 0 perfect, task 1 degenerate (all zeros) -> skipped
        let scores = [0.1, 0.3, 0.9, 0.3];
        let labels = [0, 0, 1, 0];
        let fold = [0, 1];
        let auc = mean_roc_auc(&scores, 2, &labels, &fold);
        assert!((auc - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mean_std_matches_paper_format() {
        let xs = [0.67, 0.68, 0.66];
        let cell = fmt_cell(&xs);
        assert!(cell.starts_with("0.670 ±"));
    }
}
