//! Parameter initialization: embedding tables (via `embedding::init_params`)
//! plus the GNN stack, in the exact canonical order of the artifact ABI
//! (`python/compile/train_step.py::param_specs`).

use crate::config::{ModelKind, HIDDEN, NUM_LAYERS};
use crate::embedding::{init_params, EmbeddingPlan, ParamStore, TableShape};
use crate::util::rng::Rng;

/// GNN parameter shapes in ABI order (mirrors `model.py::gnn_param_specs`).
pub fn gnn_param_shapes(model: ModelKind, d: usize, classes: usize) -> Vec<TableShape> {
    let mut dims = vec![d];
    dims.extend(std::iter::repeat_n(HIDDEN, NUM_LAYERS - 1));
    dims.push(classes);
    let mut out = Vec::new();
    for l in 0..NUM_LAYERS {
        let (din, dout) = (dims[l], dims[l + 1]);
        let t = |name: String, rows: usize, cols: usize| TableShape { name, rows, cols };
        match model {
            ModelKind::Gcn => {
                out.push(t(format!("gcn_w{l}"), din, dout));
                out.push(t(format!("gcn_b{l}"), 1, dout));
            }
            ModelKind::Sage => {
                out.push(t(format!("sage_self_w{l}"), din, dout));
                out.push(t(format!("sage_neigh_w{l}"), din, dout));
                out.push(t(format!("sage_b{l}"), 1, dout));
            }
            ModelKind::Gat => {
                out.push(t(format!("gat_w{l}"), din, dout));
                out.push(t(format!("gat_al{l}"), 1, dout));
                out.push(t(format!("gat_ar{l}"), 1, dout));
                out.push(t(format!("gat_b{l}"), 1, dout));
            }
        }
    }
    out
}

/// Initialize embedding + GNN parameters in ABI order.
///
/// Policy: embedding tables per `embedding::init_params`; GNN weights
/// uniform ±1/sqrt(fan_in); biases zero; GAT attention vectors ±0.1.
pub fn init_full_params(
    plan: &EmbeddingPlan,
    model: ModelKind,
    classes: usize,
    seed: u64,
) -> ParamStore {
    let mut store = init_params(plan, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x6A11);
    for t in gnn_param_shapes(model, plan.d, classes) {
        let data: Vec<f32> = if t.name.contains("_b") && !t.name.contains("_w") {
            vec![0.0; t.size()]
        } else if t.name.contains("gat_al") || t.name.contains("gat_ar") {
            (0..t.size()).map(|_| rng.gen_f32_range(-0.1, 0.1)).collect()
        } else {
            let a = 1.0 / (t.rows as f32).sqrt();
            (0..t.size()).map(|_| rng.gen_f32_range(-a, a)).collect()
        };
        store.insert(&t.name, vec![t.rows, t.cols], data);
    }
    store
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingMethod;

    #[test]
    fn gcn_shapes_follow_dims() {
        let shapes = gnn_param_shapes(ModelKind::Gcn, 64, 40);
        assert_eq!(shapes.len(), 2 * NUM_LAYERS);
        assert_eq!((shapes[0].rows, shapes[0].cols), (64, HIDDEN));
        let last_w = &shapes[2 * (NUM_LAYERS - 1)];
        assert_eq!((last_w.rows, last_w.cols), (HIDDEN, 40));
    }

    #[test]
    fn gat_has_attention_vectors() {
        let shapes = gnn_param_shapes(ModelKind::Gat, 32, 5);
        let names: Vec<&str> = shapes.iter().map(|t| t.name.as_str()).collect();
        assert!(names.contains(&"gat_al0"));
        assert!(names.contains(&"gat_ar1"));
    }

    #[test]
    fn full_params_order_embedding_first() {
        let plan = EmbeddingPlan::build(100, 16, &EmbeddingMethod::Full, None, 0);
        let store = init_full_params(&plan, ModelKind::Gcn, 7, 1);
        let names = store.names();
        assert_eq!(names[0], "node_x");
        assert_eq!(names[1], "gcn_w0");
        assert_eq!(names[2], "gcn_b0");
        // biases are zero
        assert!(store.get("gcn_b0").iter().all(|&x| x == 0.0));
        // weights are not all zero
        assert!(store.get("gcn_w0").iter().any(|&x| x != 0.0));
    }

    #[test]
    fn sage_param_count() {
        let shapes = gnn_param_shapes(ModelKind::Sage, 16, 3);
        assert_eq!(shapes.len(), 3 * NUM_LAYERS);
    }
}
