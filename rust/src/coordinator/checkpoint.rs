//! Crash-safe training checkpoints: atomic snapshots of full trainer
//! state, with keep-last-K retention and torn-checkpoint fallback.
//!
//! Because every random draw in the minibatch path is a pure function
//! of `(seed, epoch, batch, …)` (see the determinism ledger in
//! `docs/ARCHITECTURE.md`), a checkpoint does not need RNG state — it
//! only needs the parameter bits, the Adam moments, the optimizer step
//! counter and the `(epoch, batch)` cursor, plus the completed-epoch
//! loss history and the in-progress epoch's `f64` loss accumulator.
//! Restoring those and replaying from the cursor reproduces the
//! uninterrupted run **bit for bit**, serial or pipelined
//! (`rust/tests/checkpoint.rs`, `rust/tests/crash_resume.rs`).
//!
//! On disk a checkpoint is a directory of checksummed little-endian
//! sections ([`crate::util::sections`] — the same substrate as model
//! artifacts) under the checkpoint root:
//!
//! ```text
//! <root>/LATEST                  name of the newest checkpoint
//! <root>/ckpt-0000000420/        named by optimizer step count
//!   manifest.json                run key + cursor + section specs
//!   param__<table>.bin           every ParamStore tensor (f32)
//!   adam_m__<table>.bin          lazy Adam moments (f32, if any)
//!   adam_v__<table>.bin
//!   trainer_losses.bin           completed-epoch losses (f64)
//!   trainer_epoch_ns.bin         completed-epoch wall times (u64)
//!   trainer_loss_accum.bin       partial-epoch loss sum (f64[1])
//! ```
//!
//! Publication is atomic: sections are written fsynced into a temp
//! sibling, the manifest is written **last**, the directory is renamed
//! into place and only then is `LATEST` (itself replaced atomically)
//! pointed at it — a reader can observe the previous checkpoint or the
//! new one, never a torn one. [`load_latest`] walks `LATEST` first and
//! then every `ckpt-*` newest-first, verifying each candidate fully
//! (byte lengths, checksums, shapes, counts) and falling back past
//! corrupt ones with a warning naming the bad section.

use super::optim::Optimizer;
use crate::bench_harness::bench_git_sha;
use crate::embedding::ParamStore;
use crate::util::fault;
use crate::util::sections::{
    atomic_write_text, publish_dir, read_section, temp_sibling, write_section, SectionData,
    SectionSpec,
};
use anyhow::{bail, Context, Result};
use serde::{Deserialize, Serialize};
use std::collections::BTreeMap;
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Checkpoint layout version; loaders bail on anything else.
pub const CHECKPOINT_FORMAT_VERSION: u32 = 1;

/// The manifest `kind` discriminator (model artifacts use
/// `poshashemb-model`; the tags keep the two directory formats
/// unmistakable even though they share the section substrate).
pub const CHECKPOINT_KIND: &str = "poshashemb-checkpoint";

/// Name of the newest-checkpoint pointer file under the root.
pub const LATEST_FILE: &str = "LATEST";

/// Manifest file name inside a checkpoint directory.
const MANIFEST_FILE: &str = "manifest.json";

/// Trainer-side checkpointing knobs.
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    /// Checkpoint root directory (created on first save).
    pub dir: PathBuf,
    /// Snapshot every N optimizer steps (0 disables periodic saves;
    /// a failing run still writes a final checkpoint before aborting).
    pub every: usize,
    /// Keep the newest K checkpoints (0 = keep everything). At least
    /// 2 is recommended: the fallback path needs an older intact
    /// checkpoint when the newest is torn.
    pub keep: usize,
}

/// The run identity a checkpoint belongs to. Resume refuses a
/// checkpoint whose key differs from the live run's — silently
/// continuing a run with a different dataset, method, schedule or
/// optimizer would produce garbage that *looks* resumed.
///
/// Deliberately absent: `parallel` / `prefetch`. The pipelined and
/// serial engines are bit-identical (`tests/parallel_train.rs`), so a
/// checkpoint written by one resumes under the other with the same
/// guarantee.
#[derive(Debug, Clone, PartialEq, Serialize, Deserialize)]
pub struct RunKey {
    /// Dataset name.
    pub dataset: String,
    /// Round-trippable embedding-method tag.
    pub method: String,
    /// Fanout list display form (e.g. `10,5`) — keys the sampler.
    pub fanouts: String,
    /// Seed nodes per batch.
    pub batch_size: usize,
    /// Per-epoch seed shuffling.
    pub shuffle: bool,
    /// Optimizer tag (`sgd` / `adam`).
    pub optimizer: String,
    /// Learning rate as raw f32 bits (exact comparison, no float
    /// round-trip through JSON text).
    pub lr_bits: u32,
    /// Hidden width of intermediate head layers.
    pub hidden: usize,
    /// Master seed (parameter init, shuffles, neighbor draws).
    pub seed: u64,
    /// Total epochs of the run.
    pub epochs: usize,
    /// Training objective display form (`nodeclass` or
    /// `linkpred(decoder,neg=N)`). Defaults on deserialize so
    /// pre-link-prediction checkpoints (all node classification) stay
    /// resumable.
    #[serde(default = "default_objective")]
    pub objective: String,
}

fn default_objective() -> String {
    "nodeclass".to_string()
}

impl RunKey {
    /// Fail with the first differing field, named, when `self` (the
    /// checkpoint's key) does not match `live` (the current run's).
    pub fn ensure_matches(&self, live: &RunKey) -> Result<()> {
        let pairs: [(&str, String, String); 11] = [
            ("dataset", self.dataset.clone(), live.dataset.clone()),
            ("method", self.method.clone(), live.method.clone()),
            ("fanouts", self.fanouts.clone(), live.fanouts.clone()),
            ("batch_size", self.batch_size.to_string(), live.batch_size.to_string()),
            ("shuffle", self.shuffle.to_string(), live.shuffle.to_string()),
            ("optimizer", self.optimizer.clone(), live.optimizer.clone()),
            (
                "lr",
                f32::from_bits(self.lr_bits).to_string(),
                f32::from_bits(live.lr_bits).to_string(),
            ),
            ("hidden", self.hidden.to_string(), live.hidden.to_string()),
            ("seed", self.seed.to_string(), live.seed.to_string()),
            ("epochs", self.epochs.to_string(), live.epochs.to_string()),
            ("objective", self.objective.clone(), live.objective.clone()),
        ];
        for (field, ours, theirs) in pairs {
            if ours != theirs {
                bail!(
                    "checkpoint belongs to a different run: {field} is {ours} in the \
                     checkpoint but {theirs} in this invocation"
                );
            }
        }
        Ok(())
    }
}

/// Where in the run a checkpoint was taken. `(epoch, batch)` is the
/// **next** batch to process: a snapshot at an epoch boundary has
/// `batch == 0` and `epoch` = completed epochs.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct Cursor {
    /// Epoch of the next batch (0-based; == completed epochs).
    pub epoch: usize,
    /// Next batch index within `epoch`.
    pub batch: usize,
    /// Optimizer steps taken so far (keys Adam bias correction).
    pub global_step: u64,
    /// Seed nodes already consumed in the in-progress epoch.
    pub epoch_seen: usize,
    /// Largest composed block so far (outcome bookkeeping).
    pub peak_compose_rows: usize,
}

/// The JSON manifest of one checkpoint directory.
#[derive(Debug, Clone, Serialize, Deserialize)]
pub struct CheckpointManifest {
    /// Layout version; loaders bail on anything but
    /// [`CHECKPOINT_FORMAT_VERSION`].
    pub format_version: u32,
    /// Always [`CHECKPOINT_KIND`].
    pub kind: String,
    /// Producing build's git revision.
    pub git_sha: String,
    /// The run this checkpoint belongs to.
    pub run: RunKey,
    /// Where in the run it was taken.
    pub cursor: Cursor,
    /// All parameter tensor names in canonical store order.
    pub param_names: Vec<String>,
    /// Tables with saved Adam moments, name-sorted.
    pub moment_names: Vec<String>,
    /// Every binary section, in write order.
    pub sections: Vec<SectionSpec>,
}

/// A fully verified, decoded checkpoint.
#[derive(Debug)]
pub struct LoadedCheckpoint {
    /// The parsed manifest.
    pub manifest: CheckpointManifest,
    /// `(name, shape, data)` per parameter tensor, in canonical order.
    pub params: Vec<(String, Vec<usize>, Vec<f32>)>,
    /// `(name, m, v)` per table with Adam moments, name-sorted.
    pub moments: Vec<(String, Vec<f32>, Vec<f32>)>,
    /// Completed-epoch mean losses.
    pub losses: Vec<f64>,
    /// Completed-epoch wall times (ns).
    pub epoch_ns: Vec<u64>,
    /// Partial-epoch loss sum (bit-exact f64).
    pub loss_accum: f64,
    /// Directory name under the root (e.g. `ckpt-0000000420`).
    pub name: String,
}

/// Directory name for a checkpoint taken at optimizer step `step`.
/// Zero-padded so lexicographic order is step order.
pub fn checkpoint_name(step: u64) -> String {
    format!("ckpt-{step:010}")
}

fn section_f32(dir: &Path, name: &str, shape: &[usize], data: &[f32]) -> Result<SectionSpec> {
    write_section(dir, name, shape, &SectionData::F32(data.to_vec()), "checkpoint.section")
}

/// Write one checkpoint under `root` and point `LATEST` at it, then
/// apply keep-last-`keep` retention. Returns the checkpoint directory.
///
/// The publish order is the crash-safety protocol: fsynced sections
/// into a temp sibling → `manifest.json` last → atomic directory
/// rename → atomic `LATEST` replace. A crash (or injected fault:
/// `checkpoint.section` / `checkpoint.manifest` / `checkpoint.rename` /
/// `checkpoint.latest`) anywhere in between leaves the previous
/// checkpoint fully intact and discoverable.
#[allow(clippy::too_many_arguments)]
pub fn save_checkpoint(
    root: &Path,
    keep: usize,
    run: &RunKey,
    cursor: &Cursor,
    params: &ParamStore,
    opt: &Optimizer,
    losses: &[f64],
    epoch_ns: &[u64],
    loss_accum: f64,
) -> Result<PathBuf> {
    fs::create_dir_all(root)
        .with_context(|| format!("creating checkpoint root {}", root.display()))?;
    let name = checkpoint_name(cursor.global_step);
    let dst = root.join(&name);
    let tmp = temp_sibling(&dst);
    fs::create_dir_all(&tmp)
        .with_context(|| format!("creating checkpoint temp dir {}", tmp.display()))?;
    let written =
        write_checkpoint_contents(&tmp, run, cursor, params, opt, losses, epoch_ns, loss_accum)
            .and_then(|()| fault::hit("checkpoint.rename").context("publishing checkpoint"))
            .and_then(|()| publish_dir(&tmp, &dst));
    if let Err(e) = written {
        // best-effort cleanup; the torn temp dir never looks like a
        // checkpoint (publication *is* the rename that just failed)
        let _ = fs::remove_dir_all(&tmp);
        return Err(e);
    }
    fault::hit("checkpoint.latest").context("updating LATEST")?;
    atomic_write_text(&root.join(LATEST_FILE), &format!("{name}\n"))?;
    apply_retention(root, keep, &name)?;
    Ok(dst)
}

#[allow(clippy::too_many_arguments)]
fn write_checkpoint_contents(
    tmp: &Path,
    run: &RunKey,
    cursor: &Cursor,
    params: &ParamStore,
    opt: &Optimizer,
    losses: &[f64],
    epoch_ns: &[u64],
    loss_accum: f64,
) -> Result<()> {
    let mut specs: Vec<SectionSpec> = Vec::new();
    for pname in params.names() {
        let shape = params.shape(pname).to_vec();
        specs.push(section_f32(tmp, &format!("param__{pname}"), &shape, params.get(pname))?);
    }
    let moments = opt.moment_tables();
    for (mname, m, v) in &moments {
        specs.push(section_f32(tmp, &format!("adam_m__{mname}"), &[m.len()], m)?);
        specs.push(section_f32(tmp, &format!("adam_v__{mname}"), &[v.len()], v)?);
    }
    specs.push(write_section(
        tmp,
        "trainer_losses",
        &[losses.len()],
        &SectionData::F64(losses.to_vec()),
        "checkpoint.section",
    )?);
    specs.push(write_section(
        tmp,
        "trainer_epoch_ns",
        &[epoch_ns.len()],
        &SectionData::U64(epoch_ns.to_vec()),
        "checkpoint.section",
    )?);
    specs.push(write_section(
        tmp,
        "trainer_loss_accum",
        &[1],
        &SectionData::F64(vec![loss_accum]),
        "checkpoint.section",
    )?);
    let manifest = CheckpointManifest {
        format_version: CHECKPOINT_FORMAT_VERSION,
        kind: CHECKPOINT_KIND.to_string(),
        git_sha: bench_git_sha(),
        run: run.clone(),
        cursor: cursor.clone(),
        param_names: params.names().to_vec(),
        moment_names: moments.iter().map(|(n, _, _)| n.to_string()).collect(),
        sections: specs,
    };
    fault::hit("checkpoint.manifest").context("writing checkpoint manifest")?;
    let json = serde_json::to_string_pretty(&manifest).context("serializing checkpoint manifest")?;
    let mpath = tmp.join(MANIFEST_FILE);
    let mut f = File::create(&mpath).with_context(|| format!("creating {}", mpath.display()))?;
    f.write_all(json.as_bytes()).with_context(|| format!("writing {}", mpath.display()))?;
    f.sync_all().with_context(|| format!("fsyncing {}", mpath.display()))?;
    Ok(())
}

/// Delete the oldest checkpoints beyond the newest `keep`, never
/// touching `just_written`. `keep == 0` keeps everything.
fn apply_retention(root: &Path, keep: usize, just_written: &str) -> Result<()> {
    if keep == 0 {
        return Ok(());
    }
    let mut names = checkpoint_dir_names(root)?;
    // lexicographic == step order (zero-padded names)
    names.sort();
    while names.len() > keep {
        let victim = names.remove(0);
        if victim == just_written {
            // keep == 1 pathological overlap: never delete the newest
            break;
        }
        let path = root.join(&victim);
        fs::remove_dir_all(&path)
            .with_context(|| format!("retention: removing {}", path.display()))?;
    }
    Ok(())
}

/// All `ckpt-*` directory names under `root` (unordered).
fn checkpoint_dir_names(root: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    let entries =
        fs::read_dir(root).with_context(|| format!("listing checkpoint root {}", root.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with("ckpt-") && entry.path().is_dir() {
            names.push(name);
        }
    }
    Ok(names)
}

/// Load the newest intact checkpoint under `root`.
///
/// Tries every `ckpt-*` directory newest-first (the names are
/// step-ordered; the `LATEST` pointer is an operator convenience and a
/// publish-order witness — a crash between the directory rename and
/// the pointer update leaves `LATEST` one behind, and scanning by name
/// still finds the newer published checkpoint). Candidates that fail
/// verification (torn rename, flipped bit, truncated section, missing
/// manifest) are skipped with a warning naming the failure; the
/// warnings are returned alongside the loaded checkpoint. Returns
/// `Ok(None)` when the root holds no checkpoints at all (a fresh run),
/// and an error only when candidates exist but none is intact.
pub fn load_latest(root: &Path) -> Result<Option<(LoadedCheckpoint, Vec<String>)>> {
    if !root.exists() {
        return Ok(None);
    }
    let mut candidates = checkpoint_dir_names(root)?;
    candidates.sort();
    candidates.reverse();
    if let Ok(latest) = fs::read_to_string(root.join(LATEST_FILE)) {
        let latest = latest.trim().to_string();
        if !latest.is_empty() && !candidates.contains(&latest) {
            candidates.push(latest);
        }
    }
    if candidates.is_empty() {
        return Ok(None);
    }
    let mut warnings = Vec::new();
    for name in &candidates {
        match load_checkpoint_dir(&root.join(name)) {
            Ok(mut ck) => {
                ck.name.clone_from(name);
                return Ok(Some((ck, warnings)));
            }
            Err(e) => warnings.push(format!("skipping checkpoint '{name}': {e:#}")),
        }
    }
    bail!(
        "no intact checkpoint under {} ({} candidate(s) failed verification): {}",
        root.display(),
        candidates.len(),
        warnings.join("; ")
    );
}

/// Read, verify and decode one checkpoint directory.
pub fn load_checkpoint_dir(dir: &Path) -> Result<LoadedCheckpoint> {
    let mpath = dir.join(MANIFEST_FILE);
    let text = fs::read_to_string(&mpath)
        .with_context(|| format!("reading checkpoint manifest {}", mpath.display()))?;
    let manifest: CheckpointManifest =
        serde_json::from_str(&text).with_context(|| format!("parsing {}", mpath.display()))?;
    if manifest.kind != CHECKPOINT_KIND {
        bail!("{} is a '{}' directory, expected '{CHECKPOINT_KIND}'", dir.display(), manifest.kind);
    }
    if manifest.format_version != CHECKPOINT_FORMAT_VERSION {
        bail!(
            "checkpoint {} has format_version {}, this build reads {CHECKPOINT_FORMAT_VERSION}",
            dir.display(),
            manifest.format_version
        );
    }
    let by_name: BTreeMap<&str, &SectionSpec> =
        manifest.sections.iter().map(|s| (s.name.as_str(), s)).collect();
    let take_f32 = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
        let spec = by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing required section '{name}'"))?;
        match read_section(dir, spec)? {
            SectionData::F32(v) => Ok((spec.shape.clone(), v)),
            _ => bail!("section '{name}' has the wrong dtype (expected f32)"),
        }
    };
    let mut params = Vec::with_capacity(manifest.param_names.len());
    for pname in &manifest.param_names {
        let (shape, data) = take_f32(&format!("param__{pname}"))?;
        params.push((pname.clone(), shape, data));
    }
    let mut moments = Vec::with_capacity(manifest.moment_names.len());
    for mname in &manifest.moment_names {
        let (_, m) = take_f32(&format!("adam_m__{mname}"))?;
        let (_, v) = take_f32(&format!("adam_v__{mname}"))?;
        if m.len() != v.len() {
            bail!("moment sections for '{mname}' disagree on length");
        }
        moments.push((mname.clone(), m, v));
    }
    let take = |name: &str| -> Result<SectionData> {
        let spec = by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint is missing required section '{name}'"))?;
        read_section(dir, spec)
    };
    let losses = match take("trainer_losses")? {
        SectionData::F64(v) => v,
        _ => bail!("section 'trainer_losses' has the wrong dtype (expected f64)"),
    };
    let epoch_ns = match take("trainer_epoch_ns")? {
        SectionData::U64(v) => v,
        _ => bail!("section 'trainer_epoch_ns' has the wrong dtype (expected u64)"),
    };
    let loss_accum = match take("trainer_loss_accum")? {
        SectionData::F64(v) if v.len() == 1 => v[0],
        SectionData::F64(_) => bail!("section 'trainer_loss_accum' must hold exactly one value"),
        _ => bail!("section 'trainer_loss_accum' has the wrong dtype (expected f64)"),
    };
    if losses.len() != manifest.cursor.epoch {
        bail!(
            "checkpoint cursor says {} completed epochs but 'trainer_losses' holds {}",
            manifest.cursor.epoch,
            losses.len()
        );
    }
    if epoch_ns.len() != losses.len() {
        bail!("'trainer_epoch_ns' and 'trainer_losses' disagree on epoch count");
    }
    Ok(LoadedCheckpoint {
        manifest,
        params,
        moments,
        losses,
        epoch_ns,
        loss_accum,
        name: dir.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
    })
}

/// Remove stale checkpoint temp directories left behind by a crash
/// mid-write (they are invisible to [`load_latest`], but they hold
/// disk). Returns how many were removed.
pub fn sweep_stale_temps(root: &Path) -> Result<usize> {
    if !root.exists() {
        return Ok(0);
    }
    let mut removed = 0usize;
    for entry in
        fs::read_dir(root).with_context(|| format!("listing checkpoint root {}", root.display()))?
    {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(".ckpt-") && name.contains(".tmp-") && entry.path().is_dir() {
            fs::remove_dir_all(entry.path())
                .with_context(|| format!("removing stale temp {name}"))?;
            removed += 1;
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::OptimizerKind;
    use crate::util::tempdir::TempDir;

    fn tiny_state() -> (ParamStore, Optimizer) {
        let mut params = ParamStore::default();
        params.insert("table_a", vec![4, 2], (0..8).map(|i| i as f32 * 0.5).collect());
        params.insert("head_b", vec![1, 3], vec![-1.0, 0.25, 7.5]);
        let mut opt = Optimizer::new(OptimizerKind::Adam, 0.01);
        opt.restore_moments("table_a", vec![0.1; 8], vec![0.2; 8]);
        (params, opt)
    }

    fn key() -> RunKey {
        RunKey {
            dataset: "synth-arxiv".into(),
            method: "hashemb(b=32,h=2)".into(),
            fanouts: "4".into(),
            batch_size: 64,
            shuffle: true,
            optimizer: "adam".into(),
            lr_bits: 0.01f32.to_bits(),
            hidden: 64,
            seed: 7,
            epochs: 5,
            objective: "nodeclass".into(),
        }
    }

    fn cursor(step: u64, epoch: usize) -> Cursor {
        Cursor { epoch, batch: 3, global_step: step, epoch_seen: 192, peak_compose_rows: 123 }
    }

    #[test]
    fn save_load_round_trip_is_exact() {
        let t = TempDir::new("ckpt-rt").unwrap();
        let (params, opt) = tiny_state();
        let losses = vec![0.9, 0.7];
        let ns = vec![111, 222];
        let dir = save_checkpoint(
            t.path(),
            0,
            &key(),
            &cursor(9, 2),
            &params,
            &opt,
            &losses,
            &ns,
            1.2345678901234567,
        )
        .unwrap();
        assert!(dir.ends_with(checkpoint_name(9)));
        let (ck, warnings) = load_latest(t.path()).unwrap().expect("checkpoint present");
        assert!(warnings.is_empty(), "{warnings:?}");
        assert_eq!(ck.name, checkpoint_name(9));
        assert_eq!(ck.manifest.cursor.batch, 3);
        assert_eq!(ck.manifest.cursor.epoch_seen, 192);
        assert_eq!(ck.losses, losses);
        assert_eq!(ck.epoch_ns, ns);
        assert_eq!(ck.loss_accum.to_bits(), 1.2345678901234567f64.to_bits());
        assert_eq!(ck.params.len(), 2);
        let (name, shape, data) = &ck.params[0];
        assert_eq!((name.as_str(), shape.as_slice()), ("table_a", &[4usize, 2][..]));
        assert_eq!(data, params.get("table_a"));
        assert_eq!(ck.moments.len(), 1);
        assert_eq!(ck.moments[0].1, vec![0.1; 8]);
        ck.manifest.run.ensure_matches(&key()).unwrap();
    }

    #[test]
    fn run_key_mismatch_names_the_field() {
        let a = key();
        let mut b = key();
        b.batch_size = 128;
        let err = a.ensure_matches(&b).unwrap_err().to_string();
        assert!(err.contains("batch_size"), "{err}");
        let mut c = key();
        c.lr_bits = 0.5f32.to_bits();
        let err = a.ensure_matches(&c).unwrap_err().to_string();
        assert!(err.contains("lr"), "{err}");
        let mut o = key();
        o.objective = "linkpred(dot,neg=1)".into();
        let err = a.ensure_matches(&o).unwrap_err().to_string();
        assert!(err.contains("objective"), "{err}");
    }

    #[test]
    fn pre_objective_manifests_deserialize_as_nodeclass() {
        // a RunKey written before the objective field existed (PR 7 and
        // earlier) must keep loading — and must mean node classification
        let legacy = r#"{
            "dataset": "synth-arxiv", "method": "full", "fanouts": "4",
            "batch_size": 64, "shuffle": true, "optimizer": "sgd",
            "lr_bits": 1036831949, "hidden": 0, "seed": 7, "epochs": 5
        }"#;
        let k: RunKey = serde_json::from_str(legacy).unwrap();
        assert_eq!(k.objective, "nodeclass");
    }

    #[test]
    fn retention_keeps_newest_k() {
        let t = TempDir::new("ckpt-keep").unwrap();
        let (params, opt) = tiny_state();
        for step in [3u64, 6, 9, 12] {
            save_checkpoint(t.path(), 2, &key(), &cursor(step, 0), &params, &opt, &[], &[], 0.0)
                .unwrap();
        }
        let mut names = checkpoint_dir_names(t.path()).unwrap();
        names.sort();
        assert_eq!(names, vec![checkpoint_name(9), checkpoint_name(12)]);
        let (ck, _) = load_latest(t.path()).unwrap().unwrap();
        assert_eq!(ck.name, checkpoint_name(12));
    }

    #[test]
    fn empty_root_is_a_fresh_run() {
        let t = TempDir::new("ckpt-empty").unwrap();
        assert!(load_latest(t.path()).unwrap().is_none());
        assert!(load_latest(&t.path().join("never-created")).unwrap().is_none());
    }

    #[test]
    fn torn_latest_falls_back_to_previous_intact() {
        let t = TempDir::new("ckpt-torn").unwrap();
        let (params, opt) = tiny_state();
        save_checkpoint(t.path(), 0, &key(), &cursor(5, 0), &params, &opt, &[], &[], 0.5).unwrap();
        save_checkpoint(t.path(), 0, &key(), &cursor(10, 0), &params, &opt, &[], &[], 0.6).unwrap();
        // corrupt the newest checkpoint's first param section
        let victim = t.path().join(checkpoint_name(10)).join("param__table_a.bin");
        let mut bytes = fs::read(&victim).unwrap();
        bytes[0] ^= 0xFF;
        fs::write(&victim, &bytes).unwrap();
        let (ck, warnings) = load_latest(t.path()).unwrap().unwrap();
        assert_eq!(ck.name, checkpoint_name(5));
        assert_eq!(ck.loss_accum, 0.5);
        assert_eq!(warnings.len(), 1);
        assert!(
            warnings[0].contains("param__table_a") && warnings[0].contains("checksum"),
            "warning must name the bad section: {}",
            warnings[0]
        );
    }

    #[test]
    fn all_torn_is_an_error_not_a_silent_fresh_start() {
        let t = TempDir::new("ckpt-alltorn").unwrap();
        let (params, opt) = tiny_state();
        save_checkpoint(t.path(), 0, &key(), &cursor(5, 0), &params, &opt, &[], &[], 0.0).unwrap();
        fs::remove_file(t.path().join(checkpoint_name(5)).join(MANIFEST_FILE)).unwrap();
        let err = load_latest(t.path()).unwrap_err().to_string();
        assert!(err.contains("no intact checkpoint"), "{err}");
    }

    #[test]
    fn injected_faults_tear_nothing_visible() {
        let _g = fault::test_guard();
        let t = TempDir::new("ckpt-fault").unwrap();
        let (params, opt) = tiny_state();
        save_checkpoint(t.path(), 0, &key(), &cursor(1, 0), &params, &opt, &[], &[], 0.1).unwrap();
        for site in
            ["checkpoint.section", "checkpoint.manifest", "checkpoint.rename", "checkpoint.latest"]
        {
            fault::reset();
            fault::arm(&format!("{site}=1:err")).unwrap();
            let res =
                save_checkpoint(t.path(), 0, &key(), &cursor(2, 0), &params, &opt, &[], &[], 0.2);
            fault::reset();
            if site == "checkpoint.latest" {
                // the rename already happened: the new checkpoint is
                // published even though LATEST still names the old one,
                // and the fallback scan finds it
                assert!(res.is_err());
                let (ck, _) = load_latest(t.path()).unwrap().unwrap();
                assert_eq!(ck.name, checkpoint_name(2));
                let _ = fs::remove_dir_all(t.path().join(checkpoint_name(2)));
            } else {
                assert!(res.is_err(), "fault at {site} must surface");
                let (ck, warnings) = load_latest(t.path()).unwrap().unwrap();
                assert_eq!(ck.name, checkpoint_name(1), "fault at {site} tore the old checkpoint");
                assert!(warnings.is_empty(), "fault at {site}: {warnings:?}");
            }
        }
        assert_eq!(sweep_stale_temps(t.path()).unwrap(), 0, "temp dirs must be cleaned up");
    }
}
