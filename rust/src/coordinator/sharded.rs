//! Partition-sharded minibatch training with halo exchange.
//!
//! [`ShardedTrainer`] cuts the graph into `k` shards with the
//! multilevel partitioner ([`GraphShards`]), builds each shard a
//! **local** dataset (induced owned + one-hop-halo subgraph, remapped
//! labels, the global splits filtered to owned nodes in global split
//! order) and a **local** embedding plan whose position buckets are
//! aligned to the partition hierarchy: every table holds the shard's
//! own partition-aligned rows first, then a compact tail of replicated
//! **halo rows** — one per distinct `(owner shard, owner row)` a halo
//! node resolves to. Each shard's `NodePlan`/`PositionPlan` therefore
//! resolves only local + halo rows; no global table, optimizer state or
//! index array is ever materialized.
//!
//! Epochs run shard-parallel on the existing pipelined engine: one
//! [`MinibatchTrainer`] per shard advances exactly one epoch
//! ([`MinibatchTrainer::advance_to_epoch`]) on its own thread, then the
//! coordinator runs a **halo exchange** — copying every replicated
//! position/pool row from its owning shard's table into the replicas,
//! in fixed (shard, table, row) order — and, every `sync_every` epochs,
//! a **node-table sync** that refreshes per-node halo rows (`node_x`
//! identity rows for Full/PosFullEmb, `node_y` importance rows for
//! Intra) the same way. Halo rows also receive local gradient updates
//! between exchanges (halo nodes appear as sampled neighbors); the
//! exchange overwrites them with the owner's authoritative bits.
//!
//! Determinism ledger:
//! * **k = 1 bit parity** — the single shard owns `0..n` ascending, so
//!   the local graph, hierarchy, plan, splits and every seed stream are
//!   bit-identical to the un-sharded path; halo pull lists are empty,
//!   so the loss trajectory equals [`MinibatchTrainer::train`]'s bit
//!   for bit, serial and pipelined (`rust/tests/sharded.rs`).
//! * **halo-exchange ordering** — pull lists are built sorted and
//!   applied main-thread in shard id → table name → row order; no
//!   atomics, no races.
//! * **fixed (seed, k) determinism** — the partitioner, every per-shard
//!   trainer and the exchange are deterministic and thread-count
//!   independent, so repeated runs agree exactly.

use super::minibatch::{MinibatchOptions, MinibatchTrainer, Objective};
use crate::data::{Dataset, DatasetSpec, Splits, TaskKind};
use crate::embedding::{EmbeddingMethod, EmbeddingPlan, NodePlan, PositionPlan, TableShape};
use crate::hashing::HashFamily;
use crate::partition::{
    induced_subgraph_with_scratch, GraphShards, Hierarchy, HierarchyConfig, Shard,
};
use crate::sampler::{mix_seed, SamplerConfig};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One replicated row: copy the owner's `owner_row` of a table into
/// this shard's `local_row` of the same-named local table.
#[derive(Debug, Clone)]
struct HaloPull {
    owner: u32,
    owner_row: u32,
    local_row: u32,
}

/// All pulls for one named table on one shard.
#[derive(Debug, Clone)]
struct PullSet {
    name: String,
    pulls: Vec<HaloPull>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PullKind {
    /// Partition-aligned tables: position levels + intra pools
    /// (refreshed every epoch).
    Tables,
    /// Per-node rows: `node_x` identity rows / `node_y` importance rows
    /// (refreshed every `sync_every` epochs).
    Nodes,
}

/// Everything one shard owns: its local dataset + plan and its halo
/// pull lists.
struct ShardPart {
    dataset: Dataset,
    plan: EmbeddingPlan,
    owned_nodes: usize,
    halo_nodes: usize,
    table_pulls: Vec<PullSet>,
    node_pulls: Vec<PullSet>,
}

impl ShardPart {
    fn pull_sets(&self, kind: PullKind) -> &[PullSet] {
        match kind {
            PullKind::Tables => &self.table_pulls,
            PullKind::Nodes => &self.node_pulls,
        }
    }
}

/// Per-shard statistics of one sharded run.
#[derive(Debug, Clone)]
pub struct ShardStats {
    /// Shard id.
    pub shard: usize,
    /// Nodes this shard owns.
    pub owned_nodes: usize,
    /// One-hop halo replicas resident on this shard.
    pub halo_nodes: usize,
    /// Undirected edges in the local induced subgraph.
    pub local_edges: u64,
    /// Training seed nodes per epoch on this shard.
    pub train_seeds: usize,
    /// Resident embedding-table bytes (`plan.num_params() × 4`): the
    /// shard's entire optimizer-visible table footprint.
    pub resident_table_bytes: u64,
    /// Rows refreshed by one full exchange (tables + node rows).
    pub halo_rows: usize,
    /// Bytes pulled by one per-epoch table exchange.
    pub halo_bytes_per_exchange: u64,
    /// Bytes pulled by one periodic node-table sync.
    pub node_sync_bytes: u64,
    /// Training seeds per second (seeds/epoch over mean epoch wall).
    pub nodes_per_sec: f64,
    /// Per-epoch mean losses on this shard.
    pub losses: Vec<f64>,
}

/// Result of a sharded training run.
#[derive(Debug, Clone)]
pub struct ShardedOutcome {
    /// Number of shards trained.
    pub k: usize,
    /// Weighted edge cut the sharding pays.
    pub edge_cut: f64,
    /// FullEmb reference bytes at this (n, d): `n × d × 4`.
    pub full_table_bytes: u64,
    /// Largest per-shard resident table bytes.
    pub peak_resident_table_bytes: u64,
    /// Total bytes moved by all halo exchanges + node syncs.
    pub halo_bytes_total: u64,
    /// Number of per-epoch table exchanges performed.
    pub exchanges: usize,
    /// Owned-node-weighted validation metric across shards.
    pub val_metric: f64,
    /// Owned-node-weighted test metric across shards.
    pub test_metric: f64,
    /// Per-epoch aggregate loss: at k = 1 exactly shard 0's losses
    /// (bit-parity with the un-sharded trainer); at k > 1 the
    /// seed-weighted mean across shards.
    pub losses: Vec<f64>,
    /// Wall-clock time of the whole run.
    pub wall: Duration,
    /// Per-shard statistics, indexed by shard id.
    pub shards: Vec<ShardStats>,
}

/// Shard-parallel minibatch trainer (see the module docs).
pub struct ShardedTrainer {
    parts: Vec<ShardPart>,
    cfg: SamplerConfig,
    opts: MinibatchOptions,
    sync_every: usize,
    k: usize,
    edge_cut: f64,
    full_table_bytes: u64,
}

impl ShardedTrainer {
    /// Shard `ds` into `shards` parts and prepare per-shard datasets,
    /// partition-aligned plans and halo pull lists.
    ///
    /// `hier_k` is the branching factor of each shard's position
    /// hierarchy (ignored for `full`). `sync_every` is the node-table
    /// sync period in epochs (`0` disables periodic sync; the initial
    /// pre-epoch sync always runs). Supported methods: `full`,
    /// `posemb`, `posfullemb`, `intra`; supported objective: node
    /// classification. Checkpointing / artifact saving are per-trainer
    /// features the sharded driver does not forward — leave them unset.
    pub fn new(
        ds: &Dataset,
        method: &EmbeddingMethod,
        hier_k: usize,
        shards: usize,
        sync_every: usize,
        cfg: SamplerConfig,
        opts: MinibatchOptions,
    ) -> Result<Self> {
        if !matches!(opts.objective, Objective::NodeClassification) {
            bail!("sharded training supports node classification only");
        }
        if opts.checkpoint.is_some() || opts.save_model.is_some() || opts.resume {
            bail!("sharded training does not support checkpointing or artifact saving");
        }
        if !supported_method(method) {
            bail!("sharded training supports full, posemb, posfullemb and intra (got {method})");
        }
        if method.needs_hierarchy() && hier_k < 2 {
            bail!("position methods need a hierarchy branching factor k >= 2");
        }
        let n = ds.graph.num_nodes();
        let d = ds.spec.d;
        let shard_seed = mix_seed(&[opts.seed, 0x54A2D]);
        let cut = GraphShards::build(&ds.graph, shards, shard_seed);

        // Per-shard position hierarchies over the OWNED induced
        // subgraph (halo excluded: halo nodes take their owner's
        // buckets, which is what makes the tables partition-aligned
        // across shards). At k = 1 the owned subgraph is the input
        // graph bit for bit, so the hierarchy matches the global one.
        let mut scratch = vec![u32::MAX; n];
        let hierarchies: Vec<Option<Hierarchy>> = cut
            .shards
            .iter()
            .map(|s| {
                method.needs_hierarchy().then(|| {
                    let owned_graph =
                        induced_subgraph_with_scratch(&ds.graph, &s.owned, &mut scratch);
                    Hierarchy::build(&owned_graph, &HierarchyConfig::new(hier_k, method.levels()))
                })
            })
            .collect();
        drop(scratch);

        let mut parts = Vec::with_capacity(shards);
        for shard in &cut.shards {
            let (plan, table_pulls, node_pulls) =
                shard_plan(method, d, opts.seed, shard, &cut.assignment, &cut.shards, &hierarchies);
            let dataset = shard_dataset(ds, shard, &cut.assignment);
            if dataset.splits.train.is_empty() {
                bail!(
                    "shard {} owns {} nodes but no training nodes — use fewer shards",
                    shard.id,
                    shard.owned.len()
                );
            }
            parts.push(ShardPart {
                dataset,
                plan,
                owned_nodes: shard.owned.len(),
                halo_nodes: shard.halo.len(),
                table_pulls,
                node_pulls,
            });
        }
        Ok(ShardedTrainer {
            parts,
            cfg,
            opts,
            sync_every,
            k: shards,
            edge_cut: cut.edge_cut,
            full_table_bytes: (n * d * 4) as u64,
        })
    }

    /// Number of shards.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Weighted edge cut of the sharding.
    pub fn edge_cut(&self) -> f64 {
        self.edge_cut
    }

    /// Run shard-parallel epochs with per-epoch halo exchange and
    /// periodic node-table sync, then evaluate each shard on its owned
    /// val/test nodes.
    pub fn train(&self) -> Result<ShardedOutcome> {
        let t0 = Instant::now();
        let mut trainers: Vec<MinibatchTrainer<'_>> = Vec::with_capacity(self.parts.len());
        for p in &self.parts {
            trainers.push(MinibatchTrainer::new(
                &p.dataset,
                &p.plan,
                self.cfg.clone(),
                self.opts.clone(),
            )?);
        }
        let mut halo_bytes_total = 0u64;
        let mut exchanges = 0usize;
        // Seed every halo row with the owner's initial bits so epoch 1
        // composes owner parameters, not local random init. No-op at
        // k = 1 (every pull list is empty).
        halo_bytes_total += apply_pulls(&mut trainers, &self.parts, PullKind::Tables);
        halo_bytes_total += apply_pulls(&mut trainers, &self.parts, PullKind::Nodes);
        for epoch in 0..self.opts.epochs {
            let target = epoch + 1;
            std::thread::scope(|scope| -> Result<()> {
                let handles: Vec<_> = trainers
                    .iter_mut()
                    .map(|t| scope.spawn(move || t.advance_to_epoch(target)))
                    .collect();
                for (s, h) in handles.into_iter().enumerate() {
                    match h.join() {
                        Ok(r) => r.with_context(|| format!("shard {s} failed in epoch {epoch}"))?,
                        Err(_) => bail!("shard {s} trainer thread panicked in epoch {epoch}"),
                    }
                }
                Ok(())
            })?;
            halo_bytes_total += apply_pulls(&mut trainers, &self.parts, PullKind::Tables);
            exchanges += 1;
            if self.sync_every > 0 && target % self.sync_every == 0 {
                halo_bytes_total += apply_pulls(&mut trainers, &self.parts, PullKind::Nodes);
            }
        }

        // Owned-node-weighted evaluation: each shard scores only the
        // val/test nodes it owns, so every global fold node is scored
        // exactly once.
        let fold_metric = |fold: fn(&Splits) -> &Vec<u32>| -> Result<f64> {
            let (mut num, mut den) = (0.0f64, 0usize);
            for (p, t) in self.parts.iter().zip(&trainers) {
                let nodes = fold(&p.dataset.splits);
                if nodes.is_empty() {
                    continue;
                }
                num += t.evaluate(nodes)? * nodes.len() as f64;
                den += nodes.len();
            }
            Ok(if den == 0 { 0.0 } else { num / den as f64 })
        };
        let val_metric = fold_metric(|s| &s.val)?;
        let test_metric = fold_metric(|s| &s.test)?;

        let shards: Vec<ShardStats> = self
            .parts
            .iter()
            .zip(&trainers)
            .enumerate()
            .map(|(s, (p, t))| {
                let ns = t.completed_epoch_ns();
                let mean_ns = if ns.is_empty() {
                    0.0
                } else {
                    ns.iter().sum::<u64>() as f64 / ns.len() as f64
                };
                let seeds = t.seeds_per_epoch();
                let table_bytes: u64 =
                    p.table_pulls.iter().map(|ps| set_bytes(ps, &trainers[s])).sum();
                let node_bytes: u64 =
                    p.node_pulls.iter().map(|ps| set_bytes(ps, &trainers[s])).sum();
                let halo_rows = p
                    .table_pulls
                    .iter()
                    .chain(&p.node_pulls)
                    .map(|ps| ps.pulls.len())
                    .sum::<usize>();
                ShardStats {
                    shard: s,
                    owned_nodes: p.owned_nodes,
                    halo_nodes: p.halo_nodes,
                    local_edges: p.dataset.graph.num_edges() as u64,
                    train_seeds: seeds,
                    resident_table_bytes: (p.plan.num_params() * 4) as u64,
                    halo_rows,
                    halo_bytes_per_exchange: table_bytes,
                    node_sync_bytes: node_bytes,
                    nodes_per_sec: if mean_ns > 0.0 {
                        seeds as f64 / (mean_ns / 1e9)
                    } else {
                        0.0
                    },
                    losses: t.losses().to_vec(),
                }
            })
            .collect();

        // k = 1 hands shard 0's trajectory through untouched (the bit
        // parity pin); k > 1 reports the seed-weighted epoch mean.
        let losses: Vec<f64> = if self.k == 1 {
            shards[0].losses.clone()
        } else {
            let total: f64 = shards.iter().map(|s| s.train_seeds as f64).sum();
            (0..self.opts.epochs)
                .map(|e| {
                    shards
                        .iter()
                        .map(|s| s.losses.get(e).copied().unwrap_or(0.0) * s.train_seeds as f64)
                        .sum::<f64>()
                        / total
                })
                .collect()
        };

        Ok(ShardedOutcome {
            k: self.k,
            edge_cut: self.edge_cut,
            full_table_bytes: self.full_table_bytes,
            peak_resident_table_bytes: shards
                .iter()
                .map(|s| s.resident_table_bytes)
                .max()
                .unwrap_or(0),
            halo_bytes_total,
            exchanges,
            val_metric,
            test_metric,
            losses,
            wall: t0.elapsed(),
            shards,
        })
    }
}

fn supported_method(method: &EmbeddingMethod) -> bool {
    matches!(
        method,
        EmbeddingMethod::Full
            | EmbeddingMethod::PosEmb { .. }
            | EmbeddingMethod::PosFullEmb { .. }
            | EmbeddingMethod::PosHashEmbIntra { .. }
    )
}

/// Bytes one pull set moves per exchange.
fn set_bytes(ps: &PullSet, trainer: &MinibatchTrainer<'_>) -> u64 {
    if ps.pulls.is_empty() {
        return 0;
    }
    let cols = trainer.params().shape(&ps.name)[1];
    (ps.pulls.len() * cols * 4) as u64
}

/// One halo exchange: copy every replicated row from its owner's table
/// into the replica, in fixed (shard, table, row) order. Two passes —
/// stage all reads, then write — so owners are read immutably before
/// any replica is touched. Returns bytes moved.
fn apply_pulls(trainers: &mut [MinibatchTrainer<'_>], parts: &[ShardPart], kind: PullKind) -> u64 {
    let mut staged: Vec<Vec<f32>> = Vec::new();
    for part in parts {
        for set in part.pull_sets(kind) {
            if set.pulls.is_empty() {
                staged.push(Vec::new());
                continue;
            }
            let cols = trainers[set.pulls[0].owner as usize].params().shape(&set.name)[1];
            let mut buf = Vec::with_capacity(set.pulls.len() * cols);
            for p in &set.pulls {
                let src = trainers[p.owner as usize].params().get(&set.name);
                buf.extend_from_slice(
                    &src[p.owner_row as usize * cols..(p.owner_row as usize + 1) * cols],
                );
            }
            staged.push(buf);
        }
    }
    let mut bytes = 0u64;
    let mut staged = staged.into_iter();
    for (s, part) in parts.iter().enumerate() {
        for set in part.pull_sets(kind) {
            let buf = staged.next().expect("one staged buffer per pull set");
            if set.pulls.is_empty() {
                continue;
            }
            let cols = buf.len() / set.pulls.len();
            let dst = trainers[s].params_mut().get_mut(&set.name);
            for (i, p) in set.pulls.iter().enumerate() {
                dst[p.local_row as usize * cols..(p.local_row as usize + 1) * cols]
                    .copy_from_slice(&buf[i * cols..(i + 1) * cols]);
            }
            bytes += buf.len() as u64 * 4;
        }
    }
    bytes
}

/// The shard-local dataset: induced owned+halo graph, remapped labels
/// and communities, and the global splits filtered to owned nodes **in
/// global split order** (so at k = 1 the batcher sees exactly the
/// global schedule).
fn shard_dataset(ds: &Dataset, shard: &Shard, assignment: &[u32]) -> Dataset {
    let n_local = shard.locals.len();
    let classes = ds.spec.classes;
    let labels: Vec<u32> = match ds.spec.task {
        TaskKind::MultiClass => shard.locals.iter().map(|&g| ds.labels[g as usize]).collect(),
        TaskKind::MultiLabel => shard
            .locals
            .iter()
            .flat_map(|&g| {
                let g = g as usize;
                ds.labels[g * classes..(g + 1) * classes].iter().copied()
            })
            .collect(),
    };
    let communities: Vec<u32> =
        shard.locals.iter().map(|&g| ds.communities[g as usize]).collect();
    let map_fold = |fold: &[u32]| -> Vec<u32> {
        fold.iter()
            .filter(|&&g| assignment[g as usize] == shard.id as u32)
            .map(|&g| shard.local_of(g).expect("owned node is resident"))
            .collect()
    };
    let splits = Splits {
        train: map_fold(&ds.splits.train),
        val: map_fold(&ds.splits.val),
        test: map_fold(&ds.splits.test),
    };
    let spec = DatasetSpec { n: n_local, ..ds.spec.clone() };
    Dataset { spec, graph: shard.graph.clone().into(), communities, labels, splits }
}

/// Build one shard's partition-aligned plan plus its halo pull lists.
///
/// Layout contract per table: the shard's own rows occupy the same
/// index range the un-sharded plan would give them (position level `j`:
/// `0..m_j`; intra pool: `0..m_0·c`; per-node tables: local ids), and
/// replicated halo rows are appended after, one per distinct
/// `(owner, owner_row)`, in sorted order. At k = 1 no halo exists and
/// the plan equals `EmbeddingPlan::build`'s output bit for bit — node
/// hashes are keyed by **global** node id precisely so owner and
/// replica (and the k = 1 global plan) agree on every bucket.
fn shard_plan(
    method: &EmbeddingMethod,
    d: usize,
    seed: u64,
    shard: &Shard,
    assignment: &[u32],
    all_shards: &[Shard],
    hierarchies: &[Option<Hierarchy>],
) -> (EmbeddingPlan, Vec<PullSet>, Vec<PullSet>) {
    assert!(d >= 4 && d % 4 == 0, "d must be a multiple of 4 for 3-level dims");
    let n_local = shard.locals.len();
    let levels = method.levels();
    let mut table_pulls: Vec<PullSet> = Vec::new();
    let mut node_pulls: Vec<PullSet> = Vec::new();
    let owned_index = |o: u32, gid: u32| -> usize {
        all_shards[o as usize].owned.binary_search(&gid).expect("node owned by its shard")
    };
    let bucket_of = |o: u32, j: usize, oi: usize| -> u32 {
        hierarchies[o as usize].as_ref().expect("owner hierarchy").shard_assignments(j)[oi]
    };

    let position = method.needs_hierarchy().then(|| {
        let hs = hierarchies[shard.id].as_ref().expect("own hierarchy built");
        let mut tables = Vec::with_capacity(levels);
        let mut z = Vec::with_capacity(levels);
        for j in 0..levels {
            let mj = hs.m[j];
            // distinct (owner, owner bucket) pairs over the halo,
            // sorted — the appended replica rows and their pull order
            let mut extra: BTreeMap<(u32, u32), u32> = BTreeMap::new();
            for &v in &shard.halo {
                let o = assignment[v as usize];
                let oi = owned_index(o, v);
                let b = bucket_of(o, j, oi);
                extra.insert((o, b), 0);
            }
            for (idx, slot) in extra.values_mut().enumerate() {
                *slot = idx as u32;
            }
            let own_z = hs.shard_assignments(j);
            let mut zj = vec![0u32; n_local];
            for (l, &gid) in shard.locals.iter().enumerate() {
                let o = assignment[gid as usize];
                zj[l] = if o == shard.id as u32 {
                    own_z[owned_index(o, gid)]
                } else {
                    let b = bucket_of(o, j, owned_index(o, gid));
                    mj as u32 + extra[&(o, b)]
                };
            }
            let pulls: Vec<HaloPull> = extra
                .iter()
                .map(|(&(owner, owner_row), &idx)| HaloPull {
                    owner,
                    owner_row,
                    local_row: mj as u32 + idx,
                })
                .collect();
            tables.push(TableShape {
                name: format!("pos_{j}"),
                rows: mj + pulls.len(),
                cols: (d >> j).max(1),
            });
            table_pulls.push(PullSet { name: format!("pos_{j}"), pulls });
            z.push(zj);
        }
        PositionPlan { tables, z }
    });

    let per_node_pulls = || -> Vec<HaloPull> {
        shard
            .halo
            .iter()
            .map(|&v| {
                let o = assignment[v as usize];
                HaloPull {
                    owner: o,
                    owner_row: all_shards[o as usize].local_of(v).expect("owner resident"),
                    local_row: shard.local_of(v).expect("halo resident"),
                }
            })
            .collect()
    };

    let node = match method {
        EmbeddingMethod::Full | EmbeddingMethod::PosFullEmb { .. } => {
            node_pulls.push(PullSet { name: "node_x".into(), pulls: per_node_pulls() });
            Some(NodePlan {
                table: TableShape { name: "node_x".into(), rows: n_local, cols: d },
                h: 1,
                node_major: (0..n_local as u32).collect(),
                learned_weights: false,
            })
        }
        EmbeddingMethod::PosHashEmbIntra { compression, h, .. } => {
            let (c, h) = (*compression, *h);
            let hs = hierarchies[shard.id].as_ref().expect("own hierarchy built");
            let m0 = hs.m[0];
            let family = HashFamily::new(seed);
            let fns: Vec<_> = (0..h).map(|t| family.function(t as u64, c as u32)).collect();
            let pool_of = |s: usize, oi: usize, gid: u32, f: &crate::hashing::UniversalHash| {
                hierarchies[s].as_ref().expect("hierarchy").shard_assignments(0)[oi]
                    * c as u32
                    + f.hash(gid as u64)
            };
            let mut extra: BTreeMap<(u32, u32), u32> = BTreeMap::new();
            for &v in &shard.halo {
                let o = assignment[v as usize];
                let oi = owned_index(o, v);
                for f in &fns {
                    extra.insert((o, pool_of(o as usize, oi, v, f)), 0);
                }
            }
            for (idx, slot) in extra.values_mut().enumerate() {
                *slot = idx as u32;
            }
            let mut node_major = vec![0u32; n_local * h];
            for (l, &gid) in shard.locals.iter().enumerate() {
                let o = assignment[gid as usize];
                let oi = owned_index(o, gid);
                for (t, f) in fns.iter().enumerate() {
                    node_major[l * h + t] = if o == shard.id as u32 {
                        pool_of(shard.id, oi, gid, f)
                    } else {
                        (m0 * c) as u32 + extra[&(o, pool_of(o as usize, oi, gid, f))]
                    };
                }
            }
            let pulls: Vec<HaloPull> = extra
                .iter()
                .map(|(&(owner, owner_row), &idx)| HaloPull {
                    owner,
                    owner_row,
                    local_row: (m0 * c) as u32 + idx,
                })
                .collect();
            let rows = m0 * c + pulls.len();
            table_pulls.push(PullSet { name: "node_x".into(), pulls });
            node_pulls.push(PullSet { name: "node_y".into(), pulls: per_node_pulls() });
            Some(NodePlan {
                table: TableShape { name: "node_x".into(), rows, cols: d },
                h,
                node_major,
                learned_weights: true,
            })
        }
        _ => None,
    };

    let plan = EmbeddingPlan {
        method: method.clone(),
        n: n_local,
        d,
        position,
        node,
        dhe: None,
    };
    (plan, table_pulls, node_pulls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;
    use crate::embedding::EmbeddingPlan;
    use crate::partition::GraphShards;

    fn tiny_ds() -> Dataset {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 600;
        s.communities = 12;
        s.supers = 4;
        s.d = 16;
        Dataset::generate(&s)
    }

    #[test]
    fn k1_shard_plan_matches_global_plan_bit_for_bit() {
        let ds = tiny_ds();
        let n = ds.graph.num_nodes();
        let method = EmbeddingMethod::PosHashEmbIntra { levels: 2, compression: 5, h: 2 };
        let hier_k = 4;
        let cut = GraphShards::build(&ds.graph, 1, 99);
        let mut scratch = vec![u32::MAX; n];
        let owned_graph =
            induced_subgraph_with_scratch(&ds.graph, &cut.shards[0].owned, &mut scratch);
        let hiers =
            vec![Some(Hierarchy::build(&owned_graph, &HierarchyConfig::new(hier_k, 2)))];
        let (local, tp, np) =
            shard_plan(&method, 16, 7, &cut.shards[0], &cut.assignment, &cut.shards, &hiers);
        let global_h = Hierarchy::build(&ds.graph, &HierarchyConfig::new(hier_k, 2));
        let global = EmbeddingPlan::build(n, 16, &method, Some(&global_h), 7);
        assert_eq!(local.n, global.n);
        let (lp, gp) = (local.position.unwrap(), global.position.unwrap());
        assert_eq!(lp.z, gp.z);
        assert_eq!(lp.tables, gp.tables);
        let (ln, gn) = (local.node.unwrap(), global.node.unwrap());
        assert_eq!(ln.node_major, gn.node_major);
        assert_eq!(ln.table, gn.table);
        assert!(tp.iter().all(|s| s.pulls.is_empty()));
        assert!(np.iter().all(|s| s.pulls.is_empty()));
    }

    #[test]
    fn halo_rows_are_appended_and_resolved() {
        let ds = tiny_ds();
        let n = ds.graph.num_nodes();
        let method = EmbeddingMethod::PosEmb { levels: 2 };
        let cut = GraphShards::build(&ds.graph, 3, 5);
        let mut scratch = vec![u32::MAX; n];
        let hiers: Vec<Option<Hierarchy>> = cut
            .shards
            .iter()
            .map(|s| {
                let g = induced_subgraph_with_scratch(&ds.graph, &s.owned, &mut scratch);
                Some(Hierarchy::build(&g, &HierarchyConfig::new(3, 2)))
            })
            .collect();
        for shard in &cut.shards {
            let (plan, tp, _) =
                shard_plan(&method, 16, 1, shard, &cut.assignment, &cut.shards, &hiers);
            let pos = plan.position.unwrap();
            for (j, t) in pos.tables.iter().enumerate() {
                // every z entry resolves inside the local table
                assert!(pos.z[j].iter().all(|&b| (b as usize) < t.rows));
                // halo pulls land exactly on the appended tail
                for p in &tp[j].pulls {
                    assert!(p.local_row as usize >= hiers[shard.id].as_ref().unwrap().m[j]);
                    assert!((p.local_row as usize) < t.rows);
                    assert_ne!(p.owner as usize, shard.id);
                }
            }
        }
    }
}
