//! The training loop: full-batch epochs over the AOT train step with a
//! device-resident packed state vector, periodic evaluation and
//! best-validation tracking.
//!
//! Packed-state ABI (see `python/compile/train_step.py`): the whole
//! training state — parameters, Adam moments, step counter, last loss —
//! is ONE flat f32 vector. The train HLO maps `state -> state'`, so the
//! hot loop feeds each output buffer straight back as the next input:
//! zero host traffic except the loss probe.
//!
//! Host-side compose wiring: before uploading the initial state the
//! trainer (optionally, on by default) cross-checks the blocked
//! [`ComposeEngine`](crate::embedding::ComposeEngine) against the scalar
//! reference oracle on the exact plan being trained, so engine drift
//! aborts a run instead of silently diverging from what the HLO computes.

use super::params::init_full_params;
use super::statics::build_statics;
use crate::config::{materialize, Experiment};
use crate::data::{Splits, TaskKind};
use crate::embedding::{compose, MemoryReport};
use crate::metrics::{accuracy, mean_roc_auc};
use crate::runtime::{DeviceBuffer, Executable, HostTensor, Manifest, RuntimeClient};
use anyhow::{anyhow, bail, Context, Result};

/// Knobs for a training run.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Override the experiment's epoch count (None = use it).
    pub epochs: Option<usize>,
    /// Evaluate every this many epochs.
    pub eval_every: usize,
    /// Stop after this many evals without val improvement (0 = never).
    pub patience: usize,
    /// Print progress lines.
    pub verbose: bool,
    /// Cross-check ComposeEngine vs the reference oracle at startup.
    pub verify_compose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            epochs: None,
            eval_every: 5,
            patience: 6,
            verbose: false,
            verify_compose: true,
        }
    }
}

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    /// Experiment config name.
    pub experiment: String,
    /// Seed the run was trained with.
    pub seed: u64,
    /// Per-epoch training losses.
    pub losses: Vec<f32>,
    /// (epoch, val metric) curve.
    pub val_curve: Vec<(usize, f64)>,
    /// Best validation metric.
    pub val_metric: f64,
    /// Test metric at the best-validation point.
    pub test_metric: f64,
    /// Epochs actually run (early stopping may cut the budget short).
    pub epochs_run: usize,
    /// Embedding-layer memory report (paper's savings columns).
    pub memory: MemoryReport,
    /// Total wall time of the run.
    pub wall: std::time::Duration,
}

impl TrainOutcome {
    /// Paper-style summary line.
    pub fn row(&self) -> String {
        format!(
            "{:<34} seed={} test={:.3} val={:.3} params={} ({:.1}% savings) epochs={} [{:?}]",
            self.experiment,
            self.seed,
            self.test_metric,
            self.val_metric,
            self.memory.params,
            self.memory.savings_pct,
            self.epochs_run,
            self.wall
        )
    }
}

/// Train one experiment end to end on the PJRT runtime.
pub fn run_experiment(
    client: &RuntimeClient,
    manifest: &Manifest,
    e: &Experiment,
    seed: u64,
    opts: &TrainOptions,
) -> Result<TrainOutcome> {
    let t0 = std::time::Instant::now();
    let (ds, _hier, plan) = materialize(e, seed);
    let n = ds.graph.num_nodes();
    let classes = ds.spec.classes;

    let train_spec = manifest.get(&format!("{}.train", e.name))?;
    let eval_spec = manifest.get(&format!("{}.eval", e.name))?;
    let train_exe = client.load(manifest, train_spec)?;
    let eval_exe = client.load(manifest, eval_spec)?;

    // ---- packed initial state ----
    let store = init_full_params(&plan, e.model, classes, seed);
    if opts.verify_compose {
        compose::self_check(&plan, &store, 1e-5)
            .map_err(|msg| anyhow!("{}: compose engine self-check failed: {msg}", e.name))?;
    }
    let num_p = store.names().len();
    if num_p != train_spec.num_params {
        bail!(
            "{}: built {num_p} params but artifact expects {} — grid/artifact drift, re-run `make artifacts`",
            e.name,
            train_spec.num_params
        );
    }
    let psize: usize = store.names().iter().map(|n| store.get(n).len()).sum();
    let total = 3 * psize + 2;
    let state_spec = &train_spec.inputs[0];
    if state_spec.name != "state" || state_spec.shape != [total] {
        bail!(
            "{}: packed-state mismatch: built [{total}], artifact wants {}{:?}",
            e.name,
            state_spec.name,
            state_spec.shape
        );
    }
    let mut state_host = vec![0f32; total];
    let mut off = 0usize;
    for name in store.names() {
        let data = store.get(name);
        state_host[off..off + data.len()].copy_from_slice(data);
        off += data.len();
    }
    state_host[3 * psize] = 1.0; // 1-based Adam step counter
    let mut state = client.upload(&HostTensor::F32(state_host, vec![total]))?;

    // ---- statics, labels, mask ----
    let statics = build_statics(&ds, e.model, &plan);
    let mut static_bufs = Vec::with_capacity(statics.len());
    for (name, tensor) in &statics {
        let idx = train_spec.input_index(name).with_context(|| format!("static {name}"))?;
        tensor.check(&train_spec.inputs[idx])?;
        static_bufs.push(client.upload(tensor)?);
    }
    let labels_tensor = match ds.spec.task {
        TaskKind::MultiClass => HostTensor::I32(ds.labels_i32(), vec![n]),
        TaskKind::MultiLabel => {
            HostTensor::F32(ds.labels.iter().map(|&x| x as f32).collect(), vec![n, classes])
        }
    };
    let labels_buf = client.upload(&labels_tensor)?;
    let mask_buf =
        client.upload(&HostTensor::F32(Splits::mask_f32(&ds.splits.train, n), vec![n]))?;

    // ---- epoch loop ----
    let epochs = opts.epochs.unwrap_or(e.epochs);
    let mut losses = Vec::with_capacity(epochs);
    let mut val_curve = Vec::new();
    let mut best_val = f64::NEG_INFINITY;
    let mut best_test = 0.0f64;
    let mut stale = 0usize;
    let mut epochs_run = 0usize;

    for epoch in 0..epochs {
        let mut args: Vec<&DeviceBuffer> = Vec::with_capacity(static_bufs.len() + 3);
        args.push(&state);
        args.extend(static_bufs.iter());
        args.push(&labels_buf);
        args.push(&mask_buf);
        let mut outs = client
            .execute(&train_exe, &args)
            .map_err(|err| anyhow!("{}: train step: {err}", e.name))?;
        if outs.len() != 1 {
            bail!("{}: expected 1 state output, got {}", e.name, outs.len());
        }
        state = outs.swap_remove(0);
        epochs_run = epoch + 1;

        let is_eval = (epoch + 1) % opts.eval_every == 0 || epoch + 1 == epochs;
        // Loss probe. Downloading the packed state is a memcpy on the CPU
        // client; for big states (FullEmb on products: ~9 MB) probing
        // every epoch costs ~8% of the step (§Perf), so large states are
        // probed only at eval cadence.
        let probe_every_epoch = total < 400_000;
        if probe_every_epoch || is_eval {
            let snapshot = client.download_f32(&state)?;
            let loss = snapshot[3 * psize + 1];
            losses.push(loss);
            if !loss.is_finite() {
                bail!("{}: non-finite loss at epoch {epoch}", e.name);
            }
        }

        if is_eval {
            let loss = losses.last().copied().unwrap_or(f32::NAN);
            let logits = run_eval(client, &eval_exe, &state, &static_bufs)?;

            let (val, test) = score(&ds, &logits, classes);
            val_curve.push((epoch + 1, val));
            if opts.verbose {
                println!("  epoch {:>4}  loss {loss:.4}  val {val:.4}  test {test:.4}", epoch + 1);
            }
            if val > best_val {
                best_val = val;
                best_test = test;
                stale = 0;
            } else {
                stale += 1;
                if opts.patience > 0 && stale >= opts.patience {
                    break;
                }
            }
        }
    }

    Ok(TrainOutcome {
        experiment: e.name.clone(),
        seed,
        losses,
        val_curve,
        val_metric: best_val,
        test_metric: best_test,
        epochs_run,
        memory: MemoryReport::from_plan(&plan),
        wall: t0.elapsed(),
    })
}

fn run_eval(
    client: &RuntimeClient,
    eval_exe: &Executable,
    state: &DeviceBuffer,
    static_bufs: &[DeviceBuffer],
) -> Result<Vec<f32>> {
    let mut args: Vec<&DeviceBuffer> = Vec::with_capacity(1 + static_bufs.len());
    args.push(state);
    args.extend(static_bufs.iter());
    let outs = client.execute(eval_exe, &args).map_err(|err| anyhow!("eval step: {err}"))?;
    client.download_f32(&outs[0])
}

/// (val, test) metric from logits.
fn score(ds: &crate::data::Dataset, logits: &[f32], classes: usize) -> (f64, f64) {
    match ds.spec.task {
        TaskKind::MultiClass => (
            accuracy(logits, classes, &ds.labels, &ds.splits.val),
            accuracy(logits, classes, &ds.labels, &ds.splits.test),
        ),
        TaskKind::MultiLabel => (
            mean_roc_auc(logits, classes, &ds.labels, &ds.splits.val),
            mean_roc_auc(logits, classes, &ds.labels, &ds.splits.test),
        ),
    }
}
