//! Host-side minibatch training on `ComposeEngine::compose_batch`.
//!
//! The paper's scaling argument is that the embedding layer's parameters
//! fit in memory even when the composed `n × d` input matrix does not —
//! so the trainer must never materialize that matrix. This module closes
//! the loop: a GraphSAGE-style loop ([`MinibatchTrainer`]) draws seed
//! batches from the train split ([`SeedBatcher`]), samples a bounded
//! one-hop neighborhood per batch ([`NeighborSampler`]), composes
//! **only the block's rows** with
//! [`ComposeEngine::compose_batch`],
//! runs a one-layer mean-aggregation head (`logits = W_self·v_i +
//! W_neigh·mean_{j∈N(i)} v_j + b`), and backpropagates through the
//! compose (Eq. 7/11/12) into the embedding tables with a sparse
//! SGD/Adam step ([`Optimizer`]). Peak compose allocation is
//! `block_rows × d`, tracked as [`MinibatchOutcome::peak_compose_rows`]
//! and asserted `< n` by `rust/tests/minibatch.rs`.
//!
//! **Pipelined execution.** By default the trainer overlaps and
//! parallelizes every phase without changing a single bit of the
//! result: a [`BlockPrefetcher`] samples batch *b + 1* on a dedicated
//! thread while batch *b* is stepped (blocks are keyed per
//! `(seed, epoch, batch, node)`, so sampling ahead cannot change them,
//! and they arrive in batch order through a bounded channel with a
//! recycle pool); the step itself fans out on rayon — per-seed forward
//! rows are disjoint, `dL/dv` uses an order-preserving reverse-topology
//! scatter, embedding gradients accumulate into row-range
//! [`GradBuffer`] shards that merge touch lists in fixed shard order,
//! and the optimizer updates touched rows independently. The
//! `MinibatchOptions { parallel: false, prefetch: 0, .. }` path keeps
//! the original serial step in-tree as the oracle;
//! `tests/parallel_train.rs` pins exact (bit-for-bit) loss-trajectory
//! equality between the two at 1 and 4 threads.
//!
//! **Oracle parity.** [`train_full_batch`] is the same model trained the
//! classic way — `compose_all`, dense `n × d` activations — kept as the
//! reference implementation. In the oracle configuration
//! ([`SamplerConfig::oracle`]: fanout = ∞, one batch = the whole train
//! split, no shuffle) the minibatch path performs the same update: the
//! composed rows are bit-identical (compose-engine parity), neighbor
//! aggregation and gradient scatter follow the same order, so the two
//! loss trajectories agree within 1e-5 per epoch (pinned by proptest).
//!
//! DHE is the one method family not supported here: it has no embedding
//! tables to scatter gradients into (an MLP backward would be needed),
//! and the paper itself could not scale DHE to its largest graph.

use super::optim::{GradBuffer, Optimizer, OptimizerKind};
use crate::data::{Dataset, TaskKind};
use crate::embedding::{
    compose, init_params, ComposeEngine, ComposeOptions, EmbeddingPlan, ParamStore,
};
use crate::metrics::{accuracy, mean_roc_auc};
use crate::sampler::{
    mix_seed, BlockPrefetcher, Fanout, NeighborSampler, SampledBlock, SamplerConfig, SeedBatcher,
};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Row-range shards per gradient table in the parallel scatter phase —
/// a fixed constant (not the pool size), so the work decomposition and
/// therefore the touch-merge order never depend on thread count.
const SCATTER_SHARDS: usize = 16;

/// Knobs for a host-side training run (minibatch or full-batch).
#[derive(Debug, Clone)]
pub struct MinibatchOptions {
    /// Training epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// Update rule (SGD, or Adam with lazy sparse moments).
    pub optimizer: OptimizerKind,
    /// Seed for parameter init, epoch shuffles and neighbor draws.
    pub seed: u64,
    /// Print a progress line per epoch.
    pub verbose: bool,
    /// Cross-check the compose engine at startup: full scalar-oracle
    /// parity at small `n·d`, a bounded parallel-vs-serial probe beyond
    /// (the minibatch trainer never materializes `n × d`, not even to
    /// verify itself; the full-batch trainer always uses the full check).
    pub verify_compose: bool,
    /// Run the forward/backward/apply phases of every step on the rayon
    /// pool. The parallel step is engineered to be **bit-identical** to
    /// the serial one (disjoint output ownership, order-preserving
    /// reverse scatter, row-range gradient sharding — see the module
    /// docs), so this knob trades nothing but wall time; `false` keeps
    /// the original serial step in-tree as the oracle
    /// (`tests/parallel_train.rs` pins serial ≡ parallel at 1 and 4
    /// threads).
    pub parallel: bool,
    /// Sampled blocks prefetched ahead of the trainer by a dedicated
    /// sampler thread (see [`BlockPrefetcher`]); `0` samples on the
    /// calling thread exactly as the serial loop always has. Prefetching
    /// cannot change results — blocks are keyed per
    /// `(seed, epoch, batch, node)` and delivered in batch order.
    pub prefetch: usize,
}

impl Default for MinibatchOptions {
    fn default() -> Self {
        MinibatchOptions {
            epochs: 20,
            lr: 0.01,
            optimizer: OptimizerKind::Adam,
            seed: 0,
            verbose: false,
            verify_compose: true,
            parallel: true,
            prefetch: 2,
        }
    }
}

/// Result of one host-side training run.
#[derive(Debug, Clone)]
pub struct MinibatchOutcome {
    /// Per-epoch mean training loss (seed-weighted; each batch's loss is
    /// measured on the parameters it starts from).
    pub losses: Vec<f64>,
    /// Wall time of each epoch in nanoseconds.
    pub epoch_ns: Vec<u64>,
    /// Validation metric after the final epoch (accuracy or ROC-AUC).
    pub val_metric: f64,
    /// Test metric after the final epoch.
    pub test_metric: f64,
    /// Largest number of rows composed for a single training batch. The
    /// minibatch trainer's memory invariant: strictly less than `n`
    /// whenever batches are smaller than the graph.
    pub peak_compose_rows: usize,
    /// Seed nodes visited per epoch (train-split size).
    pub seeds_per_epoch: usize,
    /// Batches per epoch.
    pub batches_per_epoch: usize,
    /// Total training wall time.
    pub wall: Duration,
}

impl MinibatchOutcome {
    /// One-line summary.
    pub fn row(&self) -> String {
        format!(
            "epochs={} loss {:.4} -> {:.4} val={:.3} test={:.3} peak_rows={} [{:?}]",
            self.losses.len(),
            self.losses.first().copied().unwrap_or(f64::NAN),
            self.losses.last().copied().unwrap_or(f64::NAN),
            self.val_metric,
            self.test_metric,
            self.peak_compose_rows,
            self.wall
        )
    }
}

/// Neighbor-sampled minibatch trainer over a borrowed (dataset, plan).
///
/// Owns the parameters, the optimizer state and all reusable scratch
/// buffers; the compose buffer grows to the largest sampled block and is
/// never `n × d`. Runs are bit-identical across rayon thread counts: the
/// sampler is keyed per `(seed, epoch, batch, node)` and the compose
/// engine is bitwise thread-count-independent.
pub struct MinibatchTrainer<'a> {
    ds: &'a Dataset,
    engine: ComposeEngine<'a>,
    cfg: SamplerConfig,
    opts: MinibatchOptions,
    params: ParamStore,
    opt: Optimizer,
    grads: BTreeMap<String, GradBuffer>,
    batcher: SeedBatcher,
    /// Inline sampler for the un-prefetched path, built lazily on first
    /// use: the default pipelined path samples on the prefetch thread
    /// (which owns its own sampler), and the `O(n)` global→local
    /// scratch should not sit allocated twice at large `n`.
    sampler: Option<NeighborSampler<'a>>,
    /// Composed block rows (`block_rows × d`, reused across batches).
    x: Vec<f32>,
    /// Per-seed neighbor means (`num_seeds × d`).
    nbar: Vec<f32>,
    /// Per-seed logits (`num_seeds × classes`).
    logits: Vec<f32>,
    /// Per-seed `dL/dlogits`.
    glogits: Vec<f32>,
    /// Per-block-row `dL/dv` (`block_rows × d`).
    dx: Vec<f32>,
    /// One seed's `W_neigh·g` back-signal (`d`) — serial path only.
    dn: Vec<f32>,
    /// Sampler stream seed (shared verbatim with the prefetcher so
    /// prefetched blocks are bit-identical to inline sampling).
    sampler_seed: u64,
    /// Per-seed losses (parallel path: computed concurrently, summed in
    /// seed order so the epoch loss matches the serial path's bits).
    losses_buf: Vec<f64>,
    /// Per-seed `W_self·g` back-signals (`num_seeds × d`, parallel path).
    dself: Vec<f32>,
    /// Per-seed `W_neigh·g` back-signals (`num_seeds × d`, parallel path).
    dnbuf: Vec<f32>,
    /// Per-seed `1 / |sampled neighbors|` (0 when isolated).
    inv_deg: Vec<f32>,
    /// Reverse-topology CSR offsets (`block_rows + 1`).
    rev_ptr: Vec<u32>,
    /// Reverse-topology fill cursors (scratch for the counting sort).
    rev_cur: Vec<u32>,
    /// Reverse-topology entries: for each block row, the seeds that
    /// scatter into it (ascending), with the row's own seed id doubling
    /// as the "add your own `W_self` signal here" marker.
    rev_idx: Vec<u32>,
    peak_compose_rows: usize,
}

impl<'a> MinibatchTrainer<'a> {
    /// Build a trainer. Fails on DHE plans (no tables to scatter into)
    /// and, when `verify_compose` is on, on compose-engine drift.
    pub fn new(
        ds: &'a Dataset,
        plan: &'a EmbeddingPlan,
        cfg: SamplerConfig,
        opts: MinibatchOptions,
    ) -> Result<Self> {
        if plan.dhe.is_some() {
            bail!("minibatch training does not support DHE (no embedding tables to train)");
        }
        if plan.n != ds.graph.num_nodes() {
            bail!("plan is for n = {} but dataset has {} nodes", plan.n, ds.graph.num_nodes());
        }
        if ds.splits.train.is_empty() {
            bail!("dataset has no training nodes to batch");
        }
        let params = init_host_params(plan, ds.spec.classes, opts.seed);
        if opts.verify_compose {
            verify_compose_bounded(plan, &params)
                .map_err(|msg| anyhow!("compose engine self-check failed: {msg}"))?;
        }
        let grads = make_grad_buffers(plan, ds.spec.classes);
        let batcher = SeedBatcher::new(
            &ds.splits.train,
            cfg.batch_size,
            cfg.shuffle,
            mix_seed(&[opts.seed, 0x5EED5]),
        );
        let sampler_seed = mix_seed(&[opts.seed, 0x54AFF]);
        let mut opt = Optimizer::new(opts.optimizer, opts.lr);
        opt.parallel = opts.parallel;
        let dn = vec![0.0; plan.d];
        Ok(MinibatchTrainer {
            ds,
            engine: ComposeEngine::new(plan),
            cfg,
            opts,
            params,
            opt,
            grads,
            batcher,
            sampler: None,
            x: Vec::new(),
            nbar: Vec::new(),
            logits: Vec::new(),
            glogits: Vec::new(),
            dx: Vec::new(),
            dn,
            sampler_seed,
            losses_buf: Vec::new(),
            dself: Vec::new(),
            dnbuf: Vec::new(),
            inv_deg: Vec::new(),
            rev_ptr: Vec::new(),
            rev_cur: Vec::new(),
            rev_idx: Vec::new(),
            peak_compose_rows: 0,
        })
    }

    /// The trained parameters (embedding tables + head).
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Largest number of rows composed for a single training batch so far.
    pub fn peak_compose_rows(&self) -> usize {
        self.peak_compose_rows
    }

    /// Compose one sampled block and step on it: the shared body of the
    /// inline and prefetched epoch loops. Returns the block's summed
    /// per-seed loss.
    fn process_block(&mut self, block: &SampledBlock) -> f64 {
        let d = self.engine.plan().d;
        let rows = block.num_rows();
        self.peak_compose_rows = self.peak_compose_rows.max(rows);
        if self.x.len() < rows * d {
            self.x.resize(rows * d, 0.0);
        }
        // one plan resolution per step; the sampler guarantees every id
        // is < n, so the per-call bounds pre-scan is skipped
        let prepared = self.engine.prepare(&self.params);
        prepared.compose_into_unchecked(&block.nodes, &mut self.x[..rows * d]);
        self.step_block(block)
    }

    /// Run one epoch, sampling every block on the calling thread (the
    /// original, un-prefetched loop — [`train`](MinibatchTrainer::train)
    /// overlaps sampling instead when `opts.prefetch > 0`). Returns the
    /// epoch's mean training loss.
    pub fn train_epoch(&mut self, epoch: usize) -> Result<f64> {
        if self.sampler.is_none() {
            let ds = self.ds;
            let sampler = NeighborSampler::new(&ds.graph, self.cfg.fanout, self.sampler_seed);
            self.sampler = Some(sampler);
        }
        let batches = self.batcher.epoch_batches(epoch);
        let mut loss_sum = 0f64;
        let mut seen = 0usize;
        let mut block = SampledBlock::default();
        for (bi, seeds) in batches.iter().enumerate() {
            let sampler = self.sampler.as_mut().expect("inline sampler initialized above");
            sampler.sample_block_into(seeds, epoch, bi, &mut block);
            loss_sum += self.process_block(&block);
            seen += block.num_seeds;
        }
        let loss = loss_sum / seen as f64;
        if !loss.is_finite() {
            bail!("non-finite training loss at epoch {epoch}");
        }
        Ok(loss)
    }

    /// One epoch over blocks delivered by the prefetcher (bit-identical
    /// to [`train_epoch`](MinibatchTrainer::train_epoch): same blocks,
    /// same order — only the sampling overlaps the stepping).
    fn train_epoch_streamed(&mut self, epoch: usize, stream: &BlockPrefetcher) -> Result<f64> {
        let batches = self.batcher.num_batches();
        let mut loss_sum = 0f64;
        let mut seen = 0usize;
        for _ in 0..batches {
            let block = stream
                .recv()
                .map_err(|_| anyhow!("block prefetch thread stopped early at epoch {epoch}"))?;
            loss_sum += self.process_block(&block);
            seen += block.num_seeds;
            stream.recycle(block);
        }
        let loss = loss_sum / seen as f64;
        if !loss.is_finite() {
            bail!("non-finite training loss at epoch {epoch}");
        }
        Ok(loss)
    }

    /// Train for `opts.epochs` epochs, then evaluate val/test. With
    /// `opts.prefetch > 0` a dedicated sampler thread materializes
    /// upcoming blocks while the current one is stepped.
    pub fn train(&mut self) -> Result<MinibatchOutcome> {
        let t0 = Instant::now();
        let epochs = self.opts.epochs;
        let mut losses = Vec::with_capacity(epochs);
        let mut epoch_ns = Vec::with_capacity(epochs);
        if self.opts.prefetch > 0 && epochs > 0 {
            let ds = self.ds;
            let batcher = self.batcher.clone();
            let (fanout, seed, depth) = (self.cfg.fanout, self.sampler_seed, self.opts.prefetch);
            std::thread::scope(|scope| -> Result<()> {
                let stream =
                    BlockPrefetcher::spawn(scope, &ds.graph, batcher, fanout, seed, epochs, depth);
                for epoch in 0..epochs {
                    let e0 = Instant::now();
                    let loss = self.train_epoch_streamed(epoch, &stream)?;
                    epoch_ns.push(e0.elapsed().as_nanos() as u64);
                    if self.opts.verbose {
                        println!("  epoch {:>4}  loss {loss:.4}", epoch + 1);
                    }
                    losses.push(loss);
                }
                Ok(())
            })?;
        } else {
            for epoch in 0..epochs {
                let e0 = Instant::now();
                let loss = self.train_epoch(epoch)?;
                epoch_ns.push(e0.elapsed().as_nanos() as u64);
                if self.opts.verbose {
                    println!("  epoch {:>4}  loss {loss:.4}", epoch + 1);
                }
                losses.push(loss);
            }
        }
        let ds = self.ds;
        let val_metric = self.evaluate(&ds.splits.val)?;
        let test_metric = self.evaluate(&ds.splits.test)?;
        Ok(MinibatchOutcome {
            losses,
            epoch_ns,
            val_metric,
            test_metric,
            peak_compose_rows: self.peak_compose_rows,
            seeds_per_epoch: self.batcher.num_seeds(),
            batches_per_epoch: self.batcher.num_batches(),
            wall: t0.elapsed(),
        })
    }

    /// Score a fold with the current parameters, composed chunk by
    /// chunk. Evaluation uses **full** neighborhoods (standard GraphSAGE
    /// practice), so one chunk's block is bounded by
    /// `chunk × (max degree + 1)` rows (and by `n` via dedup) — larger
    /// than a training block and outside the `peak_compose_rows`
    /// invariant, but still far from `n × d` on bounded-degree graphs.
    /// Returns accuracy (multi-class) or mean ROC-AUC (multi-label).
    pub fn evaluate(&self, fold: &[u32]) -> Result<f64> {
        if fold.is_empty() {
            bail!("empty evaluation fold");
        }
        let ds = self.ds;
        let d = self.engine.plan().d;
        let classes = ds.spec.classes;
        let chunk = self.cfg.batch_size.max(1);
        let mut sampler = NeighborSampler::new(&ds.graph, Fanout::All, 0);
        let mut x: Vec<f32> = Vec::new();
        let mut nb = vec![0f32; d];
        let mut scores = vec![0f32; fold.len() * classes];
        let w_self = self.params.get("head_w_self");
        let w_neigh = self.params.get("head_w_neigh");
        let bias = self.params.get("head_b");
        // parameters are frozen during evaluation: resolve the plan once
        // for the whole fold instead of once per chunk
        let prepared = self.engine.prepare(&self.params);
        let mut done = 0usize;
        for (ci, seeds) in fold.chunks(chunk).enumerate() {
            let block = sampler.sample_block(seeds, 0, ci);
            let rows = block.num_rows();
            if x.len() < rows * d {
                x.resize(rows * d, 0.0);
            }
            prepared.compose_into_unchecked(&block.nodes, &mut x[..rows * d]);
            for si in 0..block.num_seeds {
                mean_rows(&mut nb, &x, block.neighbors_of(si));
                let xs = &x[si * d..(si + 1) * d];
                let out = &mut scores[(done + si) * classes..(done + si + 1) * classes];
                head_logits_row(xs, &nb, w_self, w_neigh, bias, out);
            }
            done += block.num_seeds;
        }
        // both branches hand the shared metric fns fold-local labels
        // and indices, so minibatch eval can never drift from the
        // metric implementations the full-batch paths use
        let local: Vec<u32> = (0..fold.len() as u32).collect();
        let metric = match ds.spec.task {
            TaskKind::MultiClass => {
                let labels_sub: Vec<u32> = fold.iter().map(|&i| ds.labels[i as usize]).collect();
                accuracy(&scores, classes, &labels_sub, &local)
            }
            TaskKind::MultiLabel => {
                let labels_sub: Vec<u32> = fold
                    .iter()
                    .flat_map(|&i| {
                        let i = i as usize;
                        ds.labels[i * classes..(i + 1) * classes].iter().copied()
                    })
                    .collect();
                mean_roc_auc(&scores, classes, &labels_sub, &local)
            }
        };
        Ok(metric)
    }

    /// Forward + backward + optimizer step on one composed block
    /// (`self.x[..rows*d]` must hold the block's composed rows).
    /// Returns the sum of per-seed losses. Dispatches to the serial
    /// oracle step or the bit-identical parallel step per
    /// `opts.parallel`.
    fn step_block(&mut self, block: &SampledBlock) -> f64 {
        if self.opts.parallel {
            self.step_block_parallel(block)
        } else {
            self.step_block_serial(block)
        }
    }

    /// The original single-threaded step — kept verbatim as the oracle
    /// the parallel step is pinned against (`tests/parallel_train.rs`).
    fn step_block_serial(&mut self, block: &SampledBlock) -> f64 {
        let d = self.engine.plan().d;
        let classes = self.ds.spec.classes;
        let s = block.num_seeds;
        let rows = block.num_rows();

        // ---- neighbor means (seeds are block rows 0..s) ----
        if self.nbar.len() < s * d {
            self.nbar.resize(s * d, 0.0);
        }
        for si in 0..s {
            let nbs = block.neighbors_of(si);
            mean_rows(&mut self.nbar[si * d..(si + 1) * d], &self.x, nbs);
        }

        // ---- head forward ----
        if self.logits.len() < s * classes {
            self.logits.resize(s * classes, 0.0);
        }
        if self.glogits.len() < s * classes {
            self.glogits.resize(s * classes, 0.0);
        }
        {
            let w_self = self.params.get("head_w_self");
            let w_neigh = self.params.get("head_w_neigh");
            let bias = self.params.get("head_b");
            for si in 0..s {
                let xs = &self.x[si * d..(si + 1) * d];
                let nb = &self.nbar[si * d..(si + 1) * d];
                let out = &mut self.logits[si * classes..(si + 1) * classes];
                head_logits_row(xs, nb, w_self, w_neigh, bias, out);
            }
        }

        // ---- loss + dL/dlogits (mean over the batch's seeds) ----
        let gscale = match self.ds.spec.task {
            TaskKind::MultiClass => 1.0 / s as f32,
            TaskKind::MultiLabel => 1.0 / (s * classes) as f32,
        };
        let mut loss_sum = 0f64;
        for si in 0..s {
            let node = block.nodes[si] as usize;
            let lrow = &self.logits[si * classes..(si + 1) * classes];
            let grow = &mut self.glogits[si * classes..(si + 1) * classes];
            loss_sum +=
                loss_and_grad_row(self.ds.spec.task, &self.ds.labels, node, lrow, grow, gscale);
        }

        // ---- head gradients ----
        {
            let gb = self.grads.get_mut("head_w_self").expect("head_w_self grads");
            for si in 0..s {
                let g = &self.glogits[si * classes..(si + 1) * classes];
                let xs = &self.x[si * d..(si + 1) * d];
                for (a, &xa) in xs.iter().enumerate() {
                    gb.add_row(a, xa, g);
                }
            }
        }
        {
            let gb = self.grads.get_mut("head_w_neigh").expect("head_w_neigh grads");
            for si in 0..s {
                let g = &self.glogits[si * classes..(si + 1) * classes];
                let nb = &self.nbar[si * d..(si + 1) * d];
                for (a, &na) in nb.iter().enumerate() {
                    gb.add_row(a, na, g);
                }
            }
        }
        {
            let gb = self.grads.get_mut("head_b").expect("head_b grads");
            for si in 0..s {
                gb.add_row(0, 1.0, &self.glogits[si * classes..(si + 1) * classes]);
            }
        }

        // ---- dL/dv per block row ----
        if self.dx.len() < rows * d {
            self.dx.resize(rows * d, 0.0);
        }
        self.dx[..rows * d].fill(0.0);
        {
            let w_self = self.params.get("head_w_self");
            let w_neigh = self.params.get("head_w_neigh");
            for si in 0..s {
                let g = &self.glogits[si * classes..(si + 1) * classes];
                for a in 0..d {
                    let ws = &w_self[a * classes..(a + 1) * classes];
                    let wn = &w_neigh[a * classes..(a + 1) * classes];
                    let mut acc_s = 0f32;
                    let mut acc_n = 0f32;
                    for ((&gj, wsj), wnj) in g.iter().zip(ws).zip(wn) {
                        acc_s += gj * wsj;
                        acc_n += gj * wnj;
                    }
                    self.dx[si * d + a] += acc_s;
                    self.dn[a] = acc_n;
                }
                let nbs = block.neighbors_of(si);
                if !nbs.is_empty() {
                    let inv = 1.0 / nbs.len() as f32;
                    for &r in nbs {
                        let dst = &mut self.dx[r as usize * d..(r as usize + 1) * d];
                        for (o, v) in dst.iter_mut().zip(&self.dn) {
                            *o += inv * v;
                        }
                    }
                }
            }
        }

        // ---- scatter into embedding tables (block-row order) ----
        let plan = self.engine.plan();
        for (r, &node) in block.nodes.iter().enumerate() {
            let gv = &self.dx[r * d..(r + 1) * d];
            scatter_embedding_grad(plan, &self.params, node as usize, gv, &mut self.grads);
        }

        // ---- optimizer step (BTreeMap order: deterministic) ----
        self.opt.begin_step();
        for (name, gb) in self.grads.iter_mut() {
            self.opt.apply(name, self.params.get_mut(name), gb);
            gb.clear();
        }
        loss_sum
    }

    /// The rayon-parallel step. Produces the **same bits** as
    /// [`step_block_serial`](MinibatchTrainer::step_block_serial) at any
    /// thread count, by preserving the serial per-element accumulation
    /// order everywhere floats meet:
    ///
    /// * per-seed forward rows (means, logits, loss grads) are disjoint;
    ///   per-seed losses land in a buffer summed in seed order;
    /// * head-weight gradients shard over **W's rows**: each element's
    ///   contributions still arrive in ascending-seed order;
    /// * `dL/dv` runs in two phases — per-seed back-signals into
    ///   disjoint rows, then a reverse-topology scatter in which each
    ///   block row replays its incoming contributions in ascending
    ///   iteration order (the row's own `W_self` signal merged at its
    ///   serial position via the self-marker);
    /// * embedding-table gradients shard over **destination rows**
    ///   ([`GradBuffer::sharded_accumulate`]): every shard scans block
    ///   rows in order, so per-element order is block-row ascending,
    ///   exactly as the serial scatter;
    /// * the optimizer updates touched rows independently (order-free).
    fn step_block_parallel(&mut self, block: &SampledBlock) -> f64 {
        let plan = self.engine.plan();
        let d = plan.d;
        let classes = self.ds.spec.classes;
        let s = block.num_seeds;
        let rows = block.num_rows();

        // ---- scratch sizing ----
        grow(&mut self.nbar, s * d);
        grow(&mut self.logits, s * classes);
        grow(&mut self.glogits, s * classes);
        grow(&mut self.dx, rows * d);
        grow(&mut self.dself, s * d);
        grow(&mut self.dnbuf, s * d);
        grow(&mut self.inv_deg, s);
        if self.losses_buf.len() < s {
            self.losses_buf.resize(s, 0.0);
        }

        // ---- fused per-seed forward: mean, logits, loss, dlogits ----
        let gscale = match self.ds.spec.task {
            TaskKind::MultiClass => 1.0 / s as f32,
            TaskKind::MultiLabel => 1.0 / (s * classes) as f32,
        };
        {
            let x = &self.x;
            let labels = &self.ds.labels;
            let task = self.ds.spec.task;
            let w_self = self.params.get("head_w_self");
            let w_neigh = self.params.get("head_w_neigh");
            let bias = self.params.get("head_b");
            let nbar_rows = self.nbar[..s * d].par_chunks_mut(d);
            let logit_rows = self.logits[..s * classes].par_chunks_mut(classes);
            let glog_rows = self.glogits[..s * classes].par_chunks_mut(classes);
            let loss_cells = self.losses_buf[..s].par_iter_mut();
            let fwd = nbar_rows.zip(logit_rows).zip(glog_rows);
            let fwd = fwd.zip(loss_cells).enumerate();
            fwd.for_each(|(si, (((nb, lrow), grow_row), loss))| {
                mean_rows(nb, x, block.neighbors_of(si));
                let xs = &x[si * d..(si + 1) * d];
                head_logits_row(xs, nb, w_self, w_neigh, bias, lrow);
                let node = block.nodes[si] as usize;
                *loss = loss_and_grad_row(task, labels, node, lrow, grow_row, gscale);
            });
        }
        // seed-order sum: the exact f64 additions of the serial loop
        let loss_sum: f64 = self.losses_buf[..s].iter().sum();

        // ---- head gradients (sharded over W's d rows) ----
        {
            let x = &self.x;
            let nbar = &self.nbar;
            let glog = &self.glogits;
            let gb = self.grads.get_mut("head_w_self").expect("head_w_self grads");
            gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                for si in 0..s {
                    let g = &glog[si * classes..(si + 1) * classes];
                    let xs = &x[si * d..(si + 1) * d];
                    for a in sh.rows() {
                        sh.add_row(a, xs[a], g);
                    }
                }
            });
            let gb = self.grads.get_mut("head_w_neigh").expect("head_w_neigh grads");
            gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                for si in 0..s {
                    let g = &glog[si * classes..(si + 1) * classes];
                    let nb = &nbar[si * d..(si + 1) * d];
                    for a in sh.rows() {
                        sh.add_row(a, nb[a], g);
                    }
                }
            });
            // one bias row: serial, preserving the seed-order adds
            let gb = self.grads.get_mut("head_b").expect("head_b grads");
            for si in 0..s {
                gb.add_row(0, 1.0, &glog[si * classes..(si + 1) * classes]);
            }
        }

        // ---- dL/dv phase 1: per-seed W_self / W_neigh back-signals ----
        {
            let w_self = self.params.get("head_w_self");
            let w_neigh = self.params.get("head_w_neigh");
            let glog = &self.glogits;
            let ds_rows = self.dself[..s * d].par_chunks_mut(d);
            let dn_rows = self.dnbuf[..s * d].par_chunks_mut(d);
            let signals = ds_rows.zip(dn_rows).enumerate();
            signals.for_each(|(si, (ds_row, dn_row))| {
                let g = &glog[si * classes..(si + 1) * classes];
                for a in 0..d {
                    let ws = &w_self[a * classes..(a + 1) * classes];
                    let wn = &w_neigh[a * classes..(a + 1) * classes];
                    let mut acc_s = 0f32;
                    let mut acc_n = 0f32;
                    for ((&gj, wsj), wnj) in g.iter().zip(ws).zip(wn) {
                        acc_s += gj * wsj;
                        acc_n += gj * wnj;
                    }
                    ds_row[a] = acc_s;
                    dn_row[a] = acc_n;
                }
            });
        }
        for (si, inv) in self.inv_deg[..s].iter_mut().enumerate() {
            let deg = block.neighbors_of(si).len();
            *inv = if deg == 0 { 0.0 } else { 1.0 / deg as f32 };
        }

        // ---- dL/dv phase 2: order-preserving reverse scatter ----
        // Counting-sort the block topology into row-major incoming
        // lists. Appending while walking seeds in ascending order keeps
        // every row's list ascending; a seed row's own entry (the
        // self-marker, value == row id — impossible for a topology
        // entry, the graph has no self loops) lands exactly where the
        // serial loop added its `W_self` signal.
        self.rev_ptr.clear();
        self.rev_ptr.resize(rows + 1, 0);
        for &r in &block.neigh_idx {
            self.rev_ptr[r as usize + 1] += 1;
        }
        for si in 0..s {
            self.rev_ptr[si + 1] += 1; // self-marker slot
        }
        for i in 0..rows {
            self.rev_ptr[i + 1] += self.rev_ptr[i];
        }
        let total = self.rev_ptr[rows] as usize;
        self.rev_cur.clear();
        self.rev_cur.extend_from_slice(&self.rev_ptr[..rows]);
        if self.rev_idx.len() < total {
            self.rev_idx.resize(total, 0);
        }
        for si in 0..s {
            let cur = self.rev_cur[si] as usize;
            self.rev_idx[cur] = si as u32;
            self.rev_cur[si] += 1;
            for &r in block.neighbors_of(si) {
                let cur = self.rev_cur[r as usize] as usize;
                self.rev_idx[cur] = si as u32;
                self.rev_cur[r as usize] += 1;
            }
        }
        {
            let rev_ptr = &self.rev_ptr;
            let rev_idx = &self.rev_idx;
            let dself = &self.dself;
            let dn = &self.dnbuf;
            let inv = &self.inv_deg;
            let dx_rows = self.dx[..rows * d].par_chunks_mut(d);
            dx_rows.enumerate().for_each(|(r, dst)| {
                dst.fill(0.0);
                for &sj in &rev_idx[rev_ptr[r] as usize..rev_ptr[r + 1] as usize] {
                    let sj = sj as usize;
                    if sj == r {
                        // the row's own W_self signal (serial: dx[si] += acc_s)
                        for (o, v) in dst.iter_mut().zip(&dself[sj * d..(sj + 1) * d]) {
                            *o += v;
                        }
                    } else {
                        let w = inv[sj];
                        for (o, v) in dst.iter_mut().zip(&dn[sj * d..(sj + 1) * d]) {
                            *o += w * v;
                        }
                    }
                }
            });
        }

        // ---- embedding-table scatter (destination-row sharding) ----
        let dx = &self.dx;
        let nodes = &block.nodes;
        if let Some(pos) = &plan.position {
            for (j, table) in pos.tables.iter().enumerate() {
                let z = &pos.z[j];
                let dj = table.cols;
                let gb = self.grads.get_mut(&table.name).expect("position grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for (r, &node) in nodes.iter().enumerate() {
                        let row = z[node as usize] as usize;
                        if sh.contains(row) {
                            sh.add_row(row, 1.0, &dx[r * d..r * d + dj]);
                        }
                    }
                });
            }
        }
        if let Some(nx) = &plan.node {
            let h = nx.indices.len();
            let idx = &nx.node_major;
            let x_table = self.params.get(&nx.table.name);
            let y = nx.learned_weights.then(|| self.params.get("node_y"));
            let gb = self.grads.get_mut(&nx.table.name).expect("node_x grads");
            gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                for (r, &node) in nodes.iter().enumerate() {
                    let i = node as usize;
                    let gv = &dx[r * d..(r + 1) * d];
                    for t in 0..h {
                        let row = idx[i * h + t] as usize;
                        if sh.contains(row) {
                            let w = y.map_or(1.0, |y| y[i * h + t]);
                            sh.add_row(row, w, gv);
                        }
                    }
                }
            });
            if nx.learned_weights {
                // node_y rows are block nodes — unique, one writer each
                let gb = self.grads.get_mut("node_y").expect("node_y grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for (r, &node) in nodes.iter().enumerate() {
                        let i = node as usize;
                        if sh.contains(i) {
                            let gv = &dx[r * d..(r + 1) * d];
                            for t in 0..h {
                                let row = idx[i * h + t] as usize;
                                let xrow = &x_table[row * d..(row + 1) * d];
                                let dot: f32 = xrow.iter().zip(gv).map(|(a, b)| a * b).sum();
                                sh.add_at(i, t, dot);
                            }
                        }
                    }
                });
            }
        }

        // ---- optimizer step (BTreeMap order; rows update in parallel) ----
        self.opt.begin_step();
        for (name, gb) in self.grads.iter_mut() {
            self.opt.apply(name, self.params.get_mut(name), gb);
            gb.clear();
        }
        loss_sum
    }
}

/// Grow a scratch buffer to at least `len` elements (never shrinks —
/// steady-state steps reuse the largest block's allocation).
fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Train the same one-layer model full-batch over `compose_all` — the
/// reference trainer the minibatch path is pinned against, and the only
/// host path that materializes the full `n × d` matrix.
///
/// In the oracle configuration ([`SamplerConfig::oracle`]) the minibatch
/// trainer reproduces this loss trajectory within 1e-5 per epoch; the
/// gradient scatter here deliberately walks nodes in the same order as
/// the oracle block (train seeds in split order, then discovered
/// neighbors) so the two paths agree to float associativity.
pub fn train_full_batch(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    opts: &MinibatchOptions,
) -> Result<MinibatchOutcome> {
    if plan.dhe.is_some() {
        bail!("full-batch host training does not support DHE (no embedding tables to train)");
    }
    let n = plan.n;
    let d = plan.d;
    let classes = ds.spec.classes;
    if n != ds.graph.num_nodes() {
        bail!("plan is for n = {} but dataset has {} nodes", n, ds.graph.num_nodes());
    }
    let mut params = init_host_params(plan, classes, opts.seed);
    if opts.verify_compose {
        compose::self_check(plan, &params, 1e-5)
            .map_err(|msg| anyhow!("compose engine self-check failed: {msg}"))?;
    }
    let engine = ComposeEngine::new(plan);
    let mut opt = Optimizer::new(opts.optimizer, opts.lr);
    let mut grads = make_grad_buffers(plan, classes);
    let train = &ds.splits.train;
    let mut v = vec![0f32; n * d]; // the matrix the minibatch path never builds
    let mut dv = vec![0f32; n * d];
    let mut is_touched = vec![false; n];
    let mut touched: Vec<u32> = Vec::with_capacity(train.len());
    let mut nbar = vec![0f32; d];
    let mut dn = vec![0f32; d];
    let mut logits = vec![0f32; classes];
    let mut glog = vec![0f32; classes];
    let gscale = match ds.spec.task {
        TaskKind::MultiClass => 1.0 / train.len() as f32,
        TaskKind::MultiLabel => 1.0 / (train.len() * classes) as f32,
    };
    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(opts.epochs);
    let mut epoch_ns = Vec::with_capacity(opts.epochs);
    for epoch in 0..opts.epochs {
        let e0 = Instant::now();
        engine.compose_all_into(&params, &mut v);
        // seeds first (split order), then frontier in discovery order —
        // the oracle block's exact row order.
        for &i in train {
            is_touched[i as usize] = true;
            touched.push(i);
        }
        let w_self = params.get("head_w_self");
        let w_neigh = params.get("head_w_neigh");
        let bias = params.get("head_b");
        let mut loss_sum = 0f64;
        for &i in train {
            let iu = i as usize;
            let xs = &v[iu * d..(iu + 1) * d];
            let nbs = ds.graph.neighbors(i);
            mean_rows(&mut nbar, &v, nbs);
            head_logits_row(xs, &nbar, w_self, w_neigh, bias, &mut logits);
            loss_sum += loss_and_grad_row(ds.spec.task, &ds.labels, iu, &logits, &mut glog, gscale);
            let gb = grads.get_mut("head_w_self").expect("head grads");
            for (a, &xa) in xs.iter().enumerate() {
                gb.add_row(a, xa, &glog);
            }
            let gb = grads.get_mut("head_w_neigh").expect("head grads");
            for (a, &na) in nbar.iter().enumerate() {
                gb.add_row(a, na, &glog);
            }
            grads.get_mut("head_b").expect("head grads").add_row(0, 1.0, &glog);
            for a in 0..d {
                let ws = &w_self[a * classes..(a + 1) * classes];
                let wn = &w_neigh[a * classes..(a + 1) * classes];
                let mut acc_s = 0f32;
                let mut acc_n = 0f32;
                for ((&gj, wsj), wnj) in glog.iter().zip(ws).zip(wn) {
                    acc_s += gj * wsj;
                    acc_n += gj * wnj;
                }
                dv[iu * d + a] += acc_s;
                dn[a] = acc_n;
            }
            if !nbs.is_empty() {
                let inv = 1.0 / nbs.len() as f32;
                for &u in nbs {
                    let uu = u as usize;
                    if !is_touched[uu] {
                        is_touched[uu] = true;
                        touched.push(u);
                    }
                    let dst = &mut dv[uu * d..(uu + 1) * d];
                    for (o, s) in dst.iter_mut().zip(&dn) {
                        *o += inv * s;
                    }
                }
            }
        }
        for &u in &touched {
            let uu = u as usize;
            let gv = &dv[uu * d..(uu + 1) * d];
            scatter_embedding_grad(plan, &params, uu, gv, &mut grads);
        }
        opt.begin_step();
        for (name, gb) in grads.iter_mut() {
            opt.apply(name, params.get_mut(name), gb);
            gb.clear();
        }
        for &u in &touched {
            let uu = u as usize;
            dv[uu * d..(uu + 1) * d].fill(0.0);
            is_touched[uu] = false;
        }
        touched.clear();
        let loss = loss_sum / train.len() as f64;
        if !loss.is_finite() {
            bail!("non-finite training loss at epoch {epoch}");
        }
        losses.push(loss);
        epoch_ns.push(e0.elapsed().as_nanos() as u64);
        if opts.verbose {
            println!("  epoch {:>4}  loss {loss:.4}  (full batch)", epoch + 1);
        }
    }

    // ---- final full-matrix evaluation ----
    engine.compose_all_into(&params, &mut v);
    let mut scores = vec![0f32; n * classes];
    {
        let w_self = params.get("head_w_self");
        let w_neigh = params.get("head_w_neigh");
        let bias = params.get("head_b");
        for i in 0..n {
            let xs = &v[i * d..(i + 1) * d];
            mean_rows(&mut nbar, &v, ds.graph.neighbors(i as u32));
            let out = &mut scores[i * classes..(i + 1) * classes];
            head_logits_row(xs, &nbar, w_self, w_neigh, bias, out);
        }
    }
    let (val_metric, test_metric) = match ds.spec.task {
        TaskKind::MultiClass => (
            accuracy(&scores, classes, &ds.labels, &ds.splits.val),
            accuracy(&scores, classes, &ds.labels, &ds.splits.test),
        ),
        TaskKind::MultiLabel => (
            mean_roc_auc(&scores, classes, &ds.labels, &ds.splits.val),
            mean_roc_auc(&scores, classes, &ds.labels, &ds.splits.test),
        ),
    };
    Ok(MinibatchOutcome {
        losses,
        epoch_ns,
        val_metric,
        test_metric,
        peak_compose_rows: n,
        seeds_per_epoch: train.len(),
        batches_per_epoch: 1,
        wall: t0.elapsed(),
    })
}

/// Startup compose verification that respects the minibatch memory
/// budget: at small scale (`n·d` ≤ ~4M elements) run the full
/// [`compose::self_check`] against the scalar oracle; beyond that the
/// oracle itself would materialize `n × d`, so fall back to a bounded
/// probe — a ≤4k-row strided `compose_batch` must be bit-identical
/// between the parallel and serial engine paths (the engine's
/// thread-count-determinism contract, `O(probe × d)` memory).
fn verify_compose_bounded(plan: &EmbeddingPlan, params: &ParamStore) -> Result<(), String> {
    const FULL_CHECK_MAX_ELEMS: usize = 1 << 22;
    if plan.n * plan.d <= FULL_CHECK_MAX_ELEMS {
        return compose::self_check(plan, params, 1e-5);
    }
    let stride = (plan.n / 4096).max(1);
    let probe: Vec<u32> = (0..plan.n as u32).step_by(stride).collect();
    let popts = ComposeOptions { parallel: true, ..Default::default() };
    let sopts = ComposeOptions { parallel: false, ..Default::default() };
    let par = ComposeEngine::with_options(plan, popts).compose_batch(params, &probe);
    let ser = ComposeEngine::with_options(plan, sopts).compose_batch(params, &probe);
    if par != ser {
        return Err("parallel and serial compose_batch diverge on the probe batch".into());
    }
    Ok(())
}

/// Embedding tables (via `embedding::init_params`) plus the one-layer
/// SAGE head (`head_w_self`/`head_w_neigh` uniform ±1/√d, `head_b`
/// zero), deterministically from `seed`.
fn init_host_params(plan: &EmbeddingPlan, classes: usize, seed: u64) -> ParamStore {
    let mut store = init_params(plan, seed);
    let d = plan.d;
    let mut rng = Rng::seed_from_u64(mix_seed(&[seed, 0x6EAD]));
    let a = 1.0 / (d as f32).sqrt();
    let w_self: Vec<f32> = (0..d * classes).map(|_| rng.gen_f32_range(-a, a)).collect();
    let w_neigh: Vec<f32> = (0..d * classes).map(|_| rng.gen_f32_range(-a, a)).collect();
    store.insert("head_w_self", vec![d, classes], w_self);
    store.insert("head_w_neigh", vec![d, classes], w_neigh);
    store.insert("head_b", vec![1, classes], vec![0.0; classes]);
    store
}

/// One [`GradBuffer`] per trainable table (embedding tables + head).
fn make_grad_buffers(plan: &EmbeddingPlan, classes: usize) -> BTreeMap<String, GradBuffer> {
    let mut grads = BTreeMap::new();
    for t in plan.param_shapes() {
        grads.insert(t.name.clone(), GradBuffer::new(t.rows, t.cols));
    }
    grads.insert("head_w_self".into(), GradBuffer::new(plan.d, classes));
    grads.insert("head_w_neigh".into(), GradBuffer::new(plan.d, classes));
    grads.insert("head_b".into(), GradBuffer::new(1, classes));
    grads
}

/// Write into `dst` the mean of the given `rows` of the row-major
/// matrix `mat` (row width = `dst.len()`); zero when `rows` is empty.
/// Sums in `rows` order — both trainers and both eval paths share this
/// one implementation, so aggregation bits can never diverge between
/// them (the oracle-parity contract leans on that).
fn mean_rows(dst: &mut [f32], mat: &[f32], rows: &[u32]) {
    let d = dst.len();
    dst.fill(0.0);
    for &r in rows {
        let src = &mat[r as usize * d..(r as usize + 1) * d];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += s;
        }
    }
    if !rows.is_empty() {
        let inv = 1.0 / rows.len() as f32;
        for o in dst.iter_mut() {
            *o *= inv;
        }
    }
}

/// `out = bias + W_self^T·xs + W_neigh^T·nbar` for one seed
/// (`W ∈ R^{d×classes}` row-major).
fn head_logits_row(
    xs: &[f32],
    nbar: &[f32],
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    let classes = out.len();
    out.copy_from_slice(bias);
    for (a, (&xa, &na)) in xs.iter().zip(nbar).enumerate() {
        let ws = &w_self[a * classes..(a + 1) * classes];
        let wn = &w_neigh[a * classes..(a + 1) * classes];
        for ((o, wsj), wnj) in out.iter_mut().zip(ws).zip(wn) {
            *o += xa * wsj + na * wnj;
        }
    }
}

/// Per-seed loss and `dL/dlogits` (written to `glog`, scaled by
/// `scale`): softmax cross-entropy for multi-class, stable
/// BCE-with-logits (mean over tasks) for multi-label.
fn loss_and_grad_row(
    task: TaskKind,
    labels: &[u32],
    node: usize,
    logits: &[f32],
    glog: &mut [f32],
    scale: f32,
) -> f64 {
    let classes = logits.len();
    match task {
        TaskKind::MultiClass => {
            let label = labels[node] as usize;
            let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut sum = 0f32;
            for (g, &x) in glog.iter_mut().zip(logits) {
                let e = (x - max).exp();
                *g = e;
                sum += e;
            }
            let inv = scale / sum;
            for g in glog.iter_mut() {
                *g *= inv;
            }
            glog[label] -= scale;
            let logz = max + sum.ln();
            (logz - logits[label]) as f64
        }
        TaskKind::MultiLabel => {
            let mut loss = 0f64;
            let row = &labels[node * classes..(node + 1) * classes];
            for ((g, &x), &y) in glog.iter_mut().zip(logits).zip(row) {
                let yf = y as f32;
                // stable BCE-with-logits: max(x,0) - x·y + ln(1 + e^-|x|)
                loss += (x.max(0.0) - x * yf + (-x.abs()).exp().ln_1p()) as f64;
                let sig = 1.0 / (1.0 + (-x).exp());
                *g = (sig - yf) * scale;
            }
            loss / classes as f64
        }
    }
}

/// Backpropagate one node's `dL/dv` row into its embedding tables
/// (the compose backward): position levels get the leading `d_j`
/// coordinates (Eq. 11's zero-extension), the node-specific table gets
/// `y_t · gv` per hash, and learned importance weights get
/// `⟨X[idx_t], gv⟩` (Eq. 12/13).
fn scatter_embedding_grad(
    plan: &EmbeddingPlan,
    params: &ParamStore,
    node: usize,
    gv: &[f32],
    grads: &mut BTreeMap<String, GradBuffer>,
) {
    if let Some(pos) = &plan.position {
        for (j, table) in pos.tables.iter().enumerate() {
            let row = pos.z[j][node] as usize;
            let gb = grads.get_mut(&table.name).expect("position grads");
            gb.add_row(row, 1.0, &gv[..table.cols]);
        }
    }
    if let Some(nx) = &plan.node {
        let h = nx.indices.len();
        let d = plan.d;
        let x = params.get(&nx.table.name);
        let y = nx.learned_weights.then(|| params.get("node_y"));
        for t in 0..h {
            let row = nx.indices[t][node] as usize;
            let w = y.map_or(1.0, |y| y[node * h + t]);
            grads.get_mut(&nx.table.name).expect("node_x grads").add_row(row, w, gv);
            if nx.learned_weights {
                let xrow = &x[row * d..(row + 1) * d];
                let dot: f32 = xrow.iter().zip(gv).map(|(a, b)| a * b).sum();
                grads.get_mut("node_y").expect("node_y grads").add_at(node, t, dot);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;
    use crate::embedding::EmbeddingMethod;

    fn tiny_dataset() -> Dataset {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 400;
        s.communities = 20;
        s.d = 16;
        Dataset::generate(&s)
    }

    #[test]
    fn dhe_plans_are_rejected() {
        let ds = tiny_dataset();
        let method = EmbeddingMethod::Dhe { encoding_dim: 8, hidden: 16, layers: 1 };
        let plan = EmbeddingPlan::build(ds.graph.num_nodes(), 16, &method, None, 0);
        let err = MinibatchTrainer::new(&ds, &plan, SamplerConfig::default(), Default::default());
        assert!(err.is_err());
        assert!(train_full_batch(&ds, &plan, &MinibatchOptions::default()).is_err());
    }

    #[test]
    fn host_params_include_head_tables() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let p = init_host_params(&plan, ds.spec.classes, 7);
        assert_eq!(p.shape("head_w_self"), &[16, ds.spec.classes]);
        assert_eq!(p.shape("head_w_neigh"), &[16, ds.spec.classes]);
        assert!(p.get("head_b").iter().all(|&b| b == 0.0));
        // deterministic per seed
        let q = init_host_params(&plan, ds.spec.classes, 7);
        assert_eq!(p.get("head_w_self"), q.get("head_w_self"));
    }

    #[test]
    fn single_epoch_runs_and_reports_finite_loss() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let cfg = SamplerConfig { batch_size: 64, fanout: Fanout::Max(4), shuffle: true };
        let opts = MinibatchOptions { epochs: 2, ..Default::default() };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        let out = tr.train().unwrap();
        assert_eq!(out.losses.len(), 2);
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(out.peak_compose_rows < ds.graph.num_nodes());
        assert!((0.0..=1.0).contains(&out.test_metric));
        assert!(out.row().contains("peak_rows"));
    }
}
